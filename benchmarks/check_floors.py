"""CI floor check over the repo-root BENCH trajectory.

Parses ``BENCH_topology.json`` (append-only, one JSON record per line,
mixing records committed by past PRs with lines appended by the run
just finished — unparseable/truncated lines are skipped, never fatal)
and asserts the ROADMAP's ``gs_contention`` floors on the LATEST
record per ground-station set:

  * grid round <= ring round under RB contention,
  * handover round <= no-handover round at 1-RB scarcity,
  * async re-admission round <= book-at-schedule baseline (and its
    mean no worse), when the record carries the async arms.

Run after the contention smoke so "latest" reflects the code under
test:  PYTHONPATH=src python -m benchmarks.check_floors
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List

from benchmarks.common import BENCH_TRAJECTORY


def load_latest_contention(path: str = BENCH_TRAJECTORY) -> List[Dict]:
    """Latest ``gs_contention`` record per ground-station set, scanning
    the whole append-only trajectory and skipping anything unparseable
    (the file deliberately mixes committed history with fresh lines
    and may carry a truncated tail)."""
    latest: Dict[tuple, Dict] = {}
    try:
        with open(path) as f:
            lines = f.readlines()
    except FileNotFoundError:
        return []
    for line in lines:
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            continue                    # quarantined/corrupt line
        if not isinstance(rec, dict) or rec.get("bench") != "gs_contention":
            continue
        key = tuple(rec.get("ground_stations") or ())
        latest[key] = rec               # later lines win: append-only
    return [latest[k] for k in sorted(latest)]


def check(records: List[Dict]) -> List[str]:
    failures = []
    if not records:
        return ["no gs_contention records found in the BENCH trajectory"]

    def le(a, b) -> bool:
        # a floor holds vacuously when either side was not measured
        return a is None or b is None or a <= b

    for r in records:
        tag = f"{len(r.get('ground_stations', []))} GS"
        if r.get("grid_contended_s") is None:
            failures.append(f"{tag}: grid contended round did not complete")
        if not le(r.get("grid_contended_s"), r.get("ring_contended_s")):
            failures.append(
                f"{tag}: grid {r['grid_contended_s']}s > "
                f"ring {r['ring_contended_s']}s under RB contention"
            )
        for kind in ("ring", "grid"):
            if not le(r.get(f"{kind}_handover_s"), r.get(f"{kind}_scarce_s")):
                failures.append(
                    f"{tag}: {kind} handover {r[f'{kind}_handover_s']}s > "
                    f"no-handover {r[f'{kind}_scarce_s']}s at 1-RB scarcity"
                )
        # async arms exist only from PR 5 on — older records skip them
        if "async_readmit_s" in r:
            if not le(r.get("async_readmit_s"), r.get("async_scarce_s")):
                failures.append(
                    f"{tag}: async re-admission {r['async_readmit_s']}s > "
                    f"baseline {r['async_scarce_s']}s"
                )
            if not le(r.get("async_readmit_mean_s"),
                      r.get("async_scarce_mean_s")):
                failures.append(
                    f"{tag}: async re-admission mean "
                    f"{r['async_readmit_mean_s']}s > baseline mean "
                    f"{r['async_scarce_mean_s']}s"
                )
    return failures


def main() -> None:
    records = load_latest_contention()
    failures = check(records)
    for r in records:
        print(
            f"# checked {len(r.get('ground_stations', []))} GS: "
            f"grid {r.get('grid_contended_s')}s vs ring "
            f"{r.get('ring_contended_s')}s; handover "
            f"{r.get('ring_handover_s')}/{r.get('grid_handover_s')}s vs "
            f"scarce {r.get('ring_scarce_s')}/{r.get('grid_scarce_s')}s; "
            f"async {r.get('async_readmit_s')}s vs "
            f"{r.get('async_scarce_s')}s"
        )
    if failures:
        for msg in failures:
            print(f"FLOOR VIOLATION: {msg}", file=sys.stderr)
        raise SystemExit(1)
    print("# all gs_contention floors hold")


if __name__ == "__main__":
    main()
