"""CI floor check over the repo-root BENCH trajectory.

Parses ``BENCH_topology.json`` (append-only, one JSON record per line,
mixing records committed by past PRs with lines appended by the run
just finished — unparseable/truncated lines are skipped, never fatal)
and asserts the ROADMAP's ``gs_contention`` floors on the LATEST
record per ground-station set:

  * grid round <= ring round under RB contention,
  * handover round <= no-handover round at 1-RB scarcity,
  * async re-admission round <= book-at-schedule baseline (and its
    mean no worse), when the record carries the async arms,
  * tracing overhead (repro.obs TraceRecorder attached vs not, on the
    contended pricing pass) <= 5% of plan wall, when the record
    carries the overhead columns (schema >= 2),

plus the predictor query-latency floor on the latest
``predictor_queries`` record (the 2.86 -> 16.77 us/query regression
this floor exists to catch: ``next_window``/``wait_time`` must stay
bisect-indexed, not re-materialize the full window list per call).

A missing trajectory file is a warning, not a failure (a fresh clone
or a CI job that skipped the smokes has no floors to assert yet).

Run after the contention smoke so "latest" reflects the code under
test:  PYTHONPATH=src python -m benchmarks.check_floors
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional

from benchmarks.common import BENCH_TRAJECTORY

# generous ceiling over the healthy ~3 us/query (the regressed
# implementation sat at 16.77): catches an O(windows) query path
# without flaking on a loaded CI runner
US_PER_QUERY_FLOOR = 10.0

# tracing must stay within 5% of the untraced plan wall (ISSUE 7
# acceptance): the overhead estimate is a median of interleaved
# samples clamped at >= 0, so a sustained recorder slowdown trips this
# without CI-noise flakes
TRACE_OVERHEAD_FLOOR = 0.05

# mega-scale floors (ISSUE 8): the 72x22 predictor build must stay
# within 4x the 40x22 build measured in the same process (1.8x the
# satellite count — superlinear blowup means the scan stopped being
# memory-bounded), and every row's build tracemalloc peak must stay
# under the configured mem_budget_mb (the budget IS the contract).
# The ratio floor gates only the constellation it was calibrated for;
# larger presets (two-shell at 2.7x the baseline satellites) are
# gated on memory and completion, not on this wall-clock ratio.
MEGA_BUILD_RATIO_FLOOR = 4.0
MEGA_RATIO_CONSTELLATION = "starlink-gen1"

# multi-tenant floors (ISSUE 9): the swap re-packer's per-entry
# completions may never exceed their monotone floor — zero regret up
# to float noise
REPACK_REGRET_EPS = 1e-6

# hetero-fleet floors (ISSUE 10): the Pallas aggregate_flat path must
# match the reference weighted mean on real model pytrees to float
# noise (both accumulate in f32)
HETERO_PARITY_TOL = 1e-5

# near-floor early warning: any ceiling-floored metric within this
# relative margin of its floor is reported (exit 0) so the regression
# is visible one PR before it fails CI
NEAR_FLOOR_MARGIN = 0.25


def _near(value: Optional[float], floor: float) -> bool:
    """True when ``value`` passes its ceiling ``floor`` but sits inside
    the warning margin below it."""
    return (
        value is not None
        and value <= floor
        and value > floor * (1.0 - NEAR_FLOOR_MARGIN)
    )


def load_latest_contention(path: str = BENCH_TRAJECTORY) -> List[Dict]:
    """Latest ``gs_contention`` record per ground-station set, scanning
    the whole append-only trajectory and skipping anything unparseable
    (the file deliberately mixes committed history with fresh lines
    and may carry a truncated tail)."""
    latest: Dict[tuple, Dict] = {}
    try:
        with open(path) as f:
            lines = f.readlines()
    except FileNotFoundError:
        return []
    for line in lines:
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            continue                    # quarantined/corrupt line
        if not isinstance(rec, dict) or rec.get("bench") != "gs_contention":
            continue
        key = tuple(rec.get("ground_stations") or ())
        latest[key] = rec               # later lines win: append-only
    return [latest[k] for k in sorted(latest)]


def load_latest_predictor(path: str = BENCH_TRAJECTORY) -> Optional[Dict]:
    """Latest ``predictor_queries`` record, or None."""
    latest: Optional[Dict] = None
    try:
        with open(path) as f:
            lines = f.readlines()
    except FileNotFoundError:
        return None
    for line in lines:
        try:
            rec = json.loads(line.strip())
        except json.JSONDecodeError:
            continue
        if isinstance(rec, dict) and rec.get("bench") == "predictor_queries":
            latest = rec
    return latest


def load_latest_mega(path: str = BENCH_TRAJECTORY) -> List[Dict]:
    """Latest ``mega_scale`` record per constellation (same
    append-only / skip-unparseable discipline as the contention
    loader)."""
    latest: Dict[str, Dict] = {}
    try:
        with open(path) as f:
            lines = f.readlines()
    except FileNotFoundError:
        return []
    for line in lines:
        try:
            rec = json.loads(line.strip())
        except json.JSONDecodeError:
            continue
        if not isinstance(rec, dict) or rec.get("bench") != "mega_scale":
            continue
        latest[str(rec.get("constellation"))] = rec
    return [latest[k] for k in sorted(latest)]


def load_latest_multi_tenant(path: str = BENCH_TRAJECTORY) -> Optional[Dict]:
    """Latest ``multi_tenant`` record, or None (the multi-tenant smoke
    is optional per run — same append-only / skip-unparseable
    discipline as the other loaders)."""
    latest: Optional[Dict] = None
    try:
        with open(path) as f:
            lines = f.readlines()
    except FileNotFoundError:
        return None
    for line in lines:
        try:
            rec = json.loads(line.strip())
        except json.JSONDecodeError:
            continue
        if isinstance(rec, dict) and rec.get("bench") == "multi_tenant":
            latest = rec
    return latest


def load_latest_hetero(path: str = BENCH_TRAJECTORY) -> Optional[Dict]:
    """Latest ``hetero_fleet`` record, or None (the hetero smoke is
    optional per run — same append-only / skip-unparseable discipline
    as the other loaders)."""
    latest: Optional[Dict] = None
    try:
        with open(path) as f:
            lines = f.readlines()
    except FileNotFoundError:
        return None
    for line in lines:
        try:
            rec = json.loads(line.strip())
        except json.JSONDecodeError:
            continue
        if isinstance(rec, dict) and rec.get("bench") == "hetero_fleet":
            latest = rec
    return latest


def check_hetero(rec: Optional[Dict]) -> List[str]:
    """ISSUE 10 floors: degenerate-profile bit-identity, the
    fast <= hetero <= slow round-time ordering (contention-free arms:
    later train-ready times can only delay upload completion), and
    Pallas-vs-reference aggregation parity."""
    if rec is None:
        return []
    failures = []
    if rec.get("uniform_equal") is False:
        failures.append(
            "hetero_fleet: uniform compute profile diverged from "
            "SimConfig.compute=None (the degenerate case must be "
            "bit-identical)"
        )
    fast = rec.get("fast_round_s")
    het = rec.get("hetero_round_s")
    slow = rec.get("slow_round_s")
    if het is None:
        failures.append("hetero_fleet: hetero round did not complete")
    if fast is not None and het is not None and fast > het:
        failures.append(
            f"hetero_fleet: all-fast round {fast}s > hetero round "
            f"{het}s (monotone pricing broken)"
        )
    if het is not None and slow is not None and het > slow:
        failures.append(
            f"hetero_fleet: hetero round {het}s > all-slow round "
            f"{slow}s (monotone pricing broken)"
        )
    err = rec.get("aggregate_parity_max_err")
    if err is not None and err > HETERO_PARITY_TOL:
        failures.append(
            f"hetero_fleet: Pallas aggregation parity error {err} > "
            f"{HETERO_PARITY_TOL} vs the reference weighted mean"
        )
    return failures


def check_multi_tenant(rec: Optional[Dict]) -> List[str]:
    """ISSUE 9 floors: single-job transparency, Poisson-vs-serial p95,
    and the re-packer's monotone per-entry floor."""
    if rec is None:
        return []
    failures = []
    if rec.get("single_job_equal") is False:
        failures.append(
            "multi_tenant: single job through JobScheduler diverged "
            "from the standalone strategy run (must be bit-identical)"
        )
    p95_c, p95_s = rec.get("concurrent_p95_s"), rec.get("serial_p95_s")
    if p95_c is not None and p95_s is not None and p95_c > p95_s:
        failures.append(
            f"multi_tenant: concurrent p95 {p95_c}s > serial p95 "
            f"{p95_s}s (multiplexing lost to head-of-line blocking)"
        )
    cr, sr = rec.get("concurrent_rounds"), rec.get("serial_rounds")
    if cr is not None and sr is not None and cr < sr:
        failures.append(
            f"multi_tenant: concurrent arm completed {cr} rounds < "
            f"serial {sr} on the same workload"
        )
    regret = rec.get("repack_max_regret_s")
    if regret is not None and regret > REPACK_REGRET_EPS:
        failures.append(
            f"multi_tenant: repack per-entry regret {regret}s > "
            f"{REPACK_REGRET_EPS} vs the monotone floor (swap adopted "
            f"a regressing completion)"
        )
    rep, mono = rec.get("async_repack_s"), rec.get("async_monotone_s")
    if rep is not None and mono is not None and rep > mono:
        failures.append(
            f"multi_tenant: repack round {rep}s > monotone round "
            f"{mono}s (the monotone result is the re-packer's floor)"
        )
    return failures


def check_mega(records: List[Dict]) -> List[str]:
    """Mega-scale floors: build-scaling ratio and memory budget.  An
    empty record list is fine — the mega smoke is optional per run."""
    failures = []
    for r in records:
        tag = f"mega {r.get('constellation')}"
        ratio = r.get("predictor_build_ratio_vs_40x22")
        if r.get("constellation") != MEGA_RATIO_CONSTELLATION:
            ratio = None
        if ratio is not None and ratio > MEGA_BUILD_RATIO_FLOOR:
            failures.append(
                f"{tag}: predictor build {ratio}x the 40x22 build > "
                f"floor {MEGA_BUILD_RATIO_FLOOR}x (scan no longer "
                f"scales linearly in satellite count)"
            )
        peak = r.get("predictor_peak_mb")
        budget = r.get("mem_budget_mb")
        if peak is not None and budget is not None and peak > budget:
            failures.append(
                f"{tag}: predictor build peak {peak} MB > configured "
                f"mem_budget_mb {budget} (chunking stopped bounding "
                f"the scan transient)"
            )
        if r.get("plan_round_s") is None:
            failures.append(f"{tag}: planning round did not complete")
    return failures


def near_floor_warnings(
    records: List[Dict],
    pred: Optional[Dict],
    mega: List[Dict],
) -> List[str]:
    """Ceiling-floored metrics that pass but sit within
    NEAR_FLOOR_MARGIN of their floor — reported without failing so the
    drift is visible one PR before it trips CI."""
    warnings = []
    if pred is not None and _near(pred.get("us_per_query"),
                                  US_PER_QUERY_FLOOR):
        warnings.append(
            f"predictor_queries: {pred['us_per_query']} us/query is "
            f"within {NEAR_FLOOR_MARGIN:.0%} of floor "
            f"{US_PER_QUERY_FLOOR}"
        )
    for r in records:
        tag = f"{len(r.get('ground_stations', []))} GS"
        if _near(r.get("trace_overhead_fraction"), TRACE_OVERHEAD_FLOOR):
            warnings.append(
                f"{tag}: tracing overhead "
                f"{r['trace_overhead_fraction'] * 100:.1f}% is within "
                f"{NEAR_FLOOR_MARGIN:.0%} of floor "
                f"{TRACE_OVERHEAD_FLOOR * 100:.0f}%"
            )
    for r in mega:
        tag = f"mega {r.get('constellation')}"
        if (r.get("constellation") == MEGA_RATIO_CONSTELLATION
                and _near(r.get("predictor_build_ratio_vs_40x22"),
                          MEGA_BUILD_RATIO_FLOOR)):
            warnings.append(
                f"{tag}: build ratio "
                f"{r['predictor_build_ratio_vs_40x22']}x is within "
                f"{NEAR_FLOOR_MARGIN:.0%} of floor "
                f"{MEGA_BUILD_RATIO_FLOOR}x"
            )
        budget = r.get("mem_budget_mb")
        if budget is not None and _near(r.get("predictor_peak_mb"),
                                        float(budget)):
            warnings.append(
                f"{tag}: predictor build peak "
                f"{r['predictor_peak_mb']} MB is within "
                f"{NEAR_FLOOR_MARGIN:.0%} of mem_budget_mb {budget}"
            )
    return warnings


def check_predictor(rec: Optional[Dict]) -> List[str]:
    if rec is None:
        return []                       # no record yet: nothing to assert
    us = rec.get("us_per_query")
    if us is not None and us > US_PER_QUERY_FLOOR:
        return [
            f"predictor_queries: {us} us/query > floor "
            f"{US_PER_QUERY_FLOOR} (bisect-indexed queries regressed)"
        ]
    return []


def check(records: List[Dict]) -> List[str]:
    failures = []
    if not records:
        return ["no gs_contention records found in the BENCH trajectory"]

    def le(a, b) -> bool:
        # a floor holds vacuously when either side was not measured
        return a is None or b is None or a <= b

    for r in records:
        tag = f"{len(r.get('ground_stations', []))} GS"
        if r.get("grid_contended_s") is None:
            failures.append(f"{tag}: grid contended round did not complete")
        if not le(r.get("grid_contended_s"), r.get("ring_contended_s")):
            failures.append(
                f"{tag}: grid {r['grid_contended_s']}s > "
                f"ring {r['ring_contended_s']}s under RB contention"
            )
        for kind in ("ring", "grid"):
            if not le(r.get(f"{kind}_handover_s"), r.get(f"{kind}_scarce_s")):
                failures.append(
                    f"{tag}: {kind} handover {r[f'{kind}_handover_s']}s > "
                    f"no-handover {r[f'{kind}_scarce_s']}s at 1-RB scarcity"
                )
        # async arms exist only from PR 5 on — older records skip them
        if "async_readmit_s" in r:
            if not le(r.get("async_readmit_s"), r.get("async_scarce_s")):
                failures.append(
                    f"{tag}: async re-admission {r['async_readmit_s']}s > "
                    f"baseline {r['async_scarce_s']}s"
                )
            if not le(r.get("async_readmit_mean_s"),
                      r.get("async_scarce_mean_s")):
                failures.append(
                    f"{tag}: async re-admission mean "
                    f"{r['async_readmit_mean_s']}s > baseline mean "
                    f"{r['async_scarce_mean_s']}s"
                )
        # trace-overhead column exists only from schema 2 (PR 7) on
        frac = r.get("trace_overhead_fraction")
        if frac is not None and frac > TRACE_OVERHEAD_FLOOR:
            failures.append(
                f"{tag}: tracing overhead {frac * 100:.1f}% > floor "
                f"{TRACE_OVERHEAD_FLOOR * 100:.0f}% "
                f"({r.get('plan_wall_plain_s')}s -> "
                f"{r.get('plan_wall_traced_s')}s)"
            )
    return failures


def main() -> None:
    if not os.path.exists(BENCH_TRAJECTORY):
        print(
            f"WARNING: {BENCH_TRAJECTORY} not found — no BENCH "
            "trajectory to assert floors on; skipping",
            file=sys.stderr,
        )
        return
    # pass the module global explicitly: callers (and tests) may rebind
    # BENCH_TRAJECTORY, which a def-time default would not see
    records = load_latest_contention(BENCH_TRAJECTORY)
    failures = check(records)
    pred = load_latest_predictor(BENCH_TRAJECTORY)
    failures += check_predictor(pred)
    mega = load_latest_mega(BENCH_TRAJECTORY)
    failures += check_mega(mega)
    tenant = load_latest_multi_tenant(BENCH_TRAJECTORY)
    failures += check_multi_tenant(tenant)
    hetero = load_latest_hetero(BENCH_TRAJECTORY)
    failures += check_hetero(hetero)
    if pred is not None:
        print(
            f"# checked predictor_queries: {pred.get('us_per_query')} "
            f"us/query (floor {US_PER_QUERY_FLOOR})"
        )
    for r in records:
        print(
            f"# checked {len(r.get('ground_stations', []))} GS: "
            f"grid {r.get('grid_contended_s')}s vs ring "
            f"{r.get('ring_contended_s')}s; handover "
            f"{r.get('ring_handover_s')}/{r.get('grid_handover_s')}s vs "
            f"scarce {r.get('ring_scarce_s')}/{r.get('grid_scarce_s')}s; "
            f"async {r.get('async_readmit_s')}s vs "
            f"{r.get('async_scarce_s')}s"
            + (
                f"; trace overhead "
                f"{r['trace_overhead_fraction'] * 100:+.1f}% "
                f"(floor {TRACE_OVERHEAD_FLOOR * 100:.0f}%)"
                if r.get("trace_overhead_fraction") is not None else ""
            )
        )
    for r in mega:
        print(
            f"# checked mega {r.get('constellation')}: build "
            f"{r.get('predictor_build_s')}s "
            f"({r.get('predictor_build_ratio_vs_40x22')}x 40x22, floor "
            f"{MEGA_BUILD_RATIO_FLOOR}x); peak "
            f"{r.get('predictor_peak_mb')} MB (budget "
            f"{r.get('mem_budget_mb')} MB); plan round "
            f"{r.get('plan_round_s')}s"
        )
    if tenant is not None:
        print(
            f"# checked multi_tenant: p95 {tenant.get('concurrent_p95_s')}s"
            f" vs serial {tenant.get('serial_p95_s')}s; repack regret "
            f"{tenant.get('repack_max_regret_s')}s (eps "
            f"{REPACK_REGRET_EPS}); single-job equal: "
            f"{tenant.get('single_job_equal')}"
        )
    if hetero is not None:
        print(
            f"# checked hetero_fleet: fast {hetero.get('fast_round_s')}s"
            f" <= hetero {hetero.get('hetero_round_s')}s <= slow "
            f"{hetero.get('slow_round_s')}s; uniform equal: "
            f"{hetero.get('uniform_equal')}; aggregate parity "
            f"{hetero.get('aggregate_parity_max_err')} (tol "
            f"{HETERO_PARITY_TOL})"
        )
    for msg in near_floor_warnings(records, pred, mega):
        print(f"FLOOR WARNING: {msg}", file=sys.stderr)
    if failures:
        for msg in failures:
            print(f"FLOOR VIOLATION: {msg}", file=sys.stderr)
        raise SystemExit(1)
    print("# all gs_contention floors hold")


if __name__ == "__main__":
    main()
