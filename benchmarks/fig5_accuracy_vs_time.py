"""Paper Fig. 5: FedLEO accuracy vs simulated convergence time on all
three datasets (MNIST-like, CIFAR-10-like, DeepGlobe-like)."""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import FAST, PAYLOAD_BITS, make_task
from repro.core import FedLEO, FederatedTask, SimConfig, TrainHyperparams
from repro.data import make_segmentation_dataset, partition_iid
from repro.models.cnn import apply_unet, init_unet
from repro.optim import get_optimizer


def _deepglobe_task() -> FederatedTask:
    ds = make_segmentation_dataset(num_samples=40 if FAST else 80, size=32,
                                   seed=0)
    test = make_segmentation_dataset(num_samples=16, size=32, seed=9)
    clients = partition_iid(ds, 5, 8)   # DeepGlobe is non-IID by nature;
    # road-density variation provides the heterogeneity here
    hp = TrainHyperparams(local_epochs=20, learning_rate=0.01, batch_size=4)
    return FederatedTask(
        init_fn=lambda r: init_unet(r, in_ch=3, base=4, depth=2),
        apply_fn=apply_unet,
        clients=clients,
        test_set=test,
        optimizer=get_optimizer("adam", 1e-3),
        hp=hp,
        sim_epochs=2 if FAST else 3,
        payload_bits_override=PAYLOAD_BITS * 2,   # U-Net is bigger
    )


def run() -> List[Dict]:
    sim = SimConfig(horizon_hours=72.0)
    rows = []
    rounds = 3 if FAST else 5
    for dataset in ("mnist-like", "cifar10-like"):
        res = FedLEO(make_task(dataset), sim).run(max_rounds=rounds)
        for h in res.history:
            rows.append({
                "dataset": dataset, "t_hours": h.t_hours,
                "accuracy": h.metrics["accuracy"],
                "loss": h.metrics["loss"],
            })
    res = FedLEO(_deepglobe_task(), sim).run(max_rounds=2 if FAST else 3)
    for h in res.history:
        rows.append({
            "dataset": "deepglobe-like", "t_hours": h.t_hours,
            "accuracy": h.metrics["accuracy"], "loss": h.metrics["loss"],
        })
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
