"""Heterogeneous fleet compute model: hetero-vs-uniform round pricing
plus the degenerate-case and kernel-parity gates (ISSUE 10).

Three checks ride in one benchmark:

  1. Round-time pricing at starlink-40x22 via the pure plane planners,
     with per-plane training durations from ``FleetComputeModel``'s
     roofline (analytic mode, full-size configs): an all-``FAST_ARCH``
     fleet, an all-``SLOW_ARCH`` fleet, and the alternating hetero
     fleet.  Each arm gets its own contention-free session (no ledger),
     so later train-ready times can only delay upload completion —
     ``fast_round_s <= hetero_round_s <= slow_round_s`` is the floor
     ``check_floors`` gates.
  2. Degenerate-case equivalence: a real 2-round FedLEO training run
     (reduced 5x8 scale) with ``SimConfig.compute`` unset vs. the
     all-default uniform profile — round times AND metrics must be
     bit-identical (``uniform_equal``).
  3. Pallas aggregation parity: ``make_fedleo_aggregate(use_kernel=
     True)`` vs. the reference weighted mean on a real CNN TrainState
     with staleness-discounted weights (``aggregate_parity_max_err``).

Full mode (no ``--quick``) adds the fig. 5-style accuracy-vs-time
comparison — uniform vs. hetero fleet FedLEO runs at the reduced 5x8
training scale (pricing stays at starlink-40x22; CPU cannot train 880
clients).

Usage: PYTHONPATH=src python -m benchmarks.hetero_fleet [--quick]
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

from benchmarks.common import (
    append_bench,
    make_comms_env,
    make_task,
    price_ring_round,
)

CONSTELLATION = "starlink-40x22"
GS_NAMES = ("rolla", "punta-arenas")
HORIZON_HOURS = 24.0

# the hetero fleet: alternating planes of a big dense LM and a small
# SSM on the same orbital-GPU tier — the roofline spread (~10x in
# per-sample seconds) is the heterogeneity the scheduler must absorb
SLOW_ARCH = "gemma-7b"
FAST_ARCH = "mamba2-780m"
DEVICE = "orbital-gpu"
# eq. (11) knobs for pricing: 2 epochs x 2 batches x 16 samples per
# satellite (the reduced-benchmark workload)
LOCAL_EPOCHS = 2
N_BATCHES = 2
BATCH_SIZE = 16
PARITY_TOL = 1e-5


def _profile(plane_archs: List[Optional[str]]):
    from repro.compute.profiles import SatelliteComputeProfile

    # analytic mode prices the FULL-SIZE configs (no jax compile
    # needed), where the gemma/mamba roofline spread is pronounced
    return SatelliteComputeProfile.per_plane(
        plane_archs, device=DEVICE, smoke=False,
    )


def _plane_train_times(sim, plane_archs: List[Optional[str]]) -> List[float]:
    from repro.compute.fleet import FleetComputeModel

    fleet = FleetComputeModel(
        _profile(plane_archs), sim.constellation.num_planes
    )
    times = []
    for plane in range(sim.constellation.num_planes):
        t = fleet.train_time_s(
            plane, local_epochs=LOCAL_EPOCHS, n_batches=N_BATCHES,
            batch_size=BATCH_SIZE,
        )
        # degenerate planes price at the uniform 600 s benchmark rate
        times.append(600.0 if t is None else t)
    return times


def price_arms() -> Dict[str, object]:
    """Round-time pricing of the three fleets at starlink-40x22."""
    from repro.configs.constellations import make_sim_config

    sim = make_sim_config(
        CONSTELLATION, ground_stations=GS_NAMES, topology="ring",
        horizon_hours=HORIZON_HOURS,
    )
    L = sim.constellation.num_planes
    hetero_archs: List[Optional[str]] = [
        SLOW_ARCH if p % 2 == 0 else FAST_ARCH for p in range(L)
    ]
    arms = {
        "fast": [FAST_ARCH] * L,
        "hetero": hetero_archs,
        "slow": [SLOW_ARCH] * L,
    }
    # one predictor, a fresh contention-free session per arm (each arm
    # must not see another's bookings)
    base_env = make_comms_env(sim)
    out: Dict[str, object] = {}
    for name, archs in arms.items():
        times = _plane_train_times(sim, archs)
        t0 = time.perf_counter()
        round_s = price_ring_round(
            base_env.derive(), train_time_by_plane=times,
        )
        out[f"{name}_round_s"] = (
            None if round_s is None else round(round_s, 1)
        )
        out[f"{name}_plan_wall_s"] = round(time.perf_counter() - t0, 3)
        out[f"{name}_train_s_minmax"] = [
            round(min(times), 1), round(max(times), 1)
        ]
    return out


def check_uniform_equivalence(quick: bool) -> Dict[str, object]:
    """2-round FedLEO training runs: compute=None vs the all-default
    uniform profile must be bit-identical in times and metrics."""
    from repro.compute.profiles import SatelliteComputeProfile
    from repro.core import FedLEO, SimConfig

    rounds = 1 if quick else 2
    sim0 = SimConfig(horizon_hours=72.0)
    sim_u = SimConfig(
        horizon_hours=72.0, compute=SatelliteComputeProfile.uniform()
    )
    r0 = FedLEO(make_task(), sim0).run(max_rounds=rounds)
    ru = FedLEO(make_task(), sim_u).run(max_rounds=rounds)
    equal = len(r0.history) == len(ru.history) and all(
        a.t_hours == b.t_hours and a.metrics == b.metrics
        for a, b in zip(r0.history, ru.history)
    )
    return {
        "uniform_equal": bool(equal),
        "uniform_rounds": len(r0.history),
        "uniform_round_hours": [round(h.t_hours, 4) for h in r0.history],
    }


def check_aggregate_parity() -> float:
    """Max |kernel - reference| over a real CNN TrainState aggregation
    with staleness-discounted weights (zero-weight replica included)."""
    import jax
    import jax.numpy as jnp

    from repro.models.cnn import init_cnn
    from repro.optim import get_optimizer
    from repro.train.fedleo_step import make_fedleo_aggregate
    from repro.train.steps import TrainState

    r = 4
    params = init_cnn(jax.random.PRNGKey(0), (28, 28, 1), 10,
                      widths=(8, 16), hidden=32)
    stacked = jax.tree_util.tree_map(
        lambda p: jnp.stack(
            [p * (i + 1) for i in range(r)]
        ), params
    )
    opt = get_optimizer("sgd", 0.05)
    state = TrainState(
        params=stacked, opt_state=opt.init(stacked),
        step=jnp.zeros((), jnp.int32),
    )
    w = jnp.array([1.0, 2.0, 0.0, 3.0])
    stale = jnp.array([0.0, 3600.0, 0.0, 7200.0])
    ref = make_fedleo_aggregate(use_kernel=False)(state, w, stale)
    ker = make_fedleo_aggregate(use_kernel=True)(state, w, stale)
    errs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)
        ))) if a.ndim else 0.0,
        ref, ker,
    )
    return max(jax.tree_util.tree_leaves(errs), default=0.0)


def accuracy_vs_time(max_rounds: int = 3) -> Dict[str, object]:
    """Fig. 5-style uniform-vs-hetero accuracy trajectories (reduced
    5x8 training scale; full mode only)."""
    from repro.core import FedLEO, SimConfig

    sim_u = SimConfig(horizon_hours=72.0)
    sim_h = SimConfig(
        horizon_hours=72.0,
        compute=_profile(
            [SLOW_ARCH if p % 2 == 0 else FAST_ARCH for p in range(5)]
        ),
    )
    ru = FedLEO(make_task(), sim_u).run(max_rounds=max_rounds)
    rh = FedLEO(make_task(), sim_h).run(max_rounds=max_rounds)
    return {
        "fig5_uniform": [
            [round(h.t_hours, 3), round(h.metrics["accuracy"], 4)]
            for h in ru.history
        ],
        "fig5_hetero": [
            [round(h.t_hours, 3), round(h.metrics["accuracy"], 4)]
            for h in rh.history
        ],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 1 equivalence round, no fig5 arm")
    args = ap.parse_args()

    row: Dict[str, object] = {
        "bench": "hetero_fleet",
        "constellation": CONSTELLATION,
        "ground_stations": list(GS_NAMES),
        "slow_arch": SLOW_ARCH,
        "fast_arch": FAST_ARCH,
        "device": DEVICE,
    }
    row.update(price_arms())
    row.update(check_uniform_equivalence(args.quick))
    row["aggregate_parity_max_err"] = check_aggregate_parity()
    row["parity_tol"] = PARITY_TOL
    if not args.quick:
        row.update(accuracy_vs_time())
    append_bench(row)


if __name__ == "__main__":
    main()
