import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# FedLEO on the pod fabric: collective-traffic comparison (DESIGN.md §3).
#
# Lowers, on the SAME (orbit, data, model) mesh:
#   (a) the fully synchronous train_step — params replicated across the
#       orbit axis, so every step all-reduces gradients over ALL axes;
#   (b) the FedLEO local step — per-orbit parameter replicas (vmap over a
#       leading R axis sharded on "orbit"), gradient sync confined to
#       in-orbit axes;
#   (c) the FedLEO aggregation — the single scheduled weighted all-reduce
#       over "orbit" that runs once per tau local steps (eqs. 9 -> 4).
#
# Reports per-step collective bytes for each and the amortized FedLEO
# total at a given tau: the paper's claim, restated for TPU pods, is
#   bytes(b) + bytes(c)/tau  <<  bytes(a).
#
# Usage: python -m benchmarks.fedleo_collectives --arch kimi-k2-1t-a32b
import argparse
import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, build_model, get_config
from repro.launch import sharding as shardlib
from repro.launch.dryrun import collective_bytes
from repro.launch.mesh import make_fedleo_mesh
from repro.launch.specs import sds
from repro.optim import get_optimizer
from repro.train.fedleo_step import make_fedleo_aggregate, \
    make_fedleo_local_step
from repro.train.steps import TrainState, make_train_step


def _state_specs(model, cfg, mesh, replica_axis=None):
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if replica_axis:
        r = mesh.shape[replica_axis]
        shapes = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((r,) + s.shape, s.dtype), shapes
        )
    shardings = shardlib.tree_shardings(
        shapes, mesh, fsdp_axes=("data",),
        leading_replica_axis=replica_axis,
    )
    p_sds = shardlib.with_shardings(shapes, shardings)
    opt = get_optimizer(cfg.optimizer, cfg.learning_rate)
    if replica_axis:
        opt_shapes = jax.eval_shape(jax.vmap(opt.init), p_sds)
    else:
        opt_shapes = jax.eval_shape(opt.init, p_sds)
    opt_shardings = shardlib.tree_shardings(
        opt_shapes, mesh, fsdp_axes=("data",),
        leading_replica_axis=replica_axis,
    )
    opt_sds = shardlib.with_shardings(opt_shapes, opt_shardings)
    step_shape = (mesh.shape[replica_axis],) if replica_axis else ()
    return TrainState(
        params=p_sds, opt_state=opt_sds,
        step=sds(step_shape, jnp.int32, mesh,
                 P(replica_axis) if replica_axis else P()),
    ), opt


def run(arch: str, seq: int = 4096, global_batch: int = 256,
        tau: int = 8, num_orbits: int = 4):
    cfg = get_config(arch)
    model = build_model(cfg, attn_impl="chunked")
    mesh = make_fedleo_mesh(num_orbits=num_orbits)
    out = {"arch": arch, "tau": tau, "orbits": num_orbits,
           "mesh": "x".join(str(s) for s in mesh.devices.shape)}

    # (a) sync: one global batch, params replicated over orbit
    state_sds, opt = _state_specs(model, cfg, mesh, replica_axis=None)
    batch_sds = {
        "tokens": sds((global_batch, seq), jnp.int32, mesh,
                      P(("orbit", "data"), None)),
    }
    step = make_train_step(model, opt)
    sync = jax.jit(step).lower(state_sds, batch_sds).compile()
    out["sync_collective_bytes"] = collective_bytes(sync.as_text())

    # (b) FedLEO local step: per-orbit replicas
    state_r_sds, opt = _state_specs(model, cfg, mesh,
                                    replica_axis="orbit")
    rb = global_batch // num_orbits
    rbatch_sds = {
        "tokens": sds((num_orbits, 1, rb, seq), jnp.int32, mesh,
                      P("orbit", None, "data", None)),
    }
    local = make_fedleo_local_step(model, opt)
    loc = jax.jit(local).lower(state_r_sds, rbatch_sds).compile()
    out["local_collective_bytes"] = collective_bytes(loc.as_text())

    # (c) the scheduled aggregation (once per tau steps)
    agg = make_fedleo_aggregate()
    w_sds = sds((num_orbits,), jnp.float32, mesh, P())
    agg_c = jax.jit(agg).lower(state_r_sds, w_sds).compile()
    out["aggregate_collective_bytes"] = collective_bytes(agg_c.as_text())

    s_sync = sum(out["sync_collective_bytes"].values())
    s_loc = sum(out["local_collective_bytes"].values())
    s_agg = sum(out["aggregate_collective_bytes"].values())
    out["sync_total"] = s_sync
    out["fedleo_amortized_total"] = s_loc + s_agg / tau
    out["reduction_x"] = s_sync / out["fedleo_amortized_total"]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="kimi-k2-1t-a32b")
    ap.add_argument("--tau", type=int, default=8)
    ap.add_argument("--orbits", type=int, default=4)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    res = run(args.arch, seq=args.seq, global_batch=args.batch,
              tau=args.tau, num_orbits=args.orbits)
    print(json.dumps(res, indent=2))
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(res) + "\n")


if __name__ == "__main__":
    main()
