"""Visibility-engine scaling: vectorized vs scalar-reference predictor
construction at mega-constellation scale.

The visibility/scheduling layer is the simulator's hot path (ROADMAP:
production scale): the seed's per-satellite per-crossing scalar loop
cost ~5-9 s for a 6 h horizon at 40x22 — ~90 s at the predictor's
default 108 h horizon — before a single FL round ran.  This benchmark
pins the speedup of the batched-bisection engine on the same inputs and
emits a BENCH JSON line so the perf trajectory tracks it.

Usage: PYTHONPATH=src python -m benchmarks.constellation_scaling
"""
from __future__ import annotations

import time

from benchmarks.common import append_bench, peak_rss_mb
from repro.configs.constellations import (
    get_constellation,
    get_ground_stations,
)
from repro.orbits import (
    VisibilityPredictor,
    WalkerDelta,
    visibility_windows,
    visibility_windows_reference,
)

HORIZON_S = 6 * 3600.0
REQUIRED_SPEEDUP = 10.0


def _time(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def bench_constellation(name: str, with_reference: bool = True) -> dict:
    cfg = get_constellation(name)
    walker = WalkerDelta(cfg)
    (gs,) = get_ground_stations(["rolla"])

    vec, t_vec = _time(
        lambda: visibility_windows(walker, gs, 0.0, HORIZON_S)
    )
    rec = {
        "bench": "constellation_scaling",
        "constellation": name,
        "num_planes": cfg.num_planes,
        "sats_per_plane": cfg.sats_per_plane,
        "horizon_s": HORIZON_S,
        "num_windows": len(vec),
        "vectorized_s": round(t_vec, 4),
        # process-lifetime high-water mark when the row was produced:
        # a visibility-scan transient blowup shows up here first
        "peak_rss_mb": round(peak_rss_mb(), 1),
    }
    if with_reference:
        ref, t_ref = _time(
            lambda: visibility_windows_reference(walker, gs, 0.0, HORIZON_S)
        )
        windows_equal = len(vec) == len(ref)
        if windows_equal:
            pairs = zip(
                sorted(vec, key=lambda w: (w.plane, w.slot, w.t_start)),
                sorted(ref, key=lambda w: (w.plane, w.slot, w.t_start)),
            )
            max_diff = max(
                (max(abs(a.t_start - b.t_start), abs(a.t_end - b.t_end))
                 for a, b in pairs),
                default=0.0,
            )
        else:
            # counts diverged: a pairwise diff over misaligned windows
            # would understate the damage (and inf is not valid JSON)
            max_diff = None
        rec.update(
            reference_s=round(t_ref, 4),
            speedup=round(t_ref / t_vec, 2),
            windows_equal=windows_equal,
            max_boundary_diff_s=max_diff,
        )
    return rec


def bench_predictor_queries(name: str) -> dict:
    """Throughput of the bisect-indexed predictor queries."""
    cfg = get_constellation(name)
    walker = WalkerDelta(cfg)
    (gs,) = get_ground_stations(["rolla"])
    pred, t_build = _time(
        lambda: VisibilityPredictor(walker, gs, horizon_s=HORIZON_S)
    )
    sats = walker.satellites
    n_queries = 0
    t0 = time.perf_counter()
    for sat in sats:
        for tq in (0.0, HORIZON_S / 3, 2 * HORIZON_S / 3):
            pred.next_window(sat, tq)
            pred.wait_time(sat, tq)
            n_queries += 2
    t_q = time.perf_counter() - t0
    return {
        "bench": "predictor_queries",
        "constellation": name,
        "build_s": round(t_build, 4),
        "queries": n_queries,
        "us_per_query": round(t_q / n_queries * 1e6, 2),
        "peak_rss_mb": round(peak_rss_mb(), 1),
    }


def run(fast: bool = False) -> list:
    rows = [bench_constellation("paper-5x8")]
    if not fast:
        rows.append(bench_constellation("starlink-40x22"))
        rows.append(bench_predictor_queries("starlink-40x22"))
    return rows


def main() -> None:
    rows = run()
    for rec in rows:
        append_bench(rec)
    scale = next(
        r for r in rows if r["constellation"] == "starlink-40x22"
        and r["bench"] == "constellation_scaling"
    )
    ok = (
        scale["speedup"] >= REQUIRED_SPEEDUP
        and scale["windows_equal"]
        and scale["max_boundary_diff_s"] is not None
        and scale["max_boundary_diff_s"] <= 1e-3
    )
    print(
        f"# 40x22 predictor construction: {scale['reference_s']}s -> "
        f"{scale['vectorized_s']}s ({scale['speedup']}x, "
        f"floor {REQUIRED_SPEEDUP}x) — {'OK' if ok else 'REGRESSION'}"
    )
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
