"""Mega-constellation scale: the full scheduling stack at 1584+ sats.

Every other benchmark runs at starlink-40x22 (880 sats).  This one
exercises the binding mega-scale costs the ROADMAP tracks — visibility
predictor construction, all-pairs routing build, and one full
FedLEOGrid planning round — at Starlink gen1 (72x22, 1584 sats) and the
two-shell preset (72x22 + 36x22, 2376 sats), with wall AND peak-memory
columns:

  * ``predictor_peak_mb`` — tracemalloc high-water mark of the build
    (the transient the ``mem_budget_mb`` chunking bounds),
  * ``peak_rss_mb``       — process-lifetime peak RSS at row end.

Each row also re-measures the starlink-40x22 predictor build in the
same process under the same tracer, so the scaling ratio
(``predictor_build_ratio_vs_40x22``, floor-gated in ``check_floors``:
<= 4x at 1.8x the satellite count) compares like with like.

Usage: PYTHONPATH=src python -m benchmarks.mega_scale [--quick]
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Optional, Sequence

from benchmarks.common import (
    PAYLOAD_BITS,
    append_bench,
    make_comms_env,
    measure_peak_mb,
    peak_rss_mb,
    price_grid_round,
    timed,
)

GS_NAMES = ("rolla", "punta-arenas")   # 53 deg shells never rise at poles
CONSTELLATIONS = ("starlink-gen1", "starlink-2shell")
BASELINE = "starlink-40x22"
HORIZON_HOURS = 24.0
QUICK_HORIZON_HOURS = 4.0
MEM_BUDGET_MB = 256.0
CLUSTER_PLANES = 4
TRAIN_TIME_S = 600.0
LAZY_QUERY_SOURCES = 4                 # per-source Dijkstra rows to time


def _build_predictor(name: str, horizon_hours: float):
    """(walker, gs_list, predictor, build_wall_us, build_peak_mb) for a
    preset — the predictor built exactly as ``from_sim`` would (1.5x
    horizon), under tracemalloc so the scan transient is visible."""
    from repro.configs.constellations import (
        get_constellation,
        get_ground_stations,
    )
    from repro.orbits.constellation import make_walker
    from repro.orbits.prediction import VisibilityPredictor

    cfg = get_constellation(name)
    walker = make_walker(cfg)
    gs_list = list(get_ground_stations(GS_NAMES))
    pred, wall_us, peak_mb = measure_peak_mb(
        lambda: VisibilityPredictor(
            walker, gs_list,
            horizon_s=horizon_hours * 3600.0 * 1.5,
            mem_budget_mb=MEM_BUDGET_MB,
        )
    )
    return walker, gs_list, pred, wall_us, peak_mb


def bench_preset(
    name: str,
    horizon_hours: float,
    baseline_build_us: float,
    sanitize: bool,
) -> Dict:
    """One BENCH row: predictor + routing builds and a full FedLEOGrid
    planning round at mega scale."""
    from repro.comms.routing import ISLPlan, RoutingTable
    from repro.configs.constellations import make_sim_config
    from repro.orbits.topology import get_isl_topology

    sim = make_sim_config(
        name, GS_NAMES, topology="auto",
        horizon_hours=horizon_hours, mem_budget_mb=MEM_BUDGET_MB,
    )
    walker, gs_list, pred, build_us, build_peak_mb = _build_predictor(
        name, horizon_hours
    )

    topo, topo_wall_us = timed(
        lambda: get_isl_topology(sim.constellation, sim.topology)
    )
    plan = ISLPlan(intra=sim.isl, inter=sim.isl_inter or sim.isl)
    # eager all-pairs build (the first hop_split for this weight pair)
    routing, routing_wall_us = timed(
        lambda: RoutingTable(topo, plan, PAYLOAD_BITS)
    )
    # lazy option: per-source rows only — time a broadcast query from a
    # handful of sources against a fresh lazy table
    lazy = RoutingTable(topo, plan, PAYLOAD_BITS, lazy=True)
    K = topo.sats_per_plane
    sources = [p * K for p in range(LAZY_QUERY_SOURCES)]
    _, lazy_query_us = timed(
        lambda: lazy.broadcast_times(sources, [0.0] * len(sources))
    )

    env = make_comms_env(
        sim, predictor=pred, walker=walker, sanitize=sanitize
    )
    round_s, plan_wall_us = timed(
        lambda: price_grid_round(
            env, routing, cluster_planes=CLUSTER_PLANES,
            train_time_s=TRAIN_TIME_S, dynamic=True,
        )
    )
    env.finish_session(float("inf"), check_leaks=False)

    return {
        "bench": "mega_scale",
        "constellation": name,
        "num_satellites": sim.constellation.num_satellites,
        "num_planes": sim.constellation.num_planes,
        "ground_stations": list(GS_NAMES),
        "horizon_hours": horizon_hours,
        "mem_budget_mb": MEM_BUDGET_MB,
        "num_windows": len(pred.table),
        "predictor_build_s": round(build_us / 1e6, 3),
        "predictor_peak_mb": round(build_peak_mb, 1),
        "baseline_40x22_build_s": round(baseline_build_us / 1e6, 3),
        "predictor_build_ratio_vs_40x22": round(
            build_us / baseline_build_us, 2
        ),
        "topology_build_s": round(topo_wall_us / 1e6, 3),
        "routing_build_s": round(routing_wall_us / 1e6, 3),
        "routing_lazy_query_s": round(lazy_query_us / 1e6, 4),
        "cluster_planes": CLUSTER_PLANES,
        "plan_round_s": None if round_s is None else round(round_s, 1),
        "plan_wall_s": round(plan_wall_us / 1e6, 3),
        "peak_rss_mb": round(peak_rss_mb(), 1),
    }


def run(
    quick: bool = False,
    constellations: Optional[Sequence[str]] = None,
) -> List[Dict]:
    horizon = QUICK_HORIZON_HOURS if quick else HORIZON_HOURS
    # baseline measured once, same process / tracer / horizon / budget
    _, _, _, baseline_us, _ = _build_predictor(BASELINE, horizon)
    rows = []
    for name in constellations or CONSTELLATIONS:
        row = bench_preset(
            name, horizon, baseline_us, sanitize=quick
        )
        row["quick"] = quick
        rows.append(row)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced horizon (CI smoke), sanitizer on")
    args = ap.parse_args()
    failures = []
    for row in run(quick=args.quick):
        append_bench(row)
        if row["plan_round_s"] is None:
            failures.append(
                f"{row['constellation']}: planning round stalled"
            )
    if failures:
        raise SystemExit("; ".join(failures))


if __name__ == "__main__":
    main()
