"""Pallas kernel micro-benchmarks.

On this CPU container the pallas kernels execute in interpret mode, so
wall-clock numbers characterize the *oracle/XLA paths* that the models
actually run here; the kernels' TPU performance is assessed structurally
via the dry-run roofline (benchmarks/roofline.py).  What this bench
contributes: per-call timing of the aggregation hot-spot at FL-server
scale and of the XLA chunked-attention vs dense-attention paths.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def _bench(fn, *args, iters=5) -> float:
    jax.block_until_ready(fn(*args))   # warm-up / compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def run() -> List[Dict]:
    rows = []
    rng = np.random.default_rng(0)

    # aggregation at FL-server scale: 5 orbit partials x 4M params
    from repro.kernels.aggregate_ref import aggregate_flat_ref

    k, n = 5, 4_000_000
    x = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    w = jnp.asarray([0.3, 0.25, 0.2, 0.15, 0.1], jnp.float32)
    agg = jax.jit(aggregate_flat_ref)
    us = _bench(agg, x, w)
    gbps = (k * n * 4) / (us / 1e6) / 1e9
    rows.append({"name": "aggregate_4M_x5", "us_per_call": us,
                 "derived": f"stream={gbps:.1f}GB/s"})

    # chunked (flash-style XLA) vs dense attention, 2k sequence
    from repro.models.layers import (
        _attn_mask, attention_scores, chunked_attention,
    )

    b, s, h, g, d = 1, 2048, 8, 2, 64
    q = jnp.asarray(rng.standard_normal((b, s, h, d)) * 0.5, jnp.bfloat16)
    kk = jnp.asarray(rng.standard_normal((b, s, g, d)) * 0.5, jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((b, s, g, d)) * 0.5, jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))

    dense = jax.jit(lambda q, k, v: attention_scores(
        q, k, v, _attn_mask(pos, pos, True, None), h // g))
    us_dense = _bench(dense, q, kk, v)
    chunked = jax.jit(lambda q, k, v: chunked_attention(
        q, k, v, h // g, causal=True, q_chunk=256, k_chunk=256))
    us_chunked = _bench(chunked, q, kk, v)
    rows.append({"name": "attn_dense_2k", "us_per_call": us_dense,
                 "derived": f"s={s}"})
    rows.append({"name": "attn_chunked_2k", "us_per_call": us_chunked,
                 "derived": f"ratio={us_chunked / us_dense:.2f}x"})

    # SSD chunked scan vs naive recurrence, 1k sequence
    from repro.kernels.ssd_ref import ssd_naive
    from repro.models.mamba2 import ssd_chunked

    b, s, hh, p, gg, nn = 1, 1024, 8, 64, 1, 64
    xs = jnp.asarray(rng.standard_normal((b, s, hh, p)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.random((b, s, hh)) * 0.5 + 0.1, jnp.float32)
    A = -jnp.asarray(rng.random(hh) * 0.5 + 0.1, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((b, s, gg, nn)) * 0.5, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((b, s, gg, nn)) * 0.5, jnp.float32)
    naive = jax.jit(lambda *a: ssd_naive(*a))
    us_naive = _bench(naive, xs, dt, A, Bm, Cm)
    chk = jax.jit(lambda *a: ssd_chunked(*a, chunk=128)[0])
    us_chunk = _bench(chk, xs, dt, A, Bm, Cm)
    rows.append({"name": "ssd_naive_1k", "us_per_call": us_naive,
                 "derived": f"s={s}"})
    rows.append({"name": "ssd_chunked_1k", "us_per_call": us_chunk,
                 "derived": f"speedup={us_naive / us_chunk:.1f}x"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
