"""Paper §IV-A analysis: the eq. (10) -> eq. (12) round-latency collapse.

Runs, on the identical constellation state, (a) the sequential star
schedule (eq. 10, ``FedAvgStar``) and (b) FedLEO's propagate-train-
relay-sink schedule (eq. 12), and reports the realized per-phase
decomposition of the FedLEO rounds — read off the typed
``RoundDecomposition`` every ``HistoryPoint`` now carries (repro.obs),
not scraped from the legacy ``events`` dicts.

The FedLEO arm runs with ``SimConfig.trace`` on, so the decomposition
this benchmark reports is the same object a recorded trace carries
(``python -m repro.obs.report`` renders it per round).

Usage: PYTHONPATH=src python -m benchmarks.roundtime_decomposition
[--quick]  (``--quick`` = 1 round on the FAST task sizing — the CI
smoke configuration; full runs do 2 rounds at the standard sizing.)
"""
from __future__ import annotations

import argparse
from typing import Dict

import numpy as np

from benchmarks.common import append_bench, make_task
from repro.core import FedLEO, SimConfig
from repro.core.baselines import FedAvgStar
from repro.obs import mean_phase_seconds


def run(quick: bool = False) -> Dict:
    sim = SimConfig(horizon_hours=72.0, trace=True)
    rounds = 1 if quick else 2
    task_kw = dict(num_samples=800, sim_epochs=4) if quick else {}

    leo = FedLEO(make_task(**task_kw), sim)
    res_leo = leo.run(max_rounds=rounds)
    leo.recorder.detach()
    star = FedAvgStar(make_task(**task_kw), SimConfig(horizon_hours=72.0))
    res_star = star.run(max_rounds=rounds)

    decomps = [h.decomposition for h in res_leo.history]
    groups = [g for d in decomps for g in d.groups]
    phase = {
        k: round(v, 1) for k, v in mean_phase_seconds(groups).items()
    }
    # every group's phases must tile its round span exactly — the
    # decomposition is milestones, not estimates
    for g in groups:
        spans = g.phase_spans()
        assert abs(sum(t1 - t0 for _, t0, t1 in spans) - g.round_s) < 1e-6
        assert all(t1 >= t0 for _, t0, t1 in spans)

    out = {
        "bench": "roundtime_decomposition",
        "rounds": rounds,
        "fedleo_round_h_mean": float(
            np.mean([d.round_s for d in decomps]) / 3600.0
        ),
        "star_round_h_mean": float(
            np.mean([h.decomposition.round_s for h in res_star.history])
            / 3600.0
        ),
        "sink_wait_h_mean": float(
            np.mean([g.sink_wait_s for g in groups]) / 3600.0
        ),
        "planes_per_round": len(decomps[0].groups),
        "trace_events": len(leo.recorder.events),
        **{f"fedleo_{k}": v for k, v in phase.items()},
    }
    out["speedup"] = round(
        out["star_round_h_mean"] / out["fedleo_round_h_mean"], 2
    )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="1 round on the FAST task sizing (CI smoke)")
    args = ap.parse_args()
    out = run(quick=args.quick)
    append_bench(out)
    print(
        f"# FedLEO round {out['fedleo_round_h_mean']:.2f}h vs star "
        f"{out['star_round_h_mean']:.2f}h ({out['speedup']}x), "
        f"sink wait {out['sink_wait_h_mean']:.2f}h over "
        f"{out['planes_per_round']} planes/round, "
        f"{out['trace_events']} trace events"
    )


if __name__ == "__main__":
    main()
