"""Paper §IV-A analysis: the eq. (10) -> eq. (12) round-latency collapse.

Computes, on the identical constellation state, the analytic per-round
latency of (a) the sequential star schedule (eq. 10) and (b) FedLEO's
propagate-train-relay-sink schedule (eq. 12), plus the realized FedLEO
decomposition (broadcast / train / relay+wait / upload)."""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import make_task
from repro.core import FedLEO, SimConfig
from repro.core.baselines import FedAvgStar


def run() -> Dict:
    sim = SimConfig(horizon_hours=72.0)

    leo = FedLEO(make_task(), sim)
    res_leo = leo.run(max_rounds=2)
    star = FedAvgStar(make_task(), sim)
    res_star = star.run(max_rounds=2)

    rows = []
    for h in res_leo.history:
        for p in h.events["planes"]:
            rows.append(p)
    waits = [p["t_wait_sink"] for p in rows]
    out = {
        "fedleo_round_h_mean": float(
            np.mean([
                h.t_hours - (res_leo.history[i - 1].t_hours if i else 0.0)
                for i, h in enumerate(res_leo.history)
            ])
        ),
        "star_round_h_mean": float(
            np.mean([
                h.t_hours - (res_star.history[i - 1].t_hours if i else 0.0)
                for i, h in enumerate(res_star.history)
            ])
        ),
        "sink_wait_h_mean": float(np.mean(waits) / 3600.0),
        "planes_per_round": len(res_leo.history[0].events["planes"]),
    }
    out["speedup"] = out["star_round_h_mean"] / out["fedleo_round_h_mean"]
    return out


if __name__ == "__main__":
    print(run())
