"""CI observability smoke: record a short traced FedLEO run to JSONL.

Runs a 2-round FedLEO fit with ``SimConfig.trace`` on (the full hook
surface: plan/commit instants and spans, rolling-horizon extensions,
predictor query counters, routing-cache counters, round spans with
typed decompositions, structured verbose round logs) and writes the
trace with ``repro.obs.export.write_trace``.  The CI job then replays
the file through ``python -m repro.obs.report`` (and its Perfetto
export) and uploads it as a build artifact — so every PR leaves an
inspectable trace of the scheduler it shipped.

Usage: PYTHONPATH=src python -m benchmarks.obs_smoke TRACE.jsonl
       [--rounds N]
"""
from __future__ import annotations

import argparse

from benchmarks.common import make_task
from repro.core import FedLEO, SimConfig
from repro.obs.export import write_trace


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("out", help="JSONL trace path to write")
    ap.add_argument("--rounds", type=int, default=2)
    args = ap.parse_args()

    sim = SimConfig(horizon_hours=72.0, trace=True)
    leo = FedLEO(make_task(num_samples=800, sim_epochs=4), sim)
    res = leo.run(max_rounds=args.rounds, verbose=True)
    leo.recorder.detach()
    n = write_trace(leo.recorder, args.out)
    counters = leo.recorder.counters
    if not res.history:
        raise SystemExit("traced run produced no rounds")
    if counters.get("rounds", 0) != len(res.history):
        raise SystemExit("round events do not match history length")
    print(
        f"# wrote {n} events / {len(counters)} counters "
        f"({len(res.history)} rounds) to {args.out}"
    )


if __name__ == "__main__":
    main()
