"""Roofline analysis from the dry-run's compiled artifacts.

Per (arch x shape x mesh) derives the three roofline terms:

  compute    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory     = HLO_bytes_per_chip / HBM_bw
  collective = collective_bytes_per_chip / link_bw

(cost_analysis reports the per-device partitioned module, so no further
division by chip count is applied; collective bytes are parsed from the
compiled HLO, which is likewise per-device.)

Also reports MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) and the
useful-compute ratio MODEL_FLOPS / (HLO_FLOPs * chips).

Usage: PYTHONPATH=src python -m benchmarks.roofline dryrun_single_pod.jsonl
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List, Optional

from repro.configs import INPUT_SHAPES, get_config

# TPU v5e constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s/link (ICI)


def model_flops(arch: str, shape_name: str) -> float:
    """6*N*D with N = active params; D = tokens processed per step."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n = cfg.active_param_count_estimate()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens          # forward only
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyze(rec: Dict) -> Optional[Dict]:
    if not rec.get("ok"):
        return None
    chips = 1
    for d in rec["mesh"].split("x"):
        chips *= int(d)
    flops = rec["flops"] or 0.0
    byts = rec["bytes_accessed"] or 0.0
    coll = sum((rec.get("collective_bytes") or {}).values())
    if not rec.get("corrected"):
        # raw dry-run numbers under-count scanned layer bodies (XLA
        # counts a while body once) — prefer dryrun_corrected.jsonl
        pass

    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)

    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / (flops * chips) if flops else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "hlo_flops_per_chip": flops,
        "useful_ratio": useful,
        "corrected": bool(rec.get("corrected")),
        "collective_breakdown": rec.get("collective_bytes", {}),
    }


def load(path: str) -> List[Dict]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    # de-dup: keep the LAST record per (arch, shape, mesh)
    seen = {}
    for r in out:
        seen[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return list(seen.values())


def table(path: str) -> List[Dict]:
    rows = []
    for rec in load(path):
        a = analyze(rec)
        if a:
            rows.append(a)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_single_pod.jsonl"
    rows = table(path)
    hdr = ("arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
           "dominant,useful_ratio")
    print(hdr)
    for r in rows:
        print(f"{r['arch']},{r['shape']},{r['mesh']},"
              f"{r['t_compute_s']:.4e},{r['t_memory_s']:.4e},"
              f"{r['t_collective_s']:.4e},{r['dominant']},"
              f"{r['useful_ratio']:.3f}")


if __name__ == "__main__":
    main()
