"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus detail blocks as
``#`` comments).  Set BENCH_FAST=1 for a reduced pass.

  fig3   — visiting-pattern irregularity (paper Fig. 3)
  table2 — FedLEO vs SOTA accuracy + convergence time (paper Table II)
  fig5   — accuracy vs convergence time on all datasets (paper Fig. 5)
  eq12   — round-latency decomposition, star (eq. 10) vs FedLEO (eq. 12)
  kernels— Pallas kernel micro-benchmarks (interpret-mode; TPU
           wall-clock is out of scope on CPU — see benchmarks/roofline.py)
"""
from __future__ import annotations

import time


def _timed(fn):
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6


def main() -> None:
    rows = []

    from benchmarks import fig3_visiting_pattern
    out, us = _timed(fig3_visiting_pattern.run)
    rows.append(("fig3_visiting_pattern", us,
                 f"gap_cv={out['gap_cv']:.2f}|"
                 f"visits={out['visits_min']}-{out['visits_max']}"))
    print(f"# fig3: {out['num_windows']} windows, "
          f"duration {out['duration_mean_min']:.1f}"
          f"+-{out['duration_std_min']:.1f} min, gap CV {out['gap_cv']:.2f}")

    from benchmarks import roundtime_decomposition
    out, us = _timed(roundtime_decomposition.run)
    rows.append(("eq12_roundtime", us, f"speedup={out['speedup']:.2f}x"))
    print(f"# eq10 vs eq12: star {out['star_round_h_mean']:.2f} h/round, "
          f"fedleo {out['fedleo_round_h_mean']:.2f} h/round "
          f"-> {out['speedup']:.2f}x")

    from benchmarks import table2_sota
    out, us = _timed(table2_sota.run)
    print("# table2 (non-IID): method, accuracy, conv_time_h")
    best = max(r["accuracy"] for r in out)
    for r in out:
        print(f"#   {r['method']:14s} acc={r['accuracy']:.4f} "
              f"t={r['conv_time_h']:6.2f} h")
    leo = next(r for r in out if r["method"] == "FedLEO")
    rows.append(("table2_sota", us,
                 f"fedleo_acc={leo['accuracy']:.3f}|"
                 f"fedleo_h={leo['conv_time_h']:.1f}|best_acc={best:.3f}"))

    from benchmarks import fig5_accuracy_vs_time
    out, us = _timed(fig5_accuracy_vs_time.run)
    finals = {}
    for r in out:
        finals[r["dataset"]] = r
    print("# fig5 finals: " + ", ".join(
        f"{k}: acc={v['accuracy']:.3f}@{v['t_hours']:.1f}h"
        for k, v in finals.items()
    ))
    rows.append(("fig5_accuracy_vs_time", us,
                 "|".join(f"{k}={v['accuracy']:.3f}"
                          for k, v in finals.items())))

    from benchmarks import ablation_sink
    out, us = _timed(ablation_sink.run)
    print("# sink-scheduling ablation (payload, policy, sim_h, wait_h):")
    for r in out:
        print(f"#   {r['payload']:12s} {r['policy']:14s} "
              f"t={r['sim_hours']:6.2f}h wait={r['mean_sink_wait_h']:.2f}h")
    sched = [r for r in out if r["policy"] == "scheduled"][-1]
    naive = [r for r in out if r["policy"] == "first_visitor"][-1]
    rows.append(("ablation_sink", us,
                 f"sched_h={sched['sim_hours']:.1f}|"
                 f"naive_h={naive['sim_hours']:.1f}"))

    from benchmarks import kernel_bench
    out, us = _timed(kernel_bench.run)
    for r in out:
        rows.append((f"kernel_{r['name']}", r["us_per_call"], r["derived"]))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
