import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# Depth-extrapolation correction for the roofline terms.
#
# XLA's HloCostAnalysis counts a while-loop (lax.scan) body ONCE, so the
# dry-run's cost_analysis under-reports flops/bytes for scanned-layer
# models by ~num_layers x (verified empirically; see EXPERIMENTS.md
# §Dry-run).  This tool lowers each (arch x shape) twice more with the
# layer stack UNROLLED at two shallow depths and linearly extrapolates
# every cost term to the real depth:
#
#   cost(L) = cost_outer + units(L) * cost_per_unit
#   cost_per_unit = (cost(d2) - cost(d1)) / (units(d2) - units(d1))
#
# Collective bytes parsed from the HLO get the same correction (the
# layer-body collectives are likewise counted once inside the loop).
#
# Usage: python -m benchmarks.roofline_correct --out benchmarks/dryrun_corrected.jsonl
import argparse
import dataclasses
import json
import traceback

import jax

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.configs.base import EncoderConfig
from repro.launch.dryrun import collective_bytes, cost_analysis_dict, lower_pair
from repro.launch.mesh import make_production_mesh


def variant_plan(arch: str):
    """Returns (cfg_small, cfg_big, units_small, units_big, units_real)."""
    cfg = get_config(arch)
    if cfg.family == "hybrid":
        g = cfg.hybrid_attn_every
        unit = g                      # one unit = g mamba layers + 1 attn use
        l1, l2 = g, 2 * g
        units_real = cfg.num_layers / g
    elif cfg.moe is not None:
        unit = cfg.moe_every
        l1, l2 = unit, 2 * unit
        units_real = cfg.num_layers / unit
    else:
        unit = 1
        l1, l2 = 2, 4
        units_real = float(cfg.num_layers)

    def make(lyrs):
        kw = dict(num_layers=lyrs, scan_layers=False)
        if cfg.encoder is not None:
            kw["encoder"] = EncoderConfig(
                num_layers=lyrs,
                max_source_len=cfg.encoder.max_source_len,
            )
        return dataclasses.replace(cfg, **kw)

    if cfg.encoder is not None:
        # enc+dec both scale: one "unit" = one enc layer + one dec layer
        units_real = float(cfg.num_layers)   # = encoder layers too
    return make(l1), make(l2), l1 / unit, l2 / unit, units_real


def measure(arch, shape_name, cfg, mesh, sharding_mode="fsdp2d"):
    lowered, _ = lower_pair(arch, shape_name, mesh, cfg=cfg,
                            sharding_mode=sharding_mode)
    compiled = lowered.compile()
    cost = cost_analysis_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
    }


def extrapolate(m1, m2, u1, u2, u_real):
    out = {}
    for key in ("flops", "bytes"):
        slope = (m2[key] - m1[key]) / (u2 - u1)
        outer = m1[key] - u1 * slope
        out[key] = max(0.0, outer + u_real * slope)
    coll = {}
    kinds = set(m1["coll"]) | set(m2["coll"])
    for kind in kinds:
        a, b = m1["coll"].get(kind, 0.0), m2["coll"].get(kind, 0.0)
        slope = (b - a) / (u2 - u1)
        outer = a - u1 * slope
        coll[kind] = max(0.0, outer + u_real * slope)
    out["coll"] = coll
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS) + ["all"],
                    default="all")
    ap.add_argument("--shape", choices=list(INPUT_SHAPES) + ["all"],
                    default="all")
    ap.add_argument("--out",
                    default="benchmarks/dryrun_corrected.jsonl")
    ap.add_argument("--sharding", choices=["fsdp2d", "zero1"],
                    default="fsdp2d")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    mesh = make_production_mesh(multi_pod=False)

    done = set()
    if args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("ok"):
                        done.add((r["arch"], r["shape"]))
                except Exception:
                    pass

    for arch in archs:
        cfg_small, cfg_big, u1, u2, u_real = variant_plan(arch)
        for shape in shapes:
            if (arch, shape) in done:
                print(f"[correct] skip {arch} x {shape}")
                continue
            try:
                m1 = measure(arch, shape, cfg_small, mesh, args.sharding)
                m2 = measure(arch, shape, cfg_big, mesh, args.sharding)
                ex = extrapolate(m1, m2, u1, u2, u_real)
                rec = {
                    "ok": True, "arch": arch, "shape": shape,
                    "mesh": "16x16", "corrected": True,
                    "sharding": args.sharding,
                    "flops": ex["flops"], "bytes_accessed": ex["bytes"],
                    "collective_bytes": ex["coll"],
                    "raw_small": m1, "raw_big": m2,
                    "units": [u1, u2, u_real],
                }
                print(f"[correct] {arch} x {shape}: "
                      f"flops {m1['flops']:.3e}/{m2['flops']:.3e} -> "
                      f"{ex['flops']:.3e} (x{u_real:.0f} units)")
            except Exception as e:
                traceback.print_exc()
                rec = {"ok": False, "arch": arch, "shape": shape,
                       "error": f"{type(e).__name__}: {e}"}
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
