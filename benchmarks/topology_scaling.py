"""Ring vs +Grid round time at mega-constellation scale.

The ROADMAP's blocker for 40+ plane shells: under the paper's
intra-plane-only ring, EVERY plane needs its own GS download and sink
upload per round, so the round is gated by the worst-served plane.  The
grid topology (inter-plane FSO ISLs) lets one download seed a whole
cluster of planes and one sink upload collect it — L/cluster GS
round-trips instead of L.

This benchmark prices a full FedLEO round (download -> flood ->
training -> relay -> sink upload) with the *pure schedule planners* —
no JAX training, the simulated clock only — at starlink-40x22 with 1-3
ground stations, and emits BENCH JSON lines into the repo-root
trajectory (``BENCH_topology.json``).

Acceptance floor: grid round time <= ring round time with >= 2 planes
per sink cluster.

Usage: PYTHONPATH=src python -m benchmarks.topology_scaling [--quick]
(``--quick`` prices only the first ground-station set — the CI smoke
configuration.)
"""
from __future__ import annotations

import argparse
import time
from typing import List

from benchmarks.common import (
    PAYLOAD_BITS,
    append_bench,
    make_comms_env,
    price_grid_round,
    price_ring_round,
)
from repro.comms.routing import ISLPlan, get_routing_table
from repro.configs.constellations import make_sim_config
from repro.core.fedleo import make_clusters
from repro.obs import mean_phase_seconds

CONSTELLATION = "starlink-40x22"
GS_SETS = (("rolla",), ("rolla", "punta-arenas"),
           ("rolla", "punta-arenas", "awarua"))
HORIZON_HOURS = 24.0
CLUSTER_PLANES = 4
# eq. (11) with Table I compute parameters and ~50 samples/satellite
TRAIN_TIME_S = 600.0


def run(gs_sets=GS_SETS) -> List[dict]:
    rows = []
    # the ISL graph is GS-independent: build its routing table once —
    # and time the memoized re-lookup (``get_routing_table`` caches per
    # (constellation, topology, plan, payload), so every strategy and
    # benchmark arm after the first gets the table for free)
    routing = None
    t_routing = 0.0
    t_routing_cached = 0.0
    for gs_names in gs_sets:
        sim = make_sim_config(
            CONSTELLATION, ground_stations=gs_names, topology="grid",
            horizon_hours=HORIZON_HOURS,
        )
        # contention-free arms share one session per pricing pass (a
        # fresh env per arm: each must not see the other's bookings)
        base_env = make_comms_env(sim)

        # typed phase decompositions (repro.obs) ride along — pure
        # reads on each plane/cluster plan, negligible next to planning
        ring_groups: List = []
        grid_groups: List = []
        t0 = time.perf_counter()
        ring = price_ring_round(base_env.derive(), train_time_s=TRAIN_TIME_S,
                                groups=ring_groups)
        t_ring = time.perf_counter() - t0

        if routing is None:
            plan = ISLPlan(intra=sim.isl, inter=sim.isl_inter)
            t0 = time.perf_counter()
            routing = get_routing_table(
                sim.constellation, sim.topology, plan, PAYLOAD_BITS
            )
            t_routing = time.perf_counter() - t0
            t0 = time.perf_counter()
            get_routing_table(
                sim.constellation, sim.topology, plan, PAYLOAD_BITS
            )
            t_routing_cached = time.perf_counter() - t0
        t0 = time.perf_counter()
        # static clusters: this benchmark tracks the PR 2 floor
        grid = price_grid_round(
            base_env.derive(), routing,
            cluster_planes=CLUSTER_PLANES, train_time_s=TRAIN_TIME_S,
            groups=grid_groups,
        )
        t_grid = time.perf_counter() - t0

        def _rdecomp(groups):
            return {k: round(v, 1)
                    for k, v in mean_phase_seconds(groups).items()}

        rows.append({
            "bench": "topology_scaling",
            "constellation": CONSTELLATION,
            "ground_stations": list(gs_names),
            "cluster_planes": CLUSTER_PLANES,
            "train_time_s": TRAIN_TIME_S,
            "ring_round_s": None if ring is None else round(ring, 1),
            "grid_round_s": None if grid is None else round(grid, 1),
            "speedup": (
                None if ring is None or grid is None or grid == 0
                else round(ring / grid, 2)
            ),
            "gs_trips_ring": sim.constellation.num_planes,
            "gs_trips_grid": len(
                make_clusters(sim.constellation.num_planes, CLUSTER_PLANES)
            ),
            "ring_decomp": _rdecomp(ring_groups),
            "grid_decomp": _rdecomp(grid_groups),
            "plan_wall_ring_s": round(t_ring, 3),
            "plan_wall_grid_s": round(t_grid, 3),
            "routing_build_s": round(t_routing, 3),
            "routing_build_cached_s": round(t_routing_cached, 6),
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="single ground-station set (CI smoke)")
    args = ap.parse_args()
    rows = run(GS_SETS[:1] if args.quick else GS_SETS)
    for rec in rows:
        append_bench(rec)
    ok = all(
        r["grid_round_s"] is not None
        and (r["ring_round_s"] is None
             or r["grid_round_s"] <= r["ring_round_s"])
        for r in rows
    )
    for r in rows:
        print(
            f"# {len(r['ground_stations'])} GS: ring "
            f"{r['ring_round_s']}s -> grid {r['grid_round_s']}s "
            f"({r['gs_trips_ring']} -> {r['gs_trips_grid']} GS trips)"
        )
    print(f"# grid <= ring at {CLUSTER_PLANES} planes/sink — "
          f"{'OK' if ok else 'REGRESSION'}")
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
