import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# §Perf pair 2 (seamless-m4t x train_4k, most collective-bound):
# HYPOTHESIS — d_model=1024 is too small for 16-way tensor parallelism:
# per-chip matmul tiles are tiny while every layer pays all-gather/
# reduce-scatter on activations, so the collective term dominates (51 s
# vs 0.74 s compute in the baseline roofline).  Re-purposing the `model`
# axis as extra DATA parallelism (batch 256 -> 1 seq/chip, weights
# replicated, optimizer state ZeRO-1-sharded over BOTH axes) should cut
# collective bytes to ~one gradient all-reduce (params * 2 bytes) and
# remove the redundant-compute penalty entirely.
#
# Measures the depth-extrapolated corrected terms for baseline-TP vs
# pure-DP.  Usage: python -m benchmarks.perf_seamless_dp
import dataclasses
import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import EncoderConfig, INPUT_SHAPES
import repro.configs.registry as reg
from repro.launch import sharding as shardlib, specs as speclib
from repro.launch.dryrun import collective_bytes, cost_analysis_dict
from repro.launch.mesh import make_production_mesh
from repro.optim import get_optimizer
from repro.train.steps import TrainState, make_train_step


def measure_pure_dp(nl: int, mesh):
    cfg = dataclasses.replace(
        get_config("seamless-m4t-large-v2"),
        num_layers=nl, scan_layers=False,
        encoder=EncoderConfig(num_layers=nl, max_source_len=1024),
    )
    shape = INPUT_SHAPES["train_4k"]
    model = reg.build_model(cfg, attn_impl="chunked")
    opt = get_optimizer(cfg.optimizer, cfg.learning_rate)
    step = make_train_step(model, opt)

    # batch over BOTH axes; weights replicated; opt ZeRO over both axes
    b, s = shape.global_batch, shape.seq_len
    batch_sds = {
        "tokens": speclib.sds((b, s), jnp.int32, mesh,
                              P(("data", "model"), None)),
        "source": speclib.sds((b, 1024, cfg.d_model), jnp.bfloat16, mesh,
                              P(("data", "model"), None, None)),
    }
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    # params fully replicated
    p_sds = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(
            x.shape, x.dtype, sharding=NamedSharding(mesh, P())
        ), shapes,
    )
    opt_shapes = jax.eval_shape(opt.init, p_sds)

    def opt_spec(path, leaf):
        # shard the largest dim over (data, model) when divisible
        spec = [None] * len(leaf.shape)
        for i, d in enumerate(leaf.shape):
            if d % 256 == 0:
                spec[i] = ("data", "model")
                break
        return jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype,
            sharding=NamedSharding(mesh, P(*spec)),
        )

    o_sds = jax.tree_util.tree_map_with_path(opt_spec, opt_shapes)
    state = TrainState(params=p_sds, opt_state=o_sds,
                       step=speclib.sds((), jnp.int32, mesh))
    c = jax.jit(step, donate_argnums=(0,)).lower(state, batch_sds).compile()
    ca = cost_analysis_dict(c)
    return {
        "flops": float(ca["flops"]),
        "bytes": float(ca["bytes accessed"]),
        "coll": collective_bytes(c.as_text()),
    }


def main():
    mesh = make_production_mesh()
    m2 = measure_pure_dp(2, mesh)
    m4 = measure_pure_dp(4, mesh)
    real = 24.0
    out = {"arch": "seamless-m4t-large-v2", "shape": "train_4k",
           "sharding": "pure_dp_zero1", "corrected": True, "ok": True,
           "mesh": "16x16"}
    for k in ("flops", "bytes"):
        slope = (m4[k] - m2[k]) / 2.0
        out["flops" if k == "flops" else "bytes_accessed"] = max(
            0.0, m2[k] - 2 * slope + real * slope
        )
    coll = {}
    for kind in set(m2["coll"]) | set(m4["coll"]):
        a, b = m2["coll"].get(kind, 0.0), m4["coll"].get(kind, 0.0)
        slope = (b - a) / 2.0
        coll[kind] = max(0.0, a - 2 * slope + real * slope)
    out["collective_bytes"] = coll
    print(json.dumps(out, indent=2))
    with open("perf_seamless_dp.jsonl", "a") as f:
        f.write(json.dumps(out) + "\n")


if __name__ == "__main__":
    main()
