"""Paper Table II: FedLEO vs SOTA FL approaches under non-IID —
accuracy and convergence time on the simulated constellation.

Every strategy runs on the identical constellation/link/dataset; the
convergence time is the simulated wall-clock to reach the accuracy
target (95% of FedLEO's final accuracy), matching how the paper reports
"convergence time" per method.
"""
from __future__ import annotations

import os
from typing import Dict, List

from benchmarks.common import FAST, make_task
from repro.configs.constellations import make_sim_config
from repro.core import FedLEO, FedLEOGrid, SimConfig
from repro.core.baselines import ALL_BASELINES

# async methods get more (cheaper) server events than sync rounds
ROUNDS = {
    "sync": 3 if FAST else 5,
    "async": 20 if FAST else 40,
}
_SYNC = {"FedAvg", "FedSatSched", "FedHAP", "FedISL", "FedISL-ideal"}

METHODS = [
    "FedAvg", "FedISL-ideal", "FedISL", "FedHAP", "FedAsync",
    "FedSat-ideal", "FedSpace", "FedSatSched", "AsyncFLEO",
]


def run(dataset: str = "mnist-like") -> List[Dict]:
    sim = SimConfig(horizon_hours=72.0)
    rows = []

    leo = FedLEO(make_task(dataset), sim).run(
        max_rounds=ROUNDS["sync"]
    )
    target = 0.95 * leo.final_accuracy
    conv = leo.convergence_time_hours(target)
    rows.append({
        "method": "FedLEO", "dataset": dataset,
        "accuracy": leo.final_accuracy,
        "conv_time_h": conv if conv is not None else leo.final_time_hours,
        "rounds": len(leo.history),
    })

    # the grid variant: inter-plane ISLs, cluster sinks (same clock,
    # same dataset/training — only the topology layer differs)
    sim_grid = make_sim_config(
        "paper-5x8", topology="grid", horizon_hours=sim.horizon_hours
    )
    grid = FedLEOGrid(make_task(dataset), sim_grid).run(
        max_rounds=ROUNDS["sync"]
    )
    conv = grid.convergence_time_hours(target)
    rows.append({
        "method": "FedLEO-Grid", "dataset": dataset,
        "accuracy": grid.final_accuracy,
        "conv_time_h": conv if conv is not None
        else grid.final_time_hours,
        "converged": conv is not None,
        "rounds": len(grid.history),
    })

    for name in METHODS:
        cls = ALL_BASELINES[name]
        n = ROUNDS["sync"] if name in _SYNC else ROUNDS["async"]
        res = cls(make_task(dataset), sim).run(max_rounds=n)
        conv = res.convergence_time_hours(target)
        rows.append({
            "method": name, "dataset": dataset,
            "accuracy": res.final_accuracy,
            "conv_time_h": conv if conv is not None
            else res.final_time_hours,
            "converged": conv is not None,
            "rounds": len(res.history),
        })
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
