"""Shared benchmark substrate: the paper's simulation setup at a
CPU-tractable scale (the simulated *clock* keeps Table I fidelity; only
the executed epoch count and proxy-model size are reduced).

Also the single mechanism for the repo's BENCH trajectory: every
benchmark appends its ``BENCH {json}`` records to the repo-root
``BENCH_topology.json`` via ``append_bench`` so the per-PR perf history
lives in one file (ROADMAP: "track BENCH JSON per PR").

The ``repro`` imports are lazy so scheduling-only benchmarks
(constellation/topology scaling) don't pay the JAX import.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional

FAST = os.environ.get("BENCH_FAST", "0") == "1"

# the paper's deep CNN is a few M params; charge the comm model for a
# 4M-param fp32 model (z|N| = 128 Mbit) while training a small proxy.
PAYLOAD_BITS = int(4e6 * 32)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_TRAJECTORY = os.path.join(REPO_ROOT, "BENCH_topology.json")

# BENCH record schema: bumped when the row shape changes in a way
# consumers may care about.  2 = ISSUE 7 (obs): rows carry schema +
# run_id stamps and may carry decomposition/utilization columns.
BENCH_SCHEMA = 2

_RUN_ID: Optional[str] = None


def bench_run_id() -> str:
    """One id per benchmark process, stamped into every row it appends
    — rows of one invocation are groupable in the append-only
    trajectory, and obs-enriched (schema >= 2) rows are distinguishable
    from pre-PR-7 history."""
    global _RUN_ID
    if _RUN_ID is None:
        _RUN_ID = f"{os.getpid():x}-{time.time_ns():x}"
    return _RUN_ID


def append_bench(rec: Dict, path: Optional[str] = None) -> None:
    """Print a ``BENCH {json}`` line and append it to the repo-root
    trajectory file (one JSON record per line), stamped with the BENCH
    ``schema`` version and this process's ``run_id``.

    Tolerant of a corrupt/truncated final line (e.g. a benchmark killed
    mid-write): the partial line is newline-quarantined so the appended
    record always starts a fresh, parseable line.
    """
    rec = {**rec, "schema": BENCH_SCHEMA, "run_id": bench_run_id()}
    line = json.dumps(rec)
    print("BENCH " + line)
    target = path or BENCH_TRAJECTORY
    prefix = ""
    try:
        with open(target, "rb") as f:
            f.seek(-1, os.SEEK_END)
            if f.read(1) not in (b"\n", b""):
                prefix = "\n"
    except (FileNotFoundError, OSError):
        pass                    # missing or empty file: nothing to fix
    with open(target, "a") as f:
        f.write(prefix + line + "\n")


def make_task(
    dataset: str = "mnist-like",
    noniid: bool = True,
    num_samples: int = 800 if FAST else 1600,
    sim_epochs: int = 4 if FAST else 8,
    seed: int = 0,
):
    from repro.core import FederatedTask, TrainHyperparams
    from repro.data import (
        make_classification_dataset,
        partition_iid,
        partition_noniid_by_orbit,
    )
    from repro.models.cnn import apply_cnn, init_cnn
    from repro.optim import get_optimizer

    ds = make_classification_dataset(dataset, num_samples=num_samples,
                                     seed=seed)
    test = make_classification_dataset(dataset, num_samples=400,
                                       seed=seed + 1000)
    if noniid:
        clients = partition_noniid_by_orbit(ds, 5, 8, seed=seed)
    else:
        clients = partition_iid(ds, 5, 8, seed=seed)
    shape = ds.x.shape[1:]
    hp = TrainHyperparams(local_epochs=100, learning_rate=0.05,
                          batch_size=16)
    return FederatedTask(
        init_fn=lambda r: init_cnn(r, shape, 10, widths=(8, 16), hidden=32),
        apply_fn=apply_cnn,
        clients=clients,
        test_set=test,
        optimizer=get_optimizer("sgd", 0.05),
        hp=hp,
        sim_epochs=sim_epochs,
        payload_bits_override=PAYLOAD_BITS,
    )


def timed(fn: Callable) -> tuple:
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6


def measure_peak_mb(fn: Callable) -> tuple:
    """Run ``fn`` and return ``(result, wall_us, peak_mb)``.

    ``peak_mb`` is the tracemalloc high-water mark of the call: numpy
    registers its buffer allocator with tracemalloc, so transient array
    peaks (the thing ``mem_budget_mb`` bounds) are visible; allocations
    inside C extensions that bypass it (some scipy internals) are not.
    Tracing slows the call down — when a row's wall column must stay
    honest, time an untraced run separately and use this one only for
    the peak column.
    """
    import tracemalloc

    was_tracing = tracemalloc.is_tracing()
    if not was_tracing:
        tracemalloc.start()
    tracemalloc.reset_peak()
    t0 = time.time()
    out = fn()
    wall_us = (time.time() - t0) * 1e6
    _, peak = tracemalloc.get_traced_memory()
    if not was_tracing:
        tracemalloc.stop()
    return out, wall_us, peak / 1e6


def peak_rss_mb() -> float:
    """Process-lifetime peak resident set size [MB].

    ``ru_maxrss`` is kilobytes on Linux; the value is monotone over the
    process lifetime, so per-phase attribution needs tracemalloc
    (``measure_peak_mb``) — this column is the row-level "how big did
    the whole process ever get" bound the mega-scale floors gate on.
    """
    import resource

    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1e3


def overhead_fraction(
    plain: Callable, traced: Callable, samples: int = 5
) -> tuple:
    """Robust relative-overhead estimate of ``traced`` vs ``plain``.

    Takes ``samples`` interleaved (plain, traced) wall-time pairs —
    interleaving cancels slow drift (thermal, page-cache warmup) that
    biases back-to-back batches — and compares the per-arm *medians*,
    which single outlier samples cannot move.  The fraction is clamped
    at >= 0: tracing cannot speed planning up, so a negative estimate
    is measurement noise by construction and must not enter the BENCH
    trajectory (the ≤5% overhead floor should gate signal, not jitter).

    Returns ``(fraction, plain_wall_us, traced_wall_us)`` with the
    median walls.
    """
    plain_walls: List[float] = []
    traced_walls: List[float] = []
    for _ in range(max(1, samples)):
        _, w_p = timed(plain)
        plain_walls.append(w_p)
        _, w_t = timed(traced)
        traced_walls.append(w_t)
    med_p = sorted(plain_walls)[len(plain_walls) // 2]
    med_t = sorted(traced_walls)[len(traced_walls) // 2]
    frac = max(0.0, (med_t - med_p) / med_p) if med_p > 0 else 0.0
    return frac, med_p, med_t


def make_comms_env(sim, *, predictor=None, walker=None, capacity=None,
                   handover: bool = False, sanitize: bool = False,
                   trace: bool = False):
    """A benchmark-arm ``CommsEnvironment``: share one (expensive)
    predictor across arms (pass the base arm's ``predictor``/
    ``walker``), give each arm its own fresh ledger and handover
    policy.  ``capacity=None`` is the contention-free arm.  Session
    construction is ``CommsEnvironment.from_sim`` — the one recipe —
    so benchmark arms and strategies always agree on the predictor.
    ``sanitize`` attaches a strict ``ScheduleSanitizer`` to the arm
    (the ``--quick`` smoke configuration; timed arms leave it off);
    ``trace`` a ``TraceRecorder`` (detach it — ``env.recorder.detach()``
    — before pricing further untraced arms on the shared predictor)."""
    from repro.comms.environment import CommsEnvironment
    from repro.comms.ledger import GSResourceLedger

    if predictor is None:
        env = CommsEnvironment.from_sim(sim, walker=walker)
    else:
        env = CommsEnvironment(
            walker=walker, predictor=predictor, link=sim.link,
            isl=sim.isl, gs=list(sim.all_ground_stations),
        )
    ledger = (
        GSResourceLedger(len(env.ground_stations), capacity)
        if capacity is not None else None
    )
    return env.derive(ledger=ledger, handover=handover, sanitize=sanitize,
                      trace=trace)


def price_ring_round(
    env, *,
    payload_bits: float = PAYLOAD_BITS,
    train_time_s: float = 600.0,
    train_time_by_plane: Optional[List[float]] = None,
    t: float = 0.0,
    groups: Optional[List] = None,
):
    """Full FedLEO ring round time via the pure plane planners (no JAX
    training): every plane needs its own GS download and sink upload.
    Planning and booking route through the ``env`` session: with a
    ledger each chosen upload is committed so later planes are priced
    against residual station capacity (no ledger = the pre-ledger
    contention-free pricing); the session's handover policy lets each
    upload split into station-handover segments.  None if any plane
    stalls.  Pass a list as ``groups`` to collect each plane's typed
    ``GroupDecomposition`` (repro.obs) — read-only on the plans, so
    collection never changes the priced schedule.
    ``train_time_by_plane`` prices a heterogeneous fleet (one training
    duration per plane — ``FleetComputeModel.plane_summary`` order);
    omitted, every plane trains for the uniform ``train_time_s``."""
    import numpy as np

    from repro.core.fedleo import plan_plane_round
    from repro.obs import decompose_group_plan

    K = env.walker.config.sats_per_plane
    done = []
    for plane in range(env.walker.config.num_planes):
        per_plane = (
            train_time_s if train_time_by_plane is None
            else train_time_by_plane[plane]
        )
        plan = plan_plane_round(
            env=env, isl=env.isl, plane=plane, t=t,
            payload_bits=payload_bits,
            train_times=np.full(K, per_plane),
        )
        if plan is None:
            return None            # a plane stalls the whole round
        env.commit(plan.decision)
        if groups is not None:
            groups.append(decompose_group_plan(plan, t))
        done.append(plan.decision.t_upload_done)
    return max(done)


def price_grid_round(
    env, routing, *,
    cluster_planes: int,
    payload_bits: float = PAYLOAD_BITS,
    train_time_s: float = 600.0,
    dynamic: bool = False,
    t: float = 0.0,
    groups: Optional[List] = None,
):
    """Full FedLEOGrid round time via the pure cluster planners: one
    download + one sink upload per cluster.  ``dynamic=True`` re-forms
    clusters from predicted window supply (the strategy default) —
    discounted by the session ledger's residual station capacity
    (formation feedback); ``False`` keeps the static adjacent-plane
    grouping.  Session and ``groups`` semantics as in
    ``price_ring_round``."""
    import numpy as np

    from repro.core.fedleo import (
        make_clusters,
        plan_cluster_round,
        supply_driven_clusters,
    )
    from repro.obs import decompose_group_plan

    K = env.walker.config.sats_per_plane
    L = env.walker.config.num_planes
    if dynamic:
        clusters = supply_driven_clusters(
            env.predictor, routing.topology, cluster_planes, t,
            ledger=env.ledger,
        )
    else:
        clusters = make_clusters(L, cluster_planes)
    done = []
    for planes in clusters:
        train = np.full(len(planes) * K, train_time_s)
        plan = plan_cluster_round(
            env=env, routing=routing, planes=planes, t=t,
            payload_bits=payload_bits, train_times=train,
        )
        if plan is None:
            return None
        env.commit(plan.decision)
        if groups is not None:
            groups.append(decompose_group_plan(plan, t))
        done.append(plan.decision.t_upload_done)
    return max(done)


def price_async_round(
    env, *,
    payload_bits: float = PAYLOAD_BITS,
    train_time_s: float = 600.0,
    readmit: bool = False,
    t: float = 0.0,
    policy: str = "monotone",
    completions: Optional[List] = None,
):
    """AsyncFLEO-style async 'round' pricing (no JAX training): every
    plane schedules download -> ring flood -> training -> naive-sink
    upload at ``t``, BOOKING the upload at schedule time in plane
    order.  Then the release event the re-admission machinery exists
    for fires: the earliest-starting queued upload is CANCELLED (its
    plane drops out of the round — a straggler/abort, exactly how an
    async strategy abandons a cycle) and its reservation released.

    The book-at-schedule-time baseline (``readmit=False``) leaves the
    surviving bookings where they were — the freed RB stretch goes
    unused.  ``readmit=True`` re-admits the surviving queued uploads
    through the session's release hook (``CommsEnvironment.readmit``:
    per-entry monotone re-pricing in ready order, each move adopted
    only when that upload completes strictly earlier), so uploads
    cascade up into the freed capacity — the round never finishes
    later, and the server receives updates earlier on average (fresher
    async mixing).

    ``policy`` is forwarded to ``readmit`` ("monotone" per-entry
    repair, or "repack" for the regret-based swap re-packer whose
    per-entry floor IS the monotone result).  ``completions``, when a
    list, receives the surviving ``(plane, t_done)`` pairs — the
    per-entry surface the multi_tenant repack floor gates on.

    Returns ``(t_round, t_mean, repriced)`` — when every surviving
    plane's upload lands, the mean upload completion, and how many
    re-pricings were adopted — or ``(None, None, 0)`` if any plane
    stalls."""
    import numpy as np

    from repro.comms.environment import PendingUpload
    from repro.comms.isl import isl_hop_time
    from repro.core.propagation import broadcast_schedule, ring_hops_matrix
    from repro.orbits.constellation import Satellite

    K = env.walker.config.sats_per_plane
    t_hop = isl_hop_time(env.isl, payload_bits)
    hops = ring_hops_matrix(K)
    pending = []
    for plane in range(env.walker.config.num_planes):
        dl = env.first_visible_download(plane, t, payload_bits)
        if dl is None:
            return None, None, 0
        src_slot, t_recv = dl
        events = broadcast_schedule(
            K, [src_slot], [t_recv], payload_bits, env.isl
        )
        t_done = np.array(
            [events[s].t_receive + train_time_s for s in range(K)]
        )
        sink = env.naive_sink_slot(plane, float(t_done.max()))
        if sink is None:
            return None, None, 0
        t_ready = float(np.max(t_done + hops[sink] * t_hop))
        dec = env.plan_upload(Satellite(plane, sink), t_ready, payload_bits)
        if dec is None:
            return None, None, 0
        res = env.commit(dec)
        pending.append(PendingUpload(
            plane, Satellite(plane, sink), t_ready, payload_bits, dec, res
        ))
    # the release event: the earliest-starting queued upload aborts
    # and its reservation is released (fires the on_release hooks)
    victim = min(
        range(len(pending)),
        key=lambda i: (pending[i].decision.t_start, i),
    )
    env.release(pending[victim].reservation)
    survivors = [p for i, p in enumerate(pending) if i != victim]
    if not survivors:
        return None, None, 0        # single-plane round: nothing left
    repriced = 0
    if readmit:
        survivors, repriced = env.readmit(survivors, t, policy=policy)
    if completions is not None:
        completions.extend((p.key, p.decision.t_done) for p in survivors)
    done = [p.decision.t_done for p in survivors]
    return max(done), sum(done) / len(done), repriced
