"""Shared benchmark substrate: the paper's simulation setup at a
CPU-tractable scale (the simulated *clock* keeps Table I fidelity; only
the executed epoch count and proxy-model size are reduced).

Also the single mechanism for the repo's BENCH trajectory: every
benchmark appends its ``BENCH {json}`` records to the repo-root
``BENCH_topology.json`` via ``append_bench`` so the per-PR perf history
lives in one file (ROADMAP: "track BENCH JSON per PR").

The ``repro`` imports are lazy so scheduling-only benchmarks
(constellation/topology scaling) don't pay the JAX import.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, Optional

FAST = os.environ.get("BENCH_FAST", "0") == "1"

# the paper's deep CNN is a few M params; charge the comm model for a
# 4M-param fp32 model (z|N| = 128 Mbit) while training a small proxy.
PAYLOAD_BITS = int(4e6 * 32)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_TRAJECTORY = os.path.join(REPO_ROOT, "BENCH_topology.json")


def append_bench(rec: Dict, path: Optional[str] = None) -> None:
    """Print a ``BENCH {json}`` line and append it to the repo-root
    trajectory file (one JSON record per line).

    Tolerant of a corrupt/truncated final line (e.g. a benchmark killed
    mid-write): the partial line is newline-quarantined so the appended
    record always starts a fresh, parseable line.
    """
    line = json.dumps(rec)
    print("BENCH " + line)
    target = path or BENCH_TRAJECTORY
    prefix = ""
    try:
        with open(target, "rb") as f:
            f.seek(-1, os.SEEK_END)
            if f.read(1) not in (b"\n", b""):
                prefix = "\n"
    except (FileNotFoundError, OSError):
        pass                    # missing or empty file: nothing to fix
    with open(target, "a") as f:
        f.write(prefix + line + "\n")


def make_task(
    dataset: str = "mnist-like",
    noniid: bool = True,
    num_samples: int = 800 if FAST else 1600,
    sim_epochs: int = 4 if FAST else 8,
    seed: int = 0,
):
    from repro.core import FederatedTask, TrainHyperparams
    from repro.data import (
        make_classification_dataset,
        partition_iid,
        partition_noniid_by_orbit,
    )
    from repro.models.cnn import apply_cnn, init_cnn
    from repro.optim import get_optimizer

    ds = make_classification_dataset(dataset, num_samples=num_samples,
                                     seed=seed)
    test = make_classification_dataset(dataset, num_samples=400,
                                       seed=seed + 1000)
    if noniid:
        clients = partition_noniid_by_orbit(ds, 5, 8, seed=seed)
    else:
        clients = partition_iid(ds, 5, 8, seed=seed)
    shape = ds.x.shape[1:]
    hp = TrainHyperparams(local_epochs=100, learning_rate=0.05,
                          batch_size=16)
    return FederatedTask(
        init_fn=lambda r: init_cnn(r, shape, 10, widths=(8, 16), hidden=32),
        apply_fn=apply_cnn,
        clients=clients,
        test_set=test,
        optimizer=get_optimizer("sgd", 0.05),
        hp=hp,
        sim_epochs=sim_epochs,
        payload_bits_override=PAYLOAD_BITS,
    )


def timed(fn: Callable) -> tuple:
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6


def price_ring_round(
    walker, gs_list, predictor, sim, *,
    payload_bits: float = PAYLOAD_BITS,
    train_time_s: float = 600.0,
    ledger=None,
    handover: bool = False,
    t: float = 0.0,
):
    """Full FedLEO ring round time via the pure plane planners (no JAX
    training): every plane needs its own GS download and sink upload.
    With a ``ledger`` each chosen upload is booked so later planes are
    priced against residual station capacity (``ledger=None`` is the
    pre-ledger contention-free pricing); ``handover=True`` lets each
    upload split into station-handover segments.  None if any plane
    stalls."""
    import numpy as np

    from repro.core.fedleo import plan_plane_round
    from repro.core.scheduling import reserve_decision

    K = sim.constellation.sats_per_plane
    train = np.full(K, train_time_s)
    done = []
    for plane in range(sim.constellation.num_planes):
        plan = plan_plane_round(
            walker=walker, gs_list=gs_list, predictor=predictor,
            link=sim.link, isl=sim.isl, plane=plane, t=t,
            payload_bits=payload_bits, train_times=train, ledger=ledger,
            handover=handover,
        )
        if plan is None:
            return None            # a plane stalls the whole round
        reserve_decision(ledger, plan.decision)
        done.append(plan.decision.t_upload_done)
    return max(done)


def price_grid_round(
    walker, gs_list, predictor, sim, routing, *,
    cluster_planes: int,
    payload_bits: float = PAYLOAD_BITS,
    train_time_s: float = 600.0,
    ledger=None,
    dynamic: bool = False,
    handover: bool = False,
    t: float = 0.0,
):
    """Full FedLEOGrid round time via the pure cluster planners: one
    download + one sink upload per cluster.  ``dynamic=True`` re-forms
    clusters from predicted window supply (the strategy default) —
    discounted by the ledger's residual station capacity when one is
    given (formation feedback); ``False`` keeps the static
    adjacent-plane grouping.  Ledger and ``handover`` semantics as in
    ``price_ring_round``."""
    import numpy as np

    from repro.core.fedleo import (
        make_clusters,
        plan_cluster_round,
        supply_driven_clusters,
    )
    from repro.core.scheduling import reserve_decision

    K = sim.constellation.sats_per_plane
    L = sim.constellation.num_planes
    if dynamic:
        clusters = supply_driven_clusters(
            predictor, routing.topology, cluster_planes, t, ledger=ledger
        )
    else:
        clusters = make_clusters(L, cluster_planes)
    done = []
    for planes in clusters:
        train = np.full(len(planes) * K, train_time_s)
        plan = plan_cluster_round(
            walker=walker, gs_list=gs_list, predictor=predictor,
            link=sim.link, routing=routing, planes=planes, t=t,
            payload_bits=payload_bits, train_times=train, ledger=ledger,
            handover=handover,
        )
        if plan is None:
            return None
        reserve_decision(ledger, plan.decision)
        done.append(plan.decision.t_upload_done)
    return max(done)
