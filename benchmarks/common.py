"""Shared benchmark substrate: the paper's simulation setup at a
CPU-tractable scale (the simulated *clock* keeps Table I fidelity; only
the executed epoch count and proxy-model size are reduced)."""
from __future__ import annotations

import os
import time
from typing import Callable, Dict, Optional

from repro.core import FederatedTask, SimConfig, TrainHyperparams
from repro.data import (
    make_classification_dataset,
    partition_iid,
    partition_noniid_by_orbit,
)
from repro.models.cnn import apply_cnn, init_cnn
from repro.optim import get_optimizer

FAST = os.environ.get("BENCH_FAST", "0") == "1"

# the paper's deep CNN is a few M params; charge the comm model for a
# 4M-param fp32 model (z|N| = 128 Mbit) while training a small proxy.
PAYLOAD_BITS = int(4e6 * 32)


def make_task(
    dataset: str = "mnist-like",
    noniid: bool = True,
    num_samples: int = 800 if FAST else 1600,
    sim_epochs: int = 4 if FAST else 8,
    seed: int = 0,
) -> FederatedTask:
    ds = make_classification_dataset(dataset, num_samples=num_samples,
                                     seed=seed)
    test = make_classification_dataset(dataset, num_samples=400,
                                       seed=seed + 1000)
    if noniid:
        clients = partition_noniid_by_orbit(ds, 5, 8, seed=seed)
    else:
        clients = partition_iid(ds, 5, 8, seed=seed)
    shape = ds.x.shape[1:]
    hp = TrainHyperparams(local_epochs=100, learning_rate=0.05,
                          batch_size=16)
    return FederatedTask(
        init_fn=lambda r: init_cnn(r, shape, 10, widths=(8, 16), hidden=32),
        apply_fn=apply_cnn,
        clients=clients,
        test_set=test,
        optimizer=get_optimizer("sgd", 0.05),
        hp=hp,
        sim_epochs=sim_epochs,
        payload_bits_override=PAYLOAD_BITS,
    )


def timed(fn: Callable) -> tuple:
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6
