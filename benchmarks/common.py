"""Shared benchmark substrate: the paper's simulation setup at a
CPU-tractable scale (the simulated *clock* keeps Table I fidelity; only
the executed epoch count and proxy-model size are reduced).

Also the single mechanism for the repo's BENCH trajectory: every
benchmark appends its ``BENCH {json}`` records to the repo-root
``BENCH_topology.json`` via ``append_bench`` so the per-PR perf history
lives in one file (ROADMAP: "track BENCH JSON per PR").

The ``repro`` imports are lazy so scheduling-only benchmarks
(constellation/topology scaling) don't pay the JAX import.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, Optional

FAST = os.environ.get("BENCH_FAST", "0") == "1"

# the paper's deep CNN is a few M params; charge the comm model for a
# 4M-param fp32 model (z|N| = 128 Mbit) while training a small proxy.
PAYLOAD_BITS = int(4e6 * 32)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_TRAJECTORY = os.path.join(REPO_ROOT, "BENCH_topology.json")


def append_bench(rec: Dict, path: Optional[str] = None) -> None:
    """Print a ``BENCH {json}`` line and append it to the repo-root
    trajectory file (one JSON record per line)."""
    line = json.dumps(rec)
    print("BENCH " + line)
    with open(path or BENCH_TRAJECTORY, "a") as f:
        f.write(line + "\n")


def make_task(
    dataset: str = "mnist-like",
    noniid: bool = True,
    num_samples: int = 800 if FAST else 1600,
    sim_epochs: int = 4 if FAST else 8,
    seed: int = 0,
):
    from repro.core import FederatedTask, TrainHyperparams
    from repro.data import (
        make_classification_dataset,
        partition_iid,
        partition_noniid_by_orbit,
    )
    from repro.models.cnn import apply_cnn, init_cnn
    from repro.optim import get_optimizer

    ds = make_classification_dataset(dataset, num_samples=num_samples,
                                     seed=seed)
    test = make_classification_dataset(dataset, num_samples=400,
                                       seed=seed + 1000)
    if noniid:
        clients = partition_noniid_by_orbit(ds, 5, 8, seed=seed)
    else:
        clients = partition_iid(ds, 5, 8, seed=seed)
    shape = ds.x.shape[1:]
    hp = TrainHyperparams(local_epochs=100, learning_rate=0.05,
                          batch_size=16)
    return FederatedTask(
        init_fn=lambda r: init_cnn(r, shape, 10, widths=(8, 16), hidden=32),
        apply_fn=apply_cnn,
        clients=clients,
        test_set=test,
        optimizer=get_optimizer("sgd", 0.05),
        hp=hp,
        sim_epochs=sim_epochs,
        payload_bits_override=PAYLOAD_BITS,
    )


def timed(fn: Callable) -> tuple:
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6
