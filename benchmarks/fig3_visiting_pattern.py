"""Paper Fig. 3: the irregular visiting pattern of a 16-satellite
Walker-delta constellation (4 orbits x 4 sats) over 18 h against the
Rolla, MO ground station."""
from __future__ import annotations

import numpy as np

from repro.orbits import (
    ConstellationConfig,
    GroundStation,
    WalkerDelta,
    visibility_windows,
)


def run() -> dict:
    cfg = ConstellationConfig(num_planes=4, sats_per_plane=4)
    walker = WalkerDelta(cfg)
    gs = GroundStation()
    wins = visibility_windows(walker, gs, 0.0, 18 * 3600.0)

    by_sat = {}
    for w in wins:
        by_sat.setdefault((w.plane, w.slot), []).append(w)
    visits = [len(v) for v in by_sat.values()]
    durations = [w.duration for w in wins]
    gaps = []
    for sat_wins in by_sat.values():
        gaps += [b.t_start - a.t_end for a, b in zip(sat_wins, sat_wins[1:])]

    lines = ["sat,visit,t_start_h,t_end_h,duration_min"]
    for (p, s), sat_wins in sorted(by_sat.items()):
        for r, w in enumerate(sat_wins):
            lines.append(
                f"ID_{p + 1}_{s + 1},{r + 1},{w.t_start / 3600:.3f},"
                f"{w.t_end / 3600:.3f},{w.duration / 60:.2f}"
            )
    return {
        "num_windows": len(wins),
        "visits_min": int(np.min(visits)),
        "visits_max": int(np.max(visits)),
        "duration_mean_min": float(np.mean(durations) / 60),
        "duration_std_min": float(np.std(durations) / 60),
        "gap_cv": float(np.std(gaps) / np.mean(gaps)) if gaps else 0.0,
        "table": "\n".join(lines),
    }


if __name__ == "__main__":
    out = run()
    print(out["table"])
    print({k: v for k, v in out.items() if k != "table"})
