"""Multi-tenant scheduling benchmark: concurrent FL jobs on one
constellation vs running them serially (ISSUE 9 tentpole).

Three arms, one BENCH record:

  single-job equivalence (paper 5x8, real JAX training)
      One FedAvgStar job pushed through ``JobScheduler`` must
      reproduce the standalone ``FLStrategy.run`` EXACTLY — same round
      times, same metrics, bit for bit.  The scheduler's concurrency
      machinery (shared ledger, release floor, fairness metering) must
      be invisible when there is nothing to share.  Floor:
      ``single_job_equal``.

  Poisson arrivals vs serial (starlink-40x22, planner-level jobs)
      J tenants arrive by a seeded Poisson process, each running R
      FedLEOGrid cluster rounds with its own payload size, priority
      tier and fairness weight, under 1-RB-per-station scarcity (the
      regime where sharing matters).  The concurrent arm multiplexes
      them over ONE shared ``GSResourceLedger``; the serial baseline
      gives each job a private ledger but makes job i wait for job
      i-1 to finish — today's "one FL job owns the constellation"
      deployment.  Metrics: job throughput (rounds per simulated hour
      over the makespan) and p95 round-completion latency measured
      from job arrival.  Floor: concurrent p95 <= serial p95 —
      multiplexing idle RB windows must beat head-of-line blocking.

  repack floor (starlink-40x22, async re-admission)
      The ``price_async_round`` release scenario re-admitted with
      ``policy="monotone"`` vs ``policy="repack"``.  The swap
      re-packer accepts regret-reducing pairwise swaps ONLY when
      neither entry regresses its monotone completion, so per-entry
      ``t_done(repack) <= t_done(monotone)`` is a hard floor
      (``repack_max_regret_s <= 0``), and the round itself can only
      shrink.

Appends the record to ``BENCH_topology.json``; floors are gated in
``benchmarks.check_floors``.

  PYTHONPATH=src:. python -m benchmarks.multi_tenant [--quick]
"""
from __future__ import annotations

import argparse
from typing import Callable, List, Optional

import numpy as np

from benchmarks.common import (
    PAYLOAD_BITS,
    append_bench,
    make_comms_env,
    make_task,
    price_async_round,
    price_grid_round,
)

CONSTELLATION = "starlink-40x22"
GS_NAMES = ("rolla", "punta-arenas")
HORIZON_HOURS = 24.0
CLUSTER_PLANES = 4
TRAIN_TIME_S = 600.0

# per-tenant diversity: payload multipliers (model sizes), priority
# tiers and fairness weights cycle over arrival order
PAYLOAD_MULTIPLIERS = (0.5, 1.0, 2.0)
TIERS = (0, 0, 1)
WEIGHTS = (1.0, 2.0, 1.0)
ARRIVAL_MEAN_S = 1800.0
ARRIVAL_SEED = 9


class PlannerJob:
    """Planner-level tenant for the 40x22 arms: each ``run_round`` is
    one FedLEOGrid cluster round priced through the job's session
    (committing its bookings on the shared ledger).  Satisfies the
    ``repro.multitenant`` ``RoundRunner`` protocol without paying JAX
    training at 880 satellites."""

    def __init__(self, env, routing, *, payload_bits: float,
                 cluster_planes: int = CLUSTER_PLANES,
                 train_time_s: float = TRAIN_TIME_S):
        self.env = env
        self.routing = routing
        self.payload_bits = payload_bits
        self.cluster_planes = cluster_planes
        self.train_time_s = train_time_s
        self.release_floor_fn: Optional[Callable[[float], float]] = None

    def run_round(self, t: float, verbose: bool = False) -> Optional[float]:
        floor = t if self.release_floor_fn is None else self.release_floor_fn(t)
        self.env.release_before(floor)
        return price_grid_round(
            self.env, self.routing, cluster_planes=self.cluster_planes,
            payload_bits=self.payload_bits, train_time_s=self.train_time_s,
            t=t,
        )

    def finish(self, t: float) -> None:
        # planner rounds book-and-leave (uploads stay on the ledger as
        # spent capacity): no leak report, violations still attributed
        self.env.finish_session(t, check_leaks=False)


def _poisson_specs(num_jobs: int, rounds: int):
    """Seeded Poisson arrival plan: (arrival_s, payload_bits, tier,
    weight) per job, deterministic across runs."""
    rng = np.random.default_rng(ARRIVAL_SEED)
    arrivals = np.cumsum(rng.exponential(ARRIVAL_MEAN_S, size=num_jobs))
    plan = []
    for i, arr in enumerate(arrivals):
        plan.append({
            "arrival_s": float(arr),
            "payload_bits": PAYLOAD_BITS * PAYLOAD_MULTIPLIERS[
                i % len(PAYLOAD_MULTIPLIERS)],
            "tier": TIERS[i % len(TIERS)],
            "weight": WEIGHTS[i % len(WEIGHTS)],
            "rounds": rounds,
        })
    return plan


def _p95(latencies: List[float]) -> Optional[float]:
    if not latencies:
        return None
    return float(np.percentile(np.asarray(latencies), 95))


def bench_single_job_equivalence(quick: bool) -> dict:
    """Arm 1: scheduler-with-one-job vs standalone run, bit for bit."""
    from repro.core.baselines import FedAvgStar
    from repro.core.engine import SimConfig
    from repro.multitenant import JobScheduler, JobSpec

    rounds = 2 if quick else 3
    kwargs = dict(num_samples=200, sim_epochs=2) if quick else {}
    sim = SimConfig()

    standalone = FedAvgStar(make_task(**kwargs), sim)
    result = standalone.run(max_rounds=rounds)

    sched = JobScheduler(sim)
    runners: List[FedAvgStar] = []

    def factory(env):
        s = FedAvgStar(make_task(**kwargs), sim, env)
        runners.append(s)
        return s

    sched.submit(JobSpec(name="solo", rounds=rounds), factory)
    rec = sched.run()[0]

    h_a = result.history
    h_b = runners[0].history
    equal = (
        rec.status == "finished"
        and len(h_a) == len(h_b)
        and all(
            a.t_hours == b.t_hours
            and a.round_index == b.round_index
            and a.metrics == b.metrics
            for a, b in zip(h_a, h_b)
        )
    )
    return {
        "single_job_equal": bool(equal),
        "single_job_rounds": rec.rounds_done,
        "single_job_final_t_hours": round(result.final_time_hours, 6),
    }


def bench_poisson_vs_serial(quick: bool, sanitize: bool) -> dict:
    """Arm 2: J Poisson-arriving planner jobs, shared ledger vs serial
    head-of-line baseline."""
    from repro.comms.routing import (
        ISLPlan,
        get_routing_table,
        resolve_lazy_routing,
    )
    from repro.configs.constellations import make_sim_config
    from repro.multitenant import JobScheduler, JobSpec

    num_jobs = 3 if quick else 6
    rounds = 1 if quick else 2
    sim = make_sim_config(
        CONSTELLATION, ground_stations=GS_NAMES, topology="grid",
        horizon_hours=HORIZON_HOURS,
    )
    plan = ISLPlan(intra=sim.isl, inter=sim.isl_inter)
    lazy = resolve_lazy_routing(sim.constellation)
    specs = _poisson_specs(num_jobs, rounds)

    # one predictor for every arm; 1 RB per station (scarcity)
    base_env = make_comms_env(sim, capacity=1, sanitize=sanitize)

    def routing_for(payload_bits: float):
        return get_routing_table(
            sim.constellation, sim.topology, plan, payload_bits, lazy=lazy
        )

    # concurrent arm: one shared ledger, one session per job
    sched = JobScheduler(sim, base_env=base_env, sanitize=sanitize)
    for i, s in enumerate(specs):
        def factory(env, payload=s["payload_bits"]):
            return PlannerJob(env, routing_for(payload), payload_bits=payload)
        sched.submit(
            JobSpec(
                name=f"job{i}", arrival_s=s["arrival_s"],
                rounds=s["rounds"], tier=s["tier"], weight=s["weight"],
                payload_bits=s["payload_bits"],
            ),
            factory,
        )
    records = sched.run()
    conc_lat: List[float] = []
    for r in records:
        conc_lat.extend(r.round_latencies_s())
    conc_finished = [r for r in records if r.status == "finished"]
    conc_rounds = sum(r.rounds_done for r in records)
    conc_makespan = (
        max(r.finished_at_s for r in conc_finished)
        - min(r.arrival_s for r in records)
    ) if conc_finished else None

    # serial baseline: private ledger per job, job i waits for job i-1
    serial_lat: List[float] = []
    serial_rounds = 0
    t_free = 0.0
    horizon_s = HORIZON_HOURS * 3600.0
    for s in specs:
        env = make_comms_env(
            sim, predictor=base_env.predictor, walker=base_env.walker,
            capacity=1, sanitize=sanitize,
        )
        runner = PlannerJob(
            env, routing_for(s["payload_bits"]),
            payload_bits=s["payload_bits"],
        )
        t = max(s["arrival_s"], t_free)
        for _ in range(s["rounds"]):
            if t >= horizon_s:
                break
            t_done = runner.run_round(t)
            if t_done is None:
                break
            serial_lat.append(t_done - s["arrival_s"])
            serial_rounds += 1
            t = t_done
        runner.finish(t)
        t_free = t
    serial_makespan = (
        (t_free - specs[0]["arrival_s"]) if serial_rounds else None
    )

    def _rph(rounds_done: int, makespan: Optional[float]):
        if not makespan:
            return None
        return round(rounds_done / (makespan / 3600.0), 4)

    return {
        "jobs": num_jobs,
        "rounds_per_job": rounds,
        "concurrent_rounds": conc_rounds,
        "concurrent_finished": len(conc_finished),
        "concurrent_p95_s": _p95(conc_lat) and round(_p95(conc_lat), 1),
        "concurrent_makespan_s": conc_makespan and round(conc_makespan, 1),
        "concurrent_throughput_rph": _rph(conc_rounds, conc_makespan),
        "serial_rounds": serial_rounds,
        "serial_p95_s": _p95(serial_lat) and round(_p95(serial_lat), 1),
        "serial_makespan_s": serial_makespan and round(serial_makespan, 1),
        "serial_throughput_rph": _rph(serial_rounds, serial_makespan),
    }


def bench_repack_floor(sanitize: bool) -> dict:
    """Arm 3: async re-admission, monotone vs swap re-packer — the
    monotone result is the re-packer's per-entry floor."""
    from repro.configs.constellations import make_sim_config

    sim = make_sim_config(
        CONSTELLATION, ground_stations=GS_NAMES, topology="grid",
        horizon_hours=HORIZON_HOURS,
    )
    base_env = make_comms_env(sim, capacity=1, sanitize=sanitize)

    def arm(policy: str):
        env = make_comms_env(
            sim, predictor=base_env.predictor, walker=base_env.walker,
            capacity=1, sanitize=sanitize,
        )
        done: List = []
        t_round, t_mean, repriced = price_async_round(
            env, readmit=True, policy=policy, completions=done,
        )
        return t_round, t_mean, repriced, dict(done)

    mono_round, mono_mean, mono_repriced, mono = arm("monotone")
    rep_round, rep_mean, rep_repriced, rep = arm("repack")
    regrets = [
        rep[k] - mono[k] for k in mono if k in rep
    ] if mono and rep else []
    return {
        "async_monotone_s": mono_round and round(mono_round, 1),
        "async_monotone_mean_s": mono_mean and round(mono_mean, 1),
        "async_monotone_repriced": mono_repriced,
        "async_repack_s": rep_round and round(rep_round, 1),
        "async_repack_mean_s": rep_mean and round(rep_mean, 1),
        "async_repack_repriced": rep_repriced,
        "repack_max_regret_s": (
            round(max(regrets), 6) if regrets else None
        ),
    }


def run(quick: bool = False) -> dict:
    sanitize = quick           # smoke configuration checks the books
    row = {
        "bench": "multi_tenant",
        "constellation": CONSTELLATION,
        "ground_stations": list(GS_NAMES),
        "quick": bool(quick),
    }
    row.update(bench_poisson_vs_serial(quick, sanitize))
    row.update(bench_repack_floor(sanitize))
    row.update(bench_single_job_equivalence(quick))
    append_bench(row)
    return row


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer/smaller jobs, sanitizers on")
    args = ap.parse_args()
    run(quick=args.quick)


if __name__ == "__main__":
    main()
