"""Ablation: how much does the paper's SINK SCHEDULING (contribution 2)
buy on top of intra-plane propagation (contribution 1)?

Runs FedLEO twice on the same constellation/task:
  * sink_policy="scheduled"     — the paper's AW-feasible scheduler;
  * sink_policy="first_visitor" — propagation kept, scheduling ablated
    (next visitor becomes the sink; short windows force retries).

The scheduling win grows with payload size (bigger models need longer
windows); we report both the paper-CNN payload and a 10x payload.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import PAYLOAD_BITS, make_task
from repro.core import FedLEO, SimConfig


def run() -> List[Dict]:
    rows = []
    # 128 Mbit: t_c^D ~ 510 s vs windows 49-1060 s (marginal regime —
    # short windows are infeasible and the scheduler must skip them);
    # 1.5x tightens it further. (>= ~270 Mbit exceeds every window at
    # one RB: the link budget's hard feasibility cap.)
    for payload_scale, tag in [(1, "cnn_128Mbit"), (1.5, "192Mbit")]:
        for policy in ("scheduled", "first_visitor"):
            task = make_task()
            task._payload_bits = int(PAYLOAD_BITS * payload_scale)
            res = FedLEO(task, SimConfig(horizon_hours=72.0),
                         sink_policy=policy).run(max_rounds=3)
            waits = [
                p["t_wait_sink"]
                for h in res.history for p in h.events["planes"]
            ]
            rows.append({
                "payload": tag,
                "policy": policy,
                "rounds": len(res.history),
                "sim_hours": res.final_time_hours,
                "accuracy": res.final_accuracy,
                "mean_sink_wait_h": (
                    sum(waits) / len(waits) / 3600.0 if waits else None
                ),
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
