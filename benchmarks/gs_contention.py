"""Honest round times under per-station downlink RB contention.

The PR 2 topology benchmark priced every sink upload as if its ground
station were private — ``FedLEOGrid`` cluster sinks can land several
uploads on one station's window with zero resource-block competition,
overstating the grid speedup.  This benchmark re-prices a full FedLEO
round (download -> flood -> training -> relay -> sink upload) with the
shared ``GSResourceLedger``: each station has ``N`` downlink RBs
(Table I: 8) and every upload books one for its transfer, so later
sinks pay for residual capacity.

Ring (40 uploads/round) and +Grid (10 cluster uploads/round) are both
priced contention-free AND contended at starlink-40x22 with 1-3 ground
stations; the grid's fewer/larger uploads should keep it at or below
the ring under contention (acceptance floor).  Records append to the
repo-root ``BENCH_topology.json`` trajectory.

Each arm is priced through its own ``CommsEnvironment`` session (one
shared predictor per GS set, a fresh ledger per arm).

The ``handover`` arm re-prices the scarce (1-RB) rounds with
mid-window station handover (``gs_handover``): an upload may split
into segments across different stations' windows instead of waiting
for one station's free contiguous stretch.  Floor: handover round time
<= the no-handover round under 1-RB contention (with >= 2 stations;
with one station handover is the bit-identical degenerate case).

The ``heavy`` arm is the regime handover exists for (Razmi
2109.01348 / FedSpace 2202.01267): a 4x model (512 Mbit) takes longer
on one RB than ANY single 550 km pass, so the single-window planner
stalls the whole round (None) — segmented uploads across stations are
what make the round feasible at all.  Floor: with >= 2 stations the
heavy handover round completes.

The ``async`` arms price an AsyncFLEO-style round (naive sinks, upload
booked at schedule time in plane order) under 1-RB scarcity, then fire
a release event: the earliest-starting queued upload aborts and frees
its RB stretch.  ``async_scarce`` is the book-at-schedule-time
baseline (the freed capacity goes unused); ``async_readmit`` re-admits
the surviving queued uploads through the session's release hooks
(``CommsEnvironment.readmit``: per-entry monotone re-pricing — the
ROADMAP's ledger-aware async re-admission).  Floors: the re-admission
round completes no later than the baseline (guaranteed per-entry), and
the mean upload completion — the async freshness signal — improves.

Observability columns (repro.obs, ISSUE 7): the contended arms fold
their typed per-group phase decomposition means into the row
(``ring_contended_decomp``/``grid_contended_decomp``), the scarce
arms their per-station RB-utilization over the priced round
(``ring_scarce_rb_util``/``grid_scarce_rb_util``), and a dedicated
overhead pass re-prices the contended ring+grid round untraced then
traced on fresh unsanitized sessions (min of 3 repeats each) to record
``trace_overhead_fraction`` — floored at <= 5% by
``benchmarks.check_floors``.

Usage: PYTHONPATH=src python -m benchmarks.gs_contention [--quick]
"""
from __future__ import annotations

import argparse
import time
from typing import List

from benchmarks.common import (
    PAYLOAD_BITS,
    append_bench,
    make_comms_env,
    overhead_fraction,
    price_async_round,
    price_grid_round,
    price_ring_round,
)
from repro.comms.routing import ISLPlan, get_routing_table
from repro.configs.constellations import make_sim_config
from repro.obs import ledger_rb_utilization, mean_phase_seconds

CONSTELLATION = "starlink-40x22"
GS_SETS = (("rolla",), ("rolla", "punta-arenas"),
           ("rolla", "punta-arenas", "awarua"))
HORIZON_HOURS = 24.0
CLUSTER_PLANES = 4
TRAIN_TIME_S = 600.0
HEAVY_FACTOR = 4        # 4x model: one upload outlasts any single pass


def run(gs_sets=GS_SETS, sanitize: bool = False) -> List[dict]:
    rows = []
    routing = None
    for gs_names in gs_sets:
        sim = make_sim_config(
            CONSTELLATION, ground_stations=gs_names, topology="grid",
            horizon_hours=HORIZON_HOURS,
        )
        # one predictor per GS set, one session per arm (fresh ledger)
        base_env = make_comms_env(sim)
        arms_made = []

        def arm(capacity, handover=False):
            env = make_comms_env(
                sim, predictor=base_env.predictor, walker=base_env.walker,
                capacity=capacity, handover=handover, sanitize=sanitize,
            )
            arms_made.append(env)
            return env

        if routing is None:
            routing = get_routing_table(
                sim.constellation, sim.topology,
                ISLPlan(intra=sim.isl, inter=sim.isl_inter), PAYLOAD_BITS,
            )

        t0 = time.perf_counter()
        out = {}
        modes = (
            ("free", None, False),                      # pre-ledger pricing
            # Table I: N RBs
            ("contended", sim.link.num_resource_blocks, False),
            ("scarce", 1, False),                       # one RB per station
            ("handover", 1, True),                      # 1 RB + segmentation
        )
        # typed per-group decomposition of the contended arms and the
        # scarce arms' ledgers (for RB utilization) ride along — both
        # are pure reads on the priced plans/bookings
        decomp_groups = {"ring": [], "grid": []}
        scarce_envs = {}
        for label, capacity, handover in modes:
            ring_env = arm(capacity, handover)
            out[f"ring_{label}"] = price_ring_round(
                ring_env, train_time_s=TRAIN_TIME_S,
                groups=(decomp_groups["ring"] if label == "contended"
                        else None),
            )
            grid_env = arm(capacity, handover)
            out[f"grid_{label}"] = price_grid_round(
                grid_env, routing,
                cluster_planes=CLUSTER_PLANES,
                train_time_s=TRAIN_TIME_S, dynamic=True,
                groups=(decomp_groups["grid"] if label == "contended"
                        else None),
            )
            if label == "scarce":
                scarce_envs = {"ring": ring_env, "grid": grid_env}
        heavy = HEAVY_FACTOR * PAYLOAD_BITS
        for label, handover in (("heavy", False), ("heavy_handover", True)):
            out[f"ring_{label}"] = price_ring_round(
                arm(1, handover), payload_bits=heavy,
                train_time_s=TRAIN_TIME_S,
            )
            out[f"grid_{label}"] = price_grid_round(
                arm(1, handover), routing,
                cluster_planes=CLUSTER_PLANES, payload_bits=heavy,
                train_time_s=TRAIN_TIME_S, dynamic=True,
            )
        # async re-admission arms: book-at-schedule-time vs event-driven
        # re-admission, both under 1-RB scarcity
        out["async_scarce"], out["async_scarce_mean"], _ = price_async_round(
            arm(1), train_time_s=TRAIN_TIME_S, readmit=False,
        )
        (out["async_readmit"], out["async_readmit_mean"],
         out["async_repriced"]) = price_async_round(
            arm(1), train_time_s=TRAIN_TIME_S, readmit=True,
        )
        wall = time.perf_counter() - t0
        # per-station RB utilization of the scarce round, read off the
        # arm's ledger occupancy over [0, round end] before close-out
        for kind in ("ring", "grid"):
            t_end = out[f"{kind}_scarce"]
            out[f"{kind}_scarce_rb_util"] = (
                None if t_end is None else [
                    round(u, 4) for u in ledger_rb_utilization(
                        scarce_envs[kind].ledger, 0.0, t_end,
                    )
                ]
            )
        # sanitized smokes: every arm's commits were invariant-checked
        # live (strict mode raises on violation); the pricing functions
        # never release their bookings, so run only the per-commit
        # accounting close-out — no leak report on an open-ended arm
        for env in arms_made:
            env.finish_session(float("inf"), check_leaks=False)

        # tracing-overhead pass: re-price the contended ring+grid round
        # on fresh UNSANITIZED sessions so the sanitizer never pads the
        # denominator.  A single pricing pass is only ~0.1 s of wall —
        # far below this host's timer jitter — so each timed sample
        # amortizes ITERS_PER_SAMPLE full passes.  The estimate itself
        # is ``overhead_fraction``'s median-of-k interleaved samples,
        # clamped at >= 0 (min-of-k walls once recorded a *negative*
        # fraction — traced "faster" than plain — which is pure noise
        # and gates nothing).  A traced session attaches to the shared
        # predictor — detached before the next sample's envs are built.
        ITERS_PER_SAMPLE = 3

        def overhead_pass(trace: bool) -> None:
            for _ in range(ITERS_PER_SAMPLE):
                envs = [
                    make_comms_env(
                        sim, predictor=base_env.predictor,
                        walker=base_env.walker,
                        capacity=sim.link.num_resource_blocks,
                        trace=trace,
                    )
                    for _ in range(2)
                ]
                price_ring_round(envs[0], train_time_s=TRAIN_TIME_S)
                price_grid_round(
                    envs[1], routing, cluster_planes=CLUSTER_PLANES,
                    train_time_s=TRAIN_TIME_S, dynamic=True,
                )
                for env in envs:
                    if trace:
                        env.recorder.detach()
                    env.finish_session(float("inf"), check_leaks=False)

        overhead_pass(trace=False)      # warmup pair
        overhead_pass(trace=True)
        trace_overhead, plain_us, traced_us = overhead_fraction(
            lambda: overhead_pass(trace=False),
            lambda: overhead_pass(trace=True),
            samples=5,
        )
        plan_wall_plain = plain_us / 1e6
        plan_wall_traced = traced_us / 1e6

        def _r(x):
            return None if x is None else round(x, 1)

        def _rdecomp(groups):
            return {k: round(v, 1)
                    for k, v in mean_phase_seconds(groups).items()}

        ring_c, grid_c = out["ring_contended"], out["grid_contended"]
        rows.append({
            "bench": "gs_contention",
            "constellation": CONSTELLATION,
            "ground_stations": list(gs_names),
            "cluster_planes": CLUSTER_PLANES,
            "rb_capacity": sim.link.num_resource_blocks,
            "train_time_s": TRAIN_TIME_S,
            "ring_free_s": _r(out["ring_free"]),
            "ring_contended_s": _r(ring_c),
            "ring_scarce_s": _r(out["ring_scarce"]),
            "grid_free_s": _r(out["grid_free"]),
            "grid_contended_s": _r(grid_c),
            "grid_scarce_s": _r(out["grid_scarce"]),
            "ring_handover_s": _r(out["ring_handover"]),
            "grid_handover_s": _r(out["grid_handover"]),
            "heavy_factor": HEAVY_FACTOR,
            "ring_heavy_s": _r(out["ring_heavy"]),
            "grid_heavy_s": _r(out["grid_heavy"]),
            "ring_heavy_handover_s": _r(out["ring_heavy_handover"]),
            "grid_heavy_handover_s": _r(out["grid_heavy_handover"]),
            "async_scarce_s": _r(out["async_scarce"]),
            "async_readmit_s": _r(out["async_readmit"]),
            "async_scarce_mean_s": _r(out["async_scarce_mean"]),
            "async_readmit_mean_s": _r(out["async_readmit_mean"]),
            "async_repriced": out["async_repriced"],
            "speedup_contended": (
                None if ring_c is None or not grid_c
                else round(ring_c / grid_c, 2)
            ),
            "ring_contention_penalty_s": (
                None if ring_c is None or out["ring_free"] is None
                else _r(ring_c - out["ring_free"])
            ),
            "grid_contention_penalty_s": (
                None if grid_c is None or out["grid_free"] is None
                else _r(grid_c - out["grid_free"])
            ),
            "ring_handover_gain_s": (
                None if out["ring_handover"] is None
                or out["ring_scarce"] is None
                else _r(out["ring_scarce"] - out["ring_handover"])
            ),
            "grid_handover_gain_s": (
                None if out["grid_handover"] is None
                or out["grid_scarce"] is None
                else _r(out["grid_scarce"] - out["grid_handover"])
            ),
            "async_readmit_gain_s": (
                None if out["async_readmit"] is None
                or out["async_scarce"] is None
                else _r(out["async_scarce"] - out["async_readmit"])
            ),
            "ring_contended_decomp": _rdecomp(decomp_groups["ring"]),
            "grid_contended_decomp": _rdecomp(decomp_groups["grid"]),
            "ring_scarce_rb_util": out["ring_scarce_rb_util"],
            "grid_scarce_rb_util": out["grid_scarce_rb_util"],
            "plan_wall_s": round(wall, 3),
            "plan_wall_plain_s": round(plan_wall_plain, 4),
            "plan_wall_traced_s": round(plan_wall_traced, 4),
            "trace_overhead_fraction": round(trace_overhead, 4),
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="one ground-station set (CI smoke) — the 2-GS "
                         "set, so the handover arms are meaningful; "
                         "runs with the schedule sanitizer attached")
    args = ap.parse_args()
    # --quick is the CI smoke: price it sanitized (strict — a single
    # invariant violation aborts the run).  Timed full runs stay
    # unsanitized so the BENCH trajectory's wall numbers are clean.
    rows = run(GS_SETS[1:2] if args.quick else GS_SETS,
               sanitize=args.quick)
    for rec in rows:
        append_bench(rec)
    ok = all(
        r["grid_contended_s"] is not None
        and (r["ring_contended_s"] is None
             or r["grid_contended_s"] <= r["ring_contended_s"])
        for r in rows
    )
    # floor: mid-window station handover never worsens a 1-RB round
    ok_handover = all(
        r[f"{kind}_handover_s"] is not None
        and (r[f"{kind}_scarce_s"] is None
             or r[f"{kind}_handover_s"] <= r[f"{kind}_scarce_s"])
        for r in rows for kind in ("ring", "grid")
    )
    # floor: the heavy upload fits NO single pass (the no-handover
    # round stalls) yet completes through segmented handover whenever
    # >= 2 stations are available — both halves of the claim
    ok_heavy = all(
        r[f"{kind}_heavy_s"] is None
        and r[f"{kind}_heavy_handover_s"] is not None
        for r in rows if len(r["ground_stations"]) >= 2
        for kind in ("ring", "grid")
    )
    # floor: event-driven re-admission never worsens the async round,
    # nor the mean upload completion (the async freshness signal)
    ok_async = all(
        r["async_readmit_s"] is not None
        and (r["async_scarce_s"] is None
             or (r["async_readmit_s"] <= r["async_scarce_s"]
                 and r["async_readmit_mean_s"] <= r["async_scarce_mean_s"]))
        for r in rows
    )
    for r in rows:
        print(
            f"# {len(r['ground_stations'])} GS @ {r['rb_capacity']} RB: "
            f"ring {r['ring_free_s']}s -> {r['ring_contended_s']}s "
            f"(1 RB: {r['ring_scarce_s']}s, "
            f"+handover: {r['ring_handover_s']}s) | "
            f"grid {r['grid_free_s']}s -> {r['grid_contended_s']}s "
            f"(1 RB: {r['grid_scarce_s']}s, "
            f"+handover: {r['grid_handover_s']}s; "
            f"contended speedup {r['speedup_contended']}x) | "
            f"{r['heavy_factor']}x payload: ring {r['ring_heavy_s']} -> "
            f"{r['ring_heavy_handover_s']}s, grid {r['grid_heavy_s']} -> "
            f"{r['grid_heavy_handover_s']}s | "
            f"async 1 RB round {r['async_scarce_s']}s -> "
            f"{r['async_readmit_s']}s, mean "
            f"{r['async_scarce_mean_s']}s -> {r['async_readmit_mean_s']}s "
            f"({r['async_repriced']} re-priced) | "
            f"trace overhead {r['trace_overhead_fraction'] * 100:+.1f}% "
            f"({r['plan_wall_plain_s']}s -> {r['plan_wall_traced_s']}s)"
        )
    print(f"# grid <= ring under contention — "
          f"{'OK' if ok else 'REGRESSION'}")
    print(f"# handover <= no-handover under 1-RB contention — "
          f"{'OK' if ok_handover else 'REGRESSION'}")
    print(f"# heavy upload feasible only via handover (>=2 GS) — "
          f"{'OK' if ok_heavy else 'REGRESSION'}")
    print(f"# async re-admission <= book-at-schedule under 1-RB — "
          f"{'OK' if ok_async else 'REGRESSION'}")
    if not (ok and ok_handover and ok_heavy and ok_async):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
