"""Distributed sink-satellite scheduling (paper §IV-B).

Every satellite runs ``select_sink`` over the *same* deterministic inputs
(constellation config, GS position, training-completion times, link
parameters) and therefore reaches the same decision without any message
exchange — this is what makes the scheduler distributed.

Selection rule (eqs. 21-22): among candidate sinks C_l on orbit l, pick
the satellite minimizing the orbit's completion time

  T*_sum = t_c^U + t_c^D + t*_wait + t_train(K_l) + t*_h           (22)

subject to the access-window feasibility constraint

  AW(c_opt, GS) >= (time needed to exchange models with the GS),

i.e. the sink's upcoming visibility window must be long enough for the
partial-global-model upload (and next-round download).  Ties (several
candidates with equal completion) resolve to the earliest visitor,
matching "selects the one that will visit the GS the first".

Both schedulers accept a single ``GroundStation`` or a sequence
(multi-GS union semantics: a window against ANY station qualifies, and
the slant range is computed against the window's own station).  Slant
ranges are evaluated in batch — one ``walker.positions_batch`` call per
resolution round covering every candidate of the plane — instead of the
seed's per-candidate-per-window scalar ``position_of`` calls.

Contention (``GSResourceLedger``): every transfer-planning entry point
takes an optional ``ledger`` carrying the per-station resource-block
timeline.  A candidate window is then priced against the *residual*
station capacity — the effective start is pushed past saturated
stretches (``ledger.earliest_fit``) and a window with no free RB room
left is skipped entirely.  The planners only *read* the ledger; the
caller books the chosen transfer with ``reserve_decision`` (or
``ledger.reserve``) so subsequent decisions see it.  ``ledger=None``
(and unlimited capacity) is the degenerate contention-free case,
bit-identical to the pre-ledger planner.

Rolling horizon: when the predictor was built with ``rolling=True``
and a satellite set has NO feasible window inside the built horizon,
the planners extend the horizon chunk-by-chunk and retry instead of
returning None (up to the predictor's ``max_horizon_s``).

Mid-window station handover (``SimConfig.gs_handover``): a sink upload
no longer has to sit on one station for its whole transfer —
``plan_segmented_transfer`` assembles it from capacity-priced legs
across different stations' windows (Razmi et al. 2109.01348 / FedSpace
2202.01267 exploit exactly this overlap), and every upload-pricing
entry point races the segmented plan against the single-window fit.
Consecutive legs must switch stations, and a segmented plan is adopted
only when it strictly beats the single-window completion — so
handover-off, single-GS, and never-splitting runs stay bit-identical
to the unsegmented scheduler.

Session API: the canonical owner of the (predictor, ledger, handover)
state is now ``repro.comms.environment.CommsEnvironment`` — strategies
hold ONE session and plan through its typed methods (``plan_upload``,
``select_sink``, ``commit``/``release``).  The public free functions
below (``earliest_transfer``, ``select_sink``, ``select_sink_cluster``,
``naive_sink_slot``, ``first_visible_download[_sats]``) remain as thin
shims that build an ephemeral session from their explicit arguments
and delegate, so legacy callers and the environment agree bit-for-bit
(golden-tested in ``tests/test_comms_environment.py``).  The private
``*_impl`` functions hold the actual machinery both surfaces share.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.comms.isl import ISLConfig, isl_hop_time
from repro.comms.ledger import GSResourceLedger
from repro.comms.link import (
    LinkConfig,
    downlink_time,
    model_exchange_time,
    propagation_time,
    shannon_rate,
    uplink_time,
)
from repro.core.propagation import ring_hops_matrix
from repro.orbits.constellation import GroundStation, Satellite, WalkerDelta
from repro.orbits.prediction import (
    GroundStations,
    VisibilityPredictor,
)
from repro.orbits.visibility import VisibilityWindow

# (gs_index, slant_range_m) -> (need_s, done_s): the window-feasibility
# requirement and the completion offset of one candidate transfer
TransferTime = Callable[[int, float], Tuple[float, float]]
SkipWindow = Optional[Callable[[VisibilityWindow], bool]]


@dataclasses.dataclass(frozen=True)
class SinkDecision:
    plane: int
    sink_slot: int
    window: VisibilityWindow    # single-window upload, or the first leg's
    t_models_at_sink: float     # all trained models collected (eq. 21)
    t_upload_start: float       # max(window start, models ready)
    t_upload_done: float        # + t_c^D
    t_wait: float               # t*_wait
    candidates_considered: int
    # mid-window station handover: the upload's legs when it was split
    # across stations (empty = the classic single-window transfer)
    segments: Tuple["TransferSegment", ...] = ()
    payload_bits: Optional[float] = None


def _distance_at(
    walker: WalkerDelta, gs: GroundStation, sat: Satellite, t: float
) -> float:
    r_s = walker.position_of(sat, t)
    r_g = gs.eci(np.asarray(t))
    return float(np.linalg.norm(r_s - r_g))


def _slant_ranges(
    walker: WalkerDelta,
    gss: Sequence[GroundStation],
    gs_idx: np.ndarray,
    planes: np.ndarray,
    slots: np.ndarray,
    times: np.ndarray,
) -> np.ndarray:
    """|r_sat - r_gs| for a batch of (window-gs, plane, slot, time)."""
    times = np.asarray(times, dtype=np.float64)
    r_s = walker.positions_batch(planes, slots, times)     # (C, 3)
    r_g = np.empty_like(r_s)
    for gi in np.unique(np.asarray(gs_idx)):
        m = np.asarray(gs_idx) == gi
        r_g[m] = gss[int(gi)].eci(times[m])
    return np.linalg.norm(r_s - r_g, axis=-1)


def _ledger_fit(
    ledger: Optional[GSResourceLedger],
    gs_index: int,
    t0: float,
    window_end: float,
    need: float,
    occupy: float,
) -> Optional[float]:
    """Effective transfer start inside one window: ``t0`` when the
    window's remaining duration covers ``need`` and no ledger is in
    play; otherwise the earliest start with a free RB for the
    ``occupy``-long transmission (still leaving ``need`` of window)."""
    if window_end - t0 < need:
        return None
    if ledger is None:
        return t0
    return ledger.earliest_fit(gs_index, t0, window_end - need, occupy)


def _repriced_fit(
    ledger: Optional[GSResourceLedger],
    walker: WalkerDelta,
    gs: GroundStation,
    sat: Satellite,
    gs_index: int,
    t0: float,
    window_end: float,
    transfer_time: TransferTime,
    need: float,
    done: float,
    max_iters: int = 8,
) -> Tuple[Optional[float], float]:
    """(effective start, completion offset) of one window's transfer.

    The contention-free path prices the transfer once, at the
    window-feasible start ``t0`` (the planner's one-evaluation
    convention).  When the ledger pushes the start later, the slant
    range — and with it the transfer duration — changes, so the
    duration is re-evaluated at the pushed start and the fit re-run
    until it stabilizes (starts move monotonically later, so the loop
    terminates; bounded as a guard).  Without this, a push toward the
    window edge could book a duration computed at a nearer slant range
    and physically overrun the window.
    """
    t_fit = _ledger_fit(ledger, gs_index, t0, window_end, need, done)
    if t_fit is None or t_fit == t0:
        return t_fit, done
    for _ in range(max_iters):
        d = _distance_at(walker, gs, sat, t_fit)
        need, done = transfer_time(gs_index, d)
        if window_end - t_fit < need:
            return None, done       # true duration no longer fits
        nxt = ledger.earliest_fit(
            gs_index, t_fit, window_end - need, done
        )
        if nxt is None or nxt == t_fit:
            return nxt, done
        t_fit = nxt
    return t_fit, done


# --- segmented (handover) transfer planning -----------------------------------
@dataclasses.dataclass(frozen=True)
class TransferSegment:
    """One leg of a segmented sink upload: ``bits`` payload bits
    delivered to station ``gs_index`` over ``[t_start, t_end)`` (one
    RB booked for exactly that span), inside the leg's access window
    ``[window_start, window_end]``."""

    gs_index: int
    t_start: float
    t_end: float
    bits: float
    window_start: float
    window_end: float


@dataclasses.dataclass(frozen=True)
class SegmentedPlan:
    """A sink upload split across station handovers: consecutive legs
    always land on *different* stations (resuming the same station's
    next pass is a retry, not a handover), and the payload bits are
    conserved across legs."""

    segments: Tuple[TransferSegment, ...]

    @property
    def t_start(self) -> float:
        return self.segments[0].t_start

    @property
    def t_done(self) -> float:
        return self.segments[-1].t_end

    @property
    def total_bits(self) -> float:
        return float(sum(s.bits for s in self.segments))

    @property
    def stations(self) -> Tuple[int, ...]:
        return tuple(s.gs_index for s in self.segments)


@dataclasses.dataclass(frozen=True)
class HandoverSpec:
    """What the segmented planner needs to price a sink upload (one RB
    of ``link``, eq. 16) when mid-window station handover is enabled
    (``SimConfig.gs_handover``)."""

    link: LinkConfig
    payload_bits: float
    require_next_download: bool = False


def plan_segmented_transfer(
    *,
    walker: WalkerDelta,
    predictor: VisibilityPredictor,
    sat: Satellite,
    t_ready: float,
    link: LinkConfig,
    payload_bits: float,
    ledger: Optional[GSResourceLedger] = None,
    require_next_download: bool = False,
    skip_window: SkipWindow = None,
    max_segments: int = 16,
) -> Optional[SegmentedPlan]:
    """Greedy segmented (handover) plan for one sink upload.

    Instead of pinning the transfer to a single station for its whole
    duration, the upload is assembled from capacity-priced legs across
    *all* stations' windows: each leg transmits from the earliest free
    RB stretch still open (ledger residual capacity, window bounds),
    at the Shannon rate of its own slant range (re-priced per leg, at
    the leg start), paying the per-leg link overhead (propagation +
    processing — a handover re-acquires the link).  A leg that cannot
    finish the payload transmits until its stretch closes and hands
    the remainder over; consecutive legs must switch stations, so with
    a single ground station no multi-leg plan exists and the planner
    degenerates to the single-window transfer (bit-identical
    handover-off behavior).

    Ledger semantics match the single-window planner: the plan only
    *reads* residual capacity; the caller books every leg
    (``reserve_decision``).  ``require_next_download`` demands the
    final leg's window leave room for the next global-model download
    after the upload completes (eq. 22's exchange feasibility).

    Rolling horizon: a plan that ran dry inside the built table, or
    whose legs used a window still clipped at the built boundary (a
    segment straddling the horizon edge), extends the predictor and
    replans rather than silently truncating
    (``VisibilityPredictor.retry_extending``).

    Returns None when no complete plan exists within the horizon (the
    caller falls back to the single-window transfer).
    """
    gss = predictor.ground_stations

    def free_runs(
        gi: int, lo: float, hi: float
    ) -> Tuple[Tuple[float, float], ...]:
        if hi <= lo:
            return ()
        if ledger is None:
            return ((lo, hi),)
        a, b = ledger.free_runs(gi, lo, hi)
        return tuple(zip(a, b))

    def attempt() -> Tuple[Optional[SegmentedPlan], bool]:
        rec = predictor.sat_arrays(sat.plane, sat.slot)
        if rec is None:
            return None, True               # nothing built for this sat yet
        built_end = predictor.built_end if predictor.rolling else np.inf
        starts, ends, gs_idx = rec["starts"], rec["ends"], rec["gs_index"]

        def candidate(
            t: float, last_gs: Optional[int], excl: set
        ) -> Optional[
            Tuple[float, float, float, float, int, int, float, float, float]
        ]:
            """Earliest usable free stretch over all windows after t:
            (fa, fb, ws, we, gi, j, d, t_over, rate), ties resolved to
            the faster station then window order.

            Slant ranges are evaluated in batched rounds — one
            ``_slant_ranges`` call covering every still-active window's
            current free stretch, exactly as ``_resolve_first_fits``
            does — instead of a scalar ``_distance_at`` sweep per
            (window, stretch).  A window whose stretch is too short to
            deliver any bits advances to its next free stretch in the
            following round; a window whose stretch starts after the
            best key so far can never win (its later stretches start
            later still) and drops out.  The winner is the same minimum
            the scalar scan found: windows are start-ordered and the
            key orders on the stretch start first, so evaluating the
            full candidate set changes nothing but the wall time.
            """
            runs = {}                       # window -> its free stretches
            for j in range(starts.size):
                ws, we = float(starts[j]), float(ends[j])
                gi = int(gs_idx[j])
                if we <= t or j in excl:
                    continue
                if last_gs is not None and gi == last_gs:
                    continue                # a handover must switch stations
                if skip_window is not None and skip_window(
                    VisibilityWindow(sat.plane, sat.slot, ws, we, gi)
                ):
                    continue
                fr = free_runs(gi, max(ws, t), we)
                if fr:
                    runs[j] = fr
            best, best_key = None, None
            ptr = {j: 0 for j in runs}
            active = list(runs)             # ascending window order
            while active:
                fas = np.array([runs[j][ptr[j]][0] for j in active])
                gis = np.array([int(gs_idx[j]) for j in active])
                dists = _slant_ranges(
                    walker, gss, gis,
                    np.full(len(active), sat.plane),
                    np.full(len(active), sat.slot), fas,
                )
                nxt = []
                for j, fa, d in zip(active, fas, dists):
                    fa, d = float(fa), float(d)
                    if best_key is not None and fa > best_key[0]:
                        continue            # cannot beat the best stretch
                    fb = runs[j][ptr[j]][1]
                    gi = int(gs_idx[j])
                    t_over = propagation_time(d) + link.processing_delay_s
                    if fb - fa <= t_over:
                        ptr[j] += 1         # too short to deliver any bits
                        if ptr[j] < len(runs[j]):
                            nxt.append(j)   # later stretches start later
                        continue
                    rate = shannon_rate(link, d, link.rb_bandwidth_hz)
                    key = (fa, -rate, gi, j)
                    if best_key is None or key < best_key:
                        best_key, best = key, (
                            fa, fb, float(starts[j]), float(ends[j]),
                            gi, j, d, t_over, rate,
                        )
                active = nxt
            return best

        segments = []
        bits_rem = float(payload_bits)
        t = float(t_ready)
        boundary = False
        while bits_rem > 0 and len(segments) < max_segments:
            excl: set = set()
            last_gs = segments[-1].gs_index if segments else None
            while True:
                best = candidate(t, last_gs, excl)
                if best is None:
                    return None, True       # ran dry: a longer table may help
                fa, fb, ws, we, gi, j, d, t_over, rate = best
                t_done = fa + model_exchange_time(
                    link, bits_rem, d, link.rb_bandwidth_hz
                )
                if t_done <= fb:
                    if (
                        require_next_download
                        and t_done + uplink_time(link, payload_bits, d) > we
                    ):
                        # the payload would finish here but the window
                        # cannot also host the next download: the final
                        # leg must land elsewhere.  A boundary-clipped
                        # window may only LOOK too short — its true end
                        # lies in the next chunk, so the rejection must
                        # force an extension retry (like
                        # _resolve_first_fits' clipped_reject)
                        if we >= built_end:
                            boundary = True
                        excl.add(j)
                        continue
                    segments.append(TransferSegment(gi, fa, t_done, bits_rem,
                                                    ws, we))
                    bits_rem = 0.0
                else:
                    bits = (fb - fa - t_over) * rate
                    segments.append(TransferSegment(gi, fa, fb, bits, ws, we))
                    bits_rem -= bits
                    t = fb
                if we >= built_end:
                    boundary = True         # leg used a boundary-clipped window
                break
        if bits_rem > 0:
            # leg-count cap: more horizon cannot reduce the leg count
            # unless a clipped window truncated a leg
            return None, boundary
        return SegmentedPlan(tuple(segments)), boundary

    return predictor.retry_extending(attempt)


def _better_segmented(
    seg: Optional[SegmentedPlan],
    base_done: Optional[float],
) -> bool:
    """Adopt a segmented plan only when it is a TRUE handover (>= 2
    legs — single-leg plans are by construction never better than the
    single-window search) that strictly beats the single-window
    completion.  Keeps handover-off, single-GS, and contention-free
    runs bit-identical to the unsegmented planner."""
    if seg is None or len(seg.segments) < 2:
        return False
    return base_done is None or seg.t_done < base_done - 1e-9


def _first_fit_transfers(
    *,
    walker: WalkerDelta,
    predictor: VisibilityPredictor,
    sats: Sequence[Tuple[int, int]],
    t_ready: np.ndarray,
    transfer_time: TransferTime,
    ledger: Optional[GSResourceLedger] = None,
    handover: Optional[HandoverSpec] = None,
) -> List[Optional[Tuple]]:
    """Per satellite of ``sats`` (arbitrary (plane, slot) pairs — one
    plane's slots, or a whole cluster of planes): (t0, t0 + done_s,
    window_index) of the earliest-completing window after t_ready[i]
    that covers need_s, or None.

    ``need_s`` is the window-feasibility requirement, ``done_s`` the
    offset of the reported completion — they differ when a window must
    also leave room for a follow-up transfer (eq. 22's next-round
    download) that does not delay the completion itself.  With a
    ``ledger``, the start may additionally be pushed past saturated
    stretches of the window's station (residual-capacity pricing), and
    a pushed transfer is re-priced at its actual start
    (``_repriced_fit`` — the slant range moved with the delay).

    With a ``handover`` spec the single-window fits are additionally
    raced against segmented (station-handover) plans
    (``plan_segmented_transfer``) per satellite, and entries become
    4-tuples ``(t0, t_done, VisibilityWindow, segments)`` — the window
    of the first leg, and the leg tuple (empty when the single-window
    transfer won).  A satellite with NO single window long enough may
    still get a segmented plan — that is the infeasible-upload case
    handover rescues.

    When the predictor is rolling-horizon, the horizon is extended
    chunk-by-chunk and resolution retried whenever (a) NO satellite of
    the set has a feasible window, or (b) a window still *clipped at
    the built boundary* was rejected — its true end lies in the next
    chunk, so the rejection cannot be trusted.  Accepted fits are safe
    as-is (a longer window end changes neither the start nor the
    completion), which keeps rolling schedules identical to schedules
    against a prebuilt table.
    """
    sats = list(sats)

    def attempt() -> Tuple[List[Optional[Tuple]], bool]:
        out, clipped_reject = _resolve_first_fits(
            walker=walker, predictor=predictor, sats=sats,
            t_ready=t_ready, transfer_time=transfer_time, ledger=ledger,
        )
        return out, clipped_reject or (sats and all(o is None for o in out))

    out = predictor.retry_extending(attempt)
    if handover is None:
        return out
    # segmented planning may grow a rolling horizon; the single-window
    # fits must then be re-resolved against the SAME (grown) table or
    # a candidate whose only window lay past the old boundary would
    # stay None while its segmented plan exists — re-race until the
    # built horizon is stable so rolling matches a prebuilt table
    while True:
        built_before = predictor.built_end
        segs = [
            plan_segmented_transfer(
                walker=walker, predictor=predictor, sat=Satellite(p, s),
                t_ready=float(t_ready[i]), link=handover.link,
                payload_bits=handover.payload_bits, ledger=ledger,
                require_next_download=handover.require_next_download,
            ) if np.isfinite(t_ready[i]) else None
            for i, (p, s) in enumerate(sats)
        ]
        if predictor.built_end == built_before:
            break
        out = predictor.retry_extending(attempt)
    merged: List[Optional[Tuple]] = []
    for i, (p, s) in enumerate(sats):
        sat = Satellite(p, s)
        base = out[i]
        seg = segs[i]
        if _better_segmented(seg, base[1] if base is not None else None):
            lead = seg.segments[0]
            w = VisibilityWindow(p, s, lead.window_start, lead.window_end,
                                 lead.gs_index)
            merged.append((seg.t_start, seg.t_done, w, seg.segments))
        elif base is not None:
            merged.append((base[0], base[1],
                           predictor.windows_of(sat)[base[2]], ()))
        else:
            merged.append(None)
    return merged


def _resolve_first_fits(
    *,
    walker: WalkerDelta,
    predictor: VisibilityPredictor,
    sats: List[Tuple[int, int]],
    t_ready: np.ndarray,
    transfer_time: TransferTime,
    ledger: Optional[GSResourceLedger],
) -> Tuple[List[Optional[Tuple[float, float, int]]], bool]:
    """One batched resolution pass of ``_first_fit_transfers`` against
    the predictor's currently built window table.  Returns (fits,
    clipped_reject) — the flag marks a rejected boundary-clipped window
    (grounds for a rolling-horizon retry).

    Resolution proceeds in rounds: every still-pending slot contributes
    its current candidate window, ALL slant ranges of the round are
    evaluated with one batched positions call, and slots whose window is
    too short (or fully booked) advance to their next window.  With a
    single station the first fitting window in start order is the answer
    (disjoint windows: any later window starts after this one ends, and
    a ledger-delayed start still completes inside the window).  Under a
    multi-GS union, windows of the same satellite may OVERLAP, so after
    the first fit every window starting before that completion is also
    evaluated (a nearer station's overlapping pass can finish earlier);
    windows starting at or after an achieved completion can never beat
    it.
    """
    # the predictor assigned every window's gs_index, so it — not the
    # caller — is the authority on which station a window belongs to
    gss = predictor.ground_stations
    n = len(sats)
    planes_arr = np.array([p for p, _ in sats])
    slots_arr = np.array([s for _, s in sats])
    recs = [predictor.sat_arrays(p, s) for p, s in sats]
    ptrs: List[Optional[int]] = []
    for s, rec in enumerate(recs):
        if rec is None or not np.isfinite(t_ready[s]):
            ptrs.append(None)
            continue
        j = int(np.searchsorted(rec["cummax_end"], t_ready[s], side="right"))
        ptrs.append(j if j < rec["starts"].size else None)

    out: List[Optional[Tuple[float, float, int]]] = [None] * n
    sweeps: List[Tuple[int, int]] = []     # (sat index, overlap-window index)
    built_end = predictor.built_end if predictor.rolling else np.inf
    clipped_reject = False
    pending = [s for s in range(n) if ptrs[s] is not None]
    while pending:
        t0s = np.array(
            [max(recs[s]["starts"][ptrs[s]], t_ready[s]) for s in pending]
        )
        gs_idx = np.array([recs[s]["gs_index"][ptrs[s]] for s in pending])
        dists = _slant_ranges(
            walker, gss, gs_idx,
            planes_arr[pending], slots_arr[pending], t0s,
        )
        nxt = []
        for s, t0, d in zip(pending, t0s, dists):
            rec, j = recs[s], ptrs[s]
            gi = int(rec["gs_index"][j])
            need, done = transfer_time(gi, float(d))
            t_fit, done = _repriced_fit(
                ledger, walker, gss[gi], Satellite(*sats[s]), gi,
                float(t0), float(rec["ends"][j]), transfer_time,
                need, done,
            )
            if t_fit is not None:
                out[s] = (t_fit, t_fit + done, j)
                # multi-GS overlap sweep candidates: any window starting
                # before the achieved completion may still finish earlier
                for k in range(j + 1, rec["starts"].size):
                    if rec["starts"][k] >= out[s][1]:
                        break
                    if rec["ends"][k] > t_ready[s]:
                        sweeps.append((s, k))
                continue
            # window too short (or fully booked) — advance past windows
            # already over
            if rec["ends"][j] == built_end:
                clipped_reject = True
            j += 1
            while j < rec["ends"].size and rec["ends"][j] <= t_ready[s]:
                j += 1
            if j < rec["ends"].size:
                ptrs[s] = j
                nxt.append(s)
        pending = nxt

    if sweeps:
        # evaluate every overlap candidate of every slot in ONE batched
        # slant-range call (in-order processing keeps ties deterministic)
        t0s = np.array(
            [max(recs[s]["starts"][k], t_ready[s]) for s, k in sweeps]
        )
        gs_idx = np.array([recs[s]["gs_index"][k] for s, k in sweeps])
        sweep_sats = np.array([s for s, _ in sweeps])
        dists = _slant_ranges(
            walker, gss, gs_idx,
            planes_arr[sweep_sats], slots_arr[sweep_sats], t0s,
        )
        for (s, k), t0k, dk in zip(sweeps, t0s, dists):
            rec = recs[s]
            gi = int(rec["gs_index"][k])
            need_k, done_k = transfer_time(gi, float(dk))
            t_fit, done_k = _repriced_fit(
                ledger, walker, gss[gi], Satellite(*sats[s]), gi,
                float(t0k), float(rec["ends"][k]), transfer_time,
                need_k, done_k,
            )
            if t_fit is None:
                if rec["ends"][k] == built_end:
                    clipped_reject = True
            elif t_fit + done_k < out[s][1]:
                out[s] = (t_fit, t_fit + done_k, k)
    return out, clipped_reject


def symmetric_transfer(
    time_fn: Callable[[LinkConfig, float, float], float],
    link: LinkConfig,
    payload_bits: float,
) -> TransferTime:
    """transfer_time callback for a single up- or downlink: feasibility
    need and completion offset are the same transfer duration."""
    def tt(_gs_index: int, d: float) -> Tuple[float, float]:
        tc = time_fn(link, payload_bits, d)
        return tc, tc

    return tt


def earliest_transfer(
    *,
    walker: WalkerDelta,
    predictor: VisibilityPredictor,
    sat: Satellite,
    t: float,
    transfer_time: TransferTime,
    skip_window: SkipWindow = None,
    ledger: Optional[GSResourceLedger] = None,
    handover: Optional[HandoverSpec] = None,
) -> Optional[Tuple]:
    """Legacy shim over ``CommsEnvironment.plan_transfer``: builds an
    ephemeral session from the explicit (walker, predictor, ledger)
    arguments and delegates.  Same contract as always — (t0, t_done,
    window) or, with a ``handover`` spec, (t0, t_done, window,
    segments) — and bit-identical to the session API (golden-tested).
    New code should hold a ``CommsEnvironment`` and call
    ``plan_upload``/``plan_download``/``plan_transfer`` instead."""
    from repro.comms.environment import CommsEnvironment

    env = CommsEnvironment(
        walker=walker, predictor=predictor,
        link=handover.link if handover is not None else None,
        ledger=ledger, handover=handover is not None,
    )
    return env.plan_transfer(
        sat=sat, t=t, transfer_time=transfer_time,
        skip_window=skip_window, handover_spec=handover,
    )


def _earliest_transfer_impl(
    *,
    walker: WalkerDelta,
    predictor: VisibilityPredictor,
    sat: Satellite,
    t: float,
    transfer_time: TransferTime,
    skip_window: SkipWindow = None,
    ledger: Optional[GSResourceLedger] = None,
    handover: Optional[HandoverSpec] = None,
) -> Optional[Tuple]:
    """Earliest-completing feasible transfer of one satellite after t:
    (t0, t_done, window), or None.

    The scalar single-satellite analogue of ``_first_fit_transfers``,
    shared by the baseline retry loops so they price every window
    against its own station (taken from the predictor that tagged the
    windows) and agree with ``select_sink`` on earliest-completion
    semantics under multi-GS union windows (where overlapping windows
    mean the first fit in start order is not necessarily the earliest
    completion).  Ledger and rolling-horizon semantics match
    ``_first_fit_transfers``: windows are priced against residual
    station capacity, and an empty result extends a rolling predictor
    and retries.

    With a ``handover`` spec the single-window search is raced against
    a segmented station-handover plan and the result becomes the
    4-tuple ``(t0, t_done, window, segments)`` (first-leg window;
    ``segments`` empty when the single-window transfer won) — same
    contract as ``_first_fit_transfers``.
    """
    # re-race after any horizon growth: both searches must price the
    # same (final) window table, or a stale single-window miss could
    # hide a transfer the grown table affords (and vice versa)
    while True:
        best = _earliest_single_transfer(
            walker=walker, predictor=predictor, sat=sat, t=t,
            transfer_time=transfer_time, skip_window=skip_window,
            ledger=ledger,
        )
        if handover is None:
            return best
        built_before = predictor.built_end
        seg = plan_segmented_transfer(
            walker=walker, predictor=predictor, sat=sat, t_ready=t,
            link=handover.link, payload_bits=handover.payload_bits,
            ledger=ledger,
            require_next_download=handover.require_next_download,
            skip_window=skip_window,
        )
        if predictor.built_end == built_before:
            break
    if _better_segmented(seg, best[1] if best is not None else None):
        lead = seg.segments[0]
        w = VisibilityWindow(sat.plane, sat.slot, lead.window_start,
                             lead.window_end, lead.gs_index)
        return (seg.t_start, seg.t_done, w, seg.segments)
    if best is None:
        return None
    return (best[0], best[1], best[2], ())


def _earliest_single_transfer(
    *,
    walker: WalkerDelta,
    predictor: VisibilityPredictor,
    sat: Satellite,
    t: float,
    transfer_time: TransferTime,
    skip_window: SkipWindow = None,
    ledger: Optional[GSResourceLedger] = None,
) -> Optional[Tuple[float, float, VisibilityWindow]]:
    """The unsegmented single-window search of ``earliest_transfer``."""
    gss = predictor.ground_stations
    while True:
        built_end = predictor.built_end if predictor.rolling else np.inf
        best: Optional[Tuple[float, float, VisibilityWindow]] = None
        clipped_reject = False
        for w in predictor.windows_of(sat):
            if w.t_end <= t:
                continue
            if best is not None and w.t_start >= best[1]:
                break       # can no longer beat the achieved completion
            if skip_window is not None and skip_window(w):
                continue
            t0 = max(w.t_start, t)
            d = _distance_at(walker, gss[w.gs_index], sat, t0)
            need, done = transfer_time(w.gs_index, d)
            t_fit, done = _repriced_fit(
                ledger, walker, gss[w.gs_index], sat, w.gs_index,
                t0, w.t_end, transfer_time, need, done,
            )
            if t_fit is None:
                if w.t_end == built_end:
                    clipped_reject = True  # true end lies past the horizon
                continue
            if best is None or t_fit + done < best[1]:
                best = (t_fit, t_fit + done, w)
        if best is not None and not clipped_reject:
            # complete a chosen window still clipped at the built
            # boundary (its true end lies in the next chunk) so the
            # reported window matches a prebuilt table's
            if best[2].t_end == built_end and predictor.extend_once():
                continue
            return best
        if not predictor.extend_once():
            return best


def reserve_transfer(
    ledger: Optional[GSResourceLedger],
    gs_index: int,
    t0: float,
    t_done: float,
    segments: Tuple[TransferSegment, ...] = (),
) -> None:
    """Book one chosen upload on the ledger: each handover leg on its
    own station for exactly the leg span (the in-between gaps and the
    other stations' RBs stay free for other uploads), or the single
    ``[t0, t_done)`` interval when the transfer was not segmented.
    THE one booking rule — every strategy and planner routes through
    it.  No-op without a ledger (the contention-free degenerate
    case)."""
    if ledger is None:
        return
    if segments:
        for leg in segments:
            ledger.reserve(leg.gs_index, leg.t_start, leg.t_end)
    else:
        ledger.reserve(gs_index, t0, t_done)


def reserve_decision(
    ledger: Optional[GSResourceLedger],
    decision: Union["SinkDecision", "ClusterSinkDecision"],
) -> None:
    """Book a chosen sink upload (``SinkDecision`` or
    ``ClusterSinkDecision``) on the ledger so later transfer decisions
    are priced against the residual station capacity."""
    reserve_transfer(
        ledger,
        decision.window.gs_index,
        decision.t_upload_start,
        decision.t_upload_done,
        getattr(decision, "segments", ()),
    )


def select_sink(
    *,
    walker: WalkerDelta,
    gs: GroundStations,
    predictor: VisibilityPredictor,
    link: LinkConfig,
    isl: ISLConfig,
    plane: int,
    t_train_done: Sequence[float],
    payload_bits: float,
    require_next_download: bool = False,
    ledger: Optional[GSResourceLedger] = None,
    handover: bool = False,
) -> Optional[SinkDecision]:
    """Deterministic sink selection for one orbital plane.

    Args:
      gs: the ground station(s), part of the scheduler's shared
        deterministic inputs.  With several, any station's window
        qualifies and the exchange is priced against the window's own
        station (per the predictor's gs_index tags — the predictor must
        be built over these same stations).
      t_train_done: per-slot local-training completion times (absolute
        simulation seconds); index = slot on this plane.
      payload_bits: model size z|N|.
      require_next_download: also require room for the next global-model
        download inside the same window (t_c^U + t_c^D).
      ledger: optional shared RB-capacity view; candidate uploads are
        priced against the residual capacity of each window's station.
        The caller books the returned decision (``reserve_decision``).
      handover: allow mid-window station handover — candidate uploads
        may be split into segments across different stations' windows
        (``plan_segmented_transfer``) and eq. 22's completion race runs
        over the segmented plans.  ``False`` (default) is bit-identical
        to the single-window scheduler.

    Returns:
      The SinkDecision, or None if no feasible window exists in the
      predictor's horizon (a rolling predictor extends and retries
      before giving up).

    Legacy shim: delegates to ``CommsEnvironment.select_sink`` (the
    ring is the degenerate graph — eq. 21's hop metric as a relay-
    latency matrix over the one shared cluster scheduler).
    """
    from repro.comms.environment import CommsEnvironment

    env = CommsEnvironment(
        walker=walker, predictor=predictor, link=link, isl=isl,
        ledger=ledger, handover=handover, gs=gs,
    )
    return env.select_sink(
        plane=plane, t_train_done=t_train_done, payload_bits=payload_bits,
        require_next_download=require_next_download,
    )


def first_visible_download(
    *,
    walker: WalkerDelta,
    gs: GroundStations,
    predictor: VisibilityPredictor,
    link: LinkConfig,
    plane: int,
    t: float,
    payload_bits: float,
) -> Optional[tuple]:
    """Earliest (slot, t_received) at which ANY satellite of the plane can
    finish downloading w^t from the GS after time t (§IV-A step 1).

    The GS broadcasts over the full uplink bandwidth; the first visible
    satellite of the plane becomes the propagation source.

    Legacy shim over ``CommsEnvironment.first_visible_download`` (the
    gs-matches-predictor check now lives in the session constructor).
    """
    from repro.comms.environment import CommsEnvironment

    env = CommsEnvironment(
        walker=walker, predictor=predictor, link=link, gs=gs,
    )
    return env.first_visible_download(plane, t, payload_bits)


def first_visible_download_sats(
    *,
    walker: WalkerDelta,
    gs: GroundStations,
    predictor: VisibilityPredictor,
    link: LinkConfig,
    sats: Sequence[Tuple[int, int]],
    t: float,
    payload_bits: float,
    _skip_gs_check: bool = False,
) -> Optional[tuple]:
    """Earliest (index into ``sats``, t_received) at which ANY of the
    listed satellites can finish downloading w^t from the GS after time
    t — ``first_visible_download`` over an arbitrary satellite set (a
    cluster of planes under the grid topology).

    Legacy shim over ``CommsEnvironment.first_visible_download_sats``.
    """
    from repro.comms.environment import CommsEnvironment

    env = CommsEnvironment(
        walker=walker, predictor=predictor, link=link,
        gs=None if _skip_gs_check else gs,
    )
    return env.first_visible_download_sats(sats, t, payload_bits)


def _first_visible_download_sats_impl(
    *,
    walker: WalkerDelta,
    predictor: VisibilityPredictor,
    link: LinkConfig,
    sats: Sequence[Tuple[int, int]],
    t: float,
    payload_bits: float,
) -> Optional[tuple]:
    """The resolution machinery behind ``first_visible_download_sats``
    (and the session method of the same name)."""
    sats = list(sats)
    fits = _first_fit_transfers(
        walker=walker, predictor=predictor, sats=sats,
        t_ready=np.full(len(sats), float(t)),
        transfer_time=symmetric_transfer(uplink_time, link, payload_bits),
    )

    best_i, best_done = None, None
    for i in range(len(sats)):
        if fits[i] is None:
            continue
        done = fits[i][1]
        if best_done is None or done < best_done:
            best_i, best_done = i, done
    if best_i is None:
        return None
    return best_i, best_done


def naive_sink_slot(
    predictor: VisibilityPredictor, plane: int, t_ready: float
) -> Optional[int]:
    """Legacy shim over ``CommsEnvironment.naive_sink_slot`` with an
    explicit predictor (the session holds no other state this query
    touches); both call the one ``_naive_sink_slot_impl``."""
    return _naive_sink_slot_impl(predictor, plane, t_ready)


def _naive_sink_slot_impl(
    predictor: VisibilityPredictor, plane: int, t_ready: float
) -> Optional[int]:
    """The naive-sink ablation's slot choice: the plane's next visitor
    after t_ready, window duration ignored (earliest effective start,
    ties to the lowest slot).  One batched per-plane sweep instead of K
    scalar ``next_window`` calls.

    A plane with no window left inside the built horizon extends a
    rolling predictor and retries (near the horizon end the plane would
    otherwise silently drop out of the round); only when the horizon
    cannot grow further does it return None.
    """
    def attempt() -> Tuple[Optional[int], bool]:
        starts, _ = predictor.plane_next_window_starts(plane, t_ready)
        eff = np.maximum(starts, t_ready)
        if np.any(np.isfinite(eff)):
            return int(np.argmin(eff)), False
        return None, True

    return predictor.retry_extending(attempt)


@dataclasses.dataclass(frozen=True)
class ClusterSinkDecision:
    """Sink choice for a *cluster* of planes under the grid topology:
    one sink satellite collects every plane's trained models over
    cross-plane ISL relay and uploads the cluster partial in a single
    GS pass."""

    planes: Tuple[int, ...]
    sink: Satellite
    window: VisibilityWindow    # single-window upload, or the first leg's
    t_models_at_sink: float     # all cluster models collected
    t_upload_start: float
    t_upload_done: float
    t_wait: float
    candidates_considered: int
    # mid-window station handover legs (empty = single-window upload)
    segments: Tuple[TransferSegment, ...] = ()
    payload_bits: Optional[float] = None


def select_sink_cluster(
    *,
    walker: WalkerDelta,
    gs: GroundStations,
    predictor: VisibilityPredictor,
    link: LinkConfig,
    sats: Sequence[Tuple[int, int]],
    relay_latency: np.ndarray,
    t_train_done: Sequence[float],
    payload_bits: float,
    require_next_download: bool = False,
    ledger: Optional[GSResourceLedger] = None,
    handover: bool = False,
) -> Optional[ClusterSinkDecision]:
    """Legacy shim over ``CommsEnvironment.select_sink_cluster`` —
    builds an ephemeral session from the explicit arguments (which
    also runs the gs-matches-predictor check) and delegates."""
    from repro.comms.environment import CommsEnvironment

    env = CommsEnvironment(
        walker=walker, predictor=predictor, link=link,
        ledger=ledger, handover=handover, gs=gs,
    )
    return env.select_sink_cluster(
        sats=sats, relay_latency=relay_latency, t_train_done=t_train_done,
        payload_bits=payload_bits,
        require_next_download=require_next_download,
    )


def _select_sink_cluster_impl(
    *,
    walker: WalkerDelta,
    predictor: VisibilityPredictor,
    link: LinkConfig,
    sats: Sequence[Tuple[int, int]],
    relay_latency: np.ndarray,
    t_train_done: Sequence[float],
    payload_bits: float,
    require_next_download: bool = False,
    ledger: Optional[GSResourceLedger] = None,
    handover: bool = False,
) -> Optional[ClusterSinkDecision]:
    """Constellation-wide sink selection over an arbitrary satellite set.

    The eq. (21)/(22) machinery of ``select_sink`` with the ring hop
    metric replaced by a graph relay-latency matrix: candidate c's
    readiness is max_s(t_train_done[s] + relay_latency[c, s]), and the
    feasibility/completion rules are unchanged.  With ``sats`` = one
    plane and ``relay_latency = ring_hops_matrix(K) * t_hop`` this is
    bit-identical to ``select_sink`` (equivalence-tested).  With a
    ``ledger``, every candidate's upload is priced against the residual
    RB capacity of its window's station, so a saturated station loses
    the eq. (22) completion race to a station with free capacity — this
    is what load-balances cluster sinks across stations.  With
    ``handover`` every candidate may also split its upload into
    station-handover segments, so the completion race is priced over
    segmented plans (a candidate with no single long-enough window can
    still win through a split upload).
    """
    sats = list(sats)
    planes = tuple(sorted({p for p, _ in sats}))
    t_ready = np.max(
        np.asarray(t_train_done, dtype=np.float64)[None, :] + relay_latency,
        axis=1,
    )
    spec = (
        HandoverSpec(link, payload_bits, require_next_download)
        if handover else None
    )

    def exchange_time(_gi: int, d: float) -> Tuple[float, float]:
        t_dl = downlink_time(link, payload_bits, d)
        need = t_dl
        if require_next_download:
            need += uplink_time(link, payload_bits, d)
        return need, t_dl

    while True:
        fits = _first_fit_transfers(
            walker=walker, predictor=predictor, sats=sats,
            t_ready=t_ready, transfer_time=exchange_time, ledger=ledger,
            handover=spec,
        )

        best: Optional[ClusterSinkDecision] = None
        considered = 0
        for cand in range(len(sats)):
            if fits[cand] is None:
                continue
            if spec is not None:
                t0, t_done, w, segments = fits[cand]
            else:
                t0, t_done, j = fits[cand]
                w = predictor.windows_of(Satellite(*sats[cand]))[j]
                segments = ()
            considered += 1
            decision = ClusterSinkDecision(
                planes=planes,
                sink=Satellite(*sats[cand]),
                window=w,
                t_models_at_sink=float(t_ready[cand]),
                t_upload_start=t0,
                t_upload_done=t_done,
                t_wait=max(0.0, w.t_start - float(t_ready[cand])),
                candidates_considered=0,
                segments=segments,
                payload_bits=float(payload_bits),
            )
            # minimize completion; tie -> earliest window start
            if (
                best is None
                or decision.t_upload_done < best.t_upload_done - 1e-9
                or (
                    abs(decision.t_upload_done - best.t_upload_done) <= 1e-9
                    and decision.window.t_start < best.window.t_start
                )
            ):
                best = decision

        if best is None:
            return None
        # the chosen window may still be clipped at a rolling horizon's
        # built boundary — complete it so the reported window carries
        # its true end (the schedule itself is already final)
        if (
            predictor.rolling
            and best.window.t_end == predictor.built_end
            and predictor.extend_once()
        ):
            continue
        return dataclasses.replace(best, candidates_considered=considered)
