"""Distributed sink-satellite scheduling (paper §IV-B).

Every satellite runs ``select_sink`` over the *same* deterministic inputs
(constellation config, GS position, training-completion times, link
parameters) and therefore reaches the same decision without any message
exchange — this is what makes the scheduler distributed.

Selection rule (eqs. 21-22): among candidate sinks C_l on orbit l, pick
the satellite minimizing the orbit's completion time

  T*_sum = t_c^U + t_c^D + t*_wait + t_train(K_l) + t*_h           (22)

subject to the access-window feasibility constraint

  AW(c_opt, GS) >= (time needed to exchange models with the GS),

i.e. the sink's upcoming visibility window must be long enough for the
partial-global-model upload (and next-round download).  Ties (several
candidates with equal completion) resolve to the earliest visitor,
matching "selects the one that will visit the GS the first".
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.comms.isl import ISLConfig, isl_hop_time
from repro.comms.link import LinkConfig, downlink_time, uplink_time
from repro.core.propagation import ring_hops
from repro.orbits.constellation import GroundStation, Satellite, WalkerDelta
from repro.orbits.prediction import VisibilityPredictor
from repro.orbits.visibility import VisibilityWindow


@dataclasses.dataclass(frozen=True)
class SinkDecision:
    plane: int
    sink_slot: int
    window: VisibilityWindow
    t_models_at_sink: float     # all trained models collected (eq. 21)
    t_upload_start: float       # max(window start, models ready)
    t_upload_done: float        # + t_c^D
    t_wait: float               # t*_wait
    candidates_considered: int


def _distance_at(
    walker: WalkerDelta, gs: GroundStation, sat: Satellite, t: float
) -> float:
    r_s = walker.position_of(sat, t)
    r_g = gs.eci(np.asarray(t))
    return float(np.linalg.norm(r_s - r_g))


def select_sink(
    *,
    walker: WalkerDelta,
    gs: GroundStation,
    predictor: VisibilityPredictor,
    link: LinkConfig,
    isl: ISLConfig,
    plane: int,
    t_train_done: Sequence[float],
    payload_bits: float,
    require_next_download: bool = False,
) -> Optional[SinkDecision]:
    """Deterministic sink selection for one orbital plane.

    Args:
      t_train_done: per-slot local-training completion times (absolute
        simulation seconds); index = slot on this plane.
      payload_bits: model size z|N|.
      require_next_download: also require room for the next global-model
        download inside the same window (t_c^U + t_c^D).

    Returns:
      The SinkDecision, or None if no feasible window exists in the
      predictor's horizon (caller should extend the horizon).
    """
    K = walker.config.sats_per_plane
    t_hop = isl_hop_time(isl, payload_bits)
    best: Optional[SinkDecision] = None
    considered = 0

    for cand in range(K):
        sat = Satellite(plane=plane, slot=cand)
        # eq. 21: when do all models reach this candidate sink?
        arrivals = [
            t_train_done[s] + ring_hops(K, s, cand) * t_hop for s in range(K)
        ]
        t_ready = max(arrivals)

        # Feasibility: window long enough for the exchange. Distance (and
        # hence t_c^D) depends on when the window occurs, so iterate the
        # candidate's windows and evaluate the exchange time window-by-
        # window with the true slant range at upload start.
        for w in predictor.windows_of(sat):
            if w.t_end <= t_ready:
                continue
            t_start_ul = max(w.t_start, t_ready)
            d = _distance_at(walker, gs, sat, t_start_ul)
            t_dl = downlink_time(link, payload_bits, d)
            need = t_dl + (uplink_time(link, payload_bits, d)
                           if require_next_download else 0.0)
            if w.t_end - t_start_ul < need:
                continue  # AW too short — not a valid candidate sink
            considered += 1
            decision = SinkDecision(
                plane=plane,
                sink_slot=cand,
                window=w,
                t_models_at_sink=t_ready,
                t_upload_start=t_start_ul,
                t_upload_done=t_start_ul + t_dl,
                t_wait=max(0.0, w.t_start - t_ready),
                candidates_considered=0,
            )
            # minimize completion; tie -> earliest window start
            if (
                best is None
                or decision.t_upload_done < best.t_upload_done - 1e-9
                or (
                    abs(decision.t_upload_done - best.t_upload_done) <= 1e-9
                    and decision.window.t_start < best.window.t_start
                )
            ):
                best = decision
            break  # later windows of the same candidate are never better

    if best is None:
        return None
    return dataclasses.replace(best, candidates_considered=considered)


def first_visible_download(
    *,
    walker: WalkerDelta,
    gs: GroundStation,
    predictor: VisibilityPredictor,
    link: LinkConfig,
    plane: int,
    t: float,
    payload_bits: float,
) -> Optional[tuple]:
    """Earliest (slot, t_received) at which ANY satellite of the plane can
    finish downloading w^t from the GS after time t (§IV-A step 1).

    The GS broadcasts over the full uplink bandwidth; the first visible
    satellite of the plane becomes the propagation source.
    """
    K = walker.config.sats_per_plane
    best_slot, best_done = None, None
    for slot in range(K):
        sat = Satellite(plane=plane, slot=slot)
        for w in predictor.windows_of(sat):
            if w.t_end <= t:
                continue
            t0 = max(w.t_start, t)
            d = _distance_at(walker, gs, sat, t0)
            t_ul = uplink_time(link, payload_bits, d)
            if w.t_end - t0 < t_ul:
                continue  # window too short to finish the download
            done = t0 + t_ul
            if best_done is None or done < best_done:
                best_slot, best_done = slot, done
            break
    if best_slot is None:
        return None
    return best_slot, best_done
