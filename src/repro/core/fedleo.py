"""FedLEO: the paper's framework (§IV), as a strategy on the engine.

One synchronous round starting at simulated time t:

  1. Per orbit, the GS broadcasts w^t to the first satellite of the
     plane that can complete the download inside a visibility window
     (full uplink bandwidth B, eq. 15).
  2. The model floods the plane's bidirectional ISL ring
     (``broadcast_schedule``, duplicates dropped); each satellite starts
     local training as soon as it receives the model, so training
     processes run concurrently (§IV-A).
  3. After training, every satellite runs the *distributed scheduler*
     (``select_sink``, §IV-B) over shared deterministic state; all agree
     on the per-orbit sink — the first satellite whose upcoming access
     window is long enough for the partial-model exchange, minimizing
     eq. (22).
  4. Trained models relay hop-by-hop to the sink (eq. 21); the sink
     computes the partial global model w_{K_l} (eq. 9) and uploads it —
     with the piggybacked label histograms — during its window (one
     downlink RB, eq. 16).
  5. When the GS holds all L partials it aggregates them (eq. 4, with
     optional non-IID class-coverage weighting) into w^{t+1}.

The learning (local SGD, partial & global aggregation) is real JAX
compute; the clock is the Satcom simulation.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import aggregation
from repro.core.engine import FLStrategy
from repro.core.propagation import broadcast_schedule
from repro.core.scheduling import first_visible_download, select_sink


class FedLEO(FLStrategy):
    name = "FedLEO"

    def __init__(self, *args, require_next_download: bool = False,
                 sink_policy: str = "scheduled", **kwargs):
        """sink_policy:
          * "scheduled"     — the paper's distributed scheduler (§IV-B):
            first satellite whose window fits the exchange, minimizing
            eq. (22);
          * "first_visitor" — ablation: next satellite to see the GS,
            window duration ignored (upload retries if it doesn't fit) —
            isolates the contribution of the scheduling component.
        """
        super().__init__(*args, **kwargs)
        self.require_next_download = require_next_download
        assert sink_policy in ("scheduled", "first_visitor")
        self.sink_policy = sink_policy
        if sink_policy != "scheduled":
            self.name = f"FedLEO({sink_policy})"

    def _naive_sink(self, plane: int, t_train_done):
        """Ablation sink: first visitor after training, AW duration NOT
        checked — uploads that do not fit a window retry at the next one
        (the failure mode the paper's scheduler avoids)."""
        from repro.comms.isl import isl_hop_time
        from repro.comms.link import downlink_time
        from repro.core.propagation import ring_hops
        from repro.core.scheduling import (
            SinkDecision,
            earliest_transfer,
            symmetric_transfer,
        )
        from repro.orbits.constellation import Satellite

        sim = self.sim
        K = sim.constellation.sats_per_plane
        t_hop = isl_hop_time(sim.isl, self.payload_bits)
        t_ready0 = max(t_train_done)
        sink, best_start, best_w = None, None, None
        for s in range(K):
            w = self.predictor.next_window(Satellite(plane, s), t_ready0)
            if w is not None and (
                best_start is None or max(w.t_start, t_ready0) < best_start
            ):
                sink, best_start, best_w = s, max(w.t_start, t_ready0), w
        if sink is None:
            return None
        t_ready = max(
            t_train_done[s] + ring_hops(K, s, sink) * t_hop
            for s in range(K)
        )
        # upload with retries across this sink's windows
        tt = symmetric_transfer(downlink_time, sim.link, self.payload_bits)
        hit = earliest_transfer(
            walker=self.walker, predictor=self.predictor,
            sat=Satellite(plane, sink), t=t_ready, transfer_time=tt,
        )
        if hit is None:
            return None
        t0, t_done, w = hit
        return SinkDecision(
            plane=plane, sink_slot=sink, window=w,
            t_models_at_sink=t_ready, t_upload_start=t0,
            t_upload_done=t_done,
            t_wait=max(0.0, w.t_start - t_ready),
            candidates_considered=1,
        )

    def step(self, t: float) -> Tuple[Optional[float], Dict[str, Any]]:
        sim, task = self.sim, self.task
        L = sim.constellation.num_planes
        K = sim.constellation.sats_per_plane

        plane_upload_done: List[float] = []
        plane_stats: List[Dict[str, Any]] = []
        trained_stacks = []
        plane_counts: List[int] = []
        plane_hists: List[np.ndarray] = []

        for plane in range(L):
            clients = self.plane_clients(plane)
            # 1. GS -> first reachable satellite of the plane
            dl = first_visible_download(
                walker=self.walker,
                gs=self.gs_list,
                predictor=self.predictor,
                link=sim.link,
                plane=plane,
                t=t,
                payload_bits=self.payload_bits,
            )
            if dl is None:
                return None, {"failed_plane": plane}
            src_slot, t_recv = dl

            # 2. flood the ring; train upon receipt (concurrent)
            events = broadcast_schedule(
                K, [src_slot], [t_recv], self.payload_bits, sim.isl
            )
            t_train_done = [
                events[s].t_receive + task.train_time_s(clients[s])
                for s in range(K)
            ]

            # 3. distributed sink selection (same pure function on every sat)
            if self.sink_policy == "scheduled":
                decision = select_sink(
                    walker=self.walker,
                    gs=self.gs_list,
                    predictor=self.predictor,
                    link=sim.link,
                    isl=sim.isl,
                    plane=plane,
                    t_train_done=t_train_done,
                    payload_bits=self.payload_bits,
                    require_next_download=self.require_next_download,
                )
            else:
                decision = self._naive_sink(plane, t_train_done)
            if decision is None:
                return None, {"failed_plane": plane}

            # 4. real local training + sink partial aggregation (eq. 9)
            stacked = task.local_train(
                self.global_params, clients, self._next_rng()
            )
            counts = [task.num_samples(c) for c in clients]
            partial = aggregation.partial_aggregate(
                stacked, counts, use_kernel=sim.use_kernel
            )
            trained_stacks.append(partial)
            plane_counts.append(int(np.sum(counts)))
            plane_hists.append(
                np.sum([task.clients[c].histogram for c in clients], axis=0)
            )

            plane_upload_done.append(decision.t_upload_done)
            plane_stats.append(
                {
                    "plane": plane,
                    "source_slot": src_slot,
                    "t_broadcast_done": t_recv,
                    "sink_slot": decision.sink_slot,
                    "t_models_at_sink": decision.t_models_at_sink,
                    "t_wait_sink": decision.t_wait,
                    "t_upload_done": decision.t_upload_done,
                }
            )

        # 5. GS global aggregation (eq. 4 + non-IID weighting)
        stacked_partials = aggregation.stack_pytrees(trained_stacks)
        self.global_params = aggregation.global_aggregate(
            stacked_partials,
            plane_counts,
            histograms=np.stack(plane_hists),
            noniid_alpha=sim.noniid_alpha,
            use_kernel=sim.use_kernel,
        )
        t_round_end = max(plane_upload_done)
        return t_round_end, {"planes": plane_stats}
