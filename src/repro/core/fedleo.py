"""FedLEO: the paper's framework (§IV), as a strategy on the engine.

One synchronous round starting at simulated time t:

  1. Per orbit, the GS broadcasts w^t to the first satellite of the
     plane that can complete the download inside a visibility window
     (full uplink bandwidth B, eq. 15).
  2. The model floods the plane's bidirectional ISL ring
     (``broadcast_schedule``, duplicates dropped); each satellite starts
     local training as soon as it receives the model, so training
     processes run concurrently (§IV-A).
  3. After training, every satellite runs the *distributed scheduler*
     (``select_sink``, §IV-B) over shared deterministic state; all agree
     on the per-orbit sink — the first satellite whose upcoming access
     window is long enough for the partial-model exchange, minimizing
     eq. (22).
  4. Trained models relay hop-by-hop to the sink (eq. 21); the sink
     computes the partial global model w_{K_l} (eq. 9) and uploads it —
     with the piggybacked label histograms — during its window (one
     downlink RB, eq. 16).
  5. When the GS holds all L partials it aggregates them (eq. 4, with
     optional non-IID class-coverage weighting) into w^{t+1}.

``FedLEOGrid`` extends the same round structure to an inter-plane ISL
topology (+Grid): planes are grouped into *clusters*, one GS download
seeds a graph flood across each whole cluster, and sink selection runs
constellation-wide so a single well-placed sink collects a cluster of
planes over cross-plane relay and uploads one cluster partial — cutting
GS round-trips when planes outnumber usable windows.

The scheduling logic is factored into pure *planner* functions
(``plan_plane_round`` / ``plan_cluster_round``) so benchmarks can price
round times without running any JAX training; the strategies consume
the planners and add the real learning (local SGD, partial & global
aggregation).  The clock is the Satcom simulation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.comms.environment import CommsEnvironment
from repro.comms.isl import ISLConfig, isl_hop_time
from repro.comms.ledger import GSResourceLedger
from repro.comms.link import LinkConfig
from repro.comms.routing import (
    ISLPlan,
    RoutingTable,
    get_routing_table,
    resolve_lazy_routing,
)
from repro.core import aggregation
from repro.core.engine import FLStrategy, SimConfig
from repro.core.fltask import FederatedTask
from repro.core.propagation import ring_hops_matrix
from repro.core.scheduling import ClusterSinkDecision, SinkDecision
from repro.obs import decompose_group_plan
from repro.orbits.constellation import GroundStation, Satellite, WalkerDelta
from repro.orbits.prediction import VisibilityPredictor
from repro.orbits.topology import ISLTopology, get_isl_topology


# --- pure round planners (no learning; benchmarkable stand-alone) -------------
@dataclasses.dataclass(frozen=True)
class PlanePlan:
    """Schedule of one plane's round: source, flood, training, sink."""

    plane: int
    source_slot: int
    t_source: float             # download completes; flood starts
    t_receive: np.ndarray       # (K,) per-slot model receipt
    t_train_done: np.ndarray    # (K,) per-slot training completion
    decision: SinkDecision


@dataclasses.dataclass(frozen=True)
class ClusterPlan:
    """Schedule of one cluster's round under the grid topology."""

    planes: Tuple[int, ...]
    sats: Tuple[Tuple[int, int], ...]   # node order: plane-major, slot
    source: Tuple[int, int]
    t_source: float
    t_receive: np.ndarray       # (n,) per-sat model receipt
    t_train_done: np.ndarray    # (n,)
    decision: ClusterSinkDecision


def _naive_sink_decision(
    env: CommsEnvironment,
    *,
    isl: ISLConfig,
    plane: int,
    t_train_done: Sequence[float],
    payload_bits: float,
) -> Optional[SinkDecision]:
    """Ablation sink: first visitor after training, AW duration NOT
    checked — uploads that do not fit a window retry at the next one
    (the failure mode the paper's scheduler avoids)."""
    K = env.walker.config.sats_per_plane
    t_hop = isl_hop_time(isl, payload_bits)
    t_ready0 = max(t_train_done)
    sink = env.naive_sink_slot(plane, t_ready0)
    if sink is None:
        return None
    t_ready = float(np.max(
        np.asarray(t_train_done, dtype=np.float64)
        + ring_hops_matrix(K)[sink] * t_hop
    ))
    # upload with retries across this sink's windows (per the session's
    # handover policy, raced against a segmented station-switching plan)
    dec = env.plan_upload(Satellite(plane, sink), t_ready, payload_bits)
    if dec is None:
        return None
    return SinkDecision(
        plane=plane, sink_slot=sink, window=dec.window,
        t_models_at_sink=t_ready, t_upload_start=dec.t_start,
        t_upload_done=dec.t_done,
        t_wait=max(0.0, dec.window.t_start - t_ready),
        candidates_considered=1,
        segments=dec.segments,
        payload_bits=float(payload_bits),
    )


def _resolve_env(
    env: Optional[CommsEnvironment],
    walker: Optional[WalkerDelta],
    gs_list: Optional[Sequence[GroundStation]],
    predictor: Optional[VisibilityPredictor],
    link: Optional[LinkConfig],
    ledger: Optional[GSResourceLedger],
    handover: bool,
) -> CommsEnvironment:
    """The planners' session: the one the caller holds (strategies,
    benchmarks), or an ephemeral one assembled from the legacy explicit
    arguments (which also runs the gs-matches-predictor check)."""
    if env is not None:
        return env
    return CommsEnvironment(
        walker=walker, predictor=predictor, link=link,
        ledger=ledger, handover=handover, gs=gs_list,
    )


def plan_plane_round(
    *,
    plane: int,
    t: float,
    payload_bits: float,
    train_times: np.ndarray,
    isl: ISLConfig,
    env: Optional[CommsEnvironment] = None,
    walker: Optional[WalkerDelta] = None,
    gs_list: Optional[Sequence[GroundStation]] = None,
    predictor: Optional[VisibilityPredictor] = None,
    link: Optional[LinkConfig] = None,
    sink_policy: str = "scheduled",
    require_next_download: bool = False,
    ledger: Optional[GSResourceLedger] = None,
    handover: bool = False,
) -> Optional[PlanePlan]:
    """Plan one plane's round (paper §IV steps 1-3) without training:
    GS download -> ring flood -> concurrent training (simulated via
    ``train_times``) -> sink selection.  Returns None when no feasible
    window exists inside the predictor horizon.

    Planning routes through a ``CommsEnvironment`` session — pass one
    via ``env`` (its ledger/handover policy then applies), or the
    legacy explicit ``walker``/``gs_list``/``predictor``/``link``/
    ``ledger``/``handover`` arguments to assemble an ephemeral session.
    The session's ledger prices the sink upload against residual
    per-station RB capacity; the caller books the returned plan
    (``env.commit(plan.decision)``) before planning the next group.
    The GS download is a full-band broadcast of the same global model
    (eq. 15) and is not RB-contended.  The handover policy additionally
    lets the upload split into station-handover segments
    (``SimConfig.gs_handover``)."""
    env = _resolve_env(env, walker, gs_list, predictor, link, ledger,
                       handover)
    K = env.walker.config.sats_per_plane
    dl = env.first_visible_download(plane, t, payload_bits)
    if dl is None:
        return None
    src_slot, t_recv = dl

    t_hop = isl_hop_time(isl, payload_bits)
    t_receive = t_recv + ring_hops_matrix(K)[src_slot] * t_hop
    t_train_done = t_receive + np.asarray(train_times, dtype=np.float64)

    if sink_policy == "scheduled":
        decision = env.select_sink(
            plane=plane, t_train_done=t_train_done,
            payload_bits=payload_bits,
            require_next_download=require_next_download, isl=isl,
        )
    else:
        decision = _naive_sink_decision(
            env, isl=isl, plane=plane, t_train_done=t_train_done,
            payload_bits=payload_bits,
        )
    if decision is None:
        return None
    return PlanePlan(
        plane=plane, source_slot=src_slot, t_source=t_recv,
        t_receive=t_receive, t_train_done=t_train_done, decision=decision,
    )


def plan_cluster_round(
    *,
    routing: RoutingTable,
    planes: Sequence[int],
    t: float,
    payload_bits: float,
    train_times: np.ndarray,
    env: Optional[CommsEnvironment] = None,
    walker: Optional[WalkerDelta] = None,
    gs_list: Optional[Sequence[GroundStation]] = None,
    predictor: Optional[VisibilityPredictor] = None,
    link: Optional[LinkConfig] = None,
    require_next_download: bool = False,
    ledger: Optional[GSResourceLedger] = None,
    handover: bool = False,
) -> Optional[ClusterPlan]:
    """Plan one cluster's round over the ISL graph: a single GS download
    seeds a flood across every plane of the cluster, and one
    constellation-wide sink collects the cluster over cross-plane relay.
    With a single-plane cluster and a ring topology this degenerates to
    ``plan_plane_round`` exactly (bit-identical schedules).  Session
    (``env`` vs legacy explicit arguments), ledger and handover
    semantics as in ``plan_plane_round``: candidate sinks are priced
    against residual station capacity (and may split their upload
    across stations), the caller commits."""
    env = _resolve_env(env, walker, gs_list, predictor, link, ledger,
                       handover)
    K = env.walker.config.sats_per_plane
    sats = [(p, s) for p in planes for s in range(K)]
    nodes = routing.nodes_of(sats)

    dl = env.first_visible_download_sats(sats, t, payload_bits)
    if dl is None:
        return None
    src_i, t_recv = dl

    t_receive, _, _ = routing.broadcast_times(
        [nodes[src_i]], [t_recv], nodes=nodes
    )
    t_train_done = t_receive + np.asarray(train_times, dtype=np.float64)

    _, relay_latency = routing.submatrix(nodes)
    decision = env.select_sink_cluster(
        sats=sats, relay_latency=relay_latency,
        t_train_done=t_train_done, payload_bits=payload_bits,
        require_next_download=require_next_download,
    )
    if decision is None:
        return None
    return ClusterPlan(
        planes=tuple(planes), sats=tuple(sats), source=sats[src_i],
        t_source=t_recv, t_receive=t_receive, t_train_done=t_train_done,
        decision=decision,
    )


def make_clusters(
    num_planes: int, cluster_planes: int
) -> List[Tuple[int, ...]]:
    """Group adjacent planes into clusters of ``cluster_planes`` —
    the *static* grouping (rotation 0), kept as the degenerate case of
    ``form_clusters``."""
    return [
        tuple(range(i, min(i + cluster_planes, num_planes)))
        for i in range(0, num_planes, cluster_planes)
    ]


def _split_connected(
    planes: Sequence[int], adjacency: np.ndarray
) -> List[Tuple[int, ...]]:
    """Split a plane group into its connected components under the
    inter-plane adjacency (a cluster must be able to flood/relay
    internally; a seam-cut or ring topology may disconnect a run)."""
    remaining = sorted(planes)
    comps: List[Tuple[int, ...]] = []
    while remaining:
        seed = remaining.pop(0)
        comp = {seed}
        frontier = [seed]
        while frontier:
            p = frontier.pop()
            linked = [q for q in remaining if adjacency[p, q]]
            for q in linked:
                remaining.remove(q)
                comp.add(q)
                frontier.append(q)
        comps.append(tuple(sorted(comp)))
    return comps


def form_clusters(
    supply: np.ndarray,
    cluster_planes: int,
    *,
    seam_cut: bool = False,
    adjacency: Optional[np.ndarray] = None,
) -> List[Tuple[int, ...]]:
    """Per-round dynamic cluster formation from predicted window supply.

    Planes are partitioned into contiguous runs of at most
    ``cluster_planes``; among the candidate rotations the one whose
    clusters contain the best-served anchor planes wins:

      score(r) = sum over clusters of max(plane supply in cluster),

    i.e. every cluster should hold at least one plane with rich
    upcoming GS-window supply (the cluster sink will sit there).
    Rotations that need more clusters (more GS round-trips) are never
    preferred; ties resolve to the smallest rotation, which makes
    rotation 0 — the static ``make_clusters`` grouping — the
    deterministic fallback under uniform supply.

    ``seam_cut`` forbids runs that wrap the plane L-1 / plane 0 seam
    (clusters are never formed across a cut polar seam).  With an
    ``adjacency`` matrix every run is additionally split into its
    connected components, so a topology without inter-plane links
    (ring) degenerates to single-plane clusters exactly.

    Returns clusters as ascending plane tuples, ordered by first plane.
    """
    supply = np.asarray(supply, dtype=np.float64)
    L = supply.size
    c = max(1, min(int(cluster_planes), L))
    best: Optional[Tuple[Tuple[int, float, int], List[Tuple[int, ...]]]] = None
    for r in range(c if c > 1 else 1):
        if seam_cut:
            seq = list(range(L))
            runs = ([tuple(seq[:r])] if r else []) + [
                tuple(seq[i:i + c]) for i in range(r, L, c)
            ]
        else:
            seq = [(r + i) % L for i in range(L)]
            runs = [tuple(seq[i:i + c]) for i in range(0, L, c)]
        score = float(sum(supply[list(g)].max() for g in runs))
        key = (len(runs), -score, r)
        if best is None or key < best[0]:
            best = (key, runs)
    groups = best[1]
    if adjacency is not None:
        groups = [
            comp for g in groups for comp in _split_connected(g, adjacency)
        ]
    groups = [tuple(sorted(g)) for g in groups]
    groups.sort(key=lambda g: g[0])
    return groups


def supply_driven_clusters(
    predictor: VisibilityPredictor,
    topology: ISLTopology,
    cluster_planes: int,
    t: float,
    lookahead_s: Optional[float] = None,
    ledger: Optional[GSResourceLedger] = None,
) -> List[Tuple[int, ...]]:
    """One round's plane grouping from predicted window supply — THE
    dynamic-formation recipe (``FedLEOGrid``'s default and what the
    contention benchmark prices): supply over the next orbital period,
    ``form_clusters`` with the topology's seam/connectivity.

    With a ``ledger`` the per-station supply is discounted by the
    station's *residual* RB fraction over the lookahead
    (contention-aware formation feedback): window seconds on a station
    already saturated by booked uploads are worth proportionally less,
    so cluster anchors steer toward stations with free capacity.  An
    empty or unlimited ledger leaves the supply untouched — the
    degenerate case is the plain window-supply grouping."""
    if lookahead_s is None:
        lookahead_s = topology.constellation.period_s
    supply = predictor.plane_window_supply(t, t + lookahead_s)
    if ledger is not None:
        supply = supply * ledger.residual_fraction(t, t + lookahead_s)[None, :]
    return form_clusters(
        supply.sum(axis=1), cluster_planes,
        seam_cut=topology.config.seam_cut,
        adjacency=topology.plane_adjacency(),
    )


# --- strategies ---------------------------------------------------------------
class _SyncRoundMixin:
    """Shared synchronous round driver for FedLEO and FedLEOGrid: plan
    each plane group's schedule, run the real local training, aggregate
    the group partial at its sink (eq. 9), then the GS global aggregate
    (eq. 4 + non-IID weighting).  Only the planner and the per-group
    stats differ between the ring and grid variants.

    Groups are planned in order and every chosen sink upload is BOOKED
    on the strategy's resource ledger before the next group plans, so
    later sinks are priced against the residual station capacity —
    several sinks landing on one station's window now compete for its
    resource blocks instead of overlapping for free."""

    def _sync_round(
        self,
        t: float,
        groups: Sequence[Tuple[int, ...]],
        # (group, clients) -> PlanePlan | ClusterPlan | None
        plan_group: Callable[[Tuple[int, ...], List[int]], Optional[Any]],
        # group -> events dict for an infeasible round
        fail_event: Callable[[Tuple[int, ...]], Dict[str, Any]],
        # plan -> stats dict
        group_stats: Callable[[Any], Dict[str, Any]],
        events_key: str,
    ) -> Tuple[Optional[float], Dict[str, Any]]:
        sim, task = self.sim, self.task
        upload_done: List[float] = []
        stats: List[Dict[str, Any]] = []
        partials = []
        group_counts: List[int] = []
        group_hists: List[np.ndarray] = []
        self._round_groups = []

        for group in groups:
            # node-ordered client list (plane-major, slot order) so that
            # client i sits on the group's i-th satellite
            clients = [c for p in group for c in self.plane_clients(p)]
            plan = plan_group(group, clients)
            if plan is None:
                return None, fail_event(group)
            self.env.commit(plan.decision)
            # typed phase decomposition of the committed plan (read-only
            # on the plan: schedules are unaffected)
            self._round_groups.append(decompose_group_plan(plan, t))

            stacked = task.local_train(
                self.global_params, clients, self._next_rng()
            )
            counts = [task.num_samples(c) for c in clients]
            partials.append(
                aggregation.partial_aggregate(
                    stacked, counts, use_kernel=sim.use_kernel
                )
            )
            group_counts.append(int(np.sum(counts)))
            group_hists.append(
                np.sum([task.clients[c].histogram for c in clients], axis=0)
            )
            upload_done.append(plan.decision.t_upload_done)
            stats.append(group_stats(plan))

        self.global_params = aggregation.global_aggregate(
            aggregation.stack_pytrees(partials),
            group_counts,
            histograms=np.stack(group_hists),
            noniid_alpha=sim.noniid_alpha,
            use_kernel=sim.use_kernel,
        )
        return max(upload_done), {events_key: stats}


class FedLEO(_SyncRoundMixin, FLStrategy):
    name = "FedLEO"

    def __init__(self, *args: Any, require_next_download: bool = False,
                 sink_policy: str = "scheduled", **kwargs: Any):
        """sink_policy:
          * "scheduled"     — the paper's distributed scheduler (§IV-B):
            first satellite whose window fits the exchange, minimizing
            eq. (22);
          * "first_visitor" — ablation: next satellite to see the GS,
            window duration ignored (upload retries if it doesn't fit) —
            isolates the contribution of the scheduling component.
        """
        super().__init__(*args, **kwargs)
        self.require_next_download = require_next_download
        assert sink_policy in ("scheduled", "first_visitor")
        self.sink_policy = sink_policy
        if sink_policy != "scheduled":
            self.name = f"FedLEO({sink_policy})"

    def step(self, t: float) -> Tuple[Optional[float], Dict[str, Any]]:
        sim, task = self.sim, self.task

        def plan_group(
            group: Tuple[int, ...], clients: List[int]
        ) -> Optional[PlanePlan]:
            (plane,) = group
            return plan_plane_round(
                env=self.env, isl=sim.isl,
                plane=plane, t=t,
                payload_bits=self.group_payload_bits(group),
                train_times=np.array(
                    [self.train_time_s(c) for c in clients]
                ),
                sink_policy=self.sink_policy,
                require_next_download=self.require_next_download,
            )

        def group_stats(plan: PlanePlan) -> Dict[str, Any]:
            d = plan.decision
            return {
                "plane": plan.plane,
                "source_slot": plan.source_slot,
                "t_broadcast_done": plan.t_source,
                "sink_slot": d.sink_slot,
                "t_models_at_sink": d.t_models_at_sink,
                "t_wait_sink": d.t_wait,
                "t_upload_done": d.t_upload_done,
                "handover_legs": len(d.segments),
            }

        return self._sync_round(
            t,
            [(p,) for p in range(sim.constellation.num_planes)],
            plan_group,
            lambda group: {"failed_plane": group[0]},
            group_stats,
            "planes",
        )


class FedLEOGrid(_SyncRoundMixin, FLStrategy):
    """FedLEO over an inter-plane ISL topology (+Grid).

    Planes are grouped into clusters of up to ``cluster_planes``
    adjacent planes — by default re-formed *every round* from the
    predicted window supply (``form_clusters``; seam cuts respected);
    per round each cluster needs only ONE GS download (the flood
    crosses planes over inter-plane ISLs) and ONE upload (the cluster
    sink collects every plane via cross-plane relay) — L /
    cluster_planes GS round-trips instead of L.  With
    ``cluster_planes=1`` and a ring topology this is bit-identical to
    ``FedLEO`` (schedules and sink decisions; equivalence-tested).
    With a resource ledger (``SimConfig.gs_rb_capacity``) cluster sinks
    compete for per-station RBs, which load-balances them across the
    ground segment.
    """

    name = "FedLEO-Grid"

    def __init__(self, task: FederatedTask, sim: SimConfig, *,
                 cluster_planes: Optional[int] = None,
                 dynamic_clusters: bool = True,
                 require_next_download: bool = False,
                 lazy_routing: Optional[bool] = None,
                 env: Optional[CommsEnvironment] = None):
        """``dynamic_clusters`` (default): re-form the plane clusters
        every round from the predicted window supply over the next
        orbital period (``form_clusters``) — clusters are contiguous,
        never cross a cut polar seam, and each contains a well-served
        anchor plane for its sink.  ``False`` keeps the static
        adjacent-plane grouping for every round.  ``lazy_routing=None``
        (auto) defers the all-pairs routing matrices to per-source rows
        at mega-scale (``resolve_lazy_routing``); schedules are
        identical either way."""
        super().__init__(task, sim, env)
        self.require_next_download = require_next_download
        self.topology = get_isl_topology(sim.constellation, sim.topology)
        # routing latencies are prebuilt at the task's uniform payload:
        # per-group pricing (group_payload_bits) covers the sink upload,
        # while relay hop costs stay fleet-wide — rebuilding the table
        # per payload would defeat the routing cache
        self.routing = get_routing_table(
            sim.constellation,
            sim.topology,
            ISLPlan(intra=sim.isl, inter=sim.isl_inter),
            self.payload_bits,
            lazy=resolve_lazy_routing(sim.constellation, lazy_routing),
        )
        L = sim.constellation.num_planes
        if cluster_planes is None:
            cluster_planes = (
                min(4, L) if self.topology.config.has_inter_links else 1
            )
        if cluster_planes > 1 and not self.topology.config.has_inter_links:
            raise ValueError(
                "multi-plane clusters need inter-plane ISLs "
                f"(topology kind={sim.topology.kind!r} has none)"
            )
        self.cluster_planes = cluster_planes
        self.dynamic_clusters = dynamic_clusters
        self.clusters = make_clusters(L, cluster_planes)

    def round_clusters(self, t: float) -> List[Tuple[int, ...]]:
        """This round's plane grouping: the supply-driven dynamic
        partition (discounted by the ledger's residual station
        capacity when contention accounting is on), or the static one
        when ``dynamic_clusters=False``."""
        if not self.dynamic_clusters:
            return self.clusters
        return supply_driven_clusters(
            self.predictor, self.topology, self.cluster_planes, t,
            ledger=self.ledger,
        )

    def step(self, t: float) -> Tuple[Optional[float], Dict[str, Any]]:
        sim, task = self.sim, self.task

        def plan_group(
            group: Tuple[int, ...], clients: List[int]
        ) -> Optional[ClusterPlan]:
            return plan_cluster_round(
                env=self.env,
                routing=self.routing, planes=group, t=t,
                payload_bits=self.group_payload_bits(group),
                train_times=np.array(
                    [self.train_time_s(c) for c in clients]
                ),
                require_next_download=self.require_next_download,
            )

        def group_stats(plan: ClusterPlan) -> Dict[str, Any]:
            d = plan.decision
            return {
                "planes": list(plan.planes),
                "source": plan.source,
                "t_broadcast_done": plan.t_source,
                "sink": (d.sink.plane, d.sink.slot),
                "t_models_at_sink": d.t_models_at_sink,
                "t_wait_sink": d.t_wait,
                "t_upload_done": d.t_upload_done,
                "handover_legs": len(d.segments),
            }

        return self._sync_round(
            t,
            self.round_clusters(t),
            plan_group,
            lambda group: {"failed_cluster": group},
            group_stats,
            "clusters",
        )
