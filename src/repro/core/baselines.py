"""Baseline FL-Satcom strategies the paper benchmarks against (§II, §V).

Implemented on the same engine/simulator as FedLEO so the comparison is
apples-to-apples (identical constellation, link budget, datasets,
training):

  * FedAvgStar       — vanilla synchronous FedAvg [6]/[8]: star topology,
                       every satellite individually downloads/uploads.
  * FedSatSched      — [10]: star sync + visibility-aware scheduling
                       (train during invisible gaps, same-window upload).
  * FedISL           — [3]: ISL ring + naive sink (first visitor, ignores
                       window duration). ``ideal=True`` puts the GS at the
                       North Pole (regular visits), the paper's ideal setup.
  * FedHAP           — [2]: sync star against two always-high-visibility
                       HAP servers (extra hardware).
  * FedAsync         — [13]: asynchronous star with staleness-decayed
                       server mixing.
  * FedSat           — [9]: async with NP ground station (ideal setup),
                       periodic buffer aggregation.
  * FedSpace         — [7]: async buffered aggregation triggered at a
                       predicted buffer fill fraction, stale down-weights.
  * AsyncFLEO        — [4]: intra-plane propagation + naive sink (ignores
                       the sink's visible-period constraint) + async
                       staleness-weighted orbit-partial mixing.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import aggregation
from repro.core.engine import FLStrategy, SimConfig
from repro.core.fltask import FederatedTask
from repro.core.propagation import broadcast_schedule, ring_hops_matrix
from repro.comms.environment import CommsEnvironment, PendingUpload
from repro.comms.isl import isl_hop_time
from repro.configs.constellations import GROUND_STATION_PRESETS
from repro.orbits.constellation import Satellite
from repro.orbits.prediction import VisibilityPredictor
from repro.orbits.visibility import VisibilityWindow


class _StarMixin:
    """Window-search helpers shared by star-topology strategies."""

    def _first_tx(
        self, sat: Satellite, t: float, payload_bits: float, downlink: bool,
        env: Optional[CommsEnvironment] = None,
        same_window: bool = True,
    ) -> Optional[float]:
        """Completion time of the earliest feasible transfer after t.

        A window is feasible if its remaining duration after
        max(t, start) covers the transfer time computed with the true
        slant range against the window's own station (multi-GS union
        predictors tag every window with its gs_index).
        ``same_window=False`` forces the transfer to start at a window
        *after* t (the naive FedAvg behaviour of eq. (10) case 2: wait
        for the next visit).

        Everything routes through the scheduling session: uploads
        (``downlink=True``) are priced against the session's resource
        ledger and the chosen transfer is committed on it — splitting
        into station-handover segments per the session's policy;
        downloads are full-band broadcasts of the shared global model
        (eq. 15) and never contend or segment.  ``env`` overrides the
        strategy session when a strategy pairs its own
        predictor/station sets (FedHAP's per-server sessions).
        """
        env = env if env is not None else self.env

        skip = None
        if not same_window:
            def skip(w: VisibilityWindow) -> bool:
                # skip the in-progress window
                return w.contains(t) and w.t_start < t

        if downlink:
            dec = env.plan_upload(sat, t, payload_bits, skip_window=skip)
            if dec is None:
                return None
            env.commit(dec)
            return dec.t_done
        dec = env.plan_download(sat, t, payload_bits, skip_window=skip)
        return None if dec is None else dec.t_done


# --- synchronous star baselines ----------------------------------------------------
class FedAvgStar(FLStrategy, _StarMixin):
    """Vanilla sync FedAvg over the star topology (eq. 10 timing)."""

    name = "FedAvg"
    same_window_upload = False  # naive: upload waits for the *next* visit

    def step(self, t: float) -> Tuple[Optional[float], Dict[str, Any]]:
        task, sim = self.task, self.sim
        done_times = []
        for cid, client in enumerate(task.clients):
            sat = Satellite(client.plane, client.slot)
            bits = self.sat_payload_bits(client.plane, client.slot)
            t_dl = self._first_tx(sat, t, bits, downlink=False)
            if t_dl is None:
                return None, {"failed_client": cid}
            t_tr = t_dl + self.train_time_s(cid)
            t_ul = self._first_tx(
                sat, t_tr, bits, downlink=True,
                same_window=self.same_window_upload,
            )
            if t_ul is None:
                return None, {"failed_client": cid}
            done_times.append(t_ul)

        stacked = task.local_train(
            self.global_params, range(len(task.clients)), self._next_rng()
        )
        counts = [task.num_samples(c) for c in range(len(task.clients))]
        self.global_params = aggregation.global_aggregate(
            stacked, counts, use_kernel=sim.use_kernel
        )
        t_end = max(done_times)
        return t_end, {"slowest_client_h": (t_end - t) / 3600.0}


class FedSatSched(FedAvgStar):
    """[10]: visibility-aware scheduling — a satellite may finish its
    upload inside the window it downloaded in (if long enough), and
    trains during the invisible interval otherwise."""

    name = "FedSatSched"
    same_window_upload = True


class FedHAP(FLStrategy, _StarMixin):
    """[2]: replaces the GS with two HAPs (20 km altitude, near-zero
    minimum elevation -> wide frequent windows). Extra hardware, better
    visibility; synchronous aggregation."""

    name = "FedHAP"

    def __init__(self, task: FederatedTask, sim: SimConfig,
                 env: Optional[CommsEnvironment] = None):
        super().__init__(task, sim, env)
        hap_a = dataclasses.replace(
            sim.ground_station, alt_m=20_000.0, min_elevation_deg=2.0,
            name="HAP-A",
        )
        hap_b = dataclasses.replace(
            sim.ground_station, lon_deg=sim.ground_station.lon_deg + 180.0,
            alt_m=20_000.0, min_elevation_deg=2.0, name="HAP-B",
        )
        horizon = sim.horizon_hours * 3600.0 * 1.5
        # one scheduling session per HAP server: the paper's extra-
        # dedicated-hardware baseline has private capacity (no ledger),
        # and the session constructor checks each server matches its
        # own predictor's ground segment
        self.servers = [
            CommsEnvironment(
                walker=self.walker,
                predictor=VisibilityPredictor(
                    self.walker, hap, horizon,
                    coarse_step_s=sim.coarse_step_s,
                ),
                link=sim.link, isl=sim.isl,
                handover=sim.gs_handover, gs=hap,
            )
            for hap in (hap_a, hap_b)
        ]

    def _best_tx(
        self, sat: Satellite, t: float, payload_bits: float, downlink: bool
    ) -> Optional[float]:
        outs = [
            self._first_tx(sat, t, payload_bits, downlink, env=env)
            for env in self.servers
        ]
        outs = [o for o in outs if o is not None]
        return min(outs) if outs else None

    def step(self, t: float) -> Tuple[Optional[float], Dict[str, Any]]:
        task, sim = self.task, self.sim
        done_times = []
        for cid, client in enumerate(task.clients):
            sat = Satellite(client.plane, client.slot)
            bits = self.sat_payload_bits(client.plane, client.slot)
            t_dl = self._best_tx(sat, t, bits, downlink=False)
            if t_dl is None:
                return None, {"failed_client": cid}
            t_tr = t_dl + self.train_time_s(cid)
            t_ul = self._best_tx(sat, t_tr, bits, downlink=True)
            if t_ul is None:
                return None, {"failed_client": cid}
            done_times.append(t_ul)
        stacked = task.local_train(
            self.global_params, range(len(task.clients)), self._next_rng()
        )
        counts = [task.num_samples(c) for c in range(len(task.clients))]
        self.global_params = aggregation.global_aggregate(
            stacked, counts, use_kernel=sim.use_kernel
        )
        return max(done_times), {}


# --- ISL ring baselines --------------------------------------------------------------
class FedISL(FLStrategy, _StarMixin):
    """[3]: intra-plane ISL relay with a *naive* sink (the next satellite
    to visit the server, ignoring window duration — uploads that do not
    fit retry at the sink's next window).  ``ideal=True`` moves the GS to
    the North Pole, the paper's ideal setup with regular visits."""

    name = "FedISL"
    ideal = False

    def __init__(self, task: FederatedTask, sim: SimConfig):
        if self.ideal:
            sim = dataclasses.replace(
                sim,
                ground_station=GROUND_STATION_PRESETS["north-pole"],
                ground_stations=(),
            )
        super().__init__(task, sim)

    def _upload_with_retries(self, sat: Satellite, t_ready: float,
                             payload_bits: float) -> Optional[float]:
        # windows too short (or with no free RB) are skipped: the naive
        # sink retries at its next window — exactly _first_tx's
        # earliest-feasible upload (ledger booking included)
        return self._first_tx(sat, t_ready, payload_bits, downlink=True)

    def step(self, t: float) -> Tuple[Optional[float], Dict[str, Any]]:
        task, sim = self.task, self.sim
        L, K = sim.constellation.num_planes, sim.constellation.sats_per_plane
        completions, partials, counts = [], [], []

        for plane in range(L):
            clients = self.plane_clients(plane)
            bits = self.group_payload_bits((plane,))
            t_hop = isl_hop_time(sim.isl, bits)
            dl = self.env.first_visible_download(plane, t, bits)
            if dl is None:
                return None, {"failed_plane": plane}
            src_slot, t_recv = dl
            events = broadcast_schedule(
                K, [src_slot], [t_recv], bits, sim.isl
            )
            t_done = [
                events[s].t_receive + self.train_time_s(clients[s])
                for s in range(K)
            ]
            # naive sink: earliest next visitor after completion (one
            # batched per-plane window sweep)
            t_ready0 = max(t_done)
            sink = self.env.naive_sink_slot(plane, t_ready0)
            if sink is None:
                return None, {"failed_plane": plane}
            t_ready = float(np.max(
                np.asarray(t_done) + ring_hops_matrix(K)[sink] * t_hop
            ))
            t_ul = self._upload_with_retries(
                Satellite(plane, sink), t_ready, bits
            )
            if t_ul is None:
                return None, {"failed_plane": plane}
            completions.append(t_ul)

            stacked = task.local_train(
                self.global_params, clients, self._next_rng()
            )
            c = [task.num_samples(cid) for cid in clients]
            partials.append(
                aggregation.partial_aggregate(stacked, c,
                                              use_kernel=sim.use_kernel)
            )
            counts.append(int(np.sum(c)))

        self.global_params = aggregation.global_aggregate(
            aggregation.stack_pytrees(partials), counts,
            use_kernel=sim.use_kernel,
        )
        return max(completions), {}


class FedISLIdeal(FedISL):
    name = "FedISL-ideal"
    ideal = True


# --- asynchronous baselines ------------------------------------------------------------
class _AsyncQueueMixin:
    """Book-at-schedule-time upload queue shared by the asynchronous
    strategies, with optional event-driven re-admission
    (``SimConfig.async_readmit``).

    Every async cycle plans download -> train -> upload at schedule
    time and *books* the upload on the session ledger — under scarce RB
    capacity an upload whose model is ready early can therefore queue
    behind bookings merely made earlier.  With re-admission on, the
    strategy registers an ``on_release`` hook with its
    ``CommsEnvironment``; whenever booked capacity is RELEASED
    (``env.release`` — an aborted cycle, or any other component
    sharing the session; the stock strategies never abort a booked
    upload themselves), the next server event re-admits the queued
    uploads in model-ready order (``CommsEnvironment.readmit``) and
    re-keys the event queue to the re-priced completions.  Until such
    an event fires — and always with ``async_readmit=False`` — the
    schedule is bit-identical to the book-at-schedule-time baseline.
    """

    def _init_async_queue(self) -> None:
        # (t_upload_done, key, t_model_version) priority queue
        self._queue: List[Tuple[float, Any, float]] = []
        self.readmit = bool(self.sim.async_readmit)
        self._pending: Dict[Any, PendingUpload] = {}
        self._versions: Dict[Any, float] = {}
        self._capacity_freed = False
        if self.readmit:
            self.env.on_release(self._note_release)

    def _note_release(self, _reservation: Any, _freed: Any) -> None:
        # the release hook: booked capacity freed somewhere — re-admit
        # the queued uploads at the next server event
        self._capacity_freed = True

    def _admit_upload(
        self, key: Any, sat: Satellite, t_ready: float, payload_bits: float,
        version: float,
    ) -> Optional[float]:
        """Plan + book one upload at schedule time; tracked as pending —
        for re-admission when it is on, and always as the strategy's
        declared open reservations (the sanitizer's leak report exempts
        a live async queue).  Identical plan/commit path either way, so
        the schedule does not depend on ``readmit``.  Returns the
        completion."""
        dec = self.env.plan_upload(sat, t_ready, payload_bits)
        if dec is None:
            return None
        res = self.env.commit(dec)
        self._pending[key] = PendingUpload(
            key, sat, t_ready, payload_bits, dec, res
        )
        self._versions[key] = version
        return dec.t_done

    def _pop_pending(self, key: Any) -> None:
        self._pending.pop(key, None)
        self._versions.pop(key, None)

    def _readmit_queued(self, t_now: float) -> None:
        """Re-admit the queued uploads (release -> re-price in ready
        order) and re-key the event queue to the new completions."""
        self._capacity_freed = False
        if not self.readmit or not self._pending:
            return
        updated, _ = self.env.readmit(
            list(self._pending.values()), t_now,
            policy=self.sim.readmit_policy,
        )
        self._pending = {p.key: p for p in updated}
        self._queue = [
            (p.decision.t_done, p.key, self._versions[p.key])
            for p in self._pending.values()
        ]
        heapq.heapify(self._queue)
        self._capacity_freed = False


class _AsyncStar(FLStrategy, _StarMixin, _AsyncQueueMixin):
    """Shared machinery: every satellite loops download->train->upload
    independently; the server consumes an arrival stream."""

    name = "_async"
    mix_rate = 0.6            # alpha: server mixing rate
    staleness_power = 0.5     # weight = alpha / (1 + staleness_h)^power

    def __init__(self, task: FederatedTask, sim: SimConfig,
                 env: Optional[CommsEnvironment] = None):
        super().__init__(task, sim, env)
        self._init_async_queue()
        for cid, client in enumerate(task.clients):
            self._push_next(cid, 0.0)

    def _push_next(self, cid: int, t: float) -> None:
        client = self.task.clients[cid]
        sat = Satellite(client.plane, client.slot)
        bits = self.sat_payload_bits(client.plane, client.slot)
        t_dl = self._first_tx(sat, t, bits, downlink=False)
        if t_dl is None:
            return
        t_tr = t_dl + self.train_time_s(cid)
        t_ul = self._admit_upload(cid, sat, t_tr, bits, t_dl)
        if t_ul is None:
            return
        heapq.heappush(self._queue, (t_ul, cid, t_dl))

    def _staleness_weight(self, t_now: float, t_version: float) -> float:
        stale_h = max(0.0, (t_now - t_version)) / 3600.0
        return self.mix_rate / (1.0 + stale_h) ** self.staleness_power

    def step(self, t: float) -> Tuple[Optional[float], Dict[str, Any]]:
        if self._capacity_freed:
            self._readmit_queued(t)    # an external release freed capacity
        if not self._queue:
            return None, {"drained": True}
        t_ul, cid, t_version = heapq.heappop(self._queue)
        self._pop_pending(cid)
        stacked = self.task.local_train(
            self.global_params, [cid], self._next_rng()
        )
        local = aggregation.index_pytree(stacked, 0)
        alpha = self._staleness_weight(t_ul, t_version)
        self.global_params = aggregation.weighted_average(
            aggregation.stack_pytrees([self.global_params, local]),
            np.asarray([1.0 - alpha, alpha]),
            use_kernel=self.sim.use_kernel,
        )
        self._push_next(cid, t_ul)
        return t_ul, {"client": cid, "alpha": alpha}


class FedAsync(_AsyncStar):
    """[13]: asynchronous federated optimization with staleness decay."""

    name = "FedAsync"


class FedSat(_AsyncStar):
    """[9]: ideal NP ground station; arrivals buffered and folded in at a
    fixed cadence (one orbital period) with uniform weights."""

    name = "FedSat-ideal"

    def __init__(self, task: FederatedTask, sim: SimConfig):
        sim = dataclasses.replace(
            sim,
            ground_station=GROUND_STATION_PRESETS["north-pole"],
            ground_stations=(),
        )
        super().__init__(task, sim)
        self._buffer: List[Tuple[int, float]] = []
        self._next_agg = sim.constellation.period_s

    def step(self, t: float) -> Tuple[Optional[float], Dict[str, Any]]:
        if self._capacity_freed:
            self._readmit_queued(t)
        if not self._queue:
            return None, {"drained": True}
        t_ul, cid, t_version = heapq.heappop(self._queue)
        self._pop_pending(cid)
        self._buffer.append((cid, t_version))
        self._push_next(cid, t_ul)
        if t_ul < self._next_agg and self._queue:
            return t_ul, {"buffered": len(self._buffer)}
        # aggregation tick
        self._next_agg = t_ul + self.sim.constellation.period_s
        if not self._buffer:
            return t_ul, {"buffered": 0}
        cids = [c for c, _ in self._buffer]
        stacked = self.task.local_train(
            self.global_params, cids, self._next_rng()
        )
        counts = [self.task.num_samples(c) for c in cids]
        update = aggregation.global_aggregate(
            stacked, counts, use_kernel=self.sim.use_kernel
        )
        self.global_params = aggregation.weighted_average(
            aggregation.stack_pytrees([self.global_params, update]),
            np.asarray([1.0 - self.mix_rate, self.mix_rate]),
            use_kernel=self.sim.use_kernel,
        )
        self._buffer = []
        return t_ul, {"aggregated": len(cids)}


class FedSpace(_AsyncStar):
    """[7]: buffer-fill-triggered aggregation with stale down-weighting.

    (The raw-data-upload scheduling component of FedSpace violates FL
    privacy and is not reproduced; the buffer aggregation logic is.)
    """

    name = "FedSpace"
    buffer_fraction = 0.25

    def __init__(self, task: FederatedTask, sim: SimConfig,
                 env: Optional[CommsEnvironment] = None):
        super().__init__(task, sim, env)
        self._buffer: List[Tuple[int, float]] = []

    def step(self, t: float) -> Tuple[Optional[float], Dict[str, Any]]:
        if self._capacity_freed:
            self._readmit_queued(t)
        if not self._queue:
            return None, {"drained": True}
        t_ul, cid, t_version = heapq.heappop(self._queue)
        self._pop_pending(cid)
        self._buffer.append((cid, t_version))
        self._push_next(cid, t_ul)
        target = max(1, int(self.buffer_fraction * len(self.task.clients)))
        if len(self._buffer) < target and self._queue:
            return t_ul, {"buffered": len(self._buffer)}
        cids = [c for c, _ in self._buffer]
        versions = [v for _, v in self._buffer]
        stacked = self.task.local_train(
            self.global_params, cids, self._next_rng()
        )
        w = np.asarray(
            [
                self.task.num_samples(c)
                * self._staleness_weight(t_ul, v) / self.mix_rate
                for c, v in zip(cids, versions)
            ]
        )
        update = aggregation.weighted_average(
            stacked, w, use_kernel=self.sim.use_kernel
        )
        self.global_params = aggregation.weighted_average(
            aggregation.stack_pytrees([self.global_params, update]),
            np.asarray([1.0 - self.mix_rate, self.mix_rate]),
            use_kernel=self.sim.use_kernel,
        )
        self._buffer = []
        return t_ul, {"aggregated": len(cids)}


class AsyncFLEO(FLStrategy, _StarMixin, _AsyncQueueMixin):
    """[4]: intra-plane propagation + per-orbit partials like FedLEO, but
    the sink is the next visitor (its visible-period sufficiency is NOT
    checked -> upload retries), and the server mixes partials in
    asynchronously with staleness decay."""

    name = "AsyncFLEO"
    mix_rate = 0.6
    staleness_power = 0.5

    def __init__(self, task: FederatedTask, sim: SimConfig,
                 env: Optional[CommsEnvironment] = None):
        super().__init__(task, sim, env)
        self._init_async_queue()
        for plane in range(sim.constellation.num_planes):
            self._schedule_plane(plane, 0.0)

    def _schedule_plane(self, plane: int, t: float) -> None:
        sim, task = self.sim, self.task
        K = sim.constellation.sats_per_plane
        clients = self.plane_clients(plane)
        bits = self.group_payload_bits((plane,))
        dl = self.env.first_visible_download(plane, t, bits)
        if dl is None:
            return
        src_slot, t_recv = dl
        events = broadcast_schedule(
            K, [src_slot], [t_recv], bits, sim.isl
        )
        t_done = [
            events[s].t_receive + self.train_time_s(clients[s])
            for s in range(K)
        ]
        t_hop = isl_hop_time(sim.isl, bits)
        t_ready0 = max(t_done)
        sink = self.env.naive_sink_slot(plane, t_ready0)
        if sink is None:
            return
        t_ready = float(np.max(
            np.asarray(t_done) + ring_hops_matrix(K)[sink] * t_hop
        ))
        # naive upload with retries (window chosen after the fact, not
        # scheduled ahead like FedLEO); the booked RB makes later plane
        # schedules compete for residual station capacity
        t_ul = self._admit_upload(
            plane, Satellite(plane, sink), t_ready, bits,
            t_recv,
        )
        if t_ul is None:
            return
        heapq.heappush(self._queue, (t_ul, plane, t_recv))

    def step(self, t: float) -> Tuple[Optional[float], Dict[str, Any]]:
        if self._capacity_freed:
            self._readmit_queued(t)
        if not self._queue:
            return None, {"drained": True}
        t_ul, plane, t_version = heapq.heappop(self._queue)
        self._pop_pending(plane)
        clients = self.plane_clients(plane)
        stacked = self.task.local_train(
            self.global_params, clients, self._next_rng()
        )
        counts = [self.task.num_samples(c) for c in clients]
        partial = aggregation.partial_aggregate(
            stacked, counts, use_kernel=self.sim.use_kernel
        )
        stale_h = max(0.0, t_ul - t_version) / 3600.0
        alpha = self.mix_rate / (1.0 + stale_h) ** self.staleness_power
        self.global_params = aggregation.weighted_average(
            aggregation.stack_pytrees([self.global_params, partial]),
            np.asarray([1.0 - alpha, alpha]),
            use_kernel=self.sim.use_kernel,
        )
        self._schedule_plane(plane, t_ul)
        return t_ul, {"plane": plane, "alpha": alpha}


ALL_BASELINES = {
    "FedAvg": FedAvgStar,
    "FedSatSched": FedSatSched,
    "FedHAP": FedHAP,
    "FedISL": FedISL,
    "FedISL-ideal": FedISLIdeal,
    "FedAsync": FedAsync,
    "FedSat-ideal": FedSat,
    "FedSpace": FedSpace,
    "AsyncFLEO": AsyncFLEO,
}
