"""Weighted model aggregation (paper eqs. 4 and 9).

Two layers:

  * ``weighted_average(stacked_params, weights)``: the core primitive —
    given a pytree whose leaves are stacked over a leading client axis
    and normalized weights, computes sum_k a_k * theta_k.  This is the
    compute hot-spot of the FL server (for a 123B-param model a single
    aggregation streams ~1 TB through HBM), so it is backed by the
    ``repro.kernels.aggregate`` Pallas kernel on TPU with a pure-jnp
    path elsewhere.

  * Orbit/global helpers mirroring the paper:
      - ``partial_aggregate``: the sink satellite's per-orbit partial
        global model  w_{K_l} = sum_{k in K_l} (m_k / m_{K_l}) w_k^I (9)
      - ``global_aggregate``: the GS's final model
        w^{t+1} = sum_k (m_k / m) w_k                                 (4)
      - ``noniid_weights``: label-histogram-aware weighting (the
        piggybacked data distribution of §IV-A): class-coverage-balanced
        weights so orbits holding rare classes are not drowned out.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def weighted_average(
    stacked: PyTree, weights: jnp.ndarray, use_kernel: bool = False
) -> PyTree:
    """sum_k weights[k] * leaf[k] for every leaf (leading axis = clients).

    Args:
      stacked: pytree with leaves of shape (K, ...).
      weights: (K,) nonnegative weights; will be normalized to sum to 1.
      use_kernel: route through the Pallas aggregation kernel (TPU).
    """
    w = weights / jnp.sum(weights)
    if use_kernel:
        from repro.kernels import aggregate_ops

        return aggregate_ops.aggregate_pytree(stacked, w)

    def leaf(x: jnp.ndarray) -> jnp.ndarray:
        return jnp.tensordot(w.astype(jnp.float32), x.astype(jnp.float32), axes=1).astype(x.dtype)

    return jax.tree_util.tree_map(leaf, stacked)


def partial_aggregate(
    stacked: PyTree, sample_counts: Sequence[int], use_kernel: bool = False
) -> PyTree:
    """Eq. (9): sink satellite's partial global model for one orbit."""
    m = jnp.asarray(sample_counts, jnp.float32)
    return weighted_average(stacked, m, use_kernel=use_kernel)


def global_aggregate(
    stacked: PyTree,
    sample_counts: Sequence[int],
    histograms: Optional[np.ndarray] = None,
    noniid_alpha: float = 0.0,
    use_kernel: bool = False,
) -> PyTree:
    """Eq. (4) with optional non-IID correction.

    Args:
      stacked: stacked partial (or client) models, leading axis K.
      sample_counts: m_k (or m_{K_l} for orbit partials).
      histograms: (K, num_classes) label histograms piggybacked during
        model propagation. If given and noniid_alpha > 0, weights are
        blended between data-size weighting and class-coverage-balanced
        weighting.
      noniid_alpha: 0 = pure eq. (4); 1 = fully class-balanced.
    """
    m = jnp.asarray(sample_counts, jnp.float32)
    w = m / jnp.sum(m)
    if histograms is not None and noniid_alpha > 0.0:
        w_bal = jnp.asarray(noniid_weights(np.asarray(histograms)), jnp.float32)
        w = (1.0 - noniid_alpha) * w + noniid_alpha * w_bal
        w = w / jnp.sum(w)
    return weighted_average(stacked, w, use_kernel=use_kernel)


def noniid_weights(histograms: np.ndarray) -> np.ndarray:
    """Class-coverage-balanced weights from piggybacked label histograms.

    Each class's total mass is split equally among the contributors that
    hold it; a contributor's weight is its summed class shares.  Orbits
    holding classes nobody else has therefore keep their influence even
    when their m_k is small — the paper's motivation for uploading the
    data distribution with the partial model.
    """
    h = np.asarray(histograms, np.float64)
    class_tot = h.sum(axis=0, keepdims=True)       # (1, C)
    share = np.divide(h, class_tot, out=np.zeros_like(h), where=class_tot > 0)
    w = share.sum(axis=1)
    s = w.sum()
    if s <= 0:
        return np.full(h.shape[0], 1.0 / h.shape[0])
    return w / s


def stack_pytrees(trees: Sequence[PyTree]) -> PyTree:
    """Stack a list of identically-structured pytrees along a new axis 0."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def index_pytree(stacked: PyTree, i: int) -> PyTree:
    return jax.tree_util.tree_map(lambda x: x[i], stacked)
