"""FederatedTask: the learning substrate plugged into the FL engines.

Wraps a model (init/apply), an optimizer, and client datasets into
jit/vmap-compiled local-training and evaluation functions:

  * ``local_train(params, client_ids)``: vmapped I-epoch mini-batch SGD
    on every listed client *in parallel* (stacked params) — the JAX
    realization of "multiple concurrent training processes" (§IV-A).
  * ``evaluate(params)``: global-model metrics on a held-out test set.
  * ``train_time_s(client)``: eq. (11) wall-clock model
    t_train = I * n_k * b_k * c_k / f_k  (simulated clock, Table I).
  * ``payload_bits``: z|N| for the comm model.

The task is model-agnostic: classification (CNN), segmentation (U-Net)
and LM (assigned architectures) tasks all fit.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compute.fleet import FleetComputeModel
from repro.data.partition import ClientData, stack_client_arrays
from repro.data.synthetic import Dataset
from repro.models import nn
from repro.optim import Optimizer
from repro.optim.optimizers import apply_updates

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainHyperparams:
    """Paper Table I (lower part) defaults."""

    local_epochs: int = 100          # I
    learning_rate: float = 0.001     # eta
    batch_size: int = 32             # b_k
    cycles_per_sample: float = 1.0e3  # c_k
    cpu_freq_hz: float = 1.0e9       # f_k
    bits_per_param: int = 32         # z


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy; supports (B, C) or (B, H, W, C) logits."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logp.dtype)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


class FederatedTask:
    def __init__(
        self,
        *,
        init_fn: Callable[..., PyTree],
        apply_fn: Callable[[PyTree, jnp.ndarray], jnp.ndarray],
        clients: Sequence[ClientData],
        test_set: Dataset,
        optimizer: Optimizer,
        hp: TrainHyperparams = TrainHyperparams(),
        loss_fn: Callable = cross_entropy_loss,
        rng: Optional[jax.Array] = None,
        sim_epochs: Optional[int] = None,
        payload_bits_override: Optional[int] = None,
        compute: Optional[FleetComputeModel] = None,
    ):
        """Args:
          sim_epochs: epochs actually executed on this host (defaults to
            hp.local_epochs). The *simulated clock* always charges
            hp.local_epochs via eq. (11); running fewer real epochs keeps
            CPU benchmarks tractable without changing timing fidelity.
          payload_bits_override: charge the comm model for this payload
            size z|N| instead of the proxy model's true size — used to
            simulate the paper's full-size CNN/U-Net (or a 100M+ LM)
            while training a reduced proxy on CPU.
          compute: heterogeneous fleet compute model (repro.compute) —
            ``train_time_s`` consults it per client before falling back
            to the uniform eq. (11) c_k/f_k constant.  None (default)
            keeps the paper's uniform fleet; ``FLStrategy`` also
            resolves one from ``SimConfig.compute`` without mutating
            the task, so one task can be shared across arms.
        """
        self.apply_fn = apply_fn
        self.clients = list(clients)
        self.test_set = test_set
        self.optimizer = optimizer
        self.hp = hp
        self.loss_fn = loss_fn
        self.compute = compute
        self.sim_epochs = sim_epochs if sim_epochs is not None else hp.local_epochs
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.global_params = init_fn(rng)
        # `is None`, not `or`: an explicit 0-bit override must not fall
        # back to the proxy model's true size
        self._payload_bits = (
            payload_bits_override
            if payload_bits_override is not None
            else nn.param_bits(self.global_params, hp.bits_per_param)
        )

        # stacked per-client data for vmapped local training
        self._x_stack, self._y_stack, self._counts = stack_client_arrays(
            self.clients
        )
        self._x_stack = jnp.asarray(self._x_stack)
        self._y_stack = jnp.asarray(self._y_stack)

        self._local_train_vmapped = jax.jit(
            jax.vmap(self._local_train_one, in_axes=(0, 0, 0, 0))
        )
        self._eval_jit = jax.jit(self._eval)

    # --- payload & timing ------------------------------------------------------
    @property
    def payload_bits(self) -> int:
        return self._payload_bits

    def num_samples(self, client_id: int) -> int:      # m_k
        return int(self._counts[client_id])

    def executed_batches(self, client_id: int) -> Tuple[int, int]:
        """(n_batches, batch_size) as ``_local_train_one`` executes
        them: tiny clients (m < b_k) fall back to full-batch steps, so
        the simulated clock must charge the samples actually processed
        — not b_k.  For m >= b_k this is exactly eq. (11)'s
        (m // b_k, b_k)."""
        m = self.num_samples(client_id)
        bsz = min(self.hp.batch_size, max(1, m))
        return max(1, m // bsz), bsz

    def train_time_s(self, client_id: int) -> float:
        """Eq. (11): t_train(k) = I * n_k * b_k * c_k / f_k, charged
        for the batches actually executed.  With a fleet compute model
        attached, c_k / f_k is replaced by the client satellite's
        roofline per-sample cost (degenerate tiers fall through to the
        uniform constant)."""
        hp = self.hp
        n_batches, bsz = self.executed_batches(client_id)
        if self.compute is not None:
            c = self.clients[client_id]
            t = self.compute.train_time_s(
                c.plane, c.slot, local_epochs=hp.local_epochs,
                n_batches=n_batches, batch_size=bsz,
            )
            if t is not None:
                return t
        return (
            hp.local_epochs * n_batches * bsz * hp.cycles_per_sample
        ) / hp.cpu_freq_hz

    # --- local training ---------------------------------------------------------
    def _local_train_one(
        self, params: PyTree, x: jax.Array, y: jax.Array, rng: jax.Array
    ) -> PyTree:
        """I epochs of mini-batch SGD on one client (runs under vmap)."""
        hp = self.hp
        m = x.shape[0]
        bsz = min(hp.batch_size, m)   # tiny clients: full-batch steps
        n_batches = max(1, m // bsz)
        opt_state = self.optimizer.init(params)

        def loss(p: PyTree, xb: jax.Array, yb: jax.Array) -> jax.Array:
            return self.loss_fn(self.apply_fn(p, xb), yb)

        Carry = Tuple[PyTree, PyTree]

        def epoch_body(carry: Carry, ekey: jax.Array) -> Tuple[Carry, None]:
            params, opt_state = carry
            perm = jax.random.permutation(ekey, m)

            def batch_body(carry: Carry, i: jax.Array) -> Tuple[Carry, None]:
                params, opt_state = carry
                idx = jax.lax.dynamic_slice_in_dim(
                    perm, i * bsz, bsz
                )
                g = jax.grad(loss)(params, x[idx], y[idx])
                updates, opt_state = self.optimizer.update(g, opt_state, params)
                return (apply_updates(params, updates), opt_state), None

            (params, opt_state), _ = jax.lax.scan(
                batch_body, (params, opt_state), jnp.arange(n_batches)
            )
            return (params, opt_state), None

        ekeys = jax.random.split(rng, self.sim_epochs)
        (params, _), _ = jax.lax.scan(epoch_body, (params, opt_state), ekeys)
        return params

    def local_train(
        self, params: PyTree, client_ids: Sequence[int], rng: jax.Array
    ) -> PyTree:
        """Train the given global params on each listed client in parallel.

        Returns stacked params with leading axis len(client_ids).
        """
        ids = np.asarray(list(client_ids))
        stacked = jax.tree_util.tree_map(
            lambda p: jnp.broadcast_to(p, (len(ids),) + p.shape), params
        )
        rngs = jax.random.split(rng, len(ids))
        return self._local_train_vmapped(
            stacked, self._x_stack[ids], self._y_stack[ids], rngs
        )

    # --- evaluation ---------------------------------------------------------------
    def _eval(
        self, params: PyTree, x: jax.Array, y: jax.Array
    ) -> Dict[str, jax.Array]:
        logits = self.apply_fn(params, x)
        return {
            "loss": self.loss_fn(logits, y),
            "accuracy": accuracy(logits, y),
        }

    def evaluate(self, params: PyTree, max_samples: int = 1024) -> Dict[str, float]:
        x = jnp.asarray(self.test_set.x[:max_samples])
        y = jnp.asarray(self.test_set.y[:max_samples])
        out = self._eval_jit(params, x, y)
        return {k: float(v) for k, v in out.items()}

    # --- client lookup ---------------------------------------------------------------
    def clients_on_plane(self, plane: int) -> List[int]:
        return [i for i, c in enumerate(self.clients) if c.plane == plane]

    def client_histograms(self) -> np.ndarray:
        return np.stack([c.histogram for c in self.clients])
