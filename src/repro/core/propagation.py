"""Intra-plane model propagation (paper §IV-A).

Given the satellite that first receives the global model from the GS
(the *source*), the model floods both directions around the plane's
bidirectional ring; each satellite forwards to its next-hop neighbor.
Relaying trained models to the sink works the same way in reverse.

The planner is pure geometry + eq. (20) timing:

  * ``broadcast_schedule``: per-satellite model-receipt time when the
    source floods the ring (hop distance * t_h).  Duplicate receptions
    (two visible satellites, or the two flood fronts meeting) are
    dropped, i.e. each satellite keeps the *earliest* receipt — exactly
    the paper's "simply drop the duplicate".
  * ``relay_schedule``: per-satellite arrival time of its trained model
    at the sink (store-and-forward over `hops` ISL hops, eq. 21); the
    orbit's relay completion is the max arrival.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from repro.comms.isl import ISLConfig, isl_hop_time
from repro.orbits.constellation import WalkerDelta


@dataclasses.dataclass(frozen=True)
class PropagationEvent:
    slot: int
    t_receive: float
    hops: int
    source_slot: int


def ring_hops(num_slots: int, a: int, b: int) -> int:
    d = abs(a - b) % num_slots
    return min(d, num_slots - d)


def ring_hops_matrix(num_slots: int) -> np.ndarray:
    """hops[a, b] = ring_hops(num_slots, a, b) for every slot pair.

    The single source of truth for the ISL hop metric in vectorized
    code — keep it in lockstep with ``ring_hops`` if the topology ever
    grows beyond the intra-plane ring.
    """
    slots = np.arange(num_slots)
    d = np.abs(slots[:, None] - slots[None, :]) % num_slots
    return np.minimum(d, num_slots - d)


def broadcast_schedule(
    num_slots: int,
    source_slots: Sequence[int],
    t_source: Sequence[float],
    payload_bits: float,
    isl: ISLConfig,
) -> List[PropagationEvent]:
    """Flood the global model around the ring from one or more sources.

    Args:
      num_slots: satellites on the plane (K).
      source_slots: slots that received w^t directly from the GS.
      t_source: receipt time at each source (same length).
      payload_bits: z|N| of the model.

    Returns:
      One event per slot with its earliest receipt time (duplicates
      dropped by taking the min over sources/directions).
    """
    t_hop = isl_hop_time(isl, payload_bits)
    events: Dict[int, PropagationEvent] = {}
    for src, t0 in zip(source_slots, t_source):
        for slot in range(num_slots):
            h = ring_hops(num_slots, src, slot)
            t_recv = t0 + h * t_hop
            if slot not in events or t_recv < events[slot].t_receive:
                events[slot] = PropagationEvent(
                    slot=slot, t_receive=t_recv, hops=h, source_slot=src
                )
    return [events[s] for s in range(num_slots)]


def relay_schedule(
    num_slots: int,
    sink_slot: int,
    t_ready: Sequence[float],
    payload_bits: float,
    isl: ISLConfig,
) -> List[PropagationEvent]:
    """Arrival time of each satellite's trained model at the sink.

    ``t_ready[k]`` is when slot k finishes local training.  Each model is
    store-and-forwarded over ring_hops(k, sink) hops (eq. 21's h * t_h
    term).  We model per-hop pipelining conservatively: every model pays
    its full hop count (no cut-through), matching eq. (21)'s max over
    relaying satellites.
    """
    t_hop = isl_hop_time(isl, payload_bits)
    out = []
    for slot in range(num_slots):
        h = ring_hops(num_slots, slot, sink_slot)
        out.append(
            PropagationEvent(
                slot=slot,
                t_receive=t_ready[slot] + h * t_hop,
                hops=h,
                source_slot=slot,
            )
        )
    return out


def relay_completion_time(events: Sequence[PropagationEvent]) -> float:
    """Eq. (21): the orbit's t_h^* — all models collected at the sink."""
    return max(e.t_receive for e in events)
