"""Model propagation over the ISL graph (paper §IV-A, generalized).

Given the satellite(s) that first receive the global model from the GS
(the *sources*), the model floods the ISL topology; each satellite
forwards to its neighbors.  Relaying trained models to the sink works
the same way in reverse.  The paper's intra-plane bidirectional ring is
the degenerate (single-plane) case; with inter-plane cross-links the
same planners flood a whole cluster of planes.

The planner is pure geometry + eq. (20) timing:

  * ``broadcast_schedule``: per-satellite model-receipt time when the
    source floods the ring (hop distance * t_h).  Duplicate receptions
    (two visible satellites, or the two flood fronts meeting) are
    dropped, i.e. each satellite keeps the *earliest* receipt — exactly
    the paper's "simply drop the duplicate".
  * ``relay_schedule``: per-satellite arrival time of its trained model
    at the sink (store-and-forward over `hops` ISL hops, eq. 21); the
    orbit's relay completion is the max arrival.
  * ``graph_broadcast_schedule`` / ``graph_relay_schedule``: the same
    semantics over *arbitrary* hop/latency matrices (e.g. a
    ``RoutingTable`` built from an inter-plane +Grid topology).

All schedules are computed with one batched matrix expression per call
— no per-slot Python loops.  ``ring_hops_matrix`` remains the single
vectorized source of the intra-plane hop metric, and the ring
schedules are exactly the graph schedules evaluated on
``ring_hops_matrix(K) * t_hop``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from repro.comms.isl import ISLConfig, isl_hop_time
from repro.comms.routing import flood_times, relay_arrivals


@dataclasses.dataclass(frozen=True)
class PropagationEvent:
    slot: int               # node: in-plane slot (ring) or graph node id
    t_receive: float
    hops: int
    source_slot: int


def ring_hops(num_slots: int, a: int, b: int) -> int:
    d = abs(a - b) % num_slots
    return min(d, num_slots - d)


def ring_hops_matrix(num_slots: int) -> np.ndarray:
    """hops[a, b] = ring_hops(num_slots, a, b) for every slot pair.

    The single source of truth for the intra-plane ISL hop metric in
    vectorized code; ``repro.orbits.topology.ISLTopology`` reproduces it
    exactly as the per-plane blocks of the ring topology's hop matrix
    (equivalence-tested).
    """
    slots = np.arange(num_slots)
    d = np.abs(slots[:, None] - slots[None, :]) % num_slots
    return np.minimum(d, num_slots - d)


def graph_broadcast_schedule(
    hops: np.ndarray,
    latency: np.ndarray,
    source_nodes: Sequence[int],
    t_source: Sequence[float],
) -> List[PropagationEvent]:
    """Flood the model over an arbitrary ISL graph from one or more
    sources; every node keeps its earliest copy (ties resolve to the
    first listed source).

    Args:
      hops: (N, N) hop-count matrix (UNREACHABLE/-1 for disconnected).
      latency: (N, N) relay seconds between node pairs (inf when
        disconnected).
      source_nodes / t_source: nodes holding the model and when.

    Returns one event per node; unreachable nodes get t_receive=inf.
    """
    src = np.asarray(list(source_nodes), dtype=np.intp)
    n = latency.shape[0]
    t_recv, pick = flood_times(latency, src, t_source)
    h = hops[src[pick], np.arange(n)]
    return [
        PropagationEvent(
            slot=int(k),
            t_receive=float(t_recv[k]),
            hops=int(h[k]),
            source_slot=int(src[pick[k]]),
        )
        for k in range(n)
    ]


def graph_relay_schedule(
    hops: np.ndarray,
    latency: np.ndarray,
    sink_node: int,
    t_ready: Sequence[float],
) -> List[PropagationEvent]:
    """Arrival time of each node's trained model at the sink over the
    graph's min-latency paths (store-and-forward, no cut-through)."""
    t_ready = np.asarray(list(t_ready), dtype=np.float64)
    arrive = relay_arrivals(latency, sink_node, t_ready)
    return [
        PropagationEvent(
            slot=int(k),
            t_receive=float(arrive[k]),
            hops=int(hops[k, sink_node]),
            source_slot=int(k),
        )
        for k in range(t_ready.size)
    ]


def broadcast_schedule(
    num_slots: int,
    source_slots: Sequence[int],
    t_source: Sequence[float],
    payload_bits: float,
    isl: ISLConfig,
) -> List[PropagationEvent]:
    """Flood the global model around the ring from one or more sources.

    Args:
      num_slots: satellites on the plane (K).
      source_slots: slots that received w^t directly from the GS.
      t_source: receipt time at each source (same length).
      payload_bits: z|N| of the model.

    Returns:
      One event per slot with its earliest receipt time (duplicates
      dropped by taking the min over sources/directions).
    """
    t_hop = isl_hop_time(isl, payload_bits)
    hops = ring_hops_matrix(num_slots)
    return graph_broadcast_schedule(
        hops, hops * t_hop, source_slots, t_source
    )


def relay_schedule(
    num_slots: int,
    sink_slot: int,
    t_ready: Sequence[float],
    payload_bits: float,
    isl: ISLConfig,
) -> List[PropagationEvent]:
    """Arrival time of each satellite's trained model at the sink.

    ``t_ready[k]`` is when slot k finishes local training.  Each model is
    store-and-forwarded over ring_hops(k, sink) hops (eq. 21's h * t_h
    term).  We model per-hop pipelining conservatively: every model pays
    its full hop count (no cut-through), matching eq. (21)'s max over
    relaying satellites.
    """
    t_hop = isl_hop_time(isl, payload_bits)
    hops = ring_hops_matrix(num_slots)
    return graph_relay_schedule(hops, hops * t_hop, sink_slot, t_ready)


def relay_completion_time(events: Sequence[PropagationEvent]) -> float:
    """Eq. (21): the orbit's t_h^* — all models collected at the sink."""
    return max(e.t_receive for e in events)
