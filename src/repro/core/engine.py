"""Event-driven Satcom FL engine: shared substrate for FedLEO + baselines.

The engine separates:
  * the *simulated clock* — visibility windows, link latencies (eqs.
    5-8, 13-16, 20-21), training durations (eq. 11) — advanced by each
    strategy's scheduling logic, and
  * the *learning* — real JAX training/aggregation via FederatedTask.

Each strategy implements ``step(t) -> (t_next, events)`` which performs
one logical round (sync) or one server event (async) starting at
simulated time t, mutating ``self.global_params``.  ``run`` iterates
until the simulated-hours budget is exhausted, evaluating the global
model after every step to produce the accuracy-vs-time history that the
paper's Table II and Fig. 5 report.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, FrozenSet, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.comms.environment import CommsEnvironment
from repro.comms.isl import ISLConfig
from repro.comms.link import LinkConfig
from repro.compute.fleet import FleetComputeModel
from repro.compute.profiles import SatelliteComputeProfile
from repro.core.fltask import FederatedTask
from repro.obs import (
    NULL_RECORDER,
    GroupDecomposition,
    RoundDecomposition,
    TraceRecorder,
    format_round_line,
    round_log_record,
)
from repro.orbits.constellation import (
    ConstellationConfig,
    GroundStation,
    MultiShellConfig,
)
from repro.orbits.topology import TopologyConfig

PyTree = Any


@dataclasses.dataclass
class SimConfig:
    constellation: "ConstellationConfig | MultiShellConfig" = (
        dataclasses.field(default_factory=ConstellationConfig)
    )
    ground_station: GroundStation = dataclasses.field(
        default_factory=GroundStation
    )
    # Multi-GS scenarios: when non-empty this is the FULL station list
    # (``ground_station`` is ignored) and scheduling uses the union of
    # every station's visibility windows.
    ground_stations: Tuple[GroundStation, ...] = ()
    link: LinkConfig = dataclasses.field(default_factory=LinkConfig)
    isl: ISLConfig = dataclasses.field(default_factory=ISLConfig)
    # ISL graph shape (ring = the paper's intra-plane-only topology) and
    # the optional inter-plane (FSO cross-link) provisioning; intra-
    # plane links keep using ``isl``.  None falls back to ``isl``.
    topology: TopologyConfig = dataclasses.field(
        default_factory=TopologyConfig
    )
    isl_inter: Optional[ISLConfig] = None
    horizon_hours: float = 72.0           # paper simulates 3 days
    coarse_step_s: float = 10.0
    # Peak-transient budget for the vectorized visibility scan: chunk
    # lengths adapt to (num satellites, horizon) to stay under this
    # many MB of concurrent scan arrays (results are bit-identical
    # across budgets — chunking only partitions evaluation).
    mem_budget_mb: float = 256.0
    # Per-station downlink resource-block cap (eq. 13-16: N RBs of B_D
    # each).  None = contention-free (the pre-ledger degenerate case:
    # concurrent sink uploads never compete); an int enables the shared
    # GSResourceLedger so uploads are priced against residual capacity.
    gs_rb_capacity: Optional[int] = None
    # Mid-window station handover: allow a sink upload to split into
    # segments across *different* stations' access windows
    # (plan_segmented_transfer) instead of pinning the whole transfer
    # to one station.  A segmented plan is adopted only when it
    # strictly beats the single-window completion, so False — and any
    # single-station ground segment — is bit-identical to the
    # unsegmented scheduler.
    gs_handover: bool = False
    # Rolling-horizon visibility prediction: chunk length in hours, or
    # None for the legacy prebuilt table over 1.5x horizon_hours.  The
    # rolling table grows on demand (capped at 1.5x horizon_hours) and
    # is bit-identical to the prebuilt one on overlapping ranges.
    rolling_horizon_hours: Optional[float] = None
    # Event-driven async re-admission: the asynchronous strategies
    # (_AsyncStar family, AsyncFLEO) book every upload at schedule
    # time; with this on they register an on_release hook with their
    # CommsEnvironment and re-admit queued uploads in model-ready
    # order whenever a reservation RELEASES capacity
    # (CommsEnvironment.readmit).  Releases come from env.release —
    # an aborted/cancelled cycle, or any other component sharing the
    # session; the stock strategies never abort a booked upload on
    # their own, so until such an event fires the stream is identical
    # to the book-at-schedule-time default.  False (default) does not
    # arm the hook at all; meaningful only under RB contention.
    async_readmit: bool = False
    # Re-admission repair policy (CommsEnvironment.readmit): "monotone"
    # is the per-entry repair (the default; bit-identical to PR 5),
    # "repack" layers the regret-based swap-accepting global re-packer
    # on top — no queued completion may regress vs. the monotone floor.
    readmit_policy: str = "monotone"
    noniid_alpha: float = 0.5             # non-IID-aware weighting blend
    use_kernel: bool = False              # Pallas aggregation path (TPU)
    # Runtime schedule sanitizer (repro.analysis.sanitizer): every
    # commit/release/readmit on the strategy's CommsEnvironment is
    # checked against the paper's feasibility invariants (eqs. 13-16
    # RB capacity, eq. 15 window containment, eqs. 21-22 re-admission
    # monotonicity) and a reservation-leak report runs at sim end.
    # On by default — tests and --quick benchmark smokes run sanitized;
    # timed benchmark arms turn it off.
    sanitize: bool = True
    # Observability (repro.obs): attach a TraceRecorder to the
    # strategy's CommsEnvironment — every plan/commit/release/readmit,
    # rolling-horizon extension and FL round lands in a typed,
    # sim-timestamped trace (export via repro.obs.export, report via
    # ``python -m repro.obs.report``).  Tracing is zero-interference:
    # a traced run is bit-identical to an untraced one (schedules,
    # sink decisions, metrics) — equivalence-tested.  Off by default.
    trace: bool = False
    # Heterogeneous fleet compute model (repro.compute): assigns each
    # plane/satellite a device tier + model arch whose roofline step
    # time replaces eq. (11)'s uniform c_k/f_k, and (opt-in) whose real
    # param count replaces the task's uniform payload.  None (default)
    # keeps the paper's uniform fleet — bit-identical schedules, sink
    # decisions and metrics (equivalence-tested); so does a profile
    # whose every assignment is the degenerate ``arch=None`` tier.
    compute: Optional[SatelliteComputeProfile] = None
    seed: int = 0

    @property
    def all_ground_stations(self) -> Tuple[GroundStation, ...]:
        return tuple(self.ground_stations) or (self.ground_station,)


@dataclasses.dataclass
class HistoryPoint:
    t_hours: float
    round_index: int
    metrics: Dict[str, float]
    events: Dict[str, Any]
    # typed per-round phase decomposition (repro.obs) — the structured
    # replacement for scraping the ``events`` dicts; always populated
    # by ``FLStrategy.run`` (groups are empty for strategies without a
    # group planner)
    decomposition: Optional[RoundDecomposition] = None


@dataclasses.dataclass
class RunResult:
    name: str
    history: List[HistoryPoint]

    @property
    def final_accuracy(self) -> float:
        return self.history[-1].metrics["accuracy"] if self.history else 0.0

    @property
    def final_time_hours(self) -> float:
        return self.history[-1].t_hours if self.history else 0.0

    def convergence_time_hours(self, target_accuracy: float) -> Optional[float]:
        for h in self.history:
            if h.metrics["accuracy"] >= target_accuracy:
                return h.t_hours
        return None

    def summary(self) -> Dict[str, float]:
        return {
            "final_accuracy": self.final_accuracy,
            "final_time_hours": self.final_time_hours,
            "rounds": len(self.history),
        }


class FLStrategy:
    """Base class; subclasses implement one scheduling discipline each."""

    name = "base"

    def __init__(
        self,
        task: FederatedTask,
        sim: SimConfig,
        env: Optional[CommsEnvironment] = None,
    ):
        self.task = task
        self.sim = sim
        # ONE scheduling session per strategy: the environment owns the
        # predictor, the shared RB ledger and the handover policy, and
        # every planning/booking call routes through it.  The
        # multi-tenant JobScheduler injects a per-job session derived
        # over a SHARED ledger; standalone strategies build their own.
        self.env = CommsEnvironment.from_sim(sim) if env is None else env
        self.walker = self.env.walker
        self.gs_list = list(self.env.ground_stations)
        self.gs = self.gs_list[0]
        self.global_params = task.global_params
        self.rng = jax.random.PRNGKey(sim.seed)
        self.round_index = 0
        # the session's trace recorder (attached by from_sim when
        # SimConfig.trace), or the no-op NULL_RECORDER — engine-level
        # call sites never branch
        self.recorder: TraceRecorder = (
            self.env.recorder if self.env.recorder is not None
            else NULL_RECORDER
        )
        # per-round group decompositions, stashed by the round drivers
        # (_SyncRoundMixin) and drained into each HistoryPoint
        self._round_groups: List[GroupDecomposition] = []
        # accumulated accuracy-vs-time history (one point per round);
        # ``run`` drives it for standalone strategies, the multi-tenant
        # JobScheduler through ``run_round`` directly
        self.history: List[HistoryPoint] = []
        self._completed = True
        # heterogeneous fleet compute model: resolved strategy-side
        # from SimConfig.compute (falling back to any model already on
        # the task) WITHOUT mutating the shared task, so one task can
        # serve arms with different fleets.  None = uniform paper fleet.
        if sim.compute is not None:
            num_planes = getattr(sim.constellation, "num_planes", 0)
            self.compute: Optional[FleetComputeModel] = FleetComputeModel(
                sim.compute, num_planes
            )
        else:
            self.compute = task.compute
        # multi-tenant release floor: with a SHARED ledger, dropping
        # bookings up to this strategy's own clock could purge
        # intervals a slower concurrent job still prices against — the
        # JobScheduler installs min-over-active-job-clocks here.  None
        # (standalone) releases up to the strategy's own clock, the
        # bit-identical single-tenant behavior.
        self.release_floor_fn: Optional[Any] = None

    @property
    def predictor(self) -> Any:
        """The session's visibility predictor (back-compat alias)."""
        return self.env.predictor

    @property
    def ledger(self) -> Any:
        """The session's RB ledger, or None (back-compat alias)."""
        return self.env.ledger

    # -- helpers ---------------------------------------------------------------
    def _next_rng(self) -> jax.Array:
        self.rng, sub = jax.random.split(self.rng)
        return sub

    @property
    def payload_bits(self) -> float:
        return float(self.task.payload_bits)

    def train_time_s(self, client_id: int) -> float:
        """Eq. (11) training time of one client, heterogeneous-fleet
        aware: with a compute model resolved, the client satellite's
        roofline per-sample cost prices the batches the task actually
        executes; otherwise (or for degenerate-tier satellites) this is
        exactly ``task.train_time_s``."""
        if self.compute is not None:
            c = self.task.clients[client_id]
            hp = self.task.hp
            n_batches, bsz = self.task.executed_batches(client_id)
            t = self.compute.train_time_s(
                c.plane, c.slot, local_epochs=hp.local_epochs,
                n_batches=n_batches, batch_size=bsz,
            )
            if t is not None:
                return t
        return self.task.train_time_s(client_id)

    def sat_payload_bits(self, plane: int, slot: int = 0) -> float:
        """Comm payload z|N| of satellite (plane, slot): the task's
        uniform payload unless the compute profile opts into
        arch-derived sizes (``payload_from_arch``)."""
        if self.compute is not None and self.compute.payload_aware:
            bits = self.compute.payload_bits(plane, slot)
            if bits is not None:
                return float(bits)
        return float(self.task.payload_bits)

    def group_payload_bits(self, planes: Sequence[int]) -> float:
        """Conservative payload for a multi-plane group transfer: the
        max over member planes' slot-0 payloads (intra-plane
        propagation ships one aggregated model per plane, so the widest
        member bounds every hop).  Equals ``payload_bits`` for
        payload-unaware fleets."""
        if self.compute is None or not self.compute.payload_aware:
            return self.payload_bits
        return max(self.sat_payload_bits(p) for p in planes)

    def plane_clients(self, plane: int) -> List[int]:
        return self.task.clients_on_plane(plane)

    def open_reservations(self) -> FrozenSet[int]:
        """Reservation ids this strategy still legitimately holds at
        sim end — exempted from the sanitizer's leak report.  The async
        strategies override the ``_pending`` queue this reads: a queued
        upload booked beyond the horizon is live state, not a leak."""
        pending = getattr(self, "_pending", None) or {}
        return frozenset(
            p.reservation.rid for p in pending.values()
        )

    def _take_round_groups(self) -> Tuple[GroupDecomposition, ...]:
        """Drain the group decompositions the last ``step`` stashed
        (empty for strategies without a group planner)."""
        groups = tuple(self._round_groups)
        self._round_groups = []
        return groups

    # -- strategy API -----------------------------------------------------------
    def step(self, t: float) -> Tuple[float, Dict[str, Any]]:
        raise NotImplementedError

    def run_round(self, t: float, verbose: bool = False) -> Optional[float]:
        """Advance the strategy by ONE FL round starting at simulated
        time ``t``: expire spent bookings, run ``step``, evaluate the
        global model and append the ``HistoryPoint``.  Returns the
        round completion time (the next round's start), or None when no
        feasible progress exists inside the horizon — the aborted step
        may leave half-planned bookings, so the final leak report is
        skipped.  ``run`` drives this for standalone strategies; the
        multi-tenant ``JobScheduler`` calls it directly to interleave
        rounds of concurrent jobs (a single job through the scheduler
        executes the identical call sequence — bit-identical)."""
        # simulated time is monotone: bookings that ended before this
        # round can never affect another fit (under a shared ledger the
        # floor callback holds back expiry for slower concurrent jobs)
        floor = t if self.release_floor_fn is None else self.release_floor_fn(t)
        self.env.release_before(floor)
        t_next, events = self.step(t)
        if t_next is None or t_next <= t:
            self._completed = False
            return None
        self.round_index += 1
        metrics = self.task.evaluate(self.global_params)
        decomposition = RoundDecomposition(
            round_index=self.round_index,
            t_start=t,
            t_end=t_next,
            groups=self._take_round_groups(),
        )
        self.history.append(
            HistoryPoint(
                t_hours=t_next / 3600.0,
                round_index=self.round_index,
                metrics=metrics,
                events=events,
                decomposition=decomposition,
            )
        )
        self.recorder.on_round(decomposition, metrics)
        if verbose:
            record = round_log_record(
                self.name, self.round_index, t_next / 3600.0, metrics
            )
            self.recorder.on_round_log(record)
            print(format_round_line(record))
        return t_next

    def finish(self, t: float) -> None:
        """Close the session at simulated time ``t`` (sanitizer leak
        report, unless a round aborted mid-plan)."""
        self.env.finish_session(
            t, open_rids=self.open_reservations(),
            check_leaks=self._completed,
        )

    def run(
        self,
        max_sim_hours: Optional[float] = None,
        max_rounds: Optional[int] = None,
        verbose: bool = False,
    ) -> RunResult:
        # `is None`, not `or`: max_sim_hours=0 means a zero-length run,
        # not the full horizon
        hours = self.sim.horizon_hours if max_sim_hours is None else max_sim_hours
        max_s = hours * 3600.0
        t = 0.0
        while t < max_s and (max_rounds is None or self.round_index < max_rounds):
            t_next = self.run_round(t, verbose=verbose)
            if t_next is None:
                break
            t = t_next
        self.finish(t)
        return RunResult(name=self.name, history=list(self.history))
