"""FedLEO core: model propagation, sink scheduling, aggregation, engines."""
from repro.core.aggregation import (
    global_aggregate,
    noniid_weights,
    partial_aggregate,
    weighted_average,
)
from repro.core.engine import FLStrategy, RunResult, SimConfig
from repro.core.fedleo import (
    FedLEO,
    FedLEOGrid,
    form_clusters,
    make_clusters,
    plan_cluster_round,
    plan_plane_round,
)
from repro.core.fltask import FederatedTask, TrainHyperparams
from repro.core.propagation import (
    broadcast_schedule,
    graph_broadcast_schedule,
    graph_relay_schedule,
    relay_schedule,
)
from repro.core.scheduling import (
    HandoverSpec,
    SegmentedPlan,
    TransferSegment,
    plan_segmented_transfer,
    reserve_decision,
    select_sink,
    select_sink_cluster,
)

__all__ = [
    "FedLEOGrid",
    "HandoverSpec",
    "SegmentedPlan",
    "TransferSegment",
    "form_clusters",
    "make_clusters",
    "plan_segmented_transfer",
    "reserve_decision",
    "plan_cluster_round",
    "plan_plane_round",
    "graph_broadcast_schedule",
    "graph_relay_schedule",
    "select_sink_cluster",
    "global_aggregate",
    "noniid_weights",
    "partial_aggregate",
    "weighted_average",
    "FLStrategy",
    "RunResult",
    "SimConfig",
    "FedLEO",
    "FederatedTask",
    "TrainHyperparams",
    "broadcast_schedule",
    "relay_schedule",
    "select_sink",
]
