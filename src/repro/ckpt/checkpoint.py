"""Sharding-aware npz checkpointing (offline container: no orbax).

Pytrees are flattened with jax.tree_util key paths as archive keys, so
restore round-trips arbitrary nested dict/list/namedtuple structures
against a matching template.  Large arrays are gathered to host per
leaf (fine at the scales exercised on CPU; on a real pod this layer
would be swapped for per-shard array serialization, which the API shape
already permits).
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any

_SAFE = re.compile(r"[^A-Za-z0-9_.\-]")


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return _SAFE.sub("_", "/".join(parts))


def save_checkpoint(directory: str, step: int, tree: PyTree) -> str:
    os.makedirs(directory, exist_ok=True)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {}
    for i, (path, leaf) in enumerate(leaves):
        arrays[f"{i:05d}__{_key_str(path)}"] = np.asarray(leaf)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.match(r"ckpt_(\d+)\.npz$", name)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, template: PyTree) -> PyTree:
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with np.load(path) as data:
        keys = sorted(data.files, key=lambda k: int(k.split("__")[0]))
        arrays = [data[k] for k in keys]
    leaves, treedef = jax.tree_util.tree_flatten(template)
    if len(arrays) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, template {len(leaves)}"
        )
    out = [
        np.asarray(a, dtype=l.dtype) if hasattr(l, "dtype") else a
        for a, l in zip(arrays, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, out)
