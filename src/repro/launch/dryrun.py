import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# Multi-pod dry-run: lower + compile every (architecture x input shape)
# on the production meshes, with no device allocation (ShapeDtypeStruct
# inputs only).  NOTE: the XLA_FLAGS line above MUST run before any jax
# import (device count locks on first init), hence no module docstring.
#
# For each pair this prints/records:
#   * compiled.memory_analysis()  — proves the sharded program fits,
#   * compiled.cost_analysis()    — FLOPs/bytes for §Roofline,
#   * collective-bytes breakdown parsed from the compiled HLO.
#
# Usage:
#   python -m repro.launch.dryrun --arch mistral-large-123b --shape train_4k
#   python -m repro.launch.dryrun --multi-pod --out results.jsonl
import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, INPUT_SHAPES, build_model, get_config
from repro.configs.base import ArchConfig, InputShape
from repro.launch import specs as speclib
from repro.launch.mesh import make_production_mesh, use_mesh_compat
from repro.optim import get_optimizer
from repro.train.steps import (
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

# --- HLO collective-bytes accounting -------------------------------------------------
_COLLECTIVE_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(\(?[^=]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(", re.M,
)
_SHAPE_RE = re.compile(r"(bf16|f32|f16|f64|s32|u32|s8|u8|pred|s64|u64)"
                       r"\[([\d,]*)\]")
_DTYPE_BYTES = {
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
    "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8,
}


def cost_analysis_dict(compiled) -> Dict[str, Any]:
    """compiled.cost_analysis() as a flat dict on every JAX version.

    0.4.x returns a one-element list of per-computation dicts; newer
    releases return the dict directly.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-shape bytes of every collective op in the HLO."""
    out: Dict[str, float] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shapes_str, kind, suffix = m.group(2), m.group(3), m.group(4)
        if suffix == "-done":
            continue  # counted at -start
        total = 0
        for sm in _SHAPE_RE.finditer(shapes_str):
            dt, dims = sm.group(1), sm.group(2)
            n = 1
            if dims:
                for d in dims.split(","):
                    if d:
                        n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + total
    return out


# --- per-pair dry run ------------------------------------------------------------------
def lower_pair(
    arch: str,
    shape_name: str,
    mesh,
    *,
    cfg: Optional[ArchConfig] = None,
    fsdp_axes=None,
    sharding_mode: str = "fsdp2d",   # or "zero1" (EXPERIMENTS.md §Perf)
    donate: bool = True,
):
    """Build and lower the right step for (arch, shape) on a mesh.

    Returns (lowered, meta) where meta records what was lowered.
    """
    cfg = cfg or get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    window = speclib.sliding_window_for(cfg, shape)
    # chunked = flash-style online-softmax attention in pure XLA: the
    # production path for full-sequence shapes (never materializes SxS).
    attn_impl = "chunked" if shape.kind in ("train", "prefill") else "xla"
    model = build_model(cfg, sliding_window=window, attn_impl=attn_impl)
    fsdp_axes = fsdp_axes or tuple(
        a for a in ("data",) if a in mesh.axis_names
    )

    meta = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "kind": shape.kind, "window": window,
    }

    if sharding_mode == "zero1":
        param_axes, opt_axes = (), ("data",)
    else:
        param_axes, opt_axes = fsdp_axes, fsdp_axes
    meta["sharding"] = sharding_mode

    with use_mesh_compat(mesh):
        if shape.kind == "train":
            state_sds = speclib.state_specs(model, cfg, mesh, param_axes,
                                            opt_fsdp_axes=opt_axes)
            batch_sds = speclib.batch_specs(cfg, shape, mesh)
            opt = get_optimizer(cfg.optimizer, cfg.learning_rate)
            step = make_train_step(model, opt)
            fn = jax.jit(step, donate_argnums=(0,) if donate else ())
            lowered = fn.lower(state_sds, batch_sds)
        elif shape.kind == "prefill":
            p_sds = speclib.params_specs(model, mesh, param_axes)
            batch_sds = speclib.batch_specs(cfg, shape, mesh)
            step = make_prefill_step(model)
            lowered = jax.jit(step).lower(p_sds, batch_sds)
        else:  # decode
            p_sds = speclib.params_specs(model, mesh, param_axes)
            cache_sds = speclib.cache_specs(model, cfg, shape, mesh,
                                            param_axes)
            tok_sds = speclib.token_specs(cfg, shape, mesh)
            pos_sds = speclib.sds((), jnp.int32, mesh)
            step = make_serve_step(model)
            fn = jax.jit(step, donate_argnums=(2,) if donate else ())
            lowered = fn.lower(p_sds, tok_sds, cache_sds, pos_sds)
    return lowered, meta


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             verbose: bool = True, cfg: Optional[ArchConfig] = None
             ) -> Dict[str, Any]:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    lowered, meta = lower_pair(arch, shape_name, mesh, cfg=cfg)
    t_lower = time.time() - t0

    t1 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t1

    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    result = dict(meta)
    result.update(
        {
            "ok": True,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "flops": float(cost.get("flops", -1)) if cost else None,
            "bytes_accessed": float(cost.get("bytes accessed", -1))
            if cost else None,
            "collective_bytes": coll,
            "memory": _mem_dict(mem),
        }
    )
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} mesh={result['mesh']}  "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s")
        print(f"  memory_analysis: {result['memory']}")
        print(f"  cost_analysis: flops={result['flops']:.3e} "
              f"bytes={result['bytes_accessed']:.3e}")
        print(f"  collectives: { {k: f'{v:.3e}' for k, v in coll.items()} }")
    return result


def _mem_dict(mem) -> Optional[Dict[str, float]]:
    if mem is None:
        return None
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = float(v)
    return out or {"repr": str(mem)[:500]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS) + ["all"],
                    default="all")
    ap.add_argument("--shape", choices=list(INPUT_SHAPES) + ["all"],
                    default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None, help="append-JSONL output path")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]

    done = set()
    if args.out and args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("ok"):
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass

    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes:
            mesh_tag = "2x16x16" if args.multi_pod else "16x16"
            if (arch, shape, mesh_tag) in done:
                print(f"[dryrun] skip {arch} x {shape} (cached)")
                continue
            try:
                res = run_pair(arch, shape, multi_pod=args.multi_pod)
                n_ok += 1
            except Exception as e:
                traceback.print_exc()
                res = {"arch": arch, "shape": shape, "mesh": mesh_tag,
                       "ok": False, "error": f"{type(e).__name__}: {e}"}
                n_fail += 1
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(res) + "\n")
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
