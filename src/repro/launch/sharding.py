"""Parameter/activation sharding rules (FSDP x tensor parallel).

Assigns a PartitionSpec to every pytree leaf by its *name* (path) and
trailing shape, then guards divisibility: a dim is sharded only if the
mesh axis size divides it (e.g. GQA kv-head projections with 8 kv heads
replicate across a 16-way model axis instead of sharding unevenly).

Leading "extra" dims (scan stacks (L, ...), FedLEO orbit replicas) are
padded with None on the left automatically.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# name -> base spec for the TRAILING dims, using the roles:
#   F = FSDP axis ("data" [+ "pod"]), T = tensor axis ("model")
_RULES = [
    # attention
    (r"(^|/)wq$", ("F", "T", None)),
    (r"(^|/)wk$", ("F", "T", None)),
    (r"(^|/)wv$", ("F", "T", None)),
    (r"(^|/)wo$", ("T", None, "F")),
    # dense / shared-expert GLU FFN
    (r"(^|/)w_gate$", ("F", "T")),
    (r"(^|/)w_up$", ("F", "T")),
    (r"(^|/)w_down$", ("T", "F")),
    # MoE (leading expert dim -> expert parallel over T)
    (r"moe.*router$|(^|/)router$", ("F", None)),
    # embeddings / lm head
    (r"(^|/)table$", ("T", "F")),
    (r"lm_head.*(^|/)w$", ("F", "T")),
    # mamba2
    (r"(^|/)in_proj$", ("F", "T")),
    (r"(^|/)out_proj$", ("T", "F")),
    (r"(^|/)conv_w$", (None, "T")),
    (r"(^|/)conv_b$", ("T",)),
    # norms & scalars: replicated
    (r"(^|/)(scale|bias|A_log|D|dt_bias)$", None),
]

# MoE expert tensors carry a leading E dim; detect via path containing
# "moe" and 3 trailing dims on w_gate/w_up/w_down.
_MOE_RULES = [
    (r"(^|/)w_gate$", ("T", "F", None)),
    (r"(^|/)w_up$", ("T", "F", None)),
    (r"(^|/)w_down$", ("T", None, "F")),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _resolve(role, fsdp_axes, model_ax):
    if role == "F":
        if not fsdp_axes:
            return None          # TP-only / ZeRO-1 parameter layout
        return fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
    if role == "T":
        return model_ax
    return None


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def spec_for_leaf(
    path_str: str,
    shape: Tuple[int, ...],
    mesh: Mesh,
    fsdp_axes: Tuple[str, ...] = ("data",),
    model_ax: str = "model",
    leading_replica_axis: Optional[str] = None,
) -> P:
    """PartitionSpec for one leaf; unmatched names are replicated."""
    is_moe_expert = (
        "moe" in path_str
        and re.search(r"(^|/)(w_gate|w_up|w_down)$", path_str)
        and "shared" not in path_str
        and len(shape) >= 3
    )
    rules = _MOE_RULES if is_moe_expert else _RULES
    base = None
    matched = False
    for pat, spec in rules:
        if re.search(pat, path_str):
            base = spec
            matched = True
            break
    if not matched or base is None:
        base = ()

    ndim = len(shape)
    if len(base) > ndim:
        # optimizer-state leaf with reduced rank (adafactor row/col):
        # replicate — it is O(rows + cols), not worth sharding.
        base = ()
    pad = ndim - len(base)
    full = [None] * pad + [
        _resolve(r, fsdp_axes, model_ax) for r in base
    ]
    # divisibility guard
    out = []
    for dim, axis in zip(shape, full):
        if axis is not None and dim % _axis_size(mesh, axis) == 0:
            out.append(axis)
        else:
            out.append(None)
    if leading_replica_axis is not None and ndim >= 1:
        rep_size = mesh.shape[leading_replica_axis]
        if shape[0] % rep_size == 0:
            out[0] = leading_replica_axis
    return P(*out)


def tree_shardings(
    tree_shapes: PyTree,
    mesh: Mesh,
    fsdp_axes: Tuple[str, ...] = ("data",),
    model_ax: str = "model",
    leading_replica_axis: Optional[str] = None,
) -> PyTree:
    """NamedSharding tree matching a pytree of ShapeDtypeStructs."""

    def one(path, leaf):
        spec = spec_for_leaf(
            _path_str(path), leaf.shape, mesh, fsdp_axes, model_ax,
            leading_replica_axis,
        )
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, tree_shapes)


def with_shardings(tree_shapes: PyTree, shardings: PyTree) -> PyTree:
    """Attach shardings to ShapeDtypeStructs (for AOT .lower())."""
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_shapes,
        shardings,
    )


def batch_sharding(mesh: Mesh, batch_size: int) -> Tuple[str, ...]:
    """Largest prefix of (pod, data) that divides the batch."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    chosen = []
    size = 1
    for a in axes:
        if batch_size % (size * mesh.shape[a]) == 0:
            chosen.append(a)
            size *= mesh.shape[a]
    return tuple(chosen)
