"""Production meshes (TPU v5e).

Functions, not module-level constants: importing this module never
touches jax device state (the dry-run entry point sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE any jax
import; smoke tests and benches see the single real CPU device).
"""
from __future__ import annotations

from typing import Tuple

import jax


def _axis_type_kwargs(n: int) -> dict:
    """``axis_types=(Auto,) * n`` where supported.

    ``jax.sharding.AxisType`` (and the ``axis_types`` kwarg of
    ``jax.make_mesh``) only exist on newer JAX releases; 0.4.x meshes are
    implicitly Auto, so omitting the kwarg is the exact equivalent.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_mesh_compat(shape, axes):
    """jax.make_mesh with Auto axis types on any supported JAX version."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(shape)))


def use_mesh_compat(mesh):
    """Context manager activating ``mesh``, on any supported JAX version.

    Newer JAX exposes ``jax.set_mesh``; on 0.4.x the Mesh object itself
    is the context manager (all our shardings are explicit NamedShardings
    anyway, so the context only needs to exist, not alter semantics).
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_fedleo_mesh(*, num_orbits: int = 4, multi_pod: bool = False):
    """Mesh for the FedLEO hierarchical training step (DESIGN.md §3).

    The leading ``orbit`` axis carries the per-orbit model replicas
    (paper: orbital planes); gradient sync during local steps stays
    inside ("data", "model"); the scheduled sink->GS aggregation is the
    only collective crossing ``orbit``.  On the multi-pod mesh the orbit
    axis is the pod axis (2 orbits of 256 chips); single-pod it splits
    the data axis (num_orbits x (16/num_orbits) x 16).
    """
    if multi_pod:
        return make_mesh_compat((2, 16, 16), ("orbit", "data", "model"))
    assert 16 % num_orbits == 0, "orbit count must divide the data axis"
    return make_mesh_compat(
        (num_orbits, 16 // num_orbits, 16), ("orbit", "data", "model")
    )


def data_axes(mesh) -> Tuple[str, ...]:
    """Axes carrying the global batch (and the FSDP param dim)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"
