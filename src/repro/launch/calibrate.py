"""Measured calibration for the fleet compute model.

The one place the compute stack touches the wall clock: time a real
jitted train step of an arch's SMOKE config on this host.  Lives in
``launch/`` (not ``compute/``) because the repo lint bans wall-clock
reads inside the simulation packages — ``compute.roofline`` calls in
here lazily for its "measured" mode and caches the result.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.registry import build_model, get_smoke_config
from repro.optim import get_optimizer
from repro.train.steps import TrainState, make_train_step


def _smoke_batch(cfg, seq_len: int, global_batch: int) -> Dict:
    """A synthetic batch matching ``train/steps`` layouts."""
    tokens = jnp.zeros((global_batch, seq_len), dtype=jnp.int32)
    batch: Dict = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["extra"] = jnp.zeros(
            (global_batch, cfg.vision.num_patches, cfg.d_model),
            dtype=jnp.bfloat16,
        )
    if cfg.family == "audio":
        batch["source"] = jnp.zeros(
            (global_batch, cfg.encoder.max_source_len, cfg.d_model),
            dtype=jnp.bfloat16,
        )
    return batch


def measure_smoke_step_s(
    arch_id: str,
    *,
    seq_len: int = 128,
    global_batch: int = 4,
    iters: int = 3,
) -> float:
    """Wall seconds of one jitted smoke-config train step on this host.

    Compiles once (excluded), then takes the minimum over ``iters``
    fully-blocked executions — the minimum is the standard noise-robust
    estimator for a deterministic step."""
    cfg = get_smoke_config(arch_id)
    model = build_model(cfg, dtype=jnp.float32)
    opt = get_optimizer(cfg.optimizer, cfg.learning_rate)
    step = jax.jit(make_train_step(model, opt))
    params = model.init(jax.random.PRNGKey(0))
    state = TrainState(
        params=params, opt_state=opt.init(params),
        step=jnp.zeros((), jnp.int32),
    )
    batch = _smoke_batch(cfg, seq_len, global_batch)
    state, metrics = step(state, batch)             # compile + warm up
    jax.block_until_ready(metrics)
    best = float("inf")
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        state, metrics = step(state, batch)
        jax.block_until_ready(metrics)
        best = min(best, time.perf_counter() - t0)
    return best
