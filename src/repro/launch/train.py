"""Training driver: --arch <id> end-to-end LM training on synthetic
token data, with checkpointing and optional FedLEO hierarchical mode.

On CPU use the smoke configs (--smoke); the full configs are exercised
via the dry-run.  Example:

  PYTHONPATH=src python -m repro.launch.train --arch gemma-7b --smoke \
      --steps 50 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch gemma-7b --smoke \
      --fedleo --orbits 2 --tau 5 --steps 40
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import ARCH_IDS, build_model, get_config, get_smoke_config
from repro.data.synthetic import make_token_dataset
from repro.optim import get_optimizer
from repro.train.fedleo_step import (
    make_fedleo_aggregate,
    make_fedleo_local_step,
    replicate_for_orbits,
)
from repro.train.steps import TrainState, make_train_step


def _batches(tokens: np.ndarray, batch: int, seq: int, rng: np.random.Generator):
    n, s = tokens.shape
    assert s >= seq
    while True:
        rows = rng.integers(0, n, size=batch)
        col = rng.integers(0, s - seq + 1)
        yield {"tokens": jnp.asarray(tokens[rows][:, col: col + seq])}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fedleo", action="store_true",
                    help="hierarchical FedLEO local-SGD training")
    ap.add_argument("--orbits", type=int, default=2)
    ap.add_argument("--tau", type=int, default=5,
                    help="local steps between FedLEO aggregations")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family in ("vlm", "audio"):
        raise SystemExit(
            "train.py drives the LM path; use examples/ for multimodal"
        )
    model = build_model(cfg)
    opt = get_optimizer(cfg.optimizer, cfg.learning_rate)
    train_step = jax.jit(make_train_step(model, opt))

    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng)
    state = TrainState(params=params, opt_state=opt.init(params),
                       step=jnp.zeros((), jnp.int32))

    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            state = restore_checkpoint(args.ckpt_dir, last, state)
            print(f"[train] restored step {last}")

    ds = make_token_dataset(num_sequences=256, seq_len=args.seq * 2,
                            vocab_size=cfg.vocab_size, seed=args.seed)
    nprng = np.random.default_rng(args.seed)
    batches = _batches(ds.x, args.batch, args.seq, nprng)

    if args.fedleo:
        local_step = jax.jit(make_fedleo_local_step(model, opt))
        aggregate = jax.jit(make_fedleo_aggregate())
        state = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (args.orbits,) + x.shape), state
        )
        weights = jnp.ones((args.orbits,))
        t0 = time.time()
        for step_i in range(args.steps):
            rep_batch = {
                "tokens": jnp.stack(
                    [next(batches)["tokens"][None]
                     for _ in range(args.orbits)]
                )
            }
            state, metrics = local_step(state, rep_batch)
            if (step_i + 1) % args.tau == 0:
                state = aggregate(state, weights)
                tag = " [aggregated]"
            else:
                tag = ""
            loss = float(jnp.mean(metrics["loss"]))
            print(f"[fedleo] step {step_i + 1:4d} loss={loss:.4f}{tag}")
        print(f"[fedleo] {args.steps} steps in {time.time() - t0:.1f}s")
    else:
        t0 = time.time()
        for step_i in range(args.steps):
            state, metrics = train_step(state, next(batches))
            if (step_i + 1) % 10 == 0 or step_i == 0:
                print(f"[train] step {step_i + 1:4d} "
                      f"loss={float(metrics['loss']):.4f}")
            if args.ckpt_dir and (step_i + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, step_i + 1, state)
        print(f"[train] {args.steps} steps in {time.time() - t0:.1f}s "
              f"({args.steps / (time.time() - t0):.2f} steps/s)")


if __name__ == "__main__":
    main()
