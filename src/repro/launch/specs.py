"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(cfg, shape, mesh)`` returns the batch pytree for the input
shape's step kind; ``state_specs`` / ``cache_specs`` abstract-eval the
train state / decode cache and attach shardings.  Everything here is
weak-type-correct and shardable — the dry-run lowers against these.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.launch import sharding as shardlib
from repro.optim import get_optimizer
from repro.train.steps import TrainState

PyTree = Any


def sds(shape, dtype, mesh=None, spec=None):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=NamedSharding(mesh, spec or P())
    )


def sliding_window_for(cfg: ArchConfig, shape: InputShape) -> Optional[int]:
    """Window policy: long_500k uses the sub-quadratic variant for
    attention-bearing archs (DESIGN.md §4); other shapes use full attn."""
    if shape.name != "long_500k":
        return None
    if cfg.family == "ssm":
        return None                      # attention-free
    return cfg.sliding_window


def batch_specs(
    cfg: ArchConfig, shape: InputShape, mesh: Optional[Mesh] = None
) -> Dict[str, jax.ShapeDtypeStruct]:
    """Train/prefill batch pytree (decode uses token_specs/cache_specs)."""
    b, s = shape.global_batch, shape.seq_len
    dp = shardlib.batch_sharding(mesh, b) if mesh is not None else ()
    dp_spec = dp if len(dp) != 1 else dp[0]
    batch = {
        "tokens": sds((b, s), jnp.int32, mesh,
                      P(dp_spec, None) if mesh else None)
    }
    if cfg.family == "vlm":
        batch["extra"] = sds(
            (b, cfg.vision.num_patches, cfg.d_model), jnp.bfloat16, mesh,
            P(dp_spec, None, None) if mesh else None,
        )
    if cfg.family == "audio":
        batch["source"] = sds(
            (b, cfg.encoder.max_source_len, cfg.d_model), jnp.bfloat16,
            mesh, P(dp_spec, None, None) if mesh else None,
        )
    return batch


def params_specs(model, mesh: Mesh, fsdp_axes=("data",),
                 rng_seed: int = 0) -> PyTree:
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(rng_seed))
    shardings = shardlib.tree_shardings(shapes, mesh, fsdp_axes=fsdp_axes)
    return shardlib.with_shardings(shapes, shardings)


def state_specs(model, cfg: ArchConfig, mesh: Mesh,
                fsdp_axes=("data",),
                opt_fsdp_axes=None) -> PyTree:
    """Sharding modes:
      * FSDP-2D (baseline): fsdp_axes=("data",) — params 2-D sharded
        (data x model); contraction-dim sharding causes redundant compute
        (see EXPERIMENTS.md §Perf).
      * ZeRO-1 (optimized): fsdp_axes=(), opt_fsdp_axes=("data",) —
        params TP-only (replicated over data), optimizer state sharded
        over data; sharded update + update all-gather.
    """
    opt_fsdp_axes = fsdp_axes if opt_fsdp_axes is None else opt_fsdp_axes
    p_specs = params_specs(model, mesh, fsdp_axes)
    opt = get_optimizer(cfg.optimizer, cfg.learning_rate)
    opt_shapes = jax.eval_shape(opt.init, p_specs)
    opt_shardings = shardlib.tree_shardings(opt_shapes, mesh,
                                            fsdp_axes=opt_fsdp_axes)
    opt_sds = shardlib.with_shardings(opt_shapes, opt_shardings)
    step_sds = sds((), jnp.int32, mesh, P())
    return TrainState(params=p_specs, opt_state=opt_sds, step=step_sds)


def cache_specs(
    model, cfg: ArchConfig, shape: InputShape, mesh: Mesh,
    fsdp_axes=("data",),
) -> PyTree:
    """Decode-cache ShapeDtypeStructs with batch/head sharding."""
    b = shape.global_batch
    if cfg.family == "audio":
        p_specs = params_specs(model, mesh, fsdp_axes)
        src = batch_specs(cfg, shape, mesh)["source"]
        shapes = jax.eval_shape(
            functools.partial(model.init_cache, max_len=shape.seq_len),
            p_specs, src,
        )
    else:
        shapes = jax.eval_shape(
            functools.partial(model.init_cache, b, shape.seq_len)
        )
    dp = shardlib.batch_sharding(mesh, b)
    dp_spec = dp if len(dp) != 1 else (dp[0] if dp else None)

    def one(path, leaf):
        # find the batch dim: caches are (B, ...) or stacked (L, B, ...)
        shp = leaf.shape
        spec = [None] * len(shp)
        for i, d in enumerate(shp[:3]):
            if d == b and dp:
                spec[i] = dp_spec
                break
        # shard a kv-head / ssm-head dim over model when divisible
        path_s = shardlib._path_str(path)
        for i in range(len(shp) - 1, 0, -1):
            if spec[i] is None and shp[i] > 1 and \
                    shp[i] % mesh.shape["model"] == 0 and i >= 2:
                if ("ssm" in path_s or "k" == path_s.split("/")[-1]
                        or "v" == path_s.split("/")[-1]
                        or "conv" in path_s):
                    spec[i] = "model"
                    break
        return jax.ShapeDtypeStruct(
            shp, leaf.dtype, sharding=NamedSharding(mesh, P(*spec))
        )

    return jax.tree_util.tree_map_with_path(one, shapes)


def token_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh):
    b = shape.global_batch
    dp = shardlib.batch_sharding(mesh, b)
    dp_spec = dp if len(dp) != 1 else (dp[0] if dp else None)
    return sds((b, 1), jnp.int32, mesh, P(dp_spec, None))
