"""Constellation + ground-segment presets for scenario scaling.

The paper's experiment uses a 40-satellite Walker delta (5 planes x 8
sats at 1500 km).  The production-scale engine must also cover
mega-constellation shells, so the presets below parameterize the same
``ConstellationConfig`` at Starlink/Kuiper/OneWeb scale (first-shell
public filing parameters; circular-orbit Walker idealization as in
§III's system model).

Ground-segment presets pair the paper's Rolla, MO station with common
high-latitude polar teleport sites so multi-GS (union-of-windows)
scheduling scenarios are one call away.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, Optional, Sequence, Tuple, Union

if TYPE_CHECKING:
    from repro.core.engine import SimConfig

from repro.orbits.constellation import (
    ConstellationConfig,
    GroundStation,
    MultiShellConfig,
)
from repro.orbits.topology import TopologyConfig, get_topology

CONSTELLATION_PRESETS: Dict[str, ConstellationConfig] = {
    # the paper's §V-A setup: 40 sats, 5 planes, 1500 km, 80 deg
    "paper-5x8": ConstellationConfig(),
    # mid-size shell for scaling studies
    "walker-12x12": ConstellationConfig(
        num_planes=12, sats_per_plane=12, altitude_m=1200.0e3,
        inclination_deg=70.0, phasing_factor=1,
    ),
    # Starlink shell 2-like: 720 sats in 40 planes at 550 km / 53 deg
    # (the 40x22 scale ISSUE/ROADMAP track for the perf trajectory)
    "starlink-40x22": ConstellationConfig(
        num_planes=40, sats_per_plane=22, altitude_m=550.0e3,
        inclination_deg=53.0, phasing_factor=13,
    ),
    # Starlink gen1 full first shell: 1584 sats in 72 planes at 550 km
    # / 53 deg (the mega-constellation scale target)
    "starlink-gen1": ConstellationConfig(
        num_planes=72, sats_per_plane=22, altitude_m=550.0e3,
        inclination_deg=53.0, phasing_factor=39,
    ),
    # Kuiper first shell-like: 34 planes x 34 sats at 630 km / 51.9 deg
    "kuiper-34x34": ConstellationConfig(
        num_planes=34, sats_per_plane=34, altitude_m=630.0e3,
        inclination_deg=51.9, phasing_factor=11,
    ),
    # OneWeb-like polar shell: 12 planes x 49 sats at 1200 km / 87.9 deg
    "oneweb-12x49": ConstellationConfig(
        num_planes=12, sats_per_plane=49, altitude_m=1200.0e3,
        inclination_deg=87.9, phasing_factor=1,
    ),
}

MULTI_SHELL_PRESETS: Dict[str, MultiShellConfig] = {
    # Starlink gen1 shell + an idealized higher-inclination 570 km shell
    # (Walker idealization of the gen2 "550-ish + 570/70 deg" layering;
    # sats_per_plane kept at 22 so the (plane, slot) grid stays
    # rectangular across shells — 2376 satellites total).
    "starlink-2shell": MultiShellConfig(
        shells=(
            ConstellationConfig(
                num_planes=72, sats_per_plane=22, altitude_m=550.0e3,
                inclination_deg=53.0, phasing_factor=39,
            ),
            ConstellationConfig(
                num_planes=36, sats_per_plane=22, altitude_m=570.0e3,
                inclination_deg=70.0, phasing_factor=5,
            ),
        ),
        cross_max_range_m=1500.0e3,
        cross_links_per_sat=1,
    ),
}

GROUND_STATION_PRESETS: Dict[str, GroundStation] = {
    # the paper's GS (Rolla, MO) — the ConstellationConfig default
    "rolla": GroundStation(),
    # high-latitude teleports: long frequent passes for inclined shells
    "svalbard": GroundStation(
        lat_deg=78.229, lon_deg=15.408, alt_m=450.0,
        min_elevation_deg=10.0, name="Svalbard-NO",
    ),
    "punta-arenas": GroundStation(
        lat_deg=-53.163, lon_deg=-70.917, alt_m=30.0,
        min_elevation_deg=10.0, name="Punta-Arenas-CL",
    ),
    "awarua": GroundStation(
        lat_deg=-46.529, lon_deg=168.381, alt_m=10.0,
        min_elevation_deg=10.0, name="Awarua-NZ",
    ),
    # the ideal-setup pole station used by FedISL/FedSat baselines
    "north-pole": GroundStation(
        lat_deg=89.5, lon_deg=0.0, alt_m=0.0,
        min_elevation_deg=5.0, name="North-Pole",
    ),
}


def get_constellation(
    name: str,
) -> "ConstellationConfig | MultiShellConfig":
    if name in MULTI_SHELL_PRESETS:
        return MULTI_SHELL_PRESETS[name]
    if name not in CONSTELLATION_PRESETS:
        raise ValueError(
            f"unknown constellation {name!r}; have "
            f"{sorted(CONSTELLATION_PRESETS) + sorted(MULTI_SHELL_PRESETS)}"
        )
    return CONSTELLATION_PRESETS[name]


def get_ground_stations(
    names: Sequence[str],
) -> Tuple[GroundStation, ...]:
    out = []
    for n in names:
        if n not in GROUND_STATION_PRESETS:
            raise ValueError(
                f"unknown ground station {n!r}; have "
                f"{sorted(GROUND_STATION_PRESETS)}"
            )
        out.append(GROUND_STATION_PRESETS[n])
    return tuple(out)


# Default ISL topology per constellation shell: mega-constellation
# shells fly optical inter-plane cross-links (+Grid); the paper's small
# setup and the polar OneWeb-like shell keep the intra-plane ring (the
# OneWeb-like shell's near-polar seam makes sustained cross-links at
# the seam infeasible — use "grid-seam-cut" explicitly to model it).
CONSTELLATION_TOPOLOGY: Dict[str, str] = {
    "paper-5x8": "ring",
    "walker-12x12": "grid",
    "starlink-40x22": "grid",
    "starlink-gen1": "grid",
    "kuiper-34x34": "grid",
    "oneweb-12x49": "ring",
    "starlink-2shell": "grid",
}


def make_sim_config(
    constellation: str = "paper-5x8",
    ground_stations: Sequence[str] = ("rolla",),
    topology: Optional[Union[str, TopologyConfig]] = None,
    rb_contention: bool = False,
    handover: bool = False,
    **overrides: object,
) -> "SimConfig":
    """SimConfig from presets: FedLEO and every baseline in
    ``core/baselines.py`` run on any constellation/ground-segment pair.

    ``topology`` opts into the ISL graph layer: a preset name ("ring",
    "grid", "grid-seam-cut", ...), a TopologyConfig, or "auto" for the
    shell's default (``CONSTELLATION_TOPOLOGY``).  When a topology is
    requested, intra- and inter-plane ISL configs are derived from the
    constellation geometry (``ISLConfig.from_constellation``: real
    chord/c propagation delays; FSO rates on inter-plane links).
    Omitting it keeps the legacy paper provisioning untouched.

    ``rb_contention=True`` opts into honest per-station downlink
    resource-block accounting: ``SimConfig.gs_rb_capacity`` is set to
    the link's RB count (eq. 13's N, Table I default 8) so concurrent
    sink uploads on one station compete for its RB pool via the shared
    ``GSResourceLedger``.  The default keeps the contention-free
    degenerate case (``gs_rb_capacity=None`` — bit-identical to the
    pre-ledger scheduler).  Pass ``gs_rb_capacity=...`` directly for a
    non-default cap, or ``rolling_horizon_hours=...`` to grow the
    visibility table incrementally instead of prebuilding 1.5x the
    horizon.

    ``handover=True`` opts into mid-window station handover
    (``SimConfig.gs_handover``): sink uploads may split into segments
    across different stations' overlapping windows instead of pinning
    the whole transfer to one station — meaningful with a multi-GS
    ground segment; with a single station it is bit-identical to the
    unsegmented scheduler.

    Extra keyword arguments override SimConfig fields (horizon_hours,
    coarse_step_s, gs_rb_capacity, rolling_horizon_hours,
    gs_handover, ...).
    """
    from repro.core.engine import SimConfig

    cfg = get_constellation(constellation)
    gss = get_ground_stations(ground_stations)
    kwargs = dict(
        constellation=cfg,
        ground_station=gss[0],
        ground_stations=gss if len(gss) > 1 else (),
    )
    if topology is not None:
        from repro.comms.isl import ISLConfig

        if topology == "auto":
            topology = CONSTELLATION_TOPOLOGY[constellation]
        topo_cfg = get_topology(topology)
        kwargs["topology"] = topo_cfg
        kwargs["isl"] = ISLConfig.from_constellation(cfg, "intra")
        if topo_cfg.has_inter_links:
            kwargs["isl_inter"] = ISLConfig.from_constellation(
                cfg, "inter", topology=topo_cfg
            )
    kwargs.update(overrides)     # explicit overrides win over presets
    if rb_contention and kwargs.get("gs_rb_capacity") is None:
        from repro.comms.link import LinkConfig

        link = kwargs.get("link") or LinkConfig()
        kwargs["gs_rb_capacity"] = link.num_resource_blocks
    if handover:
        kwargs.setdefault("gs_handover", True)
    return SimConfig(**kwargs)
