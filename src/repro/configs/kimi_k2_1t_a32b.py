"""kimi-k2-1t-a32b [moe] — Kimi K2, trillion-param MoE [arXiv:2501.kimi2].

61L, d_model=7168, 64 heads (GQA kv=8), expert d_ff=2048, vocab=163840,
MoE 384 experts top-8 + 1 shared expert (DeepSeek-V3-style fine-grained
experts).  ~1T total / ~32B active parameters.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    source="arXiv:2501.kimi2",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,
    d_ff=2048,                   # per-expert hidden (fine-grained)
    vocab_size=163840,
    activation="silu",
    rope_theta=50_000.0,
    moe=MoEConfig(
        num_experts=384,
        top_k=8,
        d_ff_expert=2048,
        capacity_factor=1.25,
        num_shared_experts=1,
    ),
    moe_every=1,                 # every layer MoE
    long_context_mode="sliding_window",
    optimizer="adafactor",       # 1T params: factored state mandatory
    learning_rate=6e-5,
)


def smoke_config() -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=128,
        vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                      capacity_factor=1.25, num_shared_experts=1),
        moe_every=1,
        remat=False,
    )
