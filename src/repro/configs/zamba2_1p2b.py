"""zamba2-1.2b [hybrid] — Zamba2 [arXiv:2411.15242].

38 Mamba2 layers, d_model=2048, + one SHARED attention block (32 heads,
kv=32, d_ff=8192) re-applied every 6 Mamba layers; vocab=32000,
ssm_state=64.  long_500k: Mamba state is O(1); the shared attention
block uses the sliding-window cache.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm=SSMConfig(state_dim=64, head_dim=64, num_groups=1,
                  chunk_size=128, conv_width=4, expand=2),
    hybrid_attn_every=6,
    long_context_mode="native",
    tie_embeddings=True,
    optimizer="adam",
    learning_rate=3e-4,
)


def smoke_config() -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        num_layers=4,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        ssm=SSMConfig(state_dim=16, head_dim=32, num_groups=1,
                      chunk_size=32, conv_width=4, expand=2),
        hybrid_attn_every=2,
        remat=False,
    )
