"""seamless-m4t-large-v2 [audio] — SeamlessM4T v2 large [arXiv:2308.11596].

24L encoder + 24L decoder, d_model=1024, 16 heads (kv=16), d_ff=8192,
vocab=256206 (NLLB).  The speech frontend (mel-spectrogram + conformer
conv feature extractor) is the allowed STUB: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, d_model); this config covers the
transformer backbone (encoder + autoregressive text decoder).
"""
from repro.configs.base import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    source="arXiv:2308.11596",
    num_layers=24,               # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    activation="silu",
    encoder=EncoderConfig(num_layers=24, max_source_len=1024),
    long_context_mode="sliding_window",
    optimizer="adam",
    learning_rate=3e-4,
)


def smoke_config() -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        encoder=EncoderConfig(num_layers=2, max_source_len=64),
        remat=False,
    )
