"""gemma-7b [dense] — Gemma 7B [arXiv:2403.08295].

28L, d_model=3072, 16 heads (kv=16; the 2b variant uses MQA),
head_dim=256 (attention inner dim 4096 > d_model), d_ff=24576, GeGLU,
vocab=256000, tied embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    family="dense",
    source="arXiv:2403.08295",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    activation="gelu",          # GeGLU
    tie_embeddings=True,
    long_context_mode="sliding_window",
    optimizer="adam",
    learning_rate=3e-4,
)


def smoke_config() -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        remat=False,
    )
