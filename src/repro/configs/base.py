"""Architecture + input-shape configuration schema.

Every assigned architecture gets one ``<id>.py`` in this package exposing
``CONFIG`` (the exact published dims, citation in ``source``) and
``smoke_config()`` (a reduced same-family variant for CPU smoke tests:
<=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01   # load-balance loss weight
    num_shared_experts: int = 0     # always-on shared expert(s) (kimi/deepseek style)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int           # N (ssm_state)
    head_dim: int = 64       # P
    num_groups: int = 1      # B/C groups
    chunk_size: int = 128    # SSD chunk length Q
    conv_width: int = 4
    expand: int = 2          # d_inner = expand * d_model


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (audio) archs; frontend is stubbed."""

    num_layers: int
    max_source_len: int = 1024   # stubbed frame/patch embedding count


@dataclasses.dataclass(frozen=True)
class VisionStub:
    """VLM vision-frontend stub: precomputed patch embeddings."""

    num_patches: int = 256
    embed_dim: Optional[int] = None  # defaults to d_model


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    source: str                     # citation (paper/model card)
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads
    activation: str = "silu"        # silu (SwiGLU) | gelu (GeGLU)
    rope_theta: float = 10000.0
    logit_soft_cap: Optional[float] = None
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    moe_every: int = 1              # MoE block every n-th layer (1 = all)
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: int = 6      # hybrid: shared attn block interval
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionStub] = None

    # long-context policy for the 500k decode shape (see DESIGN.md §4)
    long_context_mode: str = "sliding_window"   # or "native" (SSM/hybrid)
    sliding_window: int = 8192

    # training-system choices
    optimizer: str = "adam"
    learning_rate: float = 3e-4
    remat: bool = True              # activation checkpointing per layer
    scan_layers: bool = True

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count_estimate(self) -> int:
        """Rough N for MODEL_FLOPS = 6*N*D bookkeeping (dense part exact
        enough for roofline purposes; MoE counts all experts)."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = d * hd * (self.num_heads * 2 + self.num_kv_heads * 2)
        if self.moe is not None:
            moe_layers = sum(
                1 for i in range(self.num_layers)
                if (i % self.moe_every) == self.moe_every - 1
            )
            dense_layers = self.num_layers - moe_layers
            ffn = dense_layers * 3 * d * self.d_ff + moe_layers * (
                self.moe.num_experts * 3 * d * self.moe.d_ff_expert + d * self.moe.num_experts
            )
        elif self.ssm is not None and self.family == "ssm":
            d_in = self.ssm.expand * d
            ffn = self.num_layers * (
                2 * d * d_in + d_in * d + d_in * self.ssm.state_dim * 2
            )
            attn = 0
        else:
            ffn = self.num_layers * 3 * d * self.d_ff
        layers = self.num_layers * attn if self.family != "ssm" else 0
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return layers + ffn + embed

    def active_param_count_estimate(self) -> int:
        """N_active for MoE (top-k experts instead of all)."""
        if self.moe is None:
            return self.param_count_estimate()
        full = self.param_count_estimate()
        moe_layers = sum(
            1 for i in range(self.num_layers)
            if (i % self.moe_every) == self.moe_every - 1
        )
        all_exp = moe_layers * self.moe.num_experts * 3 * self.d_model * self.moe.d_ff_expert
        act_exp = moe_layers * (self.moe.top_k + self.moe.num_shared_experts) \
            * 3 * self.d_model * self.moe.d_ff_expert
        return full - all_exp + act_exp


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
