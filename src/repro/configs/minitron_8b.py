"""minitron-8b [dense] — Minitron (pruned Nemotron-4) [arXiv:2407.14679].

32L, d_model=4096, 32 heads (GQA kv=8), d_ff=16384, vocab=256000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b",
    family="dense",
    source="arXiv:2407.14679",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    activation="silu",
    long_context_mode="sliding_window",
    optimizer="adam",
    learning_rate=3e-4,
)


def smoke_config() -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        remat=False,
    )
