"""llama4-maverick-400b-a17b [moe] — Llama 4 Maverick.

[hf:meta-llama/Llama-4-Scout-17B-16E (family card)]
48L, d_model=5120, 40 heads (GQA kv=8), d_ff=8192, vocab=202048,
MoE 128 experts top-1 interleaved every other layer (Maverick's
interleave_moe_layer_step=2) + 1 shared expert; early fusion multimodal
(text path exercised; vision tokens enter as embeddings).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    activation="silu",
    rope_theta=500_000.0,
    moe=MoEConfig(
        num_experts=128,
        top_k=1,
        d_ff_expert=8192,
        capacity_factor=2.0,     # top-1 needs headroom against imbalance
        num_shared_experts=1,
    ),
    moe_every=2,                 # dense / MoE interleave
    long_context_mode="sliding_window",
    optimizer="adafactor",
    learning_rate=1e-4,
)


def smoke_config() -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=1, d_ff_expert=256,
                      capacity_factor=2.0, num_shared_experts=1),
        moe_every=2,
        remat=False,
    )
