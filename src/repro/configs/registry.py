"""Architecture registry: --arch <id> -> config + model factory."""
from __future__ import annotations

import importlib
from typing import Any, Optional

from repro.configs.base import ArchConfig

_MODULES = {
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b_a17b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "gemma-7b": "repro.configs.gemma_7b",
    "mamba2-780m": "repro.configs.mamba2_780m",
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "minitron-8b": "repro.configs.minitron_8b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise ValueError(f"unknown arch {arch_id!r}; have {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def get_smoke_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise ValueError(f"unknown arch {arch_id!r}; have {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).smoke_config()


def build_model(
    cfg: ArchConfig,
    *,
    attn_impl: str = "xla",
    ssd_impl: str = "xla",
    dtype: Any = None,
    sliding_window: Optional[int] = None,
) -> Any:
    """Instantiate the model class for a config.

    sliding_window: pass cfg.sliding_window to build the sub-quadratic
    long-context variant (used for the long_500k input shape).
    """
    import jax.numpy as jnp

    dtype = dtype or jnp.bfloat16
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models.transformer import Transformer

        return Transformer(cfg, attn_impl=attn_impl, dtype=dtype,
                           sliding_window=sliding_window)
    if cfg.family == "audio":
        from repro.models.transformer import EncoderDecoder

        return EncoderDecoder(cfg, attn_impl=attn_impl, dtype=dtype,
                              sliding_window=sliding_window)
    if cfg.family == "ssm":
        from repro.models.mamba2 import Mamba2Model

        return Mamba2Model(cfg, dtype=dtype, ssd_impl=ssd_impl)
    if cfg.family == "hybrid":
        from repro.models.hybrid import Zamba2Model

        return Zamba2Model(cfg, dtype=dtype, attn_impl=attn_impl,
                           ssd_impl=ssd_impl, sliding_window=sliding_window)
    raise ValueError(f"unknown family {cfg.family!r}")
