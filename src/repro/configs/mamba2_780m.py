"""mamba2-780m [ssm] — Mamba2 / SSD [arXiv:2405.21060].

48L, d_model=1536, attention-free, vocab=50280, ssm_state=128.
d_inner = 2*1536 = 3072, head_dim=64 -> 48 SSD heads.
Runs long_500k natively (O(1) recurrent state).
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=48,
    d_model=1536,
    num_heads=0,                 # attention-free
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, num_groups=1,
                  chunk_size=128, conv_width=4, expand=2),
    long_context_mode="native",
    tie_embeddings=True,
    optimizer="adam",
    learning_rate=3e-4,
)


def smoke_config() -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        vocab_size=512,
        ssm=SSMConfig(state_dim=32, head_dim=32, num_groups=1,
                      chunk_size=32, conv_width=4, expand=2),
        remat=False,
    )
