"""mistral-large-123b [dense] — Mistral Large Instruct 2407.

[hf:mistralai/Mistral-Large-Instruct-2407]
88L, d_model=12288, 96 heads (GQA kv=8), d_ff=28672, vocab=32768.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-large-123b",
    family="dense",
    source="hf:mistralai/Mistral-Large-Instruct-2407",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    activation="silu",
    rope_theta=1_000_000.0,
    long_context_mode="sliding_window",
    optimizer="adafactor",      # 123B: factored state to fit v5e HBM
    learning_rate=1e-4,
)


def smoke_config() -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        remat=False,
    )
