"""internvl2-26b [vlm] — InternVL2 26B [arXiv:2404.16821].

InternViT-6B vision encoder + InternLM2-20B language model.  The vision
frontend (ViT + MLP projector) is the allowed STUB: ``input_specs``
provides projected patch embeddings (B, P, d_model); this config covers
the language transformer: 48L, d_model=6144, 48 heads (GQA kv=8),
d_ff=16384, vocab=92553.
"""
from repro.configs.base import ArchConfig, VisionStub

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    source="arXiv:2404.16821",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    activation="silu",
    rope_theta=1_000_000.0,
    vision=VisionStub(num_patches=256),
    long_context_mode="sliding_window",
    optimizer="adafactor",
    learning_rate=1e-4,
)


def smoke_config() -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        vision=VisionStub(num_patches=16),
        remat=False,
    )
