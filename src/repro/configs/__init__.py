"""Assigned-architecture configs + registry."""
from repro.configs.base import (
    ArchConfig,
    EncoderConfig,
    InputShape,
    INPUT_SHAPES,
    MoEConfig,
    SSMConfig,
    VisionStub,
)
from repro.configs.registry import (
    ARCH_IDS,
    build_model,
    get_config,
    get_smoke_config,
)
from repro.configs.constellations import (
    CONSTELLATION_PRESETS,
    GROUND_STATION_PRESETS,
    get_constellation,
    get_ground_stations,
    make_sim_config,
)

__all__ = [
    "CONSTELLATION_PRESETS",
    "GROUND_STATION_PRESETS",
    "get_constellation",
    "get_ground_stations",
    "make_sim_config",
    "ArchConfig",
    "EncoderConfig",
    "InputShape",
    "INPUT_SHAPES",
    "MoEConfig",
    "SSMConfig",
    "VisionStub",
    "ARCH_IDS",
    "build_model",
    "get_config",
    "get_smoke_config",
]
