"""Assigned-architecture configs + registry."""
from repro.configs.base import (
    ArchConfig,
    EncoderConfig,
    InputShape,
    INPUT_SHAPES,
    MoEConfig,
    SSMConfig,
    VisionStub,
)
from repro.configs.registry import (
    ARCH_IDS,
    build_model,
    get_config,
    get_smoke_config,
)

__all__ = [
    "ArchConfig",
    "EncoderConfig",
    "InputShape",
    "INPUT_SHAPES",
    "MoEConfig",
    "SSMConfig",
    "VisionStub",
    "ARCH_IDS",
    "build_model",
    "get_config",
    "get_smoke_config",
]
