"""phi3-medium-14b [dense] — Phi-3 Medium [arXiv:2404.14219].

40L, d_model=5120, 40 heads (GQA kv=10), d_ff=17920, vocab=100352.
RoPE + SwiGLU + GQA.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b",
    family="dense",
    source="arXiv:2404.14219",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab_size=100352,
    activation="silu",
    long_context_mode="sliding_window",
    optimizer="adam",
    learning_rate=3e-4,
)


def smoke_config() -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        remat=False,
    )
