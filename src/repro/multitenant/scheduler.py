"""Multi-tenant FL: N concurrent jobs scheduled over one constellation.

The ROADMAP's "millions of users" scenario: independent FL jobs
(different models, payload sizes, deadlines) share the same ground
segment — the per-station RB pools of eqs. 13-16 — instead of each
pretending the constellation is private.  FedSpace (So et al. 2022)
shows GS connectivity scheduling is exactly where naive multi-client
schedulers collapse; Razmi et al. (2109.01348) motivate the
admission/queueing semantics when jobs arrive over time.

``JobScheduler`` runs one ``CommsEnvironment`` session per job
(``derive`` over the base session), all backed by ONE shared
``GSResourceLedger``: every job's planner prices its uploads against
the residual capacity every other job's bookings leave behind, and
ledger booking ids keep identical intervals distinguishable across
sessions.  On top of the shared substrate the scheduler adds:

  admission   at arrival, a job's projected RB-seconds demand
              (``projected_demand_rb_s``: rounds x uploads/round x the
              eq. 16 per-RB service time z / (R / N)) is compared
              against the ledger's residual RB-seconds over
              [arrival, deadline] (``residual_fraction``): infeasible
              even on an EMPTY ledger -> rejected; feasible but not in
              the current residual -> queued (re-checked whenever a
              job finishes); otherwise admitted.
  tiers       jobs advance strictly by priority tier (lower first);
              within a tier, weighted max-min fairness over served
              RB-seconds — the next round always goes to the running
              job with the smallest served_rb_s / weight (ties: the
              earlier job clock, then submission order).  Service is
              metered through the session's ``on_commit``/
              ``on_release`` hooks (net booked RB-seconds), so
              re-admission churn cancels out.
  re-packing  ``SimConfig.readmit_policy="repack"`` upgrades every
              job's queued-upload repair from per-entry monotone to
              the regret-based swap re-packer
              (``CommsEnvironment.readmit``); the monotone result
              stays a per-entry floor either way.

Each job advances one FL round at a time (``FLStrategy.run_round`` —
any object satisfying ``RoundRunner`` works, e.g. the benchmark's
planner-level jobs).  Job clocks are independent; expiry of spent
bookings is held back to the slowest running job's clock
(``release_floor``) so one job's progress never purges intervals a
slower job still prices against the shared ledger.  With a single job
the floor is the job's own clock, admission is trivially satisfied and
the scheduler executes exactly the call sequence of
``FLStrategy.run`` — bit-identical to the standalone run
(equivalence-tested; the repo's degenerate-case discipline).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Protocol, Tuple

import numpy as np

from repro.comms.environment import CommsEnvironment
from repro.comms.link import LinkConfig
from repro.core.engine import SimConfig

# job lifecycle states
QUEUED = "queued"
RUNNING = "running"
FINISHED = "finished"
STALLED = "stalled"          # a round found no feasible window
REJECTED = "rejected"

# rid namespace stride between job sessions: reservation ids stay
# globally unique across concurrent sessions, so merged traces and
# cross-session tooling never conflate two jobs' bookings
RID_STRIDE = 1_000_000


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """One tenant's FL job, as the admission controller sees it."""

    name: str
    arrival_s: float = 0.0              # job submission (absolute sim s)
    deadline_s: Optional[float] = None  # absolute completion deadline
    rounds: Optional[int] = None        # FL rounds to run (None = horizon)
    tier: int = 0                       # priority tier (lower runs first)
    weight: float = 1.0                 # max-min fairness weight in tier
    payload_bits: Optional[float] = None    # per-upload model size z
    uploads_per_round: int = 1          # projected RB bookings per round

    def __post_init__(self) -> None:
        if self.weight <= 0:
            raise ValueError(f"job {self.name!r}: weight must be > 0")
        if self.uploads_per_round < 1:
            raise ValueError(
                f"job {self.name!r}: uploads_per_round must be >= 1"
            )


class RoundRunner(Protocol):
    """What the scheduler drives: one FL round per call.  ``FLStrategy``
    satisfies this; the benchmarks use planner-level runners."""

    env: CommsEnvironment
    release_floor_fn: Optional[Callable[[float], float]]

    def run_round(self, t: float, verbose: bool = False) -> Optional[float]:
        ...

    def finish(self, t: float) -> None:
        ...


# builds the job's runner over its derived (shared-ledger) session
RunnerFactory = Callable[[CommsEnvironment], RoundRunner]


@dataclasses.dataclass
class JobRecord:
    """Outcome of one job through the scheduler."""

    name: str
    status: str
    tier: int
    weight: float
    arrival_s: float
    deadline_s: Optional[float]
    admitted_at_s: Optional[float] = None
    finished_at_s: Optional[float] = None
    rounds_done: int = 0
    # absolute completion time of every finished round, in order
    round_completions_s: List[float] = dataclasses.field(
        default_factory=list
    )
    served_rb_s: float = 0.0

    @property
    def deadline_met(self) -> Optional[bool]:
        if self.deadline_s is None or self.finished_at_s is None:
            return None
        return self.finished_at_s <= self.deadline_s

    def round_latencies_s(self) -> List[float]:
        """Per-round completion latency measured from job arrival —
        the benchmark's p95 metric."""
        return [t - self.arrival_s for t in self.round_completions_s]


@dataclasses.dataclass
class _Job:
    spec: JobSpec
    index: int
    factory: RunnerFactory
    record: JobRecord
    runner: Optional[RoundRunner] = None
    env: Optional[CommsEnvironment] = None
    t: float = 0.0                      # the job's own simulated clock


def registry_payload_bits(
    arch_id: str, *, bits_per_param: int = 32, smoke: bool = True
) -> float:
    """Per-upload payload size z (bits) for a tenant training one of
    the registry architectures — param count estimate x quantization.
    ``smoke=True`` (default) sizes the scaled-down smoke config, the
    realistic per-satellite shard for multi-tenant scenarios; the full
    configs are far beyond any single eq. 16 window."""
    from repro.configs.registry import get_config, get_smoke_config

    cfg = get_smoke_config(arch_id) if smoke else get_config(arch_id)
    return float(cfg.param_count_estimate()) * bits_per_param


def projected_demand_rb_s(
    spec: JobSpec, link: Optional[LinkConfig]
) -> Optional[float]:
    """The admission controller's demand projection: RB-seconds this
    job will book if admitted — rounds x uploads/round x the nominal
    eq. 16 per-RB service time (payload z over the per-RB rate ceiling
    R / N; distance-dependent rate loss makes the true figure larger,
    so this projection is deliberately optimistic and admission errs
    toward queueing at the residual check, not here).  None when the
    spec carries no payload size (nothing to project)."""
    if spec.payload_bits is None or link is None:
        return None
    rounds = spec.rounds if spec.rounds is not None else 1
    rb_rate_bps = link.data_rate_bps / link.num_resource_blocks
    per_upload_s = float(spec.payload_bits) / rb_rate_bps
    return rounds * spec.uploads_per_round * per_upload_s


class JobScheduler:
    """N concurrent FL jobs over one constellation and one shared
    RB ledger.  ``submit`` every job, then ``run`` to completion.

    Args:
      sim: the shared scenario (constellation, stations, RB capacity,
        horizon).  ``sim.gs_rb_capacity`` sizes the SHARED ledger.
      base_env: optional pre-built base session (e.g. to share an
        expensive predictor across benchmark arms); defaults to
        ``CommsEnvironment.from_sim(sim)``.  Its ledger becomes the
        shared one.
      sanitize/trace: attach a per-job ``ScheduleSanitizer`` /
        ``TraceRecorder`` to every job session (violations and events
        carry the job label).
      admission_margin: admit only when projected demand fits within
        this fraction of the residual RB-seconds (1.0 = exact fit).
      fairness: within-tier round ordering — "maxmin" (weighted max-min
        over served RB-seconds, the default) or "edf" (earliest
        absolute deadline first; deadline-less jobs last in the tier).
    """

    def __init__(
        self,
        sim: SimConfig,
        *,
        base_env: Optional[CommsEnvironment] = None,
        sanitize: bool = False,
        trace: bool = False,
        admission_margin: float = 1.0,
        fairness: str = "maxmin",
    ) -> None:
        if fairness not in ("maxmin", "edf"):
            raise ValueError(
                f"unknown fairness {fairness!r}; have ('maxmin', 'edf')"
            )
        self.sim = sim
        self.base_env = (
            CommsEnvironment.from_sim(sim) if base_env is None else base_env
        )
        self.ledger = self.base_env.ledger
        self.sanitize = bool(sanitize)
        self.trace = bool(trace)
        self.admission_margin = float(admission_margin)
        self.fairness = fairness
        self._jobs: List[_Job] = []
        self._horizon_s = sim.horizon_hours * 3600.0

    # -- submission / admission ------------------------------------------------
    def submit(self, spec: JobSpec, factory: RunnerFactory) -> None:
        """Register one job; admission runs when ``run`` reaches its
        arrival time."""
        if any(j.spec.name == spec.name for j in self._jobs):
            raise ValueError(f"duplicate job name {spec.name!r}")
        record = JobRecord(
            name=spec.name, status=QUEUED, tier=spec.tier,
            weight=spec.weight, arrival_s=spec.arrival_s,
            deadline_s=spec.deadline_s,
        )
        self._jobs.append(_Job(spec, len(self._jobs), factory, record))

    def admission_verdict(self, spec: JobSpec, t_from: float) -> str:
        """Admission control at time ``t_from``: RUNNING (admit),
        QUEUED (feasible but the current residual can't hold it) or
        REJECTED (infeasible even on an empty ledger, or the deadline
        already passed).  Jobs without a deadline or payload projection
        are always admitted — nothing to gate on."""
        demand = projected_demand_rb_s(spec, self.base_env.link)
        if spec.deadline_s is None or demand is None:
            return RUNNING
        span = spec.deadline_s - t_from
        if span <= 0:
            return REJECTED
        if self.ledger is None:
            return RUNNING
        caps = self.ledger.capacity
        if any(not np.isfinite(c) for c in caps):
            return RUNNING                  # unlimited station capacity
        empty_supply = sum(caps) * span
        if demand > empty_supply:
            return REJECTED                 # can never fit by deadline
        frac = self.ledger.residual_fraction(t_from, spec.deadline_s)
        residual = float(sum(f * c for f, c in zip(frac, caps))) * span
        if demand <= self.admission_margin * residual:
            return RUNNING
        return QUEUED

    # -- shared-substrate plumbing ---------------------------------------------
    def _release_floor(self, t: float) -> float:
        """Expiry floor for ``release_before`` on the SHARED ledger:
        the slowest running job's clock.  One job's advance must never
        purge bookings a slower job still prices; with a single job
        this is the job's own clock — the standalone behavior."""
        clocks = [
            j.t for j in self._jobs if j.record.status == RUNNING
        ]
        return min([t] + clocks)

    def _meter(self, job: _Job) -> None:
        """Meter the job's net booked RB-seconds through its session
        hooks (commits add leg spans, releases subtract freed spans —
        re-admission's release/restore churn cancels out)."""
        assert job.env is not None

        def on_commit(reservation: Any) -> None:
            job.record.served_rb_s += sum(
                t1 - t0 for _, t0, t1 in reservation.legs
            )

        def on_release(_reservation: Any, freed: Any) -> None:
            job.record.served_rb_s -= sum(t1 - t0 for _, t0, t1 in freed)

        job.env.on_commit(on_commit)
        job.env.on_release(on_release)

    def _start(self, job: _Job, t0: float) -> None:
        env = self.base_env.derive(
            ledger=self.ledger, sanitize=self.sanitize, trace=self.trace,
            job=job.spec.name,
        )
        # disjoint reservation-id namespaces across sessions
        env.set_rid_base(job.index * RID_STRIDE)
        job.env = env
        self._meter(job)
        job.runner = job.factory(env)
        job.runner.release_floor_fn = self._release_floor
        job.t = t0
        job.record.status = RUNNING
        job.record.admitted_at_s = t0

    def _finish(self, job: _Job, status: str) -> None:
        assert job.runner is not None
        job.runner.finish(job.t)
        job.record.status = status
        job.record.finished_at_s = job.t

    # -- the multiplexing loop -------------------------------------------------
    def _eligible(self, job: _Job) -> bool:
        """May this running job start another round?  Mirrors the
        ``FLStrategy.run`` loop condition exactly (t < horizon, rounds
        below the cap) so a single job is bit-identical."""
        if job.t >= self._horizon_s:
            return False
        r = job.spec.rounds
        return r is None or job.record.rounds_done < r

    def _fairness_key(self, job: _Job) -> Tuple[int, float, float, int]:
        """Within-tier round-ordering key (min wins).  "maxmin":
        weighted max-min over served RB-seconds (the default).  "edf":
        earliest absolute deadline first — deadline-less jobs sort last
        within their tier (inf), falling back to the job clock.  Both
        keep the strict tier precedence and the (job clock, submission
        order) tie-break, so single-job runs are unaffected by the
        choice."""
        if self.fairness == "edf":
            d = job.spec.deadline_s
            urgency = float("inf") if d is None else float(d)
        else:
            urgency = job.record.served_rb_s / job.spec.weight
        return (job.spec.tier, urgency, job.t, job.index)

    def _recheck_queued(self, queued: List[_Job], running: List[_Job],
                        t_now: float) -> None:
        """Capacity changed (a job finished): re-run admission for the
        queue in submission order."""
        for job in list(queued):
            t0 = max(job.spec.arrival_s, t_now)
            verdict = self.admission_verdict(job.spec, t0)
            if verdict == RUNNING:
                queued.remove(job)
                self._start(job, t0)
                running.append(job)
            elif verdict == REJECTED:
                queued.remove(job)
                job.record.status = REJECTED

    def run(self) -> List[JobRecord]:
        """Drive every submitted job to completion (or rejection) and
        return the records in submission order."""
        pending = sorted(
            self._jobs, key=lambda j: (j.spec.arrival_s, j.index)
        )
        queued: List[_Job] = []
        running: List[_Job] = []
        while pending or queued or running:
            # process arrivals up to the causal frontier (the slowest
            # running clock; with nothing running, the next arrival)
            frontier = (
                min(j.t for j in running) if running
                else (pending[0].spec.arrival_s if pending else None)
            )
            while pending and (
                frontier is None or pending[0].spec.arrival_s <= frontier
            ):
                job = pending.pop(0)
                verdict = self.admission_verdict(
                    job.spec, job.spec.arrival_s
                )
                if verdict == RUNNING:
                    self._start(job, job.spec.arrival_s)
                    running.append(job)
                elif verdict == QUEUED:
                    queued.append(job)
                else:
                    job.record.status = REJECTED
                if not running:
                    frontier = (
                        pending[0].spec.arrival_s if pending else None
                    )
            if not running:
                if pending:
                    continue
                # nothing running and nothing arriving: no future
                # release events can admit the starved queue
                for job in queued:
                    job.record.status = REJECTED
                break
            # tiers, then weighted max-min fairness over RB-seconds
            job = min(running, key=self._fairness_key)
            if not self._eligible(job):
                running.remove(job)
                self._finish(job, FINISHED)
                self._recheck_queued(queued, running, job.t)
                continue
            assert job.runner is not None
            t_next = job.runner.run_round(job.t)
            if t_next is None:
                running.remove(job)
                self._finish(job, STALLED)
                self._recheck_queued(queued, running, job.t)
                continue
            job.record.rounds_done += 1
            job.record.round_completions_s.append(t_next)
            job.t = t_next
        return [j.record for j in self._jobs]
