"""Multi-tenant FL over one constellation: N concurrent jobs, one
shared per-station RB ledger (eqs. 13-16), admission control, priority
tiers and weighted max-min fairness over RB-seconds.

See ``repro.multitenant.scheduler`` for the full model.
"""
from repro.multitenant.scheduler import (
    FINISHED,
    QUEUED,
    REJECTED,
    RID_STRIDE,
    RUNNING,
    STALLED,
    JobRecord,
    JobScheduler,
    JobSpec,
    RoundRunner,
    projected_demand_rb_s,
    registry_payload_bits,
)

__all__ = [
    "FINISHED",
    "QUEUED",
    "REJECTED",
    "RID_STRIDE",
    "RUNNING",
    "STALLED",
    "JobRecord",
    "JobScheduler",
    "JobSpec",
    "RoundRunner",
    "projected_demand_rb_s",
    "registry_payload_bits",
]
