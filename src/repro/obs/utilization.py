"""Per-station RB-utilization timelines from ledger occupancy / traces.

Two equivalent sources:

  * a live ``GSResourceLedger`` (``ledger_rb_utilization``) — what the
    benchmarks fold into their BENCH rows right after pricing a round,
  * a recorded trace's commit/release events
    (``occupancy_timeline`` / ``trace_rb_utilization``) — what the
    reporter and the Perfetto exporter reconstruct offline.

Utilization is booked RB-seconds over available RB-seconds
(``capacity * span``); stations with unlimited capacity report the raw
booked seconds against a denominator of one RB, which keeps the number
meaningful in the contention-free degenerate case.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

import numpy as np

if TYPE_CHECKING:
    from repro.comms.ledger import GSResourceLedger
    from repro.obs.trace import TraceEvent


def ledger_rb_utilization(
    ledger: "GSResourceLedger", t0: float, t1: float
) -> List[float]:
    """Per-station fraction of RB capacity booked over ``[t0, t1]``
    (unlimited stations are normalized to one RB)."""
    span = max(0.0, t1 - t0)
    if span <= 0.0:
        return [0.0] * ledger.num_stations
    out = []
    for i in range(ledger.num_stations):
        cap = float(ledger.capacity[i])
        denom = span * (cap if np.isfinite(cap) else 1.0)
        out.append(ledger.booked_seconds(i, t0, t1) / denom)
    return out


def occupancy_timeline(
    events: Sequence["TraceEvent"],
) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
    """Reconstruct each station's RB occupancy step function from a
    trace's ``commit``/``release`` spans.

    Returns ``{gs_index: (times, occupancy)}`` where ``occupancy[i]``
    is the booked-RB count from ``times[i]`` until ``times[i+1]`` —
    the counter rows of the Perfetto export.  A released interval
    cancels its committed booking over the freed span."""
    deltas: Dict[int, List[Tuple[float, int]]] = {}
    for ev in events:
        if ev.kind not in ("commit", "release"):
            continue
        gi = int(ev.track.split("/", 1)[1])
        sign = 1 if ev.kind == "commit" else -1
        deltas.setdefault(gi, []).append((ev.t_start_s, sign))
        deltas.setdefault(gi, []).append((ev.t_end_s, -sign))
    out: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
    for gi, evs in deltas.items():
        evs.sort()
        times = np.array([t for t, _ in evs], dtype=np.float64)
        occ = np.cumsum([d for _, d in evs])
        # merge coincident timestamps: keep the final occupancy there
        keep = np.ones(times.size, dtype=bool)
        keep[:-1] = times[1:] != times[:-1]
        out[gi] = (times[keep], occ[keep])
    return out


def trace_rb_utilization(
    events: Sequence["TraceEvent"],
    t0: float,
    t1: float,
    capacities: Optional[Sequence[Optional[int]]] = None,
) -> Dict[int, float]:
    """Per-station booked fraction over ``[t0, t1]`` reconstructed from
    trace events — the offline mirror of ``ledger_rb_utilization``.
    ``capacities[gs_index]`` (None = unlimited -> one-RB normalization)
    usually comes from the trace meta's ``rb_capacity``."""
    span = max(0.0, t1 - t0)
    out: Dict[int, float] = {}
    if span <= 0.0:
        return out
    for gi, (times, occ) in occupancy_timeline(events).items():
        edges = np.concatenate([times, [max(t1, times[-1])]])
        widths = (
            np.clip(edges[1:], t0, t1) - np.clip(edges[:-1], t0, t1)
        )
        booked = float(np.sum(widths * occ))
        cap = None
        if capacities is not None and gi < len(capacities):
            cap = capacities[gi]
        out[gi] = booked / (span * (cap if cap else 1))
    return out
