"""The ONE sanctioned wall-clock read of the observability layer.

Everything in ``repro.obs`` is keyed to the *simulated* clock — the
repo lint bans wall-clock reads across the sim path (``core``,
``comms``, ``orbits``, and ``obs``) because a wall timestamp inside
recorded events would break the traced-equals-untraced bit-identity
contract.  The single legitimate use is *file provenance*: stamping an
exported trace with when it was recorded.  That read lives here, in
the one module the lint exempts, so any other wall-clock use in
``obs/`` is still a finding.
"""
from __future__ import annotations

import time


def recorded_unix_s() -> float:
    """Wall-clock unix seconds, for trace-file provenance only."""
    return time.time()


def run_id() -> str:
    """A collision-resistant id for one recording/benchmark process —
    wall nanoseconds plus nothing else (no randomness: the sim path
    must stay deterministic; two traces written the same nanosecond do
    not happen in practice)."""
    return f"{time.time_ns():x}"
