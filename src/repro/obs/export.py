"""Trace exporters: append-only JSONL and Chrome trace-event (Perfetto).

JSONL layout (one JSON object per line, safely appendable):

  {"kind": "meta", "schema": 1, "run_id": ..., "recorded_unix_s": ...,
   "stations": [...], "counters": {...}, ...}     <- header
  {"kind": "plan"|"commit"|..., "seq": ..., "track": ..., "name": ...,
   "t0": <sim s>, "t1": <sim s>, "attrs": {...}}  <- one per event

``read_trace`` tolerates blank and truncated lines (the same
corrupt-tail discipline as the BENCH trajectory) and accepts
concatenated traces (a later meta line starts a new header; the last
one wins for ``meta``, counters are summed).

``to_chrome_trace`` emits the Chrome trace-event JSON that Perfetto /
``chrome://tracing`` load directly: tracks map to process/thread rows
(rounds, one row per orbital plane, one per ground station), commit
spans become complete ("X") events, instants become "i" events, and
each station gets a booked-RB counter ("C") row reconstructed from the
commit/release lifecycle.  Sim seconds map to microseconds (the
format's native unit).
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Mapping, Optional, Sequence, TextIO, Tuple

import numpy as np

from repro.obs import _walltime
from repro.obs.trace import TRACE_SCHEMA_VERSION, TraceEvent, TraceRecorder
from repro.obs.utilization import occupancy_timeline

# stable process ids per track family (Perfetto groups rows by pid)
_PID_ROUNDS = 1
_PID_PLANES = 2
_PID_STATIONS = 3
_PID_PREDICTOR = 4
_PID_OTHER = 9


def _json_default(obj: Any) -> Any:
    """Serialize the numpy scalars that ride along in event attrs."""
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    raise TypeError(f"not JSON-serializable: {type(obj).__name__}")


def write_trace(
    recorder: TraceRecorder,
    path: str,
    *,
    append: bool = False,
) -> int:
    """Write the recorder's events as JSONL (meta header first).
    ``append=True`` adds a new header+events block to an existing file
    (``read_trace`` merges blocks).  Returns the number of event lines
    written."""
    meta = dict(recorder.meta)
    meta.setdefault("schema", TRACE_SCHEMA_VERSION)
    meta["kind"] = "meta"
    meta["counters"] = dict(recorder.counters)
    meta["recorded_unix_s"] = _walltime.recorded_unix_s()
    meta.setdefault("run_id", _walltime.run_id())
    prefix = ""
    if append:
        # quarantine a truncated final line (a recorder killed
        # mid-write) so this block's meta starts a fresh parseable line
        # — the same corrupt-tail discipline as the BENCH trajectory
        try:
            with open(path, "rb") as fb:
                fb.seek(-1, 2)
                if fb.read(1) not in (b"\n", b""):
                    prefix = "\n"
        except (FileNotFoundError, OSError):
            pass
    with open(path, "a" if append else "w") as f:
        f.write(prefix)
        _dump_line(f, meta)
        for ev in recorder.events:
            _dump_line(f, ev.as_dict())
    return len(recorder.events)


def _dump_line(f: TextIO, obj: Mapping[str, Any]) -> None:
    f.write(json.dumps(obj, default=_json_default) + "\n")


def read_trace(
    path: str,
) -> Tuple[Dict[str, Any], Dict[str, int], List[TraceEvent]]:
    """Parse a JSONL trace: ``(meta, counters, events)``.  Unparseable
    lines (a truncated tail, a corrupt append) are skipped, never
    fatal; multiple meta headers merge (last meta wins, counters sum)."""
    meta: Dict[str, Any] = {}
    counters: Dict[str, int] = {}
    events: List[TraceEvent] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if not isinstance(rec, dict):
                continue
            if rec.get("kind") == "meta":
                for k, v in (rec.get("counters") or {}).items():
                    counters[k] = counters.get(k, 0) + int(v)
                meta.update(
                    {k: v for k, v in rec.items() if k != "counters"}
                )
                continue
            try:
                events.append(TraceEvent.from_dict(rec))
            except (KeyError, TypeError, ValueError):
                continue
    return meta, counters, events


# --- Chrome trace-event / Perfetto ---------------------------------------------
def _track_row(track: str) -> Tuple[int, int]:
    """(pid, tid) of a track string."""
    if track == "rounds":
        return _PID_ROUNDS, 0
    if track == "predictor":
        return _PID_PREDICTOR, 0
    fam, _, idx = track.partition("/")
    if fam == "plane" and idx:
        return _PID_PLANES, int(idx)
    if fam == "gs" and idx:
        return _PID_STATIONS, int(idx)
    return _PID_OTHER, abs(hash(track)) % 1000


def _meta_event(pid: int, name: str, tid: Optional[int] = None,
                label: str = "") -> Dict[str, Any]:
    ev: Dict[str, Any] = {
        "ph": "M", "pid": pid,
        "name": "process_name" if tid is None else "thread_name",
        "args": {"name": label or name},
    }
    if tid is not None:
        ev["tid"] = tid
    return ev


def to_chrome_trace(
    meta: Mapping[str, Any],
    events: Sequence[TraceEvent],
    counters: Optional[Mapping[str, int]] = None,
) -> Dict[str, Any]:
    """Chrome trace-event JSON (the dict; ``json.dump`` it) with
    rounds/planes/stations as named tracks and per-station booked-RB
    counter rows.  Timestamps are simulated microseconds."""
    stations = list(meta.get("stations") or [])
    out: List[Dict[str, Any]] = [
        _meta_event(_PID_ROUNDS, "rounds", label="FL rounds"),
        _meta_event(_PID_PLANES, "planes", label="orbital planes"),
        _meta_event(_PID_STATIONS, "stations", label="ground stations"),
        _meta_event(_PID_PREDICTOR, "predictor",
                    label="visibility predictor"),
    ]
    named_rows = set()
    for ev in events:
        pid, tid = _track_row(ev.track)
        if (pid, tid) not in named_rows:
            label = ev.track
            if pid == _PID_STATIONS and tid < len(stations):
                label = f"{stations[tid]} (gs/{tid})"
            out.append(_meta_event(pid, ev.track, tid=tid, label=label))
            named_rows.add((pid, tid))
        base = {
            "name": ev.name, "cat": ev.kind, "pid": pid, "tid": tid,
            "ts": ev.t_start_s * 1e6, "args": dict(ev.attrs),
        }
        if ev.t_end_s > ev.t_start_s:
            base["ph"] = "X"
            base["dur"] = ev.duration_s * 1e6
        else:
            base["ph"] = "i"
            base["s"] = "t"                 # thread-scoped instant
        out.append(base)
    # booked-RB counter rows reconstructed from the commit/release spans
    for gi, (times, occ) in sorted(occupancy_timeline(events).items()):
        label = (
            f"RBs booked @ {stations[gi]}" if gi < len(stations)
            else f"RBs booked @ gs/{gi}"
        )
        for t, n in zip(times, occ):
            out.append({
                "name": label, "ph": "C", "pid": _PID_STATIONS,
                "tid": gi, "ts": float(t) * 1e6,
                "args": {"booked_rbs": int(n)},
            })
    trace: Dict[str, Any] = {
        "traceEvents": out,
        "displayTimeUnit": "ms",
        "otherData": {
            "schema": meta.get("schema", TRACE_SCHEMA_VERSION),
            "run_id": meta.get("run_id"),
            "counters": dict(counters or {}),
        },
    }
    return trace
