"""repro.obs: the observability layer of the scheduling stack.

Typed, sim-timestamped scheduling traces (``TraceRecorder``), the
per-round phase decomposition that replaces the untyped
``HistoryPoint.events`` scraping (``RoundDecomposition``), RB
utilization timelines, JSONL + Perfetto exporters (``repro.obs.export``)
and the CLI reporter (``python -m repro.obs.report``).

Enable per run with ``SimConfig(trace=True)``; tracing is
zero-interference — a traced run is bit-identical to an untraced one.
"""
from repro.obs.decomposition import (
    GroupDecomposition,
    RoundDecomposition,
    decompose_group_plan,
    mean_phase_seconds,
    round_decomposition,
)
from repro.obs.trace import (
    NULL_RECORDER,
    TRACE_SCHEMA_VERSION,
    TraceEvent,
    TraceRecorder,
    format_round_line,
    round_log_record,
)
from repro.obs.utilization import ledger_rb_utilization

__all__ = [
    "GroupDecomposition",
    "RoundDecomposition",
    "decompose_group_plan",
    "mean_phase_seconds",
    "round_decomposition",
    "NULL_RECORDER",
    "TRACE_SCHEMA_VERSION",
    "TraceEvent",
    "TraceRecorder",
    "format_round_line",
    "round_log_record",
    "ledger_rb_utilization",
]
