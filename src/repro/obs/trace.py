"""TraceRecorder: typed, sim-timestamped scheduling events.

The observability substrate of the repo (ISSUE 7): one recorder per
``CommsEnvironment`` session collects every scheduling action as a
typed ``TraceEvent`` on a named *track* —

  plan        ``plan_upload``/``plan_download`` queries and their
              outcome (instant, on the plane's track),
  commit      booked reservation legs (one span per RB leg, on the
              station's track; ``handover_legs`` > 1 marks a
              station-switching upload),
  release /   capacity lifecycle events of the session ledger,
  readmit
  horizon     rolling-horizon extensions of the ``VisibilityPredictor``
              (plus per-method query counters),
  round       one span per FL round with its ``RoundDecomposition``
              and evaluation metrics attached,
  log         the engine's structured verbose round log.

Everything is keyed to the SIMULATED clock — the recorder never reads
wall time (``repro.analysis.lint`` bans it here too; the single
sanctioned wall-clock shim is ``repro.obs._walltime``, used only to
stamp exported trace files with their recording time).

Zero-interference discipline (the PR 6 sanitizer contract): the
recorder only *appends to its own state* and *reads* scheduling
objects; no hook mutates a schedule, a ledger or the predictor, so a
traced run is bit-identical to an untraced one (equivalence-tested in
``tests/test_obs_trace.py``).  When tracing is off every hook site
guards on ``recorder is None`` / dispatches to ``NULL_RECORDER``.
"""
from __future__ import annotations

import dataclasses
from typing import (
    TYPE_CHECKING,
    Any,
    Callable,
    Dict,
    List,
    Mapping,
    Optional,
    Tuple,
)

from repro.obs.decomposition import RoundDecomposition

if TYPE_CHECKING:
    from repro.comms.environment import CommsEnvironment, Reservation
    from repro.orbits.constellation import Satellite

TRACE_SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One sim-timestamped event.  ``t_start_s == t_end_s`` marks an
    instant; a span covers ``[t_start_s, t_end_s]`` (absolute simulated
    seconds).  ``track`` names the timeline the event belongs to
    ("rounds", "plane/3", "gs/0", "predictor", ...) — the Perfetto
    exporter maps tracks to process/thread rows."""

    seq: int
    kind: str
    track: str
    name: str
    t_start_s: float
    t_end_s: float
    attrs: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return self.t_end_s - self.t_start_s

    def as_dict(self) -> Dict[str, Any]:
        return {
            "seq": self.seq,
            "kind": self.kind,
            "track": self.track,
            "name": self.name,
            "t0": self.t_start_s,
            "t1": self.t_end_s,
            "attrs": dict(self.attrs),
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "TraceEvent":
        return cls(
            seq=int(d["seq"]), kind=str(d["kind"]), track=str(d["track"]),
            name=str(d["name"]), t_start_s=float(d["t0"]),
            t_end_s=float(d["t1"]), attrs=dict(d.get("attrs") or {}),
        )


def _sat_track(plane: int) -> str:
    return f"plane/{plane}"


# interned "predictor.<method>" counter keys (hot-path allocation saver)
_PREDICTOR_KEYS: Dict[str, str] = {}


class TraceRecorder:
    """Collects ``TraceEvent``s and named counters for one scheduling
    session.  Construct directly for ad-hoc use, or let
    ``TraceRecorder.attach(env)`` wire it into a ``CommsEnvironment``
    (plan/commit/release/readmit hooks), its ``VisibilityPredictor``
    (horizon extensions + query counters) and the routing-table cache
    (hit/miss counters)."""

    def __init__(
        self,
        meta: Optional[Mapping[str, Any]] = None,
        job: Optional[str] = None,
    ):
        self.events: List[TraceEvent] = []
        self.counters: Dict[str, int] = {}
        self.meta: Dict[str, Any] = dict(meta or {})
        # multi-tenant job label (``CommsEnvironment.job``): when set,
        # every emitted event carries a ``job`` attr so traces of
        # concurrent sessions merge attributably.  None adds nothing —
        # single-tenant traces stay byte-identical.
        self.job = job
        self._seq = 0
        self._detachers: List[Callable[[], None]] = []

    # -- primitive emitters ----------------------------------------------------
    def span(
        self, kind: str, track: str, name: str,
        t_start_s: float, t_end_s: float, **attrs: Any,
    ) -> None:
        self._seq += 1
        if self.job is not None:
            attrs = {**attrs, "job": self.job}
        self.events.append(TraceEvent(
            self._seq, kind, track, name, float(t_start_s),
            float(t_end_s), attrs,
        ))

    def instant(
        self, kind: str, track: str, name: str, t_s: float, **attrs: Any
    ) -> None:
        self.span(kind, track, name, t_s, t_s, **attrs)

    def count(self, name: str, n: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    # -- CommsEnvironment hooks ------------------------------------------------
    def on_plan(
        self,
        direction: str,
        sat: "Satellite",
        t_request_s: float,
        decision: Optional[Any],
    ) -> None:
        """A ``plan_upload``/``plan_download`` query and its outcome
        (``decision=None`` = infeasible inside the horizon)."""
        self.count(f"plan_{direction}")
        attrs: Dict[str, Any] = {
            "plane": int(sat.plane), "slot": int(sat.slot),
            "feasible": decision is not None,
        }
        if decision is not None:
            attrs["t_xfer_start_s"] = float(decision.t_start)
            attrs["t_xfer_done_s"] = float(decision.t_done)
            attrs["legs"] = len(decision.legs)
        self.instant(
            "plan", _sat_track(int(sat.plane)), f"plan-{direction}",
            t_request_s, **attrs,
        )

    def on_commit(self, reservation: "Reservation") -> None:
        """A booked decision: one span per RB leg on the station's
        track.  More than one leg marks a mid-window station handover
        (the segmented upload planner switched stations)."""
        self.count("commit")
        legs = reservation.legs
        if len(legs) > 1:
            self.count("handover_switches", len(legs) - 1)
        for i, (gi, t0, t1) in enumerate(legs):
            self.span(
                "commit", f"gs/{int(gi)}", f"upload r{reservation.rid}",
                t0, t1, rid=reservation.rid, leg=i, legs=len(legs),
            )

    def on_release(
        self,
        reservation: "Reservation",
        freed: Tuple[Tuple[int, float, float], ...],
    ) -> None:
        self.count("release")
        for gi, t0, t1 in freed:
            self.span(
                "release", f"gs/{int(gi)}", f"release r{reservation.rid}",
                t0, t1, rid=reservation.rid,
            )

    def on_readmit(
        self, t_now_s: float, n_pending: int, repriced: int
    ) -> None:
        self.count("readmit_passes")
        self.count("readmit_repriced", repriced)
        self.instant(
            "readmit", "rounds", "readmit", t_now_s,
            pending=n_pending, repriced=repriced,
        )

    # -- VisibilityPredictor hooks ---------------------------------------------
    def on_horizon_extend(
        self, t_built_end_s: float, t_new_end_s: float
    ) -> None:
        self.count("horizon_extensions")
        self.instant(
            "horizon", "predictor", "extend",
            t_built_end_s, t_new_end_s=float(t_new_end_s),
        )

    def on_predictor_query(self, method: str) -> None:
        # hottest hook in the repo (thousands of calls per pricing
        # pass): interned key + inline increment, no f-string per call
        key = _PREDICTOR_KEYS.get(method)
        if key is None:
            key = _PREDICTOR_KEYS[method] = "predictor." + method
        counters = self.counters
        counters[key] = counters.get(key, 0) + 1

    # -- routing-cache hook ----------------------------------------------------
    def on_routing_cache(self, hit: bool) -> None:
        self.count("routing_cache_hits" if hit else "routing_cache_misses")

    # -- engine hooks ----------------------------------------------------------
    def on_round(
        self,
        decomposition: RoundDecomposition,
        metrics: Optional[Mapping[str, float]] = None,
    ) -> None:
        """One FL round: a span on the "rounds" track carrying the full
        typed decomposition, plus per-group phase spans on the group's
        plane track."""
        self.count("rounds")
        d = decomposition
        attrs: Dict[str, Any] = {"decomposition": d.as_dict()}
        if metrics:
            attrs["metrics"] = {k: float(v) for k, v in metrics.items()}
        self.span(
            "round", "rounds", f"round {d.round_index}",
            d.t_start, d.t_end, **attrs,
        )
        for g in d.groups:
            track = _sat_track(g.planes[0])
            label = (
                f"p{g.planes[0]}" if len(g.planes) == 1
                else "c" + "+".join(str(p) for p in g.planes)
            )
            for phase, t0, t1 in g.phase_spans():
                self.span(
                    "phase", track, f"{phase} {label}", t0, t1,
                    round=d.round_index, gs_index=g.gs_index,
                )

    def on_round_log(self, record: Mapping[str, Any]) -> None:
        """The engine's structured verbose round log."""
        self.instant(
            "log", "rounds", "round-log",
            float(record["t_hours"]) * 3600.0, **dict(record),
        )

    # -- session wiring --------------------------------------------------------
    @classmethod
    def attach(cls, env: "CommsEnvironment") -> "TraceRecorder":
        """Create a recorder and wire it into ``env``: the environment's
        plan/commit/release/readmit hook points, its predictor's
        horizon/query hooks, and the module-level routing-cache
        listener.  Station/constellation metadata lands in ``meta``.
        Returns the recorder (also reachable as ``env.recorder``)."""
        from repro.comms import routing

        cfg = env.walker.config
        meta: Dict[str, Any] = {
            "schema": TRACE_SCHEMA_VERSION,
            "num_planes": int(cfg.num_planes),
            "sats_per_plane": int(cfg.sats_per_plane),
            "stations": [g.name for g in env.ground_stations],
        }
        if env.ledger is not None:
            meta["rb_capacity"] = [
                (None if float(c) == float("inf") else int(c))
                for c in env.ledger.capacity
            ]
        if env.job is not None:
            meta["job"] = env.job
        recorder = cls(meta, job=env.job)
        env.recorder = recorder
        env.predictor.recorder = recorder
        recorder._detachers.append(
            routing.on_routing_cache(recorder.on_routing_cache)
        )

        def _detach_env(e: "CommsEnvironment" = env) -> None:
            if e.recorder is recorder:
                e.recorder = None
            if e.predictor.recorder is recorder:
                e.predictor.recorder = None

        recorder._detachers.append(_detach_env)
        return recorder

    def detach(self) -> None:
        """Unhook from everything ``attach`` wired up (idempotent).
        The collected events/counters stay readable."""
        for d in self._detachers:
            d()
        self._detachers = []


class _NullRecorder(TraceRecorder):
    """The disabled recorder: every hook is a no-op and nothing is ever
    stored — the ``SimConfig.trace=False`` path pays one virtual call
    at the few engine-level sites and nothing anywhere else (the
    environment/predictor hooks guard on ``recorder is None`` and are
    never entered)."""

    def span(
        self, kind: str, track: str, name: str,
        t_start_s: float, t_end_s: float, **attrs: Any,
    ) -> None:
        pass

    def count(self, name: str, n: int = 1) -> None:
        pass

    def on_predictor_query(self, method: str) -> None:
        pass

    def on_round(
        self,
        decomposition: RoundDecomposition,
        metrics: Optional[Mapping[str, float]] = None,
    ) -> None:
        pass

    def detach(self) -> None:
        pass


NULL_RECORDER = _NullRecorder()


# --- structured round logging (the engine's verbose path) ----------------------
def round_log_record(
    strategy: str,
    round_index: int,
    t_hours: float,
    metrics: Mapping[str, float],
) -> Dict[str, Any]:
    """The engine's per-round log as a typed record (what lands in the
    trace; ``format_round_line`` renders it for humans)."""
    return {
        "strategy": strategy,
        "round": int(round_index),
        "t_hours": float(t_hours),
        "accuracy": float(metrics["accuracy"]),
        "loss": float(metrics["loss"]),
    }


def format_round_line(record: Mapping[str, Any]) -> str:
    """Human-readable rendering — byte-identical to the engine's
    historical ``verbose`` print format."""
    return (
        f"[{record['strategy']}] round {record['round']:3d} "
        f"t={record['t_hours']:7.2f}h acc={record['accuracy']:.4f} "
        f"loss={record['loss']:.4f}"
    )
