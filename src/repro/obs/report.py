"""CLI trace reporter: ``python -m repro.obs.report <trace.jsonl>``.

Prints the round-time decomposition table (one row per round: mean
phase seconds across the round's plane groups) and the per-station
RB-utilization table reconstructed from the trace's commit/release
lifecycle, plus the session counters (predictor queries, horizon
extensions, routing-cache hits, plan/commit/release totals).

``--perfetto out.json`` additionally writes the Chrome trace-event
export — load it in Perfetto (ui.perfetto.dev) or chrome://tracing to
see rounds, per-plane phase spans and per-station RB bookings as
tracks.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Mapping, Optional, Sequence

from repro.obs.decomposition import RoundDecomposition
from repro.obs.export import read_trace, to_chrome_trace
from repro.obs.trace import TraceEvent
from repro.obs.utilization import trace_rb_utilization

_PHASE_COLS = (
    ("broadcast_s_mean", "bcast"),
    ("propagate_s_mean", "propag"),
    ("train_s_mean", "train"),
    ("relay_s_mean", "relay"),
    ("window_wait_s_mean", "wwait"),
    ("queue_delay_s_mean", "queue"),
    ("upload_s_mean", "upload"),
)


def round_decompositions(
    events: Sequence[TraceEvent],
) -> List[RoundDecomposition]:
    """The typed per-round decompositions a trace carries (one per
    ``round`` span, in round order)."""
    out = []
    for ev in events:
        if ev.kind == "round" and "decomposition" in ev.attrs:
            out.append(
                RoundDecomposition.from_dict(ev.attrs["decomposition"])
            )
    out.sort(key=lambda d: d.round_index)
    return out


def _fmt(x: Optional[float], width: int = 8) -> str:
    if x is None:
        return " " * (width - 1) + "-"
    return f"{x:{width}.1f}"


def print_decomposition_table(
    decomps: Sequence[RoundDecomposition], out: Any = sys.stdout
) -> None:
    if not decomps:
        print("no round decompositions in trace", file=out)
        return
    header = "round  groups " + "".join(
        f"{label:>9}" for _, label in _PHASE_COLS
    ) + f"{'round_s':>10}"
    print("per-round phase decomposition (mean seconds per group):",
          file=out)
    print(header, file=out)
    for d in decomps:
        means = d.phase_means()
        cols = "".join(
            _fmt(means.get(key), 9) for key, _ in _PHASE_COLS
        )
        print(
            f"{d.round_index:5d}  {len(d.groups):6d} {cols}"
            f"{d.round_s:10.1f}",
            file=out,
        )


def print_utilization_table(
    meta: Mapping[str, Any],
    events: Sequence[TraceEvent],
    out: Any = sys.stdout,
) -> None:
    spans = [ev for ev in events if ev.kind in ("round", "commit")]
    if not spans:
        print("no commit/round events in trace — no utilization to "
              "report", file=out)
        return
    t0 = min(ev.t_start_s for ev in spans)
    t1 = max(ev.t_end_s for ev in spans)
    caps = meta.get("rb_capacity")
    util = trace_rb_utilization(events, t0, t1, capacities=caps)
    stations = list(meta.get("stations") or [])
    if not util:
        print("no committed uploads in trace", file=out)
        return
    print(f"per-station RB utilization over [{t0:.0f}s, {t1:.0f}s]:",
          file=out)
    print(f"{'station':>20} {'capacity':>9} {'booked%':>8}", file=out)
    for gi in sorted(util):
        name = stations[gi] if gi < len(stations) else f"gs/{gi}"
        cap = (
            caps[gi] if caps is not None and gi < len(caps) else None
        )
        cap_s = str(cap) if cap else "inf"
        print(
            f"{name:>20} {cap_s:>9} {100.0 * util[gi]:8.2f}",
            file=out,
        )


def print_counters(
    counters: Mapping[str, int], out: Any = sys.stdout
) -> None:
    if not counters:
        return
    print("session counters:", file=out)
    for k in sorted(counters):
        print(f"  {k:32s} {counters[k]}", file=out)


def report(
    path: str,
    perfetto_out: Optional[str] = None,
    out: Any = sys.stdout,
) -> Dict[str, Any]:
    """Run the full report; returns the parsed (meta, counters,
    decomposition count) summary for programmatic callers/tests."""
    meta, counters, events = read_trace(path)
    print(
        f"trace {path}: schema {meta.get('schema')}, "
        f"run {meta.get('run_id')}, {len(events)} events, "
        f"stations {meta.get('stations')}",
        file=out,
    )
    decomps = round_decompositions(events)
    print_decomposition_table(decomps, out=out)
    print_utilization_table(meta, events, out=out)
    print_counters(counters, out=out)
    if perfetto_out:
        with open(perfetto_out, "w") as f:
            json.dump(to_chrome_trace(meta, events, counters), f)
        print(f"wrote Perfetto/Chrome trace: {perfetto_out}", file=out)
    return {
        "meta": meta, "counters": dict(counters),
        "events": len(events), "rounds": len(decomps),
    }


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Decomposition/utilization report over a recorded "
                    "scheduling trace (JSONL).",
    )
    ap.add_argument("trace", help="path to a JSONL trace file")
    ap.add_argument(
        "--perfetto", metavar="OUT",
        help="also write a Chrome trace-event JSON for Perfetto",
    )
    args = ap.parse_args(argv)
    try:
        report(args.trace, perfetto_out=args.perfetto)
    except FileNotFoundError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
