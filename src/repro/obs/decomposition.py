"""Typed per-round phase decomposition (paper §IV-A, eq. 10 -> 12).

The paper's round-latency claim is a *phase* claim: FedLEO collapses
the sequential star schedule into overlapping broadcast / intra-plane
propagation / concurrent training / relay-to-sink / sink-wait / upload
phases.  Until ISSUE 7 the realized phase data lived in untyped
``HistoryPoint.events`` dicts scraped by one benchmark; this module is
the typed replacement:

  ``GroupDecomposition``   one plane's (or cluster's) milestones for
                           one round, with derived phase durations,
  ``RoundDecomposition``   all groups of one round plus the round span,
  ``decompose_group_plan`` builds a GroupDecomposition from a
                           ``PlanePlan`` or ``ClusterPlan`` (duck-typed
                           so this module never imports ``repro.core``).

Milestone semantics: phases are reported as deltas between *round
milestones* (max over the group's satellites), so concurrent per-sat
work overlaps inside them — e.g. ``train_s`` is the time from the last
model receipt to the last training completion, not the per-sat
training duration.  ``sink_wait_s`` splits into the window wait the
scheduler planned for (eq. 22's AW feasibility) and the
contention-queue delay the RB ledger added on top.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

# phase name -> (start milestone attr, end milestone attr)
PHASES: Tuple[Tuple[str, str, str], ...] = (
    ("broadcast", "t_round_start", "t_broadcast_done"),
    ("propagate", "t_broadcast_done", "t_propagate_done"),
    ("train", "t_propagate_done", "t_train_done"),
    ("relay", "t_train_done", "t_models_at_sink"),
    ("sink_wait", "t_models_at_sink", "t_upload_start"),
    ("upload", "t_upload_start", "t_upload_done"),
)


@dataclasses.dataclass(frozen=True)
class GroupDecomposition:
    """One plane group's round milestones (absolute simulated seconds)
    and the derived phase durations.  A single-plane ring round has
    ``planes == (p,)``; a grid cluster lists every member plane."""

    planes: Tuple[int, ...]
    source: Tuple[int, int]         # (plane, slot) that received the DL
    sink: Tuple[int, int]           # (plane, slot) that uploads
    gs_index: int                   # station of the (first) upload leg
    t_round_start: float
    t_broadcast_done: float         # GS download at the source completes
    t_propagate_done: float         # last satellite holds the model
    t_train_done: float             # last local training completes
    t_models_at_sink: float         # last model relayed to the sink
    t_upload_start: float
    t_upload_done: float
    window_wait_s: float            # planned wait for the sink's window
    queue_delay_s: float            # RB-contention delay inside the window
    handover_legs: int              # >0: upload segmented across stations

    # -- derived phase durations ----------------------------------------------
    @property
    def broadcast_s(self) -> float:
        return self.t_broadcast_done - self.t_round_start

    @property
    def propagate_s(self) -> float:
        return self.t_propagate_done - self.t_broadcast_done

    @property
    def train_s(self) -> float:
        return self.t_train_done - self.t_propagate_done

    @property
    def relay_s(self) -> float:
        return self.t_models_at_sink - self.t_train_done

    @property
    def sink_wait_s(self) -> float:
        return self.t_upload_start - self.t_models_at_sink

    @property
    def upload_s(self) -> float:
        return self.t_upload_done - self.t_upload_start

    @property
    def round_s(self) -> float:
        return self.t_upload_done - self.t_round_start

    def phase_spans(self) -> List[Tuple[str, float, float]]:
        """(phase, t_start, t_end) triples in round order."""
        return [
            (name, getattr(self, a), getattr(self, b))
            for name, a, b in PHASES
        ]

    def as_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["planes"] = list(self.planes)
        d["source"] = list(self.source)
        d["sink"] = list(self.sink)
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "GroupDecomposition":
        return cls(
            planes=tuple(int(p) for p in d["planes"]),
            source=(int(d["source"][0]), int(d["source"][1])),
            sink=(int(d["sink"][0]), int(d["sink"][1])),
            gs_index=int(d["gs_index"]),
            t_round_start=float(d["t_round_start"]),
            t_broadcast_done=float(d["t_broadcast_done"]),
            t_propagate_done=float(d["t_propagate_done"]),
            t_train_done=float(d["t_train_done"]),
            t_models_at_sink=float(d["t_models_at_sink"]),
            t_upload_start=float(d["t_upload_start"]),
            t_upload_done=float(d["t_upload_done"]),
            window_wait_s=float(d["window_wait_s"]),
            queue_delay_s=float(d["queue_delay_s"]),
            handover_legs=int(d["handover_legs"]),
        )


@dataclasses.dataclass(frozen=True)
class RoundDecomposition:
    """All plane groups of one FL round plus the round span.  Rounds of
    strategies without a group planner (the star baselines, the async
    family) carry an empty ``groups`` tuple — the round span itself is
    still typed and traceable."""

    round_index: int
    t_start: float
    t_end: float
    groups: Tuple[GroupDecomposition, ...] = ()

    @property
    def round_s(self) -> float:
        return self.t_end - self.t_start

    def phase_means(self) -> Dict[str, float]:
        """Mean seconds per phase across the round's groups (empty dict
        for group-less rounds), plus the sink-wait split."""
        return mean_phase_seconds(self.groups)

    def as_dict(self) -> Dict[str, Any]:
        return {
            "round_index": self.round_index,
            "t_start": self.t_start,
            "t_end": self.t_end,
            "groups": [g.as_dict() for g in self.groups],
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "RoundDecomposition":
        return cls(
            round_index=int(d["round_index"]),
            t_start=float(d["t_start"]),
            t_end=float(d["t_end"]),
            groups=tuple(
                GroupDecomposition.from_dict(g) for g in d["groups"]
            ),
        )


def mean_phase_seconds(
    groups: Sequence[GroupDecomposition],
) -> Dict[str, float]:
    """Mean seconds per phase over ``groups`` — the summary the
    benchmarks fold into their BENCH rows.  Includes the sink-wait
    split (``window_wait_s`` vs ``queue_delay_s``) and the group count."""
    if not groups:
        return {}
    out: Dict[str, float] = {}
    for name, _, _ in PHASES:
        out[f"{name}_s_mean"] = float(
            np.mean([getattr(g, f"{name}_s") for g in groups])
        )
    out["window_wait_s_mean"] = float(
        np.mean([g.window_wait_s for g in groups])
    )
    out["queue_delay_s_mean"] = float(
        np.mean([g.queue_delay_s for g in groups])
    )
    out["round_s_mean"] = float(np.mean([g.round_s for g in groups]))
    out["groups"] = float(len(groups))
    return out


def decompose_group_plan(
    plan: Any, t_round_start: float
) -> GroupDecomposition:
    """Typed decomposition of one planned group round.

    Accepts a ``repro.core.fedleo.PlanePlan`` (decision: SinkDecision)
    or ``ClusterPlan`` (decision: ClusterSinkDecision) — duck-typed on
    their shared milestone fields so ``repro.obs`` never imports
    ``repro.core`` (the engine imports obs, not the reverse).

    ``queue_delay_s`` isolates the contention component of the sink
    wait: time from the later of model-arrival and window-open until
    the upload actually starts — zero without RB competition, positive
    when the ledger pushed the transfer behind other bookings."""
    d = plan.decision
    if hasattr(plan, "planes"):                 # ClusterPlan
        planes = tuple(int(p) for p in plan.planes)
        source = (int(plan.source[0]), int(plan.source[1]))
        sink = (int(d.sink.plane), int(d.sink.slot))
    else:                                       # PlanePlan
        planes = (int(plan.plane),)
        source = (int(plan.plane), int(plan.source_slot))
        sink = (int(plan.plane), int(d.sink_slot))
    segments = tuple(getattr(d, "segments", ()) or ())
    gs_index = (
        int(segments[0].gs_index) if segments
        else int(d.window.gs_index)
    )
    t_upload_start = float(d.t_upload_start)
    t_at_sink = float(d.t_models_at_sink)
    queue_delay_s = max(
        0.0, t_upload_start - max(t_at_sink, float(d.window.t_start))
    )
    return GroupDecomposition(
        planes=planes,
        source=source,
        sink=sink,
        gs_index=gs_index,
        t_round_start=float(t_round_start),
        t_broadcast_done=float(plan.t_source),
        t_propagate_done=float(np.max(plan.t_receive)),
        t_train_done=float(np.max(plan.t_train_done)),
        t_models_at_sink=t_at_sink,
        t_upload_start=t_upload_start,
        t_upload_done=float(d.t_upload_done),
        window_wait_s=float(d.t_wait),
        queue_delay_s=queue_delay_s,
        handover_legs=len(segments),
    )


def round_decomposition(
    round_index: int,
    t_start: float,
    t_end: float,
    groups: Optional[Sequence[GroupDecomposition]] = None,
) -> RoundDecomposition:
    """Assemble one round's decomposition (the engine's per-round call)."""
    return RoundDecomposition(
        round_index=int(round_index),
        t_start=float(t_start),
        t_end=float(t_end),
        groups=tuple(groups or ()),
    )
