"""Data pipeline: synthetic datasets + federated (non-)IID partitioning."""
from repro.data.synthetic import (
    make_classification_dataset,
    make_segmentation_dataset,
    make_token_dataset,
    Dataset,
)
from repro.data.partition import (
    partition_iid,
    partition_noniid_by_orbit,
    label_histogram,
    ClientData,
)

__all__ = [
    "make_classification_dataset",
    "make_segmentation_dataset",
    "make_token_dataset",
    "Dataset",
    "partition_iid",
    "partition_noniid_by_orbit",
    "label_histogram",
    "ClientData",
]
