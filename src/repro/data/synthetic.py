"""Deterministic synthetic datasets (the container is offline).

The paper evaluates on MNIST, CIFAR-10 and DeepGlobe.  We generate
*class-structured* synthetic stand-ins with the same shapes so that
non-IID federation effects (the thing FedLEO's aggregation must survive)
are faithfully reproduced: each class is a distinct distribution
(class-specific frequency/phase patterns + noise), so a model trained on
classes {0..3} genuinely fails on classes {4..9} until aggregation mixes
knowledge across orbits.

  * ``mnist-like``   : (28, 28, 1) grayscale, 10 classes
  * ``cifar10-like`` : (32, 32, 3) color, 10 classes
  * ``deepglobe-like``: (64, 64, 3) images + (64, 64) binary road masks
  * token streams for the assigned-architecture smoke tests
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Dataset:
    x: np.ndarray          # features, (N, ...) float32
    y: np.ndarray          # labels, (N,) int32 or (N, H, W) masks
    num_classes: int
    name: str = "synthetic"

    def __len__(self) -> int:
        return self.x.shape[0]

    def subset(self, idx: np.ndarray) -> "Dataset":
        return Dataset(
            x=self.x[idx], y=self.y[idx], num_classes=self.num_classes,
            name=self.name,
        )


def _class_pattern(
    rng: np.random.Generator, num_classes: int, shape: Tuple[int, ...]
) -> np.ndarray:
    """Per-class base pattern: smooth low-frequency fields, one per class."""
    h, w = shape[0], shape[1]
    c = shape[2] if len(shape) == 3 else 1
    yy, xx = np.meshgrid(np.linspace(0, 1, h), np.linspace(0, 1, w), indexing="ij")
    patterns = np.zeros((num_classes, h, w, c), np.float32)
    for k in range(num_classes):
        for ch in range(c):
            fx, fy = rng.uniform(1.0, 5.0, size=2)
            px, py = rng.uniform(0, 2 * np.pi, size=2)
            amp = rng.uniform(0.6, 1.0)
            patterns[k, :, :, ch] = amp * (
                np.sin(2 * np.pi * fx * xx + px) * np.cos(2 * np.pi * fy * yy + py)
            )
    return patterns


def make_classification_dataset(
    kind: str = "mnist-like",
    num_samples: int = 2048,
    num_classes: int = 10,
    seed: int = 0,
    noise: float = 0.35,
    pattern_seed: int = 1234,
) -> Dataset:
    """Class-structured image classification data.

    ``pattern_seed`` fixes the class-defining distributions (the "world");
    ``seed`` varies the drawn samples — so train/test splits built with
    different ``seed`` values are IID draws from the *same* task.
    """
    if kind == "mnist-like":
        shape: Tuple[int, ...] = (28, 28, 1)
    elif kind == "cifar10-like":
        shape = (32, 32, 3)
    else:
        raise ValueError(f"unknown kind {kind!r}")
    pattern_rng = np.random.default_rng(pattern_seed + hash(kind) % 1000)
    patterns = _class_pattern(pattern_rng, num_classes, shape)
    rng = np.random.default_rng(seed)
    y = rng.integers(0, num_classes, size=num_samples).astype(np.int32)
    x = patterns[y] + noise * rng.standard_normal(
        (num_samples,) + patterns.shape[1:]
    ).astype(np.float32)
    return Dataset(x=x.astype(np.float32), y=y, num_classes=num_classes, name=kind)


def make_segmentation_dataset(
    num_samples: int = 256,
    size: int = 64,
    seed: int = 0,
    noise: float = 0.25,
) -> Dataset:
    """DeepGlobe-like road-extraction data: images with synthetic road masks.

    Roads are random piecewise-linear strips; the image channels carry the
    road signature plus textured background, so a U-Net can genuinely
    learn pixel-wise extraction.
    """
    rng = np.random.default_rng(seed)
    xs = np.zeros((num_samples, size, size, 3), np.float32)
    ys = np.zeros((num_samples, size, size), np.int32)
    yy, xx = np.meshgrid(np.arange(size), np.arange(size), indexing="ij")
    for i in range(num_samples):
        mask = np.zeros((size, size), bool)
        for _ in range(rng.integers(1, 4)):
            # random line: a*x + b*y = c, thickness w
            theta = rng.uniform(0, np.pi)
            a, b = np.cos(theta), np.sin(theta)
            c = rng.uniform(0.2, 0.8) * size * (a + b)
            width = rng.uniform(1.0, 3.0)
            mask |= np.abs(a * xx + b * yy - c) < width
        bg = 0.3 * rng.standard_normal((size, size, 3))
        img = bg.copy()
        img[mask] += np.array([0.9, 0.85, 0.8])  # road signature
        img += noise * rng.standard_normal((size, size, 3))
        xs[i] = img
        ys[i] = mask.astype(np.int32)
    return Dataset(x=xs, y=ys, num_classes=2, name="deepglobe-like")


def make_token_dataset(
    num_sequences: int = 64,
    seq_len: int = 128,
    vocab_size: int = 1024,
    seed: int = 0,
    pattern_seed: int = 1234,
) -> Dataset:
    """Markov-ish synthetic token streams for LM smoke tests."""
    # chain parameters fixed by pattern_seed; sampling varies with seed
    chain_rng = np.random.default_rng(pattern_seed)
    rng = np.random.default_rng(seed)
    # sticky-state Markov chain so there is actual structure to learn
    num_states = 8
    trans = chain_rng.dirichlet(np.ones(num_states) * 0.3, size=num_states)
    emit = chain_rng.dirichlet(np.ones(vocab_size) * 0.05, size=num_states)
    toks = np.zeros((num_sequences, seq_len), np.int32)
    for i in range(num_sequences):
        s = rng.integers(0, num_states)
        for t in range(seq_len):
            toks[i, t] = rng.choice(vocab_size, p=emit[s])
            s = rng.choice(num_states, p=trans[s])
    return Dataset(
        x=toks, y=toks, num_classes=vocab_size, name="tokens"
    )
