"""Federated data partitioning across the constellation.

The paper's §V-A setting:

  * IID: training images randomly shuffled and equally distributed
    across all satellites, each satellite having all 10 classes.
  * non-IID: satellites in two orbits train on 4 classes; satellites in
    the remaining three orbits train on the other 6 classes.

``ClientData`` also carries the per-client label histogram, which FedLEO
piggybacks onto model propagation and uploads with the partial global
model (used by the GS for non-IID-aware weighting).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.data.synthetic import Dataset


@dataclasses.dataclass
class ClientData:
    plane: int
    slot: int
    data: Dataset

    @property
    def num_samples(self) -> int:            # m_k
        return len(self.data)

    @property
    def histogram(self) -> np.ndarray:       # piggybacked label distribution
        return label_histogram(self.data)


def label_histogram(ds: Dataset) -> np.ndarray:
    y = ds.y
    if y.ndim > 1:  # segmentation masks -> pixel histogram
        y = y.reshape(-1)
    return np.bincount(y, minlength=ds.num_classes).astype(np.float64)


def partition_iid(
    ds: Dataset,
    num_planes: int,
    sats_per_plane: int,
    seed: int = 0,
) -> List[ClientData]:
    """Shuffle and split evenly; every satellite sees all classes."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(ds))
    num_clients = num_planes * sats_per_plane
    shards = np.array_split(idx, num_clients)
    clients = []
    for c, shard in enumerate(shards):
        clients.append(
            ClientData(
                plane=c // sats_per_plane,
                slot=c % sats_per_plane,
                data=ds.subset(np.sort(shard)),
            )
        )
    return clients


def partition_noniid_by_orbit(
    ds: Dataset,
    num_planes: int,
    sats_per_plane: int,
    num_planes_first_group: int = 2,
    classes_first_group: int = 4,
    seed: int = 0,
) -> List[ClientData]:
    """Paper's non-IID split: orbit-level class partition.

    Satellites in the first ``num_planes_first_group`` orbits get classes
    [0, classes_first_group); the remaining orbits get the rest.
    """
    rng = np.random.default_rng(seed)
    y = ds.y if ds.y.ndim == 1 else None
    if y is None:
        raise ValueError("non-IID orbit partition requires scalar labels")
    first_classes = set(range(classes_first_group))
    idx_first = np.nonzero(np.isin(ds.y, list(first_classes)))[0]
    idx_second = np.nonzero(~np.isin(ds.y, list(first_classes)))[0]
    rng.shuffle(idx_first)
    rng.shuffle(idx_second)

    n_first_sats = num_planes_first_group * sats_per_plane
    n_second_sats = (num_planes - num_planes_first_group) * sats_per_plane
    shards_first = np.array_split(idx_first, n_first_sats)
    shards_second = np.array_split(idx_second, n_second_sats)

    clients: List[ClientData] = []
    c1 = c2 = 0
    for p in range(num_planes):
        for s in range(sats_per_plane):
            if p < num_planes_first_group:
                shard = shards_first[c1]; c1 += 1
            else:
                shard = shards_second[c2]; c2 += 1
            clients.append(
                ClientData(plane=p, slot=s, data=ds.subset(np.sort(shard)))
            )
    return clients


def stack_client_arrays(
    clients: Sequence[ClientData],
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad every client's data to the max m_k and stack for vmap training.

    Returns (x_stack, y_stack, counts): (C, M, ...), (C, M, ...), (C,).
    Padding repeats real samples (cyclic) so masked batching is not
    needed; the weighting uses the true counts m_k.
    """
    m_max = max(c.num_samples for c in clients)
    xs, ys, counts = [], [], []
    for c in clients:
        n = c.num_samples
        reps = np.resize(np.arange(n), m_max)
        xs.append(c.data.x[reps])
        ys.append(c.data.y[reps])
        counts.append(n)
    return np.stack(xs), np.stack(ys), np.asarray(counts, np.int32)
