"""Pure-JAX optimizers (no optax dependency in this offline container)."""
from repro.optim.optimizers import (
    Optimizer,
    sgd,
    momentum,
    adam,
    adafactor,
    clip_by_global_norm,
    get_optimizer,
)

__all__ = [
    "Optimizer",
    "sgd",
    "momentum",
    "adam",
    "adafactor",
    "clip_by_global_norm",
    "get_optimizer",
]
