"""Pure-JAX optimizers: SGD, momentum, Adam, Adafactor.

API mirrors the optax gradient-transformation style:

    opt = adam(1e-3)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Adafactor implements the factored second-moment estimator (Shazeer &
Stern, 2018) so that optimizer state for >=100B-parameter architectures
stays O(rows + cols) instead of O(rows * cols) — required to fit v5e HBM
for mistral-large-123b and kimi-k2-1t in the production-mesh dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, Optional[PyTree]], Tuple[PyTree, PyTree]]
    name: str = "optimizer"


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p,
        params,
        updates,
    )


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads)


# --- SGD ---------------------------------------------------------------------
def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params=None):
        updates = jax.tree_util.tree_map(lambda g: -lr * g, grads)
        return updates, state

    return Optimizer(init=init, update=update, name="sgd")


# --- SGD with momentum --------------------------------------------------------
class MomentumState(NamedTuple):
    velocity: PyTree


def momentum(lr: float, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return MomentumState(
            velocity=jax.tree_util.tree_map(jnp.zeros_like, params)
        )

    def update(grads, state, params=None):
        vel = jax.tree_util.tree_map(
            lambda v, g: beta * v + g, state.velocity, grads
        )
        if nesterov:
            updates = jax.tree_util.tree_map(
                lambda v, g: -lr * (beta * v + g), vel, grads
            )
        else:
            updates = jax.tree_util.tree_map(lambda v: -lr * v, vel)
        return updates, MomentumState(velocity=vel)

    return Optimizer(init=init, update=update, name="momentum")


# --- Adam ----------------------------------------------------------------------
class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree
    nu: PyTree


def adam(
    lr: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(f32, params),
            nu=jax.tree_util.tree_map(f32, params),
        )

    def update(grads, state, params=None):
        step = state.step + 1
        g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32
        )
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.nu, g32
        )
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            u = -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                u = u - lr * weight_decay * p.astype(jnp.float32)
            return u

        if params is None:
            params = jax.tree_util.tree_map(lambda m: None, mu)
        updates = jax.tree_util.tree_map(upd, mu, nu, params)
        return updates, AdamState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update, name="adam")


# --- Adafactor -------------------------------------------------------------------
class AdafactorState(NamedTuple):
    step: jnp.ndarray
    # per-leaf: either (row, col) factored second moments, or full `v`
    factored: PyTree


def _is_factorable(p: jnp.ndarray) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 2 and p.shape[-2] >= 2


def adafactor(
    lr: float = 1e-2,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern 2018, simplified).

    Memory: O(sum of (rows + cols)) for matrix leaves instead of
    O(rows*cols) — the standard choice for 100B+ training on TPU.
    """

    def init(params):
        def leaf(p):
            if _is_factorable(p):
                row = jnp.zeros(p.shape[:-1], jnp.float32)
                col = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                return (row, col)
            return jnp.zeros(p.shape, jnp.float32)

        return AdafactorState(
            step=jnp.zeros((), jnp.int32),
            factored=jax.tree_util.tree_map(leaf, params),
        )

    def update(grads, state, params=None):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-decay)

        def leaf(g, f):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if isinstance(f, tuple):
                row, col = f
                new_row = beta2 * row + (1 - beta2) * jnp.mean(g2, axis=-1)
                new_col = beta2 * col + (1 - beta2) * jnp.mean(g2, axis=-2)
                # rank-1 reconstruction of the second moment
                denom = jnp.mean(new_row, axis=-1, keepdims=True)
                v_hat = (
                    new_row[..., :, None]
                    * new_col[..., None, :]
                    / (denom[..., None] + eps)
                )
                u = g / (jnp.sqrt(v_hat) + eps)
                new_f = (new_row, new_col)
            else:
                new_v = beta2 * f + (1 - beta2) * g2
                u = g / (jnp.sqrt(new_v) + eps)
                new_f = new_v
            # update clipping by RMS
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -lr * u, new_f

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_f = treedef.flatten_up_to(state.factored)
        outs = [leaf(g, f) for g, f in zip(flat_g, flat_f)]
        updates = treedef.unflatten([o[0] for o in outs])
        new_factored = treedef.unflatten([o[1] for o in outs])
        return updates, AdafactorState(step=step, factored=new_factored)

    return Optimizer(init=init, update=update, name="adafactor")


_REGISTRY = {
    "sgd": sgd,
    "momentum": momentum,
    "adam": adam,
    "adafactor": adafactor,
}


def get_optimizer(name: str, lr: float, **kwargs) -> Optimizer:
    if name not in _REGISTRY:
        raise ValueError(f"unknown optimizer {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](lr, **kwargs)
