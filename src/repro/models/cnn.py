"""The paper's evaluation models: a deep CNN (MNIST/CIFAR-10) and a
U-Net (DeepGlobe road extraction).  §V-A: "we use a deep CNN for MNIST
and CIFAR-10, and a U-Net model for DeepGlobe."
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import nn


# --- deep CNN -----------------------------------------------------------------
def init_cnn(
    rng,
    input_shape: Tuple[int, int, int] = (28, 28, 1),
    num_classes: int = 10,
    widths: Tuple[int, ...] = (32, 64),
    hidden: int = 128,
) -> Dict:
    keys = jax.random.split(rng, len(widths) + 2)
    params: Dict = {"conv": []}
    in_ch = input_shape[-1]
    h, w = input_shape[0], input_shape[1]
    for i, ch in enumerate(widths):
        params["conv"].append(nn.init_conv(keys[i], in_ch, ch))
        in_ch = ch
        h, w = h // 2, w // 2
    flat = h * w * in_ch
    params["fc1"] = nn.init_dense(keys[-2], flat, hidden)
    params["fc2"] = nn.init_dense(keys[-1], hidden, num_classes)
    return params


def apply_cnn(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, H, W, C) -> logits (B, num_classes)."""
    for p in params["conv"]:
        x = nn.apply_conv(p, x)
        x = jax.nn.relu(x)
        x = nn.max_pool(x, 2)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(nn.apply_dense(params["fc1"], x))
    return nn.apply_dense(params["fc2"], x)


# --- U-Net ---------------------------------------------------------------------
def init_unet(
    rng,
    in_ch: int = 3,
    base: int = 16,
    depth: int = 3,
    num_classes: int = 2,
) -> Dict:
    n_keys = depth * 2 + depth * 2 + 2
    keys = iter(jax.random.split(rng, n_keys + 1))
    params: Dict = {"down": [], "up": [], "skipconv": []}
    ch = in_ch
    enc_chs = []
    for d in range(depth):
        out = base * (2**d)
        params["down"].append(
            {"c1": nn.init_conv(next(keys), ch, out),
             "c2": nn.init_conv(next(keys), out, out)}
        )
        enc_chs.append(out)
        ch = out
    params["bottleneck"] = {
        "c1": nn.init_conv(next(keys), ch, ch * 2),
    }
    ch = ch * 2
    for d in reversed(range(depth)):
        out = base * (2**d)
        params["up"].append(
            {"t": nn.init_conv(next(keys), ch, out, ksize=2),
             "c1": nn.init_conv(next(keys), out + enc_chs[d], out)}
        )
        ch = out
    params["head"] = nn.init_conv(next(keys), ch, num_classes, ksize=1)
    return params


def apply_unet(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, H, W, C) -> per-pixel logits (B, H, W, num_classes)."""
    skips = []
    for blk in params["down"]:
        x = jax.nn.relu(nn.apply_conv(blk["c1"], x))
        x = jax.nn.relu(nn.apply_conv(blk["c2"], x))
        skips.append(x)
        x = nn.max_pool(x, 2)
    x = jax.nn.relu(nn.apply_conv(params["bottleneck"]["c1"], x))
    for blk, skip in zip(params["up"], reversed(skips)):
        x = nn.apply_conv_transpose(blk["t"], x, stride=2)
        x = jnp.concatenate([x, skip], axis=-1)
        x = jax.nn.relu(nn.apply_conv(blk["c1"], x))
    return nn.apply_conv(params["head"], x)
