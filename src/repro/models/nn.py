"""Minimal functional NN building blocks (no flax in this container).

Every module is a pair of pure functions:

    params = init_*(rng, ...)
    out    = apply_*(params, x, ...)

Parameters are plain dict pytrees so the FL aggregation layer (weighted
sums over pytrees) and the sharding layer (NamedSharding per leaf by path
regex) stay trivial.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _uniform_init(rng, shape, scale):
    return jax.random.uniform(rng, shape, jnp.float32, -scale, scale)


def init_dense(rng, in_dim: int, out_dim: int, use_bias: bool = True) -> Dict:
    k1, _ = jax.random.split(rng)
    scale = float(np.sqrt(1.0 / in_dim))
    p = {"w": _uniform_init(k1, (in_dim, out_dim), scale)}
    if use_bias:
        p["b"] = jnp.zeros((out_dim,), jnp.float32)
    return p


def apply_dense(p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_conv(
    rng, in_ch: int, out_ch: int, ksize: int = 3, use_bias: bool = True
) -> Dict:
    scale = float(np.sqrt(1.0 / (in_ch * ksize * ksize)))
    p = {"w": _uniform_init(rng, (ksize, ksize, in_ch, out_ch), scale)}
    if use_bias:
        p["b"] = jnp.zeros((out_ch,), jnp.float32)
    return p


def apply_conv(p: Dict, x: jnp.ndarray, stride: int = 1, padding: str = "SAME"):
    y = jax.lax.conv_general_dilated(
        x,
        p["w"].astype(x.dtype),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def apply_conv_transpose(p: Dict, x: jnp.ndarray, stride: int = 2):
    y = jax.lax.conv_transpose(
        x,
        p["w"].astype(x.dtype),
        strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def max_pool(x: jnp.ndarray, window: int = 2) -> jnp.ndarray:
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, window, window, 1),
        window_strides=(1, window, window, 1),
        padding="VALID",
    )


def init_layernorm(dim: int) -> Dict:
    return {"scale": jnp.ones((dim,), jnp.float32),
            "bias": jnp.zeros((dim,), jnp.float32)}


def apply_layernorm(p: Dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)


def init_rmsnorm(dim: int) -> Dict:
    return {"scale": jnp.ones((dim,), jnp.float32)}


def apply_rmsnorm(p: Dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


def init_embedding(rng, vocab: int, dim: int) -> Dict:
    return {"table": jax.random.normal(rng, (vocab, dim), jnp.float32) * 0.02}


def apply_embedding(p: Dict, tokens: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    return p["table"].astype(dtype)[tokens]


def count_params(params: PyTree) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


def param_bits(params: PyTree, bits_per_param: int = 32) -> int:
    """Payload size z|N| for the comm model (eq. 7)."""
    return count_params(params) * bits_per_param


def tree_cast(params: PyTree, dtype) -> PyTree:
    return jax.tree_util.tree_map(lambda p: p.astype(dtype), params)
