"""Decoder-only / encoder-decoder transformer covering the assigned
architectures (dense, MoE, VLM, audio enc-dec).

Structure: pre-norm blocks, GQA attention (repro.models.layers), SwiGLU/
GeGLU FFN or MoE FFN, RMSNorm, RoPE.  Layers are *scanned* (stacked
params + ``jax.lax.scan``) so 48-88-layer configs lower to compact HLO,
with optional per-block ``jax.checkpoint`` (remat).

Three entry points per model:
  * ``forward(params, tokens, ...)``   — train/prefill full-sequence
  * ``init_cache(batch, max_len)``     — decode cache pytree
  * ``decode_step(params, tokens, cache)`` — single-token serve step

Multimodal handling (the one allowed stub): ``extra_embeds`` are
precomputed patch/frame embeddings (B, P, D) prepended to the token
embeddings (VLM), or used as the encoder source sequence (audio enc-dec).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import nn
from repro.models.layers import (
    AttentionConfig,
    KVCache,
    apply_attention,
    apply_cross_attention,
    apply_glu_ffn,
    encode_memory_kv,
    init_attention,
    init_glu_ffn,
)
from repro.models.moe import apply_moe, init_moe

PyTree = Any


def _attn_cfg(cfg: ArchConfig, sliding_window: Optional[int] = None,
              causal: bool = True) -> AttentionConfig:
    return AttentionConfig(
        d_model=cfg.d_model,
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim,
        rope_theta=cfg.rope_theta,
        causal=causal,
        sliding_window=sliding_window,
        logit_soft_cap=cfg.logit_soft_cap,
    )


class Transformer:
    """Decoder-only transformer (dense or MoE); also hosts the VLM stub."""

    def __init__(self, cfg: ArchConfig, *, attn_impl: str = "xla",
                 dtype=jnp.bfloat16, sliding_window: Optional[int] = None):
        self.cfg = cfg
        self.attn_impl = attn_impl
        self.dtype = dtype
        self.sliding_window = sliding_window
        # scan unit layout: a unit is the params of `moe_every` consecutive
        # blocks, the last of which is MoE (if cfg.moe). Dense: unit = 1 block.
        if cfg.moe is not None:
            assert cfg.num_layers % cfg.moe_every == 0, (
                f"{cfg.name}: num_layers must divide moe_every"
            )
            self.unit_size = cfg.moe_every
            self.num_units = cfg.num_layers // cfg.moe_every
        else:
            self.unit_size = 1
            self.num_units = cfg.num_layers

    # --- init -------------------------------------------------------------------
    def _init_block(self, rng, moe: bool) -> Dict:
        cfg = self.cfg
        k1, k2, k3, k4 = jax.random.split(rng, 4)
        block = {
            "ln_attn": nn.init_rmsnorm(cfg.d_model),
            "attn": init_attention(k1, _attn_cfg(cfg)),
            "ln_ffn": nn.init_rmsnorm(cfg.d_model),
        }
        if moe:
            block["moe"] = init_moe(k2, cfg.d_model, cfg.moe)
        else:
            block["ffn"] = init_glu_ffn(k3, cfg.d_model, cfg.d_ff)
        return block

    def _init_unit(self, rng) -> Dict:
        keys = jax.random.split(rng, self.unit_size)
        unit = {}
        for i in range(self.unit_size):
            is_moe = self.cfg.moe is not None and i == self.unit_size - 1
            unit[f"block{i}"] = self._init_block(keys[i], is_moe)
        return unit

    def init(self, rng) -> PyTree:
        cfg = self.cfg
        k_embed, k_layers, k_head = jax.random.split(rng, 3)
        layer_keys = jax.random.split(k_layers, self.num_units)
        # always stacked (scan_layers=False just unrolls the apply loop —
        # used by the roofline depth-extrapolation, see benchmarks/)
        layers = jax.vmap(self._init_unit)(layer_keys)
        params = {
            "embed": nn.init_embedding(k_embed, cfg.vocab_size, cfg.d_model),
            "layers": layers,
            "ln_final": nn.init_rmsnorm(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = {
                "w": jax.random.normal(
                    k_head, (cfg.d_model, cfg.vocab_size), jnp.float32
                ) * (1.0 / math.sqrt(cfg.d_model))
            }
        return params

    # --- blocks ---------------------------------------------------------------------
    def _apply_block(self, bp: Dict, x, positions, cache=None,
                     window=None):
        cfg = self.cfg
        acfg = _attn_cfg(cfg, sliding_window=window)
        h = nn.apply_rmsnorm(bp["ln_attn"], x)
        attn_out, new_cache = apply_attention(
            bp["attn"], h, acfg, positions=positions, cache=cache,
            attn_impl=self.attn_impl,
        )
        x = x + attn_out
        h = nn.apply_rmsnorm(bp["ln_ffn"], x)
        if "moe" in bp:
            ffn_out, aux = apply_moe(bp["moe"], h, cfg.moe, cfg.activation)
        else:
            ffn_out, aux = apply_glu_ffn(bp["ffn"], h, cfg.activation), 0.0
        return x + ffn_out, new_cache, aux

    def _apply_unit(self, up: Dict, x, positions, caches=None, window=None):
        new_caches = {}
        aux_total = 0.0
        for i in range(self.unit_size):
            c = caches[f"block{i}"] if caches is not None else None
            x, nc, aux = self._apply_block(
                up[f"block{i}"], x, positions, cache=c, window=window
            )
            aux_total = aux_total + aux
            if nc is not None:
                new_caches[f"block{i}"] = nc
        return x, (new_caches if caches is not None else None), aux_total

    # --- forward (train / prefill) -----------------------------------------------------
    def forward(
        self,
        params: PyTree,
        tokens: jnp.ndarray,
        extra_embeds: Optional[jnp.ndarray] = None,
        last_only: bool = False,
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """tokens: (B, S) -> (logits (B, S_total, V), aux_loss).

        extra_embeds (B, P, D): VLM patch embeddings prepended (early
        fusion); logits cover the full fused sequence.
        last_only: compute logits for the final position only (prefill
        serving path — avoids materializing the (B, S, V) tensor).
        """
        cfg = self.cfg
        x = nn.apply_embedding(params["embed"], tokens, self.dtype)
        if extra_embeds is not None:
            x = jnp.concatenate([extra_embeds.astype(self.dtype), x], axis=1)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        window = self.sliding_window

        def unit_fn(x, up):
            y, _, aux = self._apply_unit(up, x, positions, window=window)
            return y, aux

        if cfg.remat:
            unit_fn = jax.checkpoint(unit_fn)

        if cfg.scan_layers:
            x, auxes = jax.lax.scan(unit_fn, x, params["layers"])
            aux = jnp.sum(auxes) if cfg.moe is not None else 0.0
        else:
            aux = 0.0
            for i in range(self.num_units):
                up = jax.tree_util.tree_map(lambda p: p[i], params["layers"])
                x, a = unit_fn(x, up)
                aux = aux + a

        if last_only:
            x = x[:, -1:]
        x = nn.apply_rmsnorm(params["ln_final"], x)
        logits = self._lm_head(params, x)
        return logits, aux

    def _lm_head(self, params, x):
        if self.cfg.tie_embeddings:
            w = params["embed"]["table"].astype(x.dtype)
            return x @ w.T
        return x @ params["lm_head"]["w"].astype(x.dtype)

    # --- decode ------------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        """Cache pytree matching the scanned layer stack.

        For sliding-window mode the per-layer buffer is window-sized
        (ring buffer) — this is what makes long_500k sub-quadratic in
        memory and compute for full-attention archs.
        """
        cfg = self.cfg
        s_max = (
            min(max_len, self.sliding_window)
            if self.sliding_window is not None else max_len
        )

        def one(_):
            return {
                f"block{i}": KVCache.zeros(
                    batch, s_max, cfg.num_kv_heads, cfg.resolved_head_dim,
                    dtype,
                )
                for i in range(self.unit_size)
            }

        return jax.vmap(one)(jnp.arange(self.num_units))

    def prefill_into_cache(self, params, tokens, cache):
        """(Simplified) sequential prefill is exercised via decode_step;
        benchmark prefill uses ``forward``."""
        raise NotImplementedError

    def decode_step(
        self,
        params: PyTree,
        tokens: jnp.ndarray,          # (B, 1)
        cache: PyTree,
        position: jnp.ndarray,        # scalar int32: absolute position
    ) -> Tuple[jnp.ndarray, PyTree]:
        cfg = self.cfg
        x = nn.apply_embedding(params["embed"], tokens, self.dtype)
        b = x.shape[0]
        positions = jnp.broadcast_to(position, (b, 1)).astype(jnp.int32)
        window = self.sliding_window

        def unit_fn(x, scanned):
            up, cache_u = scanned
            y, new_cache, _ = self._apply_unit(
                up, x, positions, caches=cache_u, window=window
            )
            return y, new_cache

        if cfg.scan_layers:
            x, new_cache = jax.lax.scan(unit_fn, x, (params["layers"], cache))
        else:
            ncs = []
            for i in range(self.num_units):
                up = jax.tree_util.tree_map(lambda p: p[i], params["layers"])
                cu = jax.tree_util.tree_map(lambda c: c[i], cache)
                x, nc = unit_fn(x, (up, cu))
                ncs.append(nc)
            new_cache = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *ncs
            )

        x = nn.apply_rmsnorm(params["ln_final"], x)
        return self._lm_head(params, x), new_cache


# --- encoder-decoder (audio: seamless-m4t) ------------------------------------------------
class EncDecCache(NamedTuple):
    self_cache: PyTree
    cross_kv: PyTree            # per decoder unit: (k, v) from encoder


class EncoderDecoder:
    """Enc-dec transformer; the audio frontend is stubbed to frame
    embeddings (B, S_enc, D) per the multimodal carve-out."""

    def __init__(self, cfg: ArchConfig, *, attn_impl: str = "xla",
                 dtype=jnp.bfloat16, sliding_window: Optional[int] = None):
        assert cfg.encoder is not None
        self.cfg = cfg
        self.dtype = dtype
        self.attn_impl = attn_impl
        self.sliding_window = sliding_window
        self.dec = Transformer(cfg, attn_impl=attn_impl, dtype=dtype,
                               sliding_window=sliding_window)

    # encoder block: bidirectional self-attn + FFN
    def _init_enc_block(self, rng):
        cfg = self.cfg
        k1, k2 = jax.random.split(rng)
        return {
            "ln_attn": nn.init_rmsnorm(cfg.d_model),
            "attn": init_attention(k1, _attn_cfg(cfg, causal=False)),
            "ln_ffn": nn.init_rmsnorm(cfg.d_model),
            "ffn": init_glu_ffn(k2, cfg.d_model, cfg.d_ff),
        }

    def _init_dec_unit(self, rng):
        cfg = self.cfg
        k1, k2 = jax.random.split(rng)
        unit = self.dec._init_unit(k1)
        for i in range(self.dec.unit_size):
            kc, k2 = jax.random.split(k2)
            unit[f"block{i}"]["ln_cross"] = nn.init_rmsnorm(cfg.d_model)
            unit[f"block{i}"]["cross"] = init_attention(kc, _attn_cfg(cfg))
        return unit

    def init(self, rng) -> PyTree:
        cfg = self.cfg
        k_enc, k_dec, k_e, k_h, k_ln = jax.random.split(rng, 5)
        enc_keys = jax.random.split(k_enc, cfg.encoder.num_layers)
        dec_keys = jax.random.split(k_dec, self.dec.num_units)
        params = {
            "embed": nn.init_embedding(k_e, cfg.vocab_size, cfg.d_model),
            "enc_layers": jax.vmap(self._init_enc_block)(enc_keys),
            "ln_enc": nn.init_rmsnorm(cfg.d_model),
            "dec_layers": jax.vmap(self._init_dec_unit)(dec_keys),
            "ln_final": nn.init_rmsnorm(cfg.d_model),
            "lm_head": {
                "w": jax.random.normal(
                    k_h, (cfg.d_model, cfg.vocab_size), jnp.float32
                ) * (1.0 / math.sqrt(cfg.d_model))
            },
        }
        return params

    def encode(self, params, source_embeds: jnp.ndarray) -> jnp.ndarray:
        """source_embeds: stubbed frames (B, S_enc, D) -> memory."""
        cfg = self.cfg
        x = source_embeds.astype(self.dtype)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        acfg = _attn_cfg(cfg, causal=False)

        def block_fn(x, bp):
            h = nn.apply_rmsnorm(bp["ln_attn"], x)
            a, _ = apply_attention(bp["attn"], h, acfg, positions=positions,
                                   attn_impl=self.attn_impl)
            x = x + a
            h = nn.apply_rmsnorm(bp["ln_ffn"], x)
            return x + apply_glu_ffn(bp["ffn"], h, cfg.activation), None

        if cfg.remat:
            block_fn = jax.checkpoint(block_fn)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(block_fn, x, params["enc_layers"])
        else:
            for i in range(cfg.encoder.num_layers):
                bp = jax.tree_util.tree_map(
                    lambda p: p[i], params["enc_layers"]
                )
                x, _ = block_fn(x, bp)
        return nn.apply_rmsnorm(params["ln_enc"], x)

    def _dec_unit_fn(self, up, x, positions, memory_or_kv, caches=None,
                     precomputed_kv: bool = False):
        cfg = self.cfg
        acfg = _attn_cfg(cfg, sliding_window=self.sliding_window)
        new_caches = {}
        for i in range(self.dec.unit_size):
            bp = up[f"block{i}"]
            h = nn.apply_rmsnorm(bp["ln_attn"], x)
            c = caches[f"block{i}"] if caches is not None else None
            a, nc = apply_attention(bp["attn"], h, acfg, positions=positions,
                                    cache=c, attn_impl=self.attn_impl)
            x = x + a
            if nc is not None:
                new_caches[f"block{i}"] = nc
            h = nn.apply_rmsnorm(bp["ln_cross"], x)
            kv = (
                memory_or_kv[f"block{i}"] if precomputed_kv
                else encode_memory_kv(bp["cross"], memory_or_kv, acfg)
            )
            x = x + apply_cross_attention(bp["cross"], h, kv, acfg)
            h = nn.apply_rmsnorm(bp["ln_ffn"], x)
            x = x + apply_glu_ffn(bp["ffn"], h, cfg.activation)
        return x, (new_caches if caches is not None else None)

    def forward(self, params, tokens, source_embeds, last_only=False):
        """Teacher-forced training forward: (B, S_dec) + (B, S_enc, D)."""
        memory = self.encode(params, source_embeds)
        x = nn.apply_embedding(params["embed"], tokens, self.dtype)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        def unit_fn(x, up):
            y, _ = self._dec_unit_fn(up, x, positions, memory)
            return y, None

        if self.cfg.remat:
            unit_fn = jax.checkpoint(unit_fn)
        if self.cfg.scan_layers:
            x, _ = jax.lax.scan(unit_fn, x, params["dec_layers"])
        else:
            for i in range(self.dec.num_units):
                up = jax.tree_util.tree_map(
                    lambda p: p[i], params["dec_layers"]
                )
                x, _ = unit_fn(x, up)
        if last_only:
            x = x[:, -1:]
        x = nn.apply_rmsnorm(params["ln_final"], x)
        return x @ params["lm_head"]["w"].astype(x.dtype), 0.0

    def init_cache(self, params, source_embeds, max_len: int,
                   dtype=jnp.bfloat16) -> EncDecCache:
        """Encode once; precompute per-layer cross K/V; allocate self cache."""
        cfg = self.cfg
        memory = self.encode(params, source_embeds)
        acfg = _attn_cfg(cfg)
        b = source_embeds.shape[0]
        s_max = (
            min(max_len, self.sliding_window)
            if self.sliding_window is not None else max_len
        )

        def unit_kv(up):
            return {
                f"block{i}": encode_memory_kv(
                    up[f"block{i}"]["cross"], memory, acfg
                )
                for i in range(self.dec.unit_size)
            }

        cross_kv = jax.vmap(unit_kv)(params["dec_layers"])

        def one(_):
            return {
                f"block{i}": KVCache.zeros(
                    b, s_max, cfg.num_kv_heads, cfg.resolved_head_dim, dtype
                )
                for i in range(self.dec.unit_size)
            }

        self_cache = jax.vmap(one)(jnp.arange(self.dec.num_units))
        return EncDecCache(self_cache=self_cache, cross_kv=cross_kv)

    def decode_step(self, params, tokens, cache: EncDecCache,
                    position: jnp.ndarray):
        x = nn.apply_embedding(params["embed"], tokens, self.dtype)
        b = x.shape[0]
        positions = jnp.broadcast_to(position, (b, 1)).astype(jnp.int32)

        def unit_fn(x, scanned):
            up, cu, kv = scanned
            y, nc = self._dec_unit_fn(up, x, positions, kv, caches=cu,
                                      precomputed_kv=True)
            return y, nc

        if self.cfg.scan_layers:
            x, new_self = jax.lax.scan(
                unit_fn, x, (params["dec_layers"], cache.self_cache,
                             cache.cross_kv)
            )
        else:
            ncs = []
            for i in range(self.dec.num_units):
                sl = jax.tree_util.tree_map(
                    lambda p: p[i],
                    (params["dec_layers"], cache.self_cache,
                     cache.cross_kv),
                )
                x, nc = unit_fn(x, sl)
                ncs.append(nc)
            new_self = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *ncs
            )
        x = nn.apply_rmsnorm(params["ln_final"], x)
        logits = x @ params["lm_head"]["w"].astype(x.dtype)
        return logits, EncDecCache(self_cache=new_self, cross_kv=cache.cross_kv)
