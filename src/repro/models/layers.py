"""Transformer building blocks shared by the assigned architectures.

Functional style: ``init_*`` returns a param dict, ``apply_*`` is pure.
Everything supports two modes:

  * train/prefill: full-sequence forward, causal (or banded) mask;
  * decode: single-token forward against a KV cache.

Grouped-query attention (GQA) is expressed with an explicit group axis in
the einsums (no head replication), RoPE is precomputable, and the FFN
covers SwiGLU (mistral/phi3/llama4/kimi/minitron/zamba2) and GeGLU
(gemma).  A sliding-window mask implements the sub-quadratic variant used
for the ``long_500k`` shape on full-attention architectures.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import nn

PyTree = Any


# --- rotary position embeddings -------------------------------------------------
def rope_frequencies(head_dim: int, max_len: int, theta: float = 10000.0):
    """(max_len, head_dim//2) cos/sin tables."""
    inv = 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))
    t = np.arange(max_len)
    freqs = np.outer(t, inv)  # (max_len, hd/2)
    return jnp.asarray(np.cos(freqs), jnp.float32), jnp.asarray(
        np.sin(freqs), jnp.float32
    )


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0):
    """Rotate pairs of channels. x: (B, S, H, hd); positions: (B, S)."""
    hd = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    angles = positions[..., None].astype(jnp.float32) * inv  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    # re-interleave
    out = jnp.stack([out1, out2], axis=-1).reshape(x.shape)
    return out


# --- attention --------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    causal: bool = True
    sliding_window: Optional[int] = None   # None = full attention
    use_rope: bool = True
    logit_soft_cap: Optional[float] = None

    @property
    def q_per_kv(self) -> int:
        assert self.num_heads % self.num_kv_heads == 0
        return self.num_heads // self.num_kv_heads


class KVCache(NamedTuple):
    """Decode cache. k/v: (B, S_max, H_kv, hd); index: scalar write pos.

    For sliding-window attention S_max = window: the cache is a rolling
    ring buffer (index mod window)."""

    k: jnp.ndarray
    v: jnp.ndarray
    index: jnp.ndarray  # ()

    @staticmethod
    def zeros(batch: int, max_len: int, num_kv: int, head_dim: int,
              dtype=jnp.bfloat16) -> "KVCache":
        return KVCache(
            k=jnp.zeros((batch, max_len, num_kv, head_dim), dtype),
            v=jnp.zeros((batch, max_len, num_kv, head_dim), dtype),
            index=jnp.zeros((), jnp.int32),
        )


def init_attention(rng, cfg: AttentionConfig) -> Dict:
    kq, kk, kv, ko = jax.random.split(rng, 4)
    d, h, g, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s = 1.0 / math.sqrt(d)
    return {
        "wq": jax.random.normal(kq, (d, h, hd), jnp.float32) * s,
        "wk": jax.random.normal(kk, (d, g, hd), jnp.float32) * s,
        "wv": jax.random.normal(kv, (d, g, hd), jnp.float32) * s,
        "wo": jax.random.normal(ko, (h, hd, d), jnp.float32) * (s / math.sqrt(h)),
    }


def _attn_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray, causal: bool,
               window: Optional[int]) -> jnp.ndarray:
    """(B, Sq, Sk) boolean allow-mask from absolute positions."""
    diff = q_pos[:, :, None] - k_pos[:, None, :]
    mask = jnp.ones(diff.shape, bool)
    if causal:
        mask &= diff >= 0
    if window is not None:
        mask &= diff < window
    return mask


def attention_scores(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    mask: jnp.ndarray, q_per_kv: int,
    logit_soft_cap: Optional[float] = None,
) -> jnp.ndarray:
    """Grouped-query SDPA.  q: (B,Sq,H,hd), k/v: (B,Sk,G,hd), H=G*q_per_kv.

    The group axis is explicit so no KV replication is materialized —
    important when the Pallas flash kernel is swapped in on TPU.
    """
    b, sq, h, hd = q.shape
    g = k.shape[2]
    q = q.reshape(b, sq, g, q_per_kv, hd)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqgph,bkgh->bgpqk", q, k) * scale
    if logit_soft_cap is not None:
        logits = logit_soft_cap * jnp.tanh(logits / logit_soft_cap)
    logits = jnp.where(mask[:, None, None], logits, jnp.finfo(logits.dtype).min)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bgpqk,bkgh->bqgph", probs, v)
    return out.reshape(b, sq, h, hd)


def chunked_attention(
    q: jnp.ndarray,            # (B, S, H, hd)
    k: jnp.ndarray,            # (B, S, G, hd)
    v: jnp.ndarray,            # (B, S, G, hd)
    q_per_kv: int,
    causal: bool = True,
    window: Optional[int] = None,
    logit_soft_cap: Optional[float] = None,
    q_chunk: int = 512,
    k_chunk: int = 512,
) -> jnp.ndarray:
    """Flash-style attention in pure XLA: online softmax over KV chunks.

    Never materializes the (S, S) score matrix — peak live memory is
    O(q_chunk * k_chunk) per (batch, head) — which is what lets the
    32k/500k shapes lower within HBM.  The kv-chunk scan body is
    checkpointed so the backward pass recomputes chunk scores instead of
    saving them (flash-attention-style memory in the autodiff too).
    """
    b, s, h, hd = q.shape
    g = k.shape[2]
    # largest chunk <= requested that divides s (VLM fused sequences are
    # patches + tokens and need not be powers of two)
    q_chunk = math.gcd(s, min(q_chunk, s))
    k_chunk = math.gcd(s, min(k_chunk, s))
    assert s % q_chunk == 0 and s % k_chunk == 0
    nq, nk = s // q_chunk, s // k_chunk
    scale = 1.0 / math.sqrt(hd)

    # (B, G, P, S, hd) layouts
    qh = jnp.moveaxis(q.reshape(b, s, g, q_per_kv, hd), 1, 3)
    kh = jnp.moveaxis(k, 1, 2)                      # (B, G, S, hd)
    vh = jnp.moveaxis(v, 1, 2)
    qc = qh.reshape(b, g, q_per_kv, nq, q_chunk, hd)
    kc = kh.reshape(b, g, nk, k_chunk, hd)
    vc = vh.reshape(b, g, nk, k_chunk, hd)

    def one_q_chunk(qi_and_block):
        qi, qblk = qi_and_block                     # (B,G,P,Qc,hd)
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, kv):
            m_prev, l_prev, acc = carry
            ki, kblk, vblk = kv
            s_ = jnp.einsum(
                "bgpqh,bgkh->bgpqk", qblk.astype(jnp.float32),
                kblk.astype(jnp.float32),
            ) * scale
            if logit_soft_cap is not None:
                s_ = logit_soft_cap * jnp.tanh(s_ / logit_soft_cap)
            k_pos = ki * k_chunk + jnp.arange(k_chunk)
            diff = q_pos[:, None] - k_pos[None, :]
            mask = jnp.ones((q_chunk, k_chunk), bool)
            if causal:
                mask &= diff >= 0
            if window is not None:
                mask &= diff < window
            s_ = jnp.where(mask, s_, -jnp.inf)
            m_cur = jnp.max(s_, axis=-1, keepdims=True)
            m_new = jnp.maximum(m_prev, m_cur)
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s_ - m_safe)
            p = jnp.where(jnp.isfinite(s_), p, 0.0)
            corr = jnp.where(
                jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0
            )
            l_new = corr * l_prev + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * corr + jnp.einsum(
                "bgpqk,bgkh->bgpqh", p, vblk.astype(jnp.float32)
            )
            return (m_new, l_new, acc), None

        kv_step_ckpt = jax.checkpoint(kv_step)
        m0 = jnp.full((b, g, q_per_kv, q_chunk, 1), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, g, q_per_kv, q_chunk, 1), jnp.float32)
        a0 = jnp.zeros((b, g, q_per_kv, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step_ckpt, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kc, 2, 0),
             jnp.moveaxis(vc, 2, 0)),
        )
        return acc / jnp.maximum(l, 1e-30)

    out = jax.lax.map(
        one_q_chunk, (jnp.arange(nq), jnp.moveaxis(qc, 3, 0))
    )                                                # (nq,B,G,P,Qc,hd)
    out = jnp.moveaxis(out, 0, 3).reshape(b, g, q_per_kv, s, hd)
    out = jnp.moveaxis(out, 3, 1).reshape(b, s, h, hd)
    return out.astype(q.dtype)


def apply_attention(
    params: Dict,
    x: jnp.ndarray,
    cfg: AttentionConfig,
    positions: Optional[jnp.ndarray] = None,
    cache: Optional[KVCache] = None,
    attn_impl: str = "xla",
) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    """Full attention layer.  x: (B, S, D).

    Train/prefill: cache=None, positions default to arange(S).
    Decode: cache given, x is (B, 1, D), positions = current absolute pos.
    """
    b, s, d = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dgk->bsgk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dgk->bsgk", x, params["wv"].astype(x.dtype))
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is None:
        if attn_impl == "pallas":
            from repro.kernels import flash_ops

            out = flash_ops.flash_attention(
                q, k, v, causal=cfg.causal, window=cfg.sliding_window,
                logit_soft_cap=cfg.logit_soft_cap,
            )
        elif attn_impl == "chunked":
            out = chunked_attention(
                q, k, v, cfg.q_per_kv, causal=cfg.causal,
                window=cfg.sliding_window,
                logit_soft_cap=cfg.logit_soft_cap,
            )
        else:
            k_pos = positions
            mask = _attn_mask(positions, k_pos, cfg.causal,
                              cfg.sliding_window)
            out = attention_scores(q, k, v, mask, cfg.q_per_kv,
                                   cfg.logit_soft_cap)
    else:
        # decode: write k/v at cache.index (ring buffer for windowed attn)
        s_max = cache.k.shape[1]
        write_idx = (
            cache.index % s_max if cfg.sliding_window is not None
            else cache.index
        )
        k_new = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), write_idx, axis=1
        )
        v_new = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), write_idx, axis=1
        )
        new_cache = KVCache(k=k_new, v=v_new, index=cache.index + s)
        # absolute positions of cache slots
        slot = jnp.arange(s_max)
        if cfg.sliding_window is not None:
            # ring buffer: slot i holds absolute pos = largest p <= index
            # with p % s_max == i
            cur = cache.index + s - 1  # last absolute position written
            abs_pos = cur - ((cur - slot) % s_max)
            valid = abs_pos >= jnp.maximum(0, cur - s_max + 1)
        else:
            abs_pos = slot
            valid = slot < (cache.index + s)
        k_pos = jnp.broadcast_to(abs_pos, (b, s_max))
        mask = _attn_mask(positions, k_pos, cfg.causal, cfg.sliding_window)
        mask &= valid[None, None, :]
        out = attention_scores(
            q, k_new.astype(q.dtype), v_new.astype(q.dtype), mask,
            cfg.q_per_kv, cfg.logit_soft_cap,
        )

    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, new_cache


# --- cross attention (enc-dec) -----------------------------------------------------
def apply_cross_attention(
    params: Dict,
    x: jnp.ndarray,
    memory_kv: Tuple[jnp.ndarray, jnp.ndarray],
    cfg: AttentionConfig,
    memory_mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Decoder cross-attention over precomputed encoder K/V.

    memory_kv: (k, v) each (B, S_enc, G, hd) — computed once per request
    and cached across decode steps.
    """
    b, s, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k, v = memory_kv
    s_enc = k.shape[1]
    if memory_mask is None:
        mask = jnp.ones((b, s, s_enc), bool)
    else:
        mask = jnp.broadcast_to(memory_mask[:, None, :], (b, s, s_enc))
    out = attention_scores(q, k.astype(q.dtype), v.astype(q.dtype), mask,
                           cfg.q_per_kv)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


def encode_memory_kv(params: Dict, memory: jnp.ndarray, cfg: AttentionConfig):
    """Precompute cross-attention K/V from encoder output (no RoPE)."""
    k = jnp.einsum("bsd,dgk->bsgk", memory, params["wk"].astype(memory.dtype))
    v = jnp.einsum("bsd,dgk->bsgk", memory, params["wv"].astype(memory.dtype))
    return k, v


# --- gated FFN ---------------------------------------------------------------------
def init_glu_ffn(rng, d_model: int, d_ff: int) -> Dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    return {
        "w_gate": jax.random.normal(k1, (d_model, d_ff), jnp.float32) * s_in,
        "w_up": jax.random.normal(k2, (d_model, d_ff), jnp.float32) * s_in,
        "w_down": jax.random.normal(k3, (d_ff, d_model), jnp.float32) * s_out,
    }


def apply_glu_ffn(params: Dict, x: jnp.ndarray, activation: str = "silu"):
    """SwiGLU ('silu') or GeGLU ('gelu') feed-forward."""
    act = jax.nn.silu if activation == "silu" else jax.nn.gelu
    gate = act(x @ params["w_gate"].astype(x.dtype))
    up = x @ params["w_up"].astype(x.dtype)
    return (gate * up) @ params["w_down"].astype(x.dtype)
