"""Model zoo: paper's CNN/U-Net + the 10 assigned architectures."""
