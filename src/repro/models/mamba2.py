"""Mamba2: state-space duality (SSD) blocks [arXiv:2405.21060].

Implements the chunked SSD algorithm:

  h_t = exp(dt_t * A) h_{t-1} + dt_t * (B_t (x) x_t)
  y_t = C_t . h_t + D x_t

computed chunk-parallel: a within-chunk "attention-like" term
(C B^T masked by the cumulative decay L) plus an across-chunk recurrent
state pass (lax.scan over chunks).  This pure-jnp path doubles as the
oracle (ref.py) for the Pallas ``ssd_scan`` kernel; the model can route
through the kernel with ``ssd_impl="pallas"`` on TPU.

The recurrent (decode) path keeps O(1) state per layer:
conv state (B, W-1, C_conv) + SSM state (B, H, P, N) — which is why the
SSM/hybrid architectures run ``long_500k`` natively (DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.models import nn

PyTree = Any


# --- the SSD scan (pure jnp; also the kernel oracle) ------------------------------
def segsum(a: jnp.ndarray) -> jnp.ndarray:
    """L[i, j] = sum_{k=j+1..i} a[k] for i >= j else -inf.  a: (..., Q)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # (..., i, j)
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,        # (B, S, H, P)
    dt: jnp.ndarray,       # (B, S, H) positive
    A: jnp.ndarray,        # (H,) negative
    Bm: jnp.ndarray,       # (B, S, G, N)
    Cm: jnp.ndarray,       # (B, S, G, N)
    chunk: int = 128,
    initial_state: Optional[jnp.ndarray] = None,   # (B, H, P, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert s % chunk == 0, f"seq {s} % chunk {chunk} != 0"
    nc = s // chunk
    rep = h // g  # heads per B/C group

    dtype = x.dtype
    xdt = (x * dt[..., None]).astype(jnp.float32)       # dt-weighted input
    a = (dt * A[None, None, :]).astype(jnp.float32)     # (B, S, H) log-decay

    # reshape into chunks
    xc = xdt.reshape(b, nc, chunk, h, p)
    ac = a.reshape(b, nc, chunk, h)
    Bc = Bm.astype(jnp.float32).reshape(b, nc, chunk, g, n)
    Cc = Cm.astype(jnp.float32).reshape(b, nc, chunk, g, n)
    Bh = jnp.repeat(Bc, rep, axis=3)                    # (B,nc,Q,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    # within-chunk (diagonal) term
    L = jnp.exp(segsum(jnp.moveaxis(ac, -1, -2)))       # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh)   # (B,nc,H,Q,Q)
    y_diag = jnp.einsum("bchqk,bchqk,bckhp->bcqhp", scores, L,
                        xc)                              # (B,nc,Q,H,P)

    # chunk summaries: state contribution of each chunk
    a_cum = jnp.cumsum(ac, axis=2)                       # (B,nc,Q,H)
    a_tot = a_cum[:, :, -1, :]                           # (B,nc,H)
    decay_to_end = jnp.exp(a_tot[:, :, None, :] - a_cum)  # (B,nc,Q,H)
    chunk_states = jnp.einsum(
        "bcqhn,bcqh,bcqhp->bchpn", Bh, decay_to_end, xc
    )                                                     # (B,nc,H,P,N)

    # across-chunk recurrence
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)
    else:
        initial_state = initial_state.astype(jnp.float32)

    def scan_fn(state, inp):
        a_tot_c, new_c = inp                              # (B,H), (B,H,P,N)
        out_state = state                                 # state BEFORE chunk
        next_state = state * jnp.exp(a_tot_c)[:, :, None, None] + new_c
        return next_state, out_state

    final_state, states_before = jax.lax.scan(
        scan_fn,
        initial_state,
        (jnp.moveaxis(a_tot, 1, 0), jnp.moveaxis(chunk_states, 1, 0)),
    )
    states_before = jnp.moveaxis(states_before, 0, 1)     # (B,nc,H,P,N)

    # off-diagonal (carry-in) term
    state_decay = jnp.exp(a_cum)                          # (B,nc,Q,H)
    y_off = jnp.einsum(
        "bcqhn,bchpn,bcqh->bcqhp", Ch, states_before, state_decay
    )

    y = (y_diag + y_off).reshape(b, s, h, p).astype(dtype)
    return y, final_state


def ssd_decode_step(
    x: jnp.ndarray,      # (B, H, P) single token
    dt: jnp.ndarray,     # (B, H)
    A: jnp.ndarray,      # (H,)
    Bm: jnp.ndarray,     # (B, G, N)
    Cm: jnp.ndarray,     # (B, G, N)
    state: jnp.ndarray,  # (B, H, P, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = x.shape[1]
    g = Bm.shape[1]
    rep = h // g
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)   # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    dA = jnp.exp(dt.astype(jnp.float32) * A[None, :])      # (B,H)
    xdt = (x * dt[..., None]).astype(jnp.float32)          # (B,H,P)
    new_state = state * dA[:, :, None, None] + jnp.einsum(
        "bhn,bhp->bhpn", Bh, xdt
    )
    y = jnp.einsum("bhn,bhpn->bhp", Ch, new_state)
    return y.astype(x.dtype), new_state


# --- Mamba2 block -------------------------------------------------------------------
class MambaCache(NamedTuple):
    conv: jnp.ndarray    # (B, W-1, conv_channels)
    ssm: jnp.ndarray     # (B, H, P, N)


def _dims(cfg: ArchConfig) -> Tuple[int, int, int, int, int]:
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    nheads = d_inner // ssm.head_dim
    conv_ch = d_inner + 2 * ssm.num_groups * ssm.state_dim
    return d_inner, nheads, ssm.num_groups, ssm.state_dim, conv_ch


def init_mamba_block(rng, cfg: ArchConfig) -> Dict:
    ssm = cfg.ssm
    d_inner, nheads, g, n, conv_ch = _dims(cfg)
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    d = cfg.d_model
    proj_out = 2 * d_inner + 2 * g * n + nheads   # z, x, B, C, dt
    s = 1.0 / math.sqrt(d)
    return {
        "norm": nn.init_rmsnorm(d),
        "in_proj": jax.random.normal(k1, (d, proj_out), jnp.float32) * s,
        "conv_w": jax.random.normal(k2, (ssm.conv_width, conv_ch),
                                    jnp.float32) * 0.2,
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads).astype(jnp.float32)),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "out_norm": nn.init_rmsnorm(d_inner),
        "out_proj": jax.random.normal(k3, (d_inner, d), jnp.float32)
        * (1.0 / math.sqrt(d_inner)),
    }


def _split_proj(cfg: ArchConfig, proj: jnp.ndarray):
    d_inner, nheads, g, n, _ = _dims(cfg)
    idx = 0
    z = proj[..., idx: idx + d_inner]; idx += d_inner
    xin = proj[..., idx: idx + d_inner]; idx += d_inner
    Bm = proj[..., idx: idx + g * n]; idx += g * n
    Cm = proj[..., idx: idx + g * n]; idx += g * n
    dt = proj[..., idx:]
    return z, xin, Bm, Cm, dt


def _causal_conv(seq: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 prev: Optional[jnp.ndarray] = None):
    """Depthwise causal conv over (B, S, C) with width-W taps (W, C)."""
    width = w.shape[0]
    if prev is None:
        pad = jnp.zeros((seq.shape[0], width - 1, seq.shape[2]), seq.dtype)
    else:
        pad = prev.astype(seq.dtype)
    full = jnp.concatenate([pad, seq], axis=1)
    out = sum(
        full[:, i: i + seq.shape[1], :] * w[i][None, None, :].astype(seq.dtype)
        for i in range(width)
    )
    new_prev = full[:, -(width - 1):, :] if width > 1 else pad[:, :0]
    return out + b[None, None, :].astype(seq.dtype), new_prev


def apply_mamba_block(
    params: Dict,
    x: jnp.ndarray,                       # (B, S, D)
    cfg: ArchConfig,
    cache: Optional[MambaCache] = None,
    ssd_impl: str = "xla",
) -> Tuple[jnp.ndarray, Optional[MambaCache]]:
    ssm = cfg.ssm
    d_inner, nheads, g, n, conv_ch = _dims(cfg)
    residual = x
    h = nn.apply_rmsnorm(params["norm"], x)
    proj = h @ params["in_proj"].astype(h.dtype)
    z, xin, Bm, Cm, dt = _split_proj(cfg, proj)

    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    prev = cache.conv if cache is not None else None
    conv_out, new_conv = _causal_conv(
        conv_in, params["conv_w"], params["conv_b"], prev
    )
    conv_out = jax.nn.silu(conv_out)
    xin = conv_out[..., :d_inner]
    Bm = conv_out[..., d_inner: d_inner + g * n]
    Cm = conv_out[..., d_inner + g * n:]

    b, s, _ = x.shape
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"][None, None, :]
    )
    A = -jnp.exp(params["A_log"])
    xh = xin.reshape(b, s, nheads, ssm.head_dim)
    Bm = Bm.reshape(b, s, g, n)
    Cm = Cm.reshape(b, s, g, n)

    if cache is None:
        if ssd_impl == "pallas":
            from repro.kernels import ssd_ops

            y, final_state = ssd_ops.ssd(
                xh, dt, A, Bm, Cm, chunk=ssm.chunk_size
            )
        else:
            y, final_state = ssd_chunked(
                xh, dt, A, Bm, Cm, chunk=min(ssm.chunk_size, s)
            )
        new_cache = None
    else:
        y, new_ssm = ssd_decode_step(
            xh[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0], cache.ssm
        )
        y = y[:, None]
        new_cache = MambaCache(conv=new_conv, ssm=new_ssm)

    y = y + params["D"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(b, s, d_inner)
    y = y * jax.nn.silu(z)
    y = nn.apply_rmsnorm(params["out_norm"], y)
    out = residual + y @ params["out_proj"].astype(y.dtype)
    return out, new_cache


def init_mamba_cache(cfg: ArchConfig, batch: int) -> MambaCache:
    ssm = cfg.ssm
    d_inner, nheads, g, n, conv_ch = _dims(cfg)
    return MambaCache(
        conv=jnp.zeros((batch, ssm.conv_width - 1, conv_ch), jnp.bfloat16),
        ssm=jnp.zeros((batch, nheads, ssm.head_dim, n), jnp.float32),
    )


# --- full Mamba2 model ------------------------------------------------------------------
class Mamba2Model:
    def __init__(self, cfg: ArchConfig, *, dtype=jnp.bfloat16,
                 ssd_impl: str = "xla", **_):
        assert cfg.ssm is not None
        self.cfg = cfg
        self.dtype = dtype
        self.ssd_impl = ssd_impl

    def init(self, rng) -> PyTree:
        cfg = self.cfg
        ke, kl, kh = jax.random.split(rng, 3)
        layer_keys = jax.random.split(kl, cfg.num_layers)
        params = {
            "embed": nn.init_embedding(ke, cfg.vocab_size, cfg.d_model),
            "layers": jax.vmap(lambda k: init_mamba_block(k, cfg))(layer_keys),
            "ln_final": nn.init_rmsnorm(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = {
                "w": jax.random.normal(
                    kh, (cfg.d_model, cfg.vocab_size), jnp.float32
                ) * (1.0 / math.sqrt(cfg.d_model))
            }
        return params

    def forward(self, params, tokens, extra_embeds=None, last_only=False):
        x = nn.apply_embedding(params["embed"], tokens, self.dtype)

        def block_fn(x, bp):
            y, _ = apply_mamba_block(bp, x, self.cfg, ssd_impl=self.ssd_impl)
            return y, None

        if self.cfg.remat:
            block_fn = jax.checkpoint(block_fn)
        if self.cfg.scan_layers:
            x, _ = jax.lax.scan(block_fn, x, params["layers"])
        else:
            for i in range(self.cfg.num_layers):
                bp = jax.tree_util.tree_map(lambda p: p[i], params["layers"])
                x, _ = block_fn(x, bp)
        if last_only:
            x = x[:, -1:]
        x = nn.apply_rmsnorm(params["ln_final"], x)
        return self._lm_head(params, x), 0.0

    def _lm_head(self, params, x):
        if self.cfg.tie_embeddings:
            return x @ params["embed"]["table"].astype(x.dtype).T
        return x @ params["lm_head"]["w"].astype(x.dtype)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        def one(_):
            return init_mamba_cache(self.cfg, batch)

        return jax.vmap(one)(jnp.arange(self.cfg.num_layers))

    def decode_step(self, params, tokens, cache, position):
        x = nn.apply_embedding(params["embed"], tokens, self.dtype)

        def block_fn(x, scanned):
            bp, c = scanned
            y, nc = apply_mamba_block(bp, x, self.cfg, cache=c)
            return y, nc

        if self.cfg.scan_layers:
            x, new_cache = jax.lax.scan(
                block_fn, x, (params["layers"], cache)
            )
        else:
            ncs = []
            for i in range(self.cfg.num_layers):
                bp = jax.tree_util.tree_map(lambda p: p[i], params["layers"])
                cu = jax.tree_util.tree_map(lambda c: c[i], cache)
                x, nc = block_fn(x, (bp, cu))
                ncs.append(nc)
            new_cache = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *ncs
            )
        x = nn.apply_rmsnorm(params["ln_final"], x)
        return self._lm_head(params, x), new_cache
