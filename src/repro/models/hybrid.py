"""Zamba2-style hybrid: Mamba2 backbone + a SHARED attention block
[arXiv:2411.15242].

The defining trick: one set of transformer-block weights (attention +
MLP) is re-applied at multiple depths (every ``hybrid_attn_every`` Mamba
layers).  Weights are shared; activations are not — each application gets
its own KV cache slot during decode.

Layout for L mamba layers with interval g:
  [g mamba] -> shared attn -> [g mamba] -> shared attn -> ... -> remainder
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import nn
from repro.models.layers import KVCache, apply_attention, apply_glu_ffn, \
    init_attention, init_glu_ffn
from repro.models.mamba2 import (
    MambaCache,
    apply_mamba_block,
    init_mamba_block,
    init_mamba_cache,
)
from repro.models.transformer import _attn_cfg

PyTree = Any


class HybridCache(NamedTuple):
    mamba: PyTree            # stacked MambaCache (L, ...)
    attn: PyTree             # list-stacked KVCache per shared-block use


class Zamba2Model:
    def __init__(self, cfg: ArchConfig, *, dtype=jnp.bfloat16,
                 attn_impl: str = "xla", ssd_impl: str = "xla",
                 sliding_window: Optional[int] = None, **_):
        assert cfg.ssm is not None
        self.cfg = cfg
        self.dtype = dtype
        self.attn_impl = attn_impl
        self.ssd_impl = ssd_impl
        self.sliding_window = sliding_window
        g = cfg.hybrid_attn_every
        self.group = g
        self.n_full = cfg.num_layers // g
        self.rem = cfg.num_layers % g
        self.n_attn_uses = self.n_full + (1 if self.rem else 0)

    def init(self, rng) -> PyTree:
        cfg = self.cfg
        ke, km, ka, kf, kh = jax.random.split(rng, 5)
        full_keys = jax.random.split(km, self.n_full * self.group).reshape(
            self.n_full, self.group, 2
        )
        params = {
            "embed": nn.init_embedding(ke, cfg.vocab_size, cfg.d_model),
            # (n_full, group, ...) stacked mamba blocks, scanned two-level
            "mamba_full": jax.vmap(
                jax.vmap(lambda k: init_mamba_block(k, cfg))
            )(full_keys),
            # one SHARED transformer block
            "shared_attn": {
                "ln_attn": nn.init_rmsnorm(cfg.d_model),
                "attn": init_attention(ka, _attn_cfg(cfg)),
                "ln_ffn": nn.init_rmsnorm(cfg.d_model),
                "ffn": init_glu_ffn(kf, cfg.d_model, cfg.d_ff),
            },
            "ln_final": nn.init_rmsnorm(cfg.d_model),
        }
        if self.rem:
            krem = jax.random.split(rng, self.rem)
            params["mamba_rem"] = jax.vmap(
                lambda k: init_mamba_block(k, cfg)
            )(krem)
        if not cfg.tie_embeddings:
            params["lm_head"] = {
                "w": jax.random.normal(
                    kh, (cfg.d_model, cfg.vocab_size), jnp.float32
                ) * (1.0 / math.sqrt(cfg.d_model))
            }
        return params

    def _shared_attn(self, sp, x, positions, cache=None):
        acfg = _attn_cfg(self.cfg, sliding_window=self.sliding_window)
        h = nn.apply_rmsnorm(sp["ln_attn"], x)
        a, nc = apply_attention(sp["attn"], h, acfg, positions=positions,
                                cache=cache, attn_impl=self.attn_impl)
        x = x + a
        h = nn.apply_rmsnorm(sp["ln_ffn"], x)
        return x + apply_glu_ffn(sp["ffn"], h, self.cfg.activation), nc

    def forward(self, params, tokens, extra_embeds=None, last_only=False):
        cfg = self.cfg
        x = nn.apply_embedding(params["embed"], tokens, self.dtype)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        def mamba_fn(x, bp):
            y, _ = apply_mamba_block(bp, x, cfg, ssd_impl=self.ssd_impl)
            return y, None

        if cfg.remat:
            mamba_fn = jax.checkpoint(mamba_fn)

        def group_fn(x, gp):
            if cfg.scan_layers:
                y, _ = jax.lax.scan(mamba_fn, x, gp)
                return y
            for i in range(self.group):
                bp = jax.tree_util.tree_map(lambda p: p[i], gp)
                x, _ = mamba_fn(x, bp)
            return x

        # scan over groups is unrolled (n_full <= ~7): shared weights are
        # re-applied, so a lax.scan over uses would capture them as carry
        # constants anyway.
        for gi in range(self.n_full):
            gp = jax.tree_util.tree_map(lambda p: p[gi], params["mamba_full"])
            x = group_fn(x, gp)
            x, _ = self._shared_attn(params["shared_attn"], x, positions)
        if self.rem:
            if cfg.scan_layers:
                x, _ = jax.lax.scan(mamba_fn, x, params["mamba_rem"])
            else:
                for i in range(self.rem):
                    bp = jax.tree_util.tree_map(
                        lambda p: p[i], params["mamba_rem"]
                    )
                    x, _ = mamba_fn(x, bp)
            x, _ = self._shared_attn(params["shared_attn"], x, positions)

        if last_only:
            x = x[:, -1:]
        x = nn.apply_rmsnorm(params["ln_final"], x)
        return self._lm_head(params, x), 0.0

    def _lm_head(self, params, x):
        if self.cfg.tie_embeddings:
            return x @ params["embed"]["table"].astype(x.dtype).T
        return x @ params["lm_head"]["w"].astype(x.dtype)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        s_max = (
            min(max_len, self.sliding_window)
            if self.sliding_window is not None else max_len
        )
        mamba_full = jax.vmap(
            lambda _: jax.vmap(lambda __: init_mamba_cache(cfg, batch))(
                jnp.arange(self.group)
            )
        )(jnp.arange(self.n_full))
        caches = {"mamba_full": mamba_full}
        if self.rem:
            caches["mamba_rem"] = jax.vmap(
                lambda _: init_mamba_cache(cfg, batch)
            )(jnp.arange(self.rem))
        caches["attn"] = jax.vmap(
            lambda _: KVCache.zeros(
                batch, s_max, cfg.num_kv_heads, cfg.resolved_head_dim, dtype
            )
        )(jnp.arange(self.n_attn_uses))
        return caches

    def decode_step(self, params, tokens, cache, position):
        cfg = self.cfg
        x = nn.apply_embedding(params["embed"], tokens, self.dtype)
        b = x.shape[0]
        positions = jnp.broadcast_to(position, (b, 1)).astype(jnp.int32)

        def mamba_fn(x, scanned):
            bp, c = scanned
            y, nc = apply_mamba_block(bp, x, cfg, cache=c)
            return y, nc

        new_mamba_full = []
        new_attn = []
        use = 0
        for gi in range(self.n_full):
            gp = jax.tree_util.tree_map(lambda p: p[gi], params["mamba_full"])
            gc = jax.tree_util.tree_map(lambda c: c[gi], cache["mamba_full"])
            x, nmc = jax.lax.scan(mamba_fn, x, (gp, gc))
            new_mamba_full.append(nmc)
            ac = jax.tree_util.tree_map(lambda c: c[use], cache["attn"])
            x, nac = self._shared_attn(params["shared_attn"], x, positions,
                                       cache=ac)
            new_attn.append(nac)
            use += 1
        new_cache = {
            "mamba_full": jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *new_mamba_full
            )
        }
        if self.rem:
            x, nrc = jax.lax.scan(
                mamba_fn, x, (params["mamba_rem"], cache["mamba_rem"])
            )
            new_cache["mamba_rem"] = nrc
            ac = jax.tree_util.tree_map(lambda c: c[use], cache["attn"])
            x, nac = self._shared_attn(params["shared_attn"], x, positions,
                                       cache=ac)
            new_attn.append(nac)
        new_cache["attn"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *new_attn
        )

        x = nn.apply_rmsnorm(params["ln_final"], x)
        return self._lm_head(params, x), new_cache
