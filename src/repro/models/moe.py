"""Mixture-of-Experts FFN with capacity-based dispatch (expert parallel).

Top-k routing with Switch-style capacity: tokens are scattered into
per-expert buffers of capacity C = ceil(cap_factor * N * k / E), experts
run as one batched einsum over the expert axis (sharded over the mesh
``model`` axis => expert parallelism; GSPMD inserts the all-to-alls for
the dispatch/combine resharding), and outputs are gathered back weighted
by the router gates.  Overflowing tokens are dropped (standard
load-balance behaviour) and a Shazeer-style auxiliary load-balance loss
is returned for the trainer.

Used by llama4-maverick (128e top-1) and kimi-k2 (384e top-8 + 1 shared
expert).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig


def init_moe(rng, d_model: int, cfg: MoEConfig) -> Dict:
    kr, kg, ku, kd, ks = jax.random.split(rng, 5)
    E, F = cfg.num_experts, cfg.d_ff_expert
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(F)
    params = {
        "router": jax.random.normal(kr, (d_model, E), jnp.float32) * s_in,
        "w_gate": jax.random.normal(kg, (E, d_model, F), jnp.float32) * s_in,
        "w_up": jax.random.normal(ku, (E, d_model, F), jnp.float32) * s_in,
        "w_down": jax.random.normal(kd, (E, F, d_model), jnp.float32) * s_out,
    }
    if cfg.num_shared_experts > 0:
        Fs = F * cfg.num_shared_experts
        k1, k2, k3 = jax.random.split(ks, 3)
        params["shared"] = {
            "w_gate": jax.random.normal(k1, (d_model, Fs), jnp.float32) * s_in,
            "w_up": jax.random.normal(k2, (d_model, Fs), jnp.float32) * s_in,
            "w_down": jax.random.normal(k3, (Fs, d_model), jnp.float32) * s_out,
        }
    return params


def apply_moe(
    params: Dict,
    x: jnp.ndarray,
    cfg: MoEConfig,
    activation: str = "silu",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss).

    Dispatch is fully vectorized; the expert compute einsums carry the
    expert axis so sharding the E dim gives expert parallelism.
    """
    act = jax.nn.silu if activation == "silu" else jax.nn.gelu
    b, s, d = x.shape
    n = b * s
    E, k = cfg.num_experts, cfg.top_k
    cap = max(1, int(cfg.capacity_factor * n * k / E))

    xt = x.reshape(n, d)
    logits = (xt @ params["router"].astype(xt.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (N, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)             # (N, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balance auxiliary loss (Shazeer et al.): E * sum_e f_e * p_e.
    # bincount instead of a one-hot (N, E) materialization: N can be 1M+.
    me = jnp.mean(probs, axis=0)                                 # (E,)
    tokens_per_expert = jnp.zeros((E,), jnp.float32)
    for j in range(k):
        tokens_per_expert = tokens_per_expert + jnp.bincount(
            expert_idx[:, j], length=E
        ).astype(jnp.float32)
    ce = tokens_per_expert / n
    aux_loss = cfg.router_aux_loss * E * jnp.sum(me * ce)

    # sort-based position assignment: O(N log N) and O(N) memory — never
    # builds the (N, E) one-hot the cumsum formulation needs.
    counts = jnp.zeros((E,), jnp.int32)
    buf = jnp.zeros((E * cap, d), xt.dtype)
    flat_positions = []
    valids = []
    arange_n = jnp.arange(n)
    for j in range(k):
        idx_j = expert_idx[:, j]                                 # (N,)
        order = jnp.argsort(idx_j)
        sorted_e = idx_j[order]
        starts = jnp.searchsorted(sorted_e, jnp.arange(E))       # (E,)
        rank_sorted = arange_n - starts[sorted_e]                # pos within expert
        pos_sorted = rank_sorted + counts[sorted_e]
        pos = jnp.zeros((n,), jnp.int32).at[order].set(
            pos_sorted.astype(jnp.int32)
        )
        counts = counts + jnp.bincount(idx_j, length=E).astype(jnp.int32)
        valid = pos < cap
        flat = idx_j * cap + jnp.minimum(pos, cap - 1)
        buf = buf.at[flat].add(
            jnp.where(valid[:, None], xt, 0).astype(xt.dtype)
        )
        flat_positions.append(flat)
        valids.append(valid)

    ex_in = buf.reshape(E, cap, d)
    # expert compute: E sharded over the mesh "model" axis
    gate = act(jnp.einsum("ecd,edf->ecf", ex_in,
                          params["w_gate"].astype(xt.dtype)))
    up = jnp.einsum("ecd,edf->ecf", ex_in, params["w_up"].astype(xt.dtype))
    ex_out = jnp.einsum("ecf,efd->ecd", gate * up,
                        params["w_down"].astype(xt.dtype))
    ex_out = ex_out.reshape(E * cap, d)

    out = jnp.zeros_like(xt)
    for j in range(k):
        piece = ex_out[flat_positions[j]]                        # (N, D)
        w = (gate_vals[:, j] * valids[j].astype(jnp.float32)).astype(xt.dtype)
        out = out + piece * w[:, None]

    if "shared" in params:
        sh = params["shared"]
        g = act(xt @ sh["w_gate"].astype(xt.dtype))
        u = xt @ sh["w_up"].astype(xt.dtype)
        out = out + (g * u) @ sh["w_down"].astype(xt.dtype)

    return out.reshape(b, s, d), aux_loss
