"""Pure-jnp oracle for the aggregation kernel."""
from __future__ import annotations

import jax.numpy as jnp


def aggregate_flat_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """out[n] = sum_k w[k] x[k, n], accumulated in fp32."""
    return jnp.tensordot(
        w.astype(jnp.float32), x.astype(jnp.float32), axes=1
    ).astype(x.dtype)
