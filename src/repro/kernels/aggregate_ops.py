"""jit'd wrapper: pytree-level weighted aggregation through the kernel.

On CPU (this container) the kernel runs in interpret mode; on TPU it
compiles to a Mosaic kernel.  ``aggregate_pytree`` flattens every leaf,
concatenates into one (K, N) stream (one kernel launch instead of
hundreds of tiny ones) and unflattens the result.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.aggregate import aggregate_flat

PyTree = Any


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def aggregate_pytree(stacked: PyTree, weights: jnp.ndarray) -> PyTree:
    """stacked: pytree with leaves (K, ...); returns weighted sum."""
    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    k = leaves[0].shape[0]
    shapes = [l.shape[1:] for l in leaves]
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    dtypes = [l.dtype for l in leaves]
    common = jnp.result_type(*dtypes)
    flat = jnp.concatenate(
        [l.reshape(k, -1).astype(common) for l in leaves], axis=1
    )
    agg = aggregate_flat(flat, weights, interpret=not _on_tpu())
    outs = []
    off = 0
    for shape, size, dt in zip(shapes, sizes, dtypes):
        outs.append(agg[off: off + size].reshape(shape).astype(dt))
        off += size
    return jax.tree_util.tree_unflatten(treedef, outs)
