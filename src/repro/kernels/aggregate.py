"""Pallas kernel: weighted model aggregation (FedLEO eqs. 4/9).

Computes out[n] = sum_k w[k] * x[k, n] for K stacked flattened parameter
vectors.  This is the FL server's hot-spot: for a 123B-parameter model
with K=5 orbit partials a single aggregation streams ~2.5 TB through
HBM, so it is purely memory-bound and the kernel's job is to tile the
stream through VMEM at full bandwidth with the accumulation in fp32.

TPU adaptation: block shape (K, BLOCK_N) with BLOCK_N a multiple of the
128-lane register width; K (the client axis) stays resident so each HBM
byte of x is touched exactly once.  Weights live in SMEM (scalar
prefetch) — they are K scalars.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 16_384   # 16k lanes * K rows of fp32 comfortably < VMEM


def _aggregate_kernel(w_ref, x_ref, o_ref):
    """w: (K, 1) VMEM; x: (K, BLOCK_N) VMEM; o: (BLOCK_N,) VMEM."""
    x = x_ref[...].astype(jnp.float32)          # (K, BN)
    w = w_ref[...].astype(jnp.float32)          # (K, 1)
    o_ref[...] = jnp.sum(x * w, axis=0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def aggregate_flat(
    x: jnp.ndarray,        # (K, N) stacked flattened params
    w: jnp.ndarray,        # (K,) normalized weights
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> jnp.ndarray:
    """Weighted sum over the leading axis; returns (N,)."""
    k, n = x.shape
    block_n = min(block_n, n)
    # pad N to a block multiple
    n_pad = (-n) % block_n
    if n_pad:
        x = jnp.pad(x, ((0, 0), (0, n_pad)))
    n_total = n + n_pad
    grid = (n_total // block_n,)

    out = pl.pallas_call(
        _aggregate_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, 1), lambda i: (0, 0)),          # weights
            pl.BlockSpec((k, block_n), lambda i: (0, i)),    # param stream
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_total,), x.dtype),
        interpret=interpret,
    )(w[:, None], x)
    return out[:n]
