"""Pallas TPU kernels for the compute hot-spots.

  * ``aggregate``  — weighted model aggregation (FedLEO eqs. 4/9): the FL
    server hot-spot, a memory-bound streaming reduction over K stacked
    parameter vectors.
  * ``flash``      — GQA flash attention (causal / sliding-window) for the
    transformer architectures.
  * ``ssd``        — Mamba2 SSD chunked scan.

Each kernel ships as <name>.py (pl.pallas_call + BlockSpec), <name>_ops.py
(jit'd wrapper with interpret fallback on CPU) and <name>_ref.py (pure-jnp
oracle used by the allclose test sweeps).
"""


def tpu_compiler_params(**kwargs):
    """Build Mosaic compiler params across the pltpu rename.

    JAX 0.4.x exposes ``pltpu.TPUCompilerParams``; newer releases renamed
    it to ``pltpu.CompilerParams``.  Kernels must work on both.
    """
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


from repro.kernels import aggregate_ops, flash_ops, ssd_ops  # noqa: E402

__all__ = ["aggregate_ops", "flash_ops", "ssd_ops", "tpu_compiler_params"]
