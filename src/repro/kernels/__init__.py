"""Pallas TPU kernels for the compute hot-spots.

  * ``aggregate``  — weighted model aggregation (FedLEO eqs. 4/9): the FL
    server hot-spot, a memory-bound streaming reduction over K stacked
    parameter vectors.
  * ``flash``      — GQA flash attention (causal / sliding-window) for the
    transformer architectures.
  * ``ssd``        — Mamba2 SSD chunked scan.

Each kernel ships as <name>.py (pl.pallas_call + BlockSpec), <name>_ops.py
(jit'd wrapper with interpret fallback on CPU) and <name>_ref.py (pure-jnp
oracle used by the allclose test sweeps).
"""
from repro.kernels import aggregate_ops, flash_ops, ssd_ops

__all__ = ["aggregate_ops", "flash_ops", "ssd_ops"]
