"""Pallas kernel: GQA flash attention (causal / sliding window).

Online-softmax attention tiled for the TPU memory hierarchy: Q blocks of
(BLOCK_Q, head_dim) stay VMEM-resident while K/V stream in blocks of
(BLOCK_K, head_dim); the running max/denominator live in VMEM scratch and
persist across the sequential kv-block grid dimension (dimension
semantics: batch/head/q-block parallel, kv-block arbitrary).

GQA is handled in the index maps: q-head h reads kv-head h // q_per_kv —
no KV replication is ever materialized in VMEM.

The sliding-window mask makes this the sub-quadratic attention used by
the long_500k shape: kv blocks wholly outside [q - window, q] are
skipped via a mask (structurally zero blocks still stream; see §Perf for
the block-skip iteration).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: Optional[int],
    block_q: int, block_k: int, num_kv_blocks: int,
    soft_cap: Optional[float],
):
    qi = pl.program_id(2)          # q-block index
    ki = pl.program_id(3)          # kv-block index (sequential)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)         # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)         # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)         # (BK, D)

    s = jnp.dot(q, k.T) * scale                 # (BQ, BK)
    if soft_cap is not None:
        s = soft_cap * jnp.tanh(s / soft_cap)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    )
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                          # (BQ, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                       # (BQ, BK)
    correction = jnp.exp(m_prev - m_new)         # (BQ, 1)
    l_new = correction * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * correction + jnp.dot(p, v)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "logit_soft_cap", "block_q", "block_k",
        "interpret",
    ),
)
def flash_attention(
    q: jnp.ndarray,        # (B, S, H, D)
    k: jnp.ndarray,        # (B, S, G, D)
    v: jnp.ndarray,        # (B, S, G, D)
    causal: bool = True,
    window: Optional[int] = None,
    logit_soft_cap: Optional[float] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jnp.ndarray:
    b, s, h, d = q.shape
    g = k.shape[2]
    assert h % g == 0
    q_per_kv = h // g
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    num_q_blocks = s // block_q
    num_kv_blocks = s // block_k
    scale = 1.0 / math.sqrt(d)

    # layout: (B, H, S, D) blocks
    qt = jnp.moveaxis(q, 1, 2)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_kv_blocks=num_kv_blocks,
        soft_cap=logit_soft_cap,
    )

    out = pl.pallas_call(
        kernel,
        grid=(b, h, num_q_blocks, num_kv_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, qpk=q_per_kv:
                         (bi, hi // qpk, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, qpk=q_per_kv:
                         (bi, hi // qpk, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)               # back to (B, S, H, D)
