"""jit'd wrapper for the SSD kernel (interpret on CPU, Mosaic on TPU).

Returns (y, final_state) to match the model's ssd_chunked signature; the
kernel itself produces y, and the final state (needed only when chaining
prefill -> decode) is recovered with one extra lightweight jnp pass.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ssd as _ssd
from repro.models.mamba2 import ssd_chunked


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssd(x, dt, A, Bm, Cm, chunk: int = 128) -> Tuple[jnp.ndarray, jnp.ndarray]:
    s = x.shape[1]
    chunk = min(chunk, s)
    if s % chunk != 0:
        return ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    y = _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk,
                      interpret=not _on_tpu())
    # final state via the jnp chunk recurrence (cheap relative to y)
    _, final_state = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    return y, final_state
