"""jit'd wrapper for the flash-attention kernel with CPU interpret
fallback; the model layer calls this when attn_impl="pallas"."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash as _flash
from repro.kernels.flash_ref import flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: Optional[int] = None,
    logit_soft_cap: Optional[float] = None,
) -> jnp.ndarray:
    s = q.shape[1]
    block = 128 if s % 128 == 0 else (64 if s % 64 == 0 else None)
    if block is None:
        # ragged sequence: fall back to the oracle
        return flash_attention_ref(q, k, v, causal, window, logit_soft_cap)
    return _flash.flash_attention(
        q, k, v, causal=causal, window=window,
        logit_soft_cap=logit_soft_cap,
        block_q=block, block_k=block,
        interpret=not _on_tpu(),
    )
