"""Pure-jnp oracle for flash attention (GQA, causal, sliding window)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jnp.ndarray,        # (B, S, H, D)
    k: jnp.ndarray,        # (B, S, G, D)
    v: jnp.ndarray,        # (B, S, G, D)
    causal: bool = True,
    window: Optional[int] = None,
    logit_soft_cap: Optional[float] = None,
) -> jnp.ndarray:
    b, s, h, d = q.shape
    g = k.shape[2]
    rep = h // g
    qf = q.astype(jnp.float32).reshape(b, s, g, rep, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bqgrd,bkgd->bgrqk", qf, kf) / math.sqrt(d)
    if logit_soft_cap is not None:
        logits = logit_soft_cap * jnp.tanh(logits / logit_soft_cap)
    pos = jnp.arange(s)
    diff = pos[:, None] - pos[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= diff >= 0
    if window is not None:
        mask &= diff < window
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, vf)
    return out.reshape(b, s, h, d).astype(q.dtype)
