"""Pallas kernel: Mamba2 SSD chunked scan [arXiv:2405.21060].

Grid: (batch, heads, num_chunks) with the chunk dimension sequential
("arbitrary"); the inter-chunk recurrent state (P, N) lives in VMEM
scratch and carries across chunk iterations — the TPU-native analogue of
the paper's chunk-parallel SSD: the within-chunk quadratic term uses the
MXU (Q x Q matmuls), the cross-chunk term is a rank-1-style state update,
and HBM traffic is one pass over x/dt/B/C.

Per-block shapes (Q = chunk length, P = head dim, N = state dim):
  x: (Q, P), dt: (Q, 1), B/C: (Q, N)  ->  y: (Q, P), state scratch (P, N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_scr,
                *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (Q, 1)
    bm = b_ref[0, 0].astype(jnp.float32)         # (Q, N)
    cm = c_ref[0, 0].astype(jnp.float32)         # (Q, N)
    a_h = a_ref[0, 0]                            # (1, 1) scalar A for head

    xdt = x * dt                                 # dt-weighted input
    a = dt * a_h                                 # (Q, 1) log-decay
    a_cum = jnp.cumsum(a[:, 0])                  # (Q,)

    # within-chunk decay matrix L[i, j] = exp(acum_i - acum_j), i >= j
    diff = a_cum[:, None] - a_cum[None, :]
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(li >= lj, jnp.exp(diff), 0.0)

    scores = jnp.dot(cm, bm.T) * L               # (Q, Q) masked CB^T
    y_diag = jnp.dot(scores, xdt)                # (Q, P)

    # carry-in from previous chunks' state
    state = state_scr[...]                        # (P, N)
    y_off = jnp.exp(a_cum)[:, None] * jnp.dot(cm, state.T)  # (Q, P)

    y_ref[0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: state' = exp(a_tot) * state + sum_q decay_q B_q (x) xdt_q
    a_tot = a_cum[-1]
    decay_to_end = jnp.exp(a_tot - a_cum)         # (Q,)
    new_contrib = jnp.dot((xdt * decay_to_end[:, None]).T, bm)  # (P, N)
    state_scr[...] = state * jnp.exp(a_tot) + new_contrib


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jnp.ndarray,        # (B, S, H, P)
    dt: jnp.ndarray,       # (B, S, H) positive
    A: jnp.ndarray,        # (H,) negative
    Bm: jnp.ndarray,       # (B, S, G, N)
    Cm: jnp.ndarray,       # (B, S, G, N)
    chunk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns y (B, S, H, P).  Heads map to their B/C group (H % G)."""
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert s % chunk == 0
    nc = s // chunk
    rep = h // g

    # layouts: (B, H, S, *) so heads are a parallel grid dim
    xt = jnp.moveaxis(x, 1, 2)                       # (B, H, S, P)
    dtt = jnp.moveaxis(dt, 1, 2)[..., None]          # (B, H, S, 1)
    bt = jnp.moveaxis(Bm, 1, 2)                      # (B, G, S, N)
    ct = jnp.moveaxis(Cm, 1, 2)
    a2 = A[None, :, None, None]                      # (1, H, 1, 1)
    a2 = jnp.broadcast_to(a2, (b, h, 1, 1))

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, 1), lambda bi, hi, ci: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, chunk, p),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, 1),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci, r=rep: (bi, hi // r, ci, 0)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci, r=rep: (bi, hi // r, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p),
                               lambda bi, hi, ci: (bi, hi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a2, xt, dtt, bt, ct)
    return jnp.moveaxis(out, 1, 2)
