"""Oracle for the SSD kernel: the model's chunked jnp implementation
(itself validated against a naive per-step recurrence in the tests)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.mamba2 import ssd_chunked


def ssd_ref(x, dt, A, Bm, Cm, chunk: int = 128):
    y, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    return y


def ssd_naive(x, dt, A, Bm, Cm):
    """O(S) sequential recurrence — ground truth for both implementations."""
    import jax

    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    Bh = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)   # (B,S,H,N)
    Ch = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)
    a = dt.astype(jnp.float32) * A[None, None, :]          # (B,S,H)
    xdt = x.astype(jnp.float32) * dt[..., None].astype(jnp.float32)

    def step(state, t):
        dA = jnp.exp(a[:, t])                              # (B,H)
        state = state * dA[:, :, None, None] + jnp.einsum(
            "bhn,bhp->bhpn", Bh[:, t], xdt[:, t]
        )
        y = jnp.einsum("bhn,bhpn->bhp", Ch[:, t], state)
        return state, y

    state = jnp.zeros((b, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, state, jnp.arange(s))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)          # (B,S,H,P)
