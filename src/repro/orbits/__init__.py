"""Orbital mechanics substrate: Walker-delta constellations, visibility."""
from repro.orbits.constellation import (
    ConstellationConfig,
    GroundStation,
    Satellite,
    WalkerDelta,
    orbital_period,
    orbital_speed,
)
from repro.orbits.visibility import (
    elevation_angle,
    visibility_mask,
    visibility_windows,
    VisibilityWindow,
)
from repro.orbits.prediction import VisibilityPredictor

__all__ = [
    "ConstellationConfig",
    "GroundStation",
    "Satellite",
    "WalkerDelta",
    "orbital_period",
    "orbital_speed",
    "elevation_angle",
    "visibility_mask",
    "visibility_windows",
    "VisibilityWindow",
    "VisibilityPredictor",
]
