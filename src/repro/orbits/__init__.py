"""Orbital mechanics substrate: Walker-delta constellations, visibility."""
from repro.orbits.constellation import (
    ConstellationConfig,
    GroundStation,
    Satellite,
    WalkerDelta,
    orbital_period,
    orbital_speed,
)
from repro.orbits.visibility import (
    elevation_angle,
    visibility_mask,
    visibility_table,
    visibility_windows,
    visibility_windows_reference,
    VisibilityWindow,
    WindowTable,
)
from repro.orbits.prediction import VisibilityPredictor, as_gs_list
from repro.orbits.topology import (
    INTER,
    INTRA,
    ISLTopology,
    TOPOLOGY_PRESETS,
    TopologyConfig,
    get_isl_topology,
    get_topology,
    phased_slot_shift,
)

__all__ = [
    "get_isl_topology",
    "INTER",
    "INTRA",
    "ISLTopology",
    "TOPOLOGY_PRESETS",
    "TopologyConfig",
    "get_topology",
    "phased_slot_shift",
    "ConstellationConfig",
    "GroundStation",
    "Satellite",
    "WalkerDelta",
    "orbital_period",
    "orbital_speed",
    "elevation_angle",
    "visibility_mask",
    "visibility_table",
    "visibility_windows",
    "visibility_windows_reference",
    "VisibilityWindow",
    "WindowTable",
    "VisibilityPredictor",
    "as_gs_list",
]
