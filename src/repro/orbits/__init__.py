"""Orbital mechanics substrate: Walker-delta constellations, visibility."""
from repro.orbits.constellation import (
    ConstellationConfig,
    GroundStation,
    Satellite,
    WalkerDelta,
    orbital_period,
    orbital_speed,
)
from repro.orbits.visibility import (
    elevation_angle,
    visibility_mask,
    visibility_table,
    visibility_windows,
    visibility_windows_reference,
    VisibilityWindow,
    WindowTable,
)
from repro.orbits.prediction import VisibilityPredictor, as_gs_list

__all__ = [
    "ConstellationConfig",
    "GroundStation",
    "Satellite",
    "WalkerDelta",
    "orbital_period",
    "orbital_speed",
    "elevation_angle",
    "visibility_mask",
    "visibility_table",
    "visibility_windows",
    "visibility_windows_reference",
    "VisibilityWindow",
    "WindowTable",
    "VisibilityPredictor",
    "as_gs_list",
]
