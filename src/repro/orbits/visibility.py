"""Satellite-GS visibility: elevation angles, masks, and access windows.

The paper's visibility condition (§III):

  a satellite k is visible from GS g at time t iff the line-of-sight is
  not blocked by the Earth and the elevation angle is at least the GS's
  minimum elevation angle theta_min:

    angle(r_g(t), r_k(t) - r_g(t)) <= pi/2 - theta_min

which is equivalent to  elevation(k, g, t) >= theta_min.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.orbits.constellation import GroundStation, WalkerDelta


def elevation_angle(r_sat: np.ndarray, r_gs: np.ndarray) -> np.ndarray:
    """Elevation of the satellite above the GS's local horizon [rad].

    Args:
      r_sat: (..., 3) satellite ECI positions [m].
      r_gs:  (..., 3) GS ECI positions [m] (broadcastable to r_sat).

    Returns:
      (...) elevation angles [rad]; >= 0 means above the horizon.
    """
    d = r_sat - r_gs
    d_norm = np.linalg.norm(d, axis=-1)
    g_norm = np.linalg.norm(r_gs, axis=-1)
    # sin(elevation) = (d . r_gs_hat) / |d|
    sin_el = np.einsum("...i,...i->...", d, r_gs) / (d_norm * g_norm)
    return np.arcsin(np.clip(sin_el, -1.0, 1.0))


def visibility_mask(
    walker: WalkerDelta,
    gs: GroundStation,
    t: np.ndarray,
) -> np.ndarray:
    """Boolean visibility (L, K, T) of every satellite at every time."""
    r_sat = walker.positions(t)            # (L, K, T, 3)
    r_gs = gs.eci(t)                       # (T, 3)
    el = elevation_angle(r_sat, r_gs[None, None])
    return el >= np.radians(gs.min_elevation_deg)


@dataclasses.dataclass(frozen=True)
class VisibilityWindow:
    """One access window AW(k, GS): [t_start, t_end] of the r-th visit."""

    plane: int
    slot: int
    t_start: float
    t_end: float

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start

    def contains(self, t: float) -> bool:
        return self.t_start <= t <= self.t_end


def _refine_crossing(
    f, lo: float, hi: float, rising: bool, iters: int = 40
) -> float:
    """Bisection root of the elevation-threshold crossing in [lo, hi]."""
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        above = f(mid) >= 0.0
        if above == rising:
            # crossing is in [lo, mid] for rising (f goes -..+), symmetric
            hi = mid
        else:
            lo = mid
    return 0.5 * (lo + hi)


def visibility_windows(
    walker: WalkerDelta,
    gs: GroundStation,
    t_start: float,
    t_end: float,
    coarse_step_s: float = 10.0,
    refine: bool = True,
) -> List[VisibilityWindow]:
    """All access windows of every satellite within [t_start, t_end].

    Coarse grid scan + bisection refinement of rise/set times (the
    deterministic analogue of the visibility prediction method of Ali et
    al. [11] used by the paper's scheduler).

    Returns windows sorted by t_start.
    """
    t = np.arange(t_start, t_end + coarse_step_s, coarse_step_s)
    mask = visibility_mask(walker, gs, t)          # (L, K, T)
    min_el = np.radians(gs.min_elevation_deg)

    windows: List[VisibilityWindow] = []
    L, K, T = mask.shape
    for p in range(L):
        for s in range(K):
            m = mask[p, s]
            # transitions: diff +1 = rise between i and i+1; -1 = set
            dm = np.diff(m.astype(np.int8))
            rises = list(np.nonzero(dm == 1)[0])
            sets_ = list(np.nonzero(dm == -1)[0])
            # handle windows clipped by the scan interval
            starts: List[float] = []
            ends: List[float] = []
            sat = walker.satellites[p * K + s]

            def el_fn(tt: float) -> float:
                r_s = walker.position_of(sat, tt)
                r_g = gs.eci(np.asarray(tt))
                return float(elevation_angle(r_s, r_g) - min_el)

            if m[0]:
                starts.append(t[0])
            for i in rises:
                if refine:
                    starts.append(_refine_crossing(el_fn, t[i], t[i + 1], True))
                else:
                    starts.append(t[i + 1])
            for i in sets_:
                if refine:
                    ends.append(_refine_crossing(el_fn, t[i], t[i + 1], False))
                else:
                    ends.append(t[i])
            if m[-1]:
                ends.append(t[-1])
            for a, b in zip(starts, ends):
                if b > a:
                    windows.append(
                        VisibilityWindow(plane=p, slot=s, t_start=a, t_end=b)
                    )
    windows.sort(key=lambda w: w.t_start)
    return windows
