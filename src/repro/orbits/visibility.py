"""Satellite-GS visibility: elevation angles, masks, and access windows.

The paper's visibility condition (§III):

  a satellite k is visible from GS g at time t iff the line-of-sight is
  not blocked by the Earth and the elevation angle is at least the GS's
  minimum elevation angle theta_min:

    angle(r_g(t), r_k(t) - r_g(t)) <= pi/2 - theta_min

which is equivalent to  elevation(k, g, t) >= theta_min.

Two implementations of the access-window extraction live here:

  * ``visibility_table`` / ``visibility_windows`` — the vectorized
    engine.  It samples the full (L, K, T) elevation tensor once
    (time-chunked so mega-constellation grids never materialize a
    multi-GB position tensor), finds every rise/set transition with one
    ``np.diff``, and refines ALL crossings of ALL satellites with a
    single batched bisection.  Windows come back as a ``WindowTable`` of
    structured NumPy arrays; ``VisibilityWindow`` dataclasses are thin
    views kept for API compatibility.
  * ``visibility_windows_reference`` — the original per-satellite
    per-crossing scalar loop, kept as the equivalence oracle for tests
    and the baseline for ``benchmarks/constellation_scaling.py``.

Both share the same clamped time grid (``_time_grid``), so a window can
never extend past the requested horizon.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List

import numpy as np

from repro.orbits.constellation import (
    GroundStation,
    MultiShellWalker,
    WalkerDelta,
)

# Transient memory budget [MB] for the coarse elevation scan.  The scan
# is evaluated in time chunks whose length adapts to the constellation
# size so the per-chunk float64 working set stays under this budget at
# ANY scale (the pre-budget code pinned the chunk length at 2048
# samples, which over-allocated at paper scale and under-utilized —
# then overflowed transients at multi-shell scale).
DEFAULT_MEM_BUDGET_MB = 256.0

# Divisor turning a byte budget into a chunk length.  Measured on the
# 72x22 preset: ``WalkerDelta.elevations_from`` holds ~6.3 concurrently
# live (num_sats, chunk) float64 arrays (theta, trig temporaries, dot,
# |d|^2, sin_el); the rest of the headroom covers what chunking cannot
# shrink — the full-horizon boolean mask, the comparison slice, and the
# per-plane GS projection — so the whole table build (not just one
# chunk) peaks under the budget at 24 h+ horizons.
_SCAN_ARRAYS_PER_SAMPLE = 12
_MIN_CHUNK_T = 16


def scan_chunk_len(num_sats: int, mem_budget_mb: float) -> int:
    """Time-chunk length keeping the elevation scan's transient float64
    working set (~``_SCAN_ARRAYS_PER_SAMPLE`` arrays of shape
    ``(num_sats, chunk)``) under ``mem_budget_mb``.  Never below
    ``_MIN_CHUNK_T`` samples, so a tiny budget degrades to many small
    chunks instead of failing."""
    if mem_budget_mb <= 0:
        raise ValueError(f"mem_budget_mb must be positive, got {mem_budget_mb}")
    bytes_per_sample = max(1, int(num_sats)) * 8 * _SCAN_ARRAYS_PER_SAMPLE
    return max(_MIN_CHUNK_T, int(mem_budget_mb * 1e6 / bytes_per_sample))


def elevation_angle(r_sat: np.ndarray, r_gs: np.ndarray) -> np.ndarray:
    """Elevation of the satellite above the GS's local horizon [rad].

    Args:
      r_sat: (..., 3) satellite ECI positions [m].
      r_gs:  (..., 3) GS ECI positions [m] (broadcastable to r_sat).

    Returns:
      (...) elevation angles [rad]; >= 0 means above the horizon.
    """
    d = r_sat - r_gs
    d_norm = np.linalg.norm(d, axis=-1)
    g_norm = np.linalg.norm(r_gs, axis=-1)
    # sin(elevation) = (d . r_gs_hat) / |d|
    sin_el = np.einsum("...i,...i->...", d, r_gs) / (d_norm * g_norm)
    return np.arcsin(np.clip(sin_el, -1.0, 1.0))


def visibility_mask(
    walker: "WalkerDelta | MultiShellWalker",
    gs: GroundStation,
    t: np.ndarray,
    mem_budget_mb: float = DEFAULT_MEM_BUDGET_MB,
) -> np.ndarray:
    """Boolean visibility (L, K, T) of every satellite at every time.

    Evaluated in time chunks sized by ``scan_chunk_len``: the
    ``(L, K, Tc)`` float64 elevation transients are the only large
    intermediates and stay under ``mem_budget_mb`` at any
    constellation scale (the boolean output mask is 1/48th of the
    per-sample transient and is the only full-horizon allocation).
    Chunking only partitions the evaluation grid — every time sample
    is computed identically — so the mask is bit-identical across
    budgets.
    """
    scalar = np.ndim(t) == 0
    t = np.atleast_1d(np.asarray(t, dtype=np.float64))
    min_el = np.radians(gs.min_elevation_deg)
    L, K = walker.config.num_planes, walker.config.sats_per_plane
    chunk = scan_chunk_len(L * K, mem_budget_mb)
    mask = np.empty((L, K, t.size), dtype=bool)
    for i in range(0, t.size, chunk):
        tc = t[i : i + chunk]
        el = walker.elevations_from(gs, tc)     # (L, K, Tc)
        mask[:, :, i : i + chunk] = el >= min_el
    return mask[:, :, 0] if scalar else mask


def _time_grid(t_start: float, t_end: float, step: float) -> np.ndarray:
    """Coarse scan grid clamped to [t_start, t_end].

    The final sample is exactly t_end (the historical
    ``arange(t_start, t_end + step, step)`` sampled past the horizon, so
    clipped windows could overshoot the requested range).
    """
    if t_end <= t_start:
        raise ValueError(f"empty scan range [{t_start}, {t_end}]")
    n = int(math.floor((t_end - t_start) / step + 1e-9))
    t = t_start + step * np.arange(n + 1, dtype=np.float64)
    if t[-1] < t_end - 1e-9 * max(1.0, abs(t_end)):
        t = np.append(t, t_end)
    else:
        t[-1] = min(t[-1], t_end)
    return t


@dataclasses.dataclass(frozen=True)
class VisibilityWindow:
    """One access window AW(k, GS): [t_start, t_end] of the r-th visit.

    ``gs_index`` identifies which ground station the window belongs to
    when a multi-GS predictor merges window sets (union semantics).
    """

    plane: int
    slot: int
    t_start: float
    t_end: float
    gs_index: int = 0

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start

    def contains(self, t: float) -> bool:
        return self.t_start <= t <= self.t_end


@dataclasses.dataclass(frozen=True)
class WindowTable:
    """Structured access-window storage: parallel arrays, one row per
    window, sorted by (t_start, plane, slot).

    This is the vectorized engine's native output; ``to_windows`` builds
    the ``VisibilityWindow`` dataclass views for the legacy list API.
    """

    plane: np.ndarray      # (W,) int32
    slot: np.ndarray       # (W,) int32
    t_start: np.ndarray    # (W,) float64
    t_end: np.ndarray      # (W,) float64
    gs_index: np.ndarray   # (W,) int32

    def __len__(self) -> int:
        return int(self.plane.size)

    def window(self, i: int) -> VisibilityWindow:
        return VisibilityWindow(
            plane=int(self.plane[i]),
            slot=int(self.slot[i]),
            t_start=float(self.t_start[i]),
            t_end=float(self.t_end[i]),
            gs_index=int(self.gs_index[i]),
        )

    def to_windows(self) -> List[VisibilityWindow]:
        return [self.window(i) for i in range(len(self))]

    def sorted_by_start(self) -> "WindowTable":
        order = np.lexsort((self.slot, self.plane, self.t_start))
        return self.take(order)

    def take(self, idx: np.ndarray) -> "WindowTable":
        return WindowTable(
            plane=self.plane[idx],
            slot=self.slot[idx],
            t_start=self.t_start[idx],
            t_end=self.t_end[idx],
            gs_index=self.gs_index[idx],
        )

    @staticmethod
    def empty() -> "WindowTable":
        z = np.zeros(0)
        return WindowTable(z.astype(np.int32), z.astype(np.int32),
                           z, z.copy(), z.astype(np.int32))

    @staticmethod
    def concatenate(tables: List["WindowTable"]) -> "WindowTable":
        if not tables:
            return WindowTable.empty()
        return WindowTable(
            plane=np.concatenate([t.plane for t in tables]),
            slot=np.concatenate([t.slot for t in tables]),
            t_start=np.concatenate([t.t_start for t in tables]),
            t_end=np.concatenate([t.t_end for t in tables]),
            gs_index=np.concatenate([t.gs_index for t in tables]),
        )


def _elevation_margin(
    walker: "WalkerDelta | MultiShellWalker",
    gs: GroundStation,
    planes: np.ndarray,
    slots: np.ndarray,
    t: np.ndarray,
    min_el: float,
) -> np.ndarray:
    """elevation - theta_min for arbitrary (plane, slot, t) triples."""
    r_s = walker.positions_batch(planes, slots, t)
    r_g = gs.eci(np.asarray(t, dtype=np.float64))
    return elevation_angle(r_s, r_g) - min_el


def _refine_crossings_batched(
    walker: "WalkerDelta | MultiShellWalker",
    gs: GroundStation,
    planes: np.ndarray,
    slots: np.ndarray,
    lo: np.ndarray,
    hi: np.ndarray,
    rising: bool,
    min_el: float,
    iters: int = 40,
    mem_budget_mb: float = DEFAULT_MEM_BUDGET_MB,
) -> np.ndarray:
    """Bisection of EVERY elevation-threshold crossing simultaneously.

    Identical iteration count and update rule as the scalar
    ``_refine_crossing``, evaluated for all C crossings per step — the
    whole refinement is ``iters`` vectorized elevation evaluations
    instead of ``iters * C`` scalar ones.  Crossings are processed in
    budget-bounded batches (each crossing's bisection is independent,
    so batching is result-invariant): ``positions_batch`` materializes
    several ``(C, 3)`` float64 temporaries per evaluation, which at
    multi-shell scale would otherwise rival the scan transient.
    """
    lo = np.array(lo, dtype=np.float64)
    hi = np.array(hi, dtype=np.float64)
    out = np.empty_like(lo)
    # ~12 live float64 arrays per crossing per evaluation ((C, 3)
    # positions + trig temporaries), vs _SCAN_ARRAYS_PER_SAMPLE flat
    # ones in the scan — reuse the same budget arithmetic scaled by 2
    batch = max(_MIN_CHUNK_T, int(mem_budget_mb * 1e6 / (8 * 12)))
    for b in range(0, lo.size, batch):
        s = slice(b, b + batch)
        blo, bhi = lo[s], hi[s]
        bplanes, bslots = planes[s], slots[s]
        for _ in range(iters):
            mid = 0.5 * (blo + bhi)
            above = (
                _elevation_margin(walker, gs, bplanes, bslots, mid, min_el)
                >= 0.0
            )
            go_hi = above == rising     # crossing is in [lo, mid]
            bhi = np.where(go_hi, mid, bhi)
            blo = np.where(go_hi, blo, mid)
        out[s] = 0.5 * (blo + bhi)
    return out


def visibility_table(
    walker: "WalkerDelta | MultiShellWalker",
    gs: GroundStation,
    t_start: float,
    t_end: float,
    coarse_step_s: float = 10.0,
    refine: bool = True,
    gs_index: int = 0,
    mem_budget_mb: float = DEFAULT_MEM_BUDGET_MB,
) -> WindowTable:
    """All access windows of every satellite within [t_start, t_end],
    as a structured ``WindowTable`` (the vectorized engine).

    Coarse grid scan + one batched bisection over every rise/set
    crossing of every satellite (the deterministic analogue of the
    visibility prediction method of Ali et al. [11] used by the paper's
    scheduler, at constellation scale).  ``mem_budget_mb`` bounds the
    transient working set of the scan and the bisection batches; the
    returned table is bit-identical across budgets.
    """
    t = _time_grid(t_start, t_end, coarse_step_s)
    mask = visibility_mask(walker, gs, t, mem_budget_mb=mem_budget_mb)
    min_el = float(np.radians(gs.min_elevation_deg))
    K = walker.config.sats_per_plane

    # Transition extraction on boolean views (rise = below->above,
    # set = above->below): one (L, K, T-1) bool temporary at a time,
    # freed before the next — the historical int8 ``np.diff`` held an
    # int8 copy of the whole mask PLUS the diff output concurrently.
    prev, nxt = mask[:, :, :-1], mask[:, :, 1:]     # views, no copies
    rise = ~prev & nxt
    rise_p, rise_s, rise_i = np.nonzero(rise)
    del rise
    fall = prev & ~nxt
    set_p, set_s, set_i = np.nonzero(fall)
    del fall

    if refine and rise_i.size:
        rise_t = _refine_crossings_batched(
            walker, gs, rise_p, rise_s, t[rise_i], t[rise_i + 1],
            rising=True, min_el=min_el, mem_budget_mb=mem_budget_mb,
        )
    else:
        rise_t = t[rise_i + 1]
    if refine and set_i.size:
        set_t = _refine_crossings_batched(
            walker, gs, set_p, set_s, t[set_i], t[set_i + 1],
            rising=False, min_el=min_el, mem_budget_mb=mem_budget_mb,
        )
    else:
        set_t = t[set_i]

    # windows clipped by the scan range open at t[0] / close at t[-1]
    clip_lo_p, clip_lo_s = np.nonzero(mask[:, :, 0])
    clip_hi_p, clip_hi_s = np.nonzero(mask[:, :, -1])

    start_p = np.concatenate([clip_lo_p, rise_p])
    start_s = np.concatenate([clip_lo_s, rise_s])
    start_t = np.concatenate(
        [np.full(clip_lo_p.size, t[0]), np.asarray(rise_t, dtype=np.float64)]
    )
    end_p = np.concatenate([set_p, clip_hi_p])
    end_s = np.concatenate([set_s, clip_hi_s])
    end_t = np.concatenate(
        [np.asarray(set_t, dtype=np.float64), np.full(clip_hi_p.size, t[-1])]
    )

    # Per satellite the 1-D mask alternates rise/set, so start and end
    # counts match; sorting both sides by (satellite, time) pairs the
    # r-th start with the r-th end of the same satellite.
    start_order = np.lexsort((start_t, start_p * K + start_s))
    end_order = np.lexsort((end_t, end_p * K + end_s))
    sp = start_p[start_order]
    ss = start_s[start_order]
    st = start_t[start_order]
    et = end_t[end_order]

    keep = et > st            # drop degenerate single-sample windows
    table = WindowTable(
        plane=sp[keep].astype(np.int32),
        slot=ss[keep].astype(np.int32),
        t_start=st[keep],
        t_end=et[keep],
        gs_index=np.full(int(np.count_nonzero(keep)), gs_index,
                         dtype=np.int32),
    )
    return table.sorted_by_start()


def visibility_windows(
    walker: "WalkerDelta | MultiShellWalker",
    gs: GroundStation,
    t_start: float,
    t_end: float,
    coarse_step_s: float = 10.0,
    refine: bool = True,
    mem_budget_mb: float = DEFAULT_MEM_BUDGET_MB,
) -> List[VisibilityWindow]:
    """Vectorized access-window extraction, legacy list-of-dataclass API.

    Returns windows sorted by t_start.
    """
    return visibility_table(
        walker, gs, t_start, t_end, coarse_step_s=coarse_step_s,
        refine=refine, mem_budget_mb=mem_budget_mb,
    ).to_windows()


# --- scalar reference implementation (equivalence oracle + benchmark baseline) ---
def _refine_crossing(
    f: "Callable[[float], float]",
    lo: float,
    hi: float,
    rising: bool,
    iters: int = 40,
) -> float:
    """Bisection root of the elevation-threshold crossing in [lo, hi]."""
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        above = f(mid) >= 0.0
        if above == rising:
            # crossing is in [lo, mid] for rising (f goes -..+), symmetric
            hi = mid
        else:
            lo = mid
    return 0.5 * (lo + hi)


def visibility_windows_reference(
    walker: "WalkerDelta | MultiShellWalker",
    gs: GroundStation,
    t_start: float,
    t_end: float,
    coarse_step_s: float = 10.0,
    refine: bool = True,
) -> List[VisibilityWindow]:
    """The original per-satellite scalar loop (per-crossing bisection).

    Kept as the oracle the vectorized engine is tested against and as
    the baseline of ``benchmarks/constellation_scaling.py``.  Returns
    windows sorted by t_start.
    """
    t = _time_grid(t_start, t_end, coarse_step_s)
    mask = visibility_mask(walker, gs, t)          # (L, K, T)
    min_el = np.radians(gs.min_elevation_deg)

    windows: List[VisibilityWindow] = []
    L, K, T = mask.shape
    for p in range(L):
        for s in range(K):
            m = mask[p, s]
            # transitions: diff +1 = rise between i and i+1; -1 = set
            dm = np.diff(m.astype(np.int8))
            rises = list(np.nonzero(dm == 1)[0])
            sets_ = list(np.nonzero(dm == -1)[0])
            # handle windows clipped by the scan interval
            starts: List[float] = []
            ends: List[float] = []
            sat = walker.satellites[p * K + s]

            def el_fn(tt: float) -> float:
                r_s = walker.position_of(sat, tt)
                r_g = gs.eci(np.asarray(tt))
                return float(elevation_angle(r_s, r_g) - min_el)

            if m[0]:
                starts.append(t[0])
            for i in rises:
                if refine:
                    starts.append(_refine_crossing(el_fn, t[i], t[i + 1], True))
                else:
                    starts.append(t[i + 1])
            for i in sets_:
                if refine:
                    ends.append(_refine_crossing(el_fn, t[i], t[i + 1], False))
                else:
                    ends.append(t[i])
            if m[-1]:
                ends.append(t[-1])
            for a, b in zip(starts, ends):
                if b > a:
                    windows.append(
                        VisibilityWindow(plane=p, slot=s, t_start=a, t_end=b)
                    )
    windows.sort(key=lambda w: w.t_start)
    return windows
