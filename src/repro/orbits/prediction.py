"""Visibility prediction service used by the distributed scheduler.

The paper (§IV-B) predicts each satellite's visibility using the method
of Ali et al. [11].  Because every satellite knows the constellation
configuration and the GS position, each can deterministically compute the
same access-window table ``AW(k, GS)`` — this is what makes the sink
selection *distributed without coordination*: all satellites run the same
pure function of shared state and agree on the result.

``VisibilityPredictor`` precomputes windows over a horizon (one
vectorized ``visibility_table`` sweep per ground station) and answers:
  * next_window(sat, t): the first window with t_end > t,
  * next_window_with_duration(sat, t, min_duration): first window after t
    that is long enough (the AW(c_opt, GS) >= T*_sum constraint),
  * wait_time(sat, t): t_wait — time until the satellite next becomes
    visible (0 if currently inside a window).

Multi-GS support: pass a *sequence* of ground stations and the predictor
holds the union of every station's windows (each tagged with its
``gs_index``) — a satellite is schedulable whenever ANY station sees it.
Queries are O(log W) via per-satellite sorted start/cummax-end arrays
instead of the seed's linear scans.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.orbits.constellation import GroundStation, Satellite, WalkerDelta
from repro.orbits.visibility import (
    VisibilityWindow,
    WindowTable,
    visibility_table,
)

GroundStations = Union[GroundStation, Sequence[GroundStation]]


def as_gs_list(gs: GroundStations) -> List[GroundStation]:
    """Normalize a single station or a sequence into a list."""
    if isinstance(gs, GroundStation):
        return [gs]
    return list(gs)


class VisibilityPredictor:
    def __init__(
        self,
        walker: WalkerDelta,
        gs: GroundStations,
        horizon_s: float,
        t0: float = 0.0,
        coarse_step_s: float = 10.0,
        engine: str = "vectorized",
    ):
        """Args:
          gs: one ground station, or a sequence for union-of-windows
            multi-GS scheduling.
          engine: "vectorized" (default) or "reference" — the scalar
            oracle, kept selectable for equivalence tests and benchmarks.
        """
        self.walker = walker
        gss = as_gs_list(gs)
        self.ground_stations: Tuple[GroundStation, ...] = tuple(gss)
        self.gs = gss[0]                       # primary station (back-compat)
        self.t0 = t0
        self.horizon_s = horizon_s

        if engine == "vectorized":
            tables = [
                visibility_table(
                    walker, g, t0, t0 + horizon_s,
                    coarse_step_s=coarse_step_s, gs_index=i,
                )
                for i, g in enumerate(gss)
            ]
            self.table = WindowTable.concatenate(tables).sorted_by_start()
        elif engine == "reference":
            from repro.orbits.visibility import visibility_windows_reference

            rows = []
            for i, g in enumerate(gss):
                for w in visibility_windows_reference(
                    walker, g, t0, t0 + horizon_s,
                    coarse_step_s=coarse_step_s,
                ):
                    rows.append((w.plane, w.slot, w.t_start, w.t_end, i))
            arr = np.asarray(rows, dtype=np.float64).reshape(-1, 5)
            self.table = WindowTable(
                plane=arr[:, 0].astype(np.int32),
                slot=arr[:, 1].astype(np.int32),
                t_start=arr[:, 2],
                t_end=arr[:, 3],
                gs_index=arr[:, 4].astype(np.int32),
            ).sorted_by_start()
        else:
            raise ValueError(f"unknown engine {engine!r}")

        # Per-satellite start-sorted slices of the table.  ``_cummax_end``
        # (running max of t_end in start order) makes "first window with
        # t_end > t" a single searchsorted even when multi-GS windows of
        # the same satellite overlap.
        self._by_sat: Dict[Tuple[int, int], Dict[str, np.ndarray]] = {}
        K = walker.config.sats_per_plane
        sat_ids = self.table.plane.astype(np.int64) * K + self.table.slot
        order = np.lexsort((self.table.t_start, sat_ids))
        sat_sorted = sat_ids[order]
        uniq, first_idx = np.unique(sat_sorted, return_index=True)
        bounds = list(first_idx) + [len(order)]
        for u, lo, hi in zip(uniq, bounds[:-1], bounds[1:]):
            idx = order[lo:hi]
            starts = self.table.t_start[idx]
            ends = self.table.t_end[idx]
            self._by_sat[(int(u) // K, int(u) % K)] = {
                "idx": idx,
                "starts": starts,
                "ends": ends,
                "cummax_end": np.maximum.accumulate(ends),
                "gs_index": self.table.gs_index[idx],
            }
        self._win_cache: Dict[Tuple[int, int], List[VisibilityWindow]] = {}
        self._plane_pads: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}

    # -- window access -----------------------------------------------------------
    @property
    def windows(self) -> List[VisibilityWindow]:
        return self.table.to_windows()

    def windows_of(self, sat: Satellite) -> List[VisibilityWindow]:
        key = (sat.plane, sat.slot)
        if key not in self._win_cache:
            rec = self._by_sat.get(key)
            if rec is None:
                self._win_cache[key] = []
            else:
                self._win_cache[key] = [
                    self.table.window(i) for i in rec["idx"]
                ]
        return list(self._win_cache[key])

    def sat_arrays(self, plane: int, slot: int) -> Optional[Dict[str, np.ndarray]]:
        """Raw per-satellite window arrays (starts, ends, cummax_end,
        gs_index) in start order — the batch-query surface used by the
        vectorized scheduler."""
        return self._by_sat.get((plane, slot))

    def _first_index_ending_after(self, key, t: float) -> Optional[int]:
        """Index (in start order) of the first window with t_end > t."""
        rec = self._by_sat.get(key)
        if rec is None:
            return None
        # cummax_end is non-decreasing; the first index where it exceeds
        # t is exactly the first window whose own t_end exceeds t.
        j = int(np.searchsorted(rec["cummax_end"], t, side="right"))
        if j >= rec["starts"].size:
            return None
        return j

    # -- queries ----------------------------------------------------------------
    def current_window(
        self, sat: Satellite, t: float
    ) -> Optional[VisibilityWindow]:
        """Window containing t, if the satellite is visible right now."""
        key = (sat.plane, sat.slot)
        rec = self._by_sat.get(key)
        if rec is None:
            return None
        wins = self.windows_of(sat)
        i = int(np.searchsorted(rec["starts"], t, side="right")) - 1
        while i >= 0 and rec["cummax_end"][i] >= t:
            if wins[i].contains(t):
                return wins[i]
            i -= 1
        return None

    def next_window(
        self, sat: Satellite, t: float
    ) -> Optional[VisibilityWindow]:
        """First window with t_end > t (possibly the one containing t)."""
        j = self._first_index_ending_after((sat.plane, sat.slot), t)
        if j is None:
            return None
        return self.windows_of(sat)[j]

    def next_window_with_duration(
        self, sat: Satellite, t: float, min_duration: float
    ) -> Optional[VisibilityWindow]:
        """First window after t whose *remaining* duration >= min_duration.

        This is the paper's sink feasibility constraint
        ``AW(c_opt, GS) >= T*_sum``: the access window must be long enough
        to exchange the partial global model with the GS.
        """
        key = (sat.plane, sat.slot)
        j = self._first_index_ending_after(key, t)
        if j is None:
            return None
        rec = self._by_sat[key]
        wins = self.windows_of(sat)
        for i in range(j, len(wins)):
            if rec["ends"][i] <= t:
                continue
            effective_start = max(rec["starts"][i], t)
            if rec["ends"][i] - effective_start >= min_duration:
                return wins[i]
        return None

    def _plane_padded(self, plane: int) -> Tuple[np.ndarray, np.ndarray]:
        """(starts, cummax_end) as (K, W+1) inf-padded matrices — the
        batch surface for one-sweep per-plane window queries."""
        if plane not in self._plane_pads:
            K = self.walker.config.sats_per_plane
            recs = [self._by_sat.get((plane, s)) for s in range(K)]
            width = max(
                (r["starts"].size for r in recs if r is not None), default=0
            )
            starts = np.full((K, width + 1), np.inf)
            cummax = np.full((K, width + 1), np.inf)
            for s, rec in enumerate(recs):
                if rec is None:
                    continue
                w = rec["starts"].size
                starts[s, :w] = rec["starts"]
                cummax[s, :w] = rec["cummax_end"]
            self._plane_pads[plane] = (starts, cummax)
        return self._plane_pads[plane]

    def plane_next_window_starts(
        self, plane: int, t: float
    ) -> Tuple[np.ndarray, np.ndarray]:
        """For every slot of a plane at once: (t_start, window index) of
        its first window with t_end > t — the batched equivalent of K
        ``next_window`` calls (one sweep over inf-padded per-plane
        arrays instead of K scalar bisections).  Slots with no such
        window get t_start=inf (their index points at padding).
        """
        starts, cummax = self._plane_padded(plane)
        # cummax_end is non-decreasing per row, so the count of entries
        # <= t is exactly searchsorted(..., side="right")
        idx = np.sum(cummax <= t, axis=1)
        return starts[np.arange(starts.shape[0]), idx], idx

    def wait_time(self, sat: Satellite, t: float) -> Optional[float]:
        """t_wait(k): time from t until the satellite is next visible."""
        w = self.next_window(sat, t)
        if w is None:
            return None
        return max(0.0, w.t_start - t)
