"""Visibility prediction service used by the distributed scheduler.

The paper (§IV-B) predicts each satellite's visibility using the method
of Ali et al. [11].  Because every satellite knows the constellation
configuration and the GS position, each can deterministically compute the
same access-window table ``AW(k, GS)`` — this is what makes the sink
selection *distributed without coordination*: all satellites run the same
pure function of shared state and agree on the result.

``VisibilityPredictor`` precomputes windows over a horizon and answers:
  * next_window(sat, t): the first window with t_end > t,
  * next_window_with_duration(sat, t, min_duration): first window after t
    that is long enough (the AW(c_opt, GS) >= T*_sum constraint),
  * wait_time(sat, t): t_wait — time until the satellite next becomes
    visible (0 if currently inside a window).
"""
from __future__ import annotations

import bisect
from typing import Dict, List, Optional, Tuple

from repro.orbits.constellation import GroundStation, Satellite, WalkerDelta
from repro.orbits.visibility import VisibilityWindow, visibility_windows


class VisibilityPredictor:
    def __init__(
        self,
        walker: WalkerDelta,
        gs: GroundStation,
        horizon_s: float,
        t0: float = 0.0,
        coarse_step_s: float = 10.0,
    ):
        self.walker = walker
        self.gs = gs
        self.t0 = t0
        self.horizon_s = horizon_s
        self._windows = visibility_windows(
            walker, gs, t0, t0 + horizon_s, coarse_step_s=coarse_step_s
        )
        # per-satellite sorted window lists + start-time index for bisect
        self._by_sat: Dict[Tuple[int, int], List[VisibilityWindow]] = {}
        for w in self._windows:
            self._by_sat.setdefault((w.plane, w.slot), []).append(w)
        self._starts: Dict[Tuple[int, int], List[float]] = {
            k: [w.t_start for w in v] for k, v in self._by_sat.items()
        }

    @property
    def windows(self) -> List[VisibilityWindow]:
        return list(self._windows)

    def windows_of(self, sat: Satellite) -> List[VisibilityWindow]:
        return list(self._by_sat.get((sat.plane, sat.slot), []))

    def current_window(
        self, sat: Satellite, t: float
    ) -> Optional[VisibilityWindow]:
        """Window containing t, if the satellite is visible right now."""
        wins = self._by_sat.get((sat.plane, sat.slot), [])
        starts = self._starts.get((sat.plane, sat.slot), [])
        i = bisect.bisect_right(starts, t) - 1
        if i >= 0 and wins[i].contains(t):
            return wins[i]
        return None

    def next_window(
        self, sat: Satellite, t: float
    ) -> Optional[VisibilityWindow]:
        """First window with t_end > t (possibly the one containing t)."""
        wins = self._by_sat.get((sat.plane, sat.slot), [])
        for w in wins:
            if w.t_end > t:
                return w
        return None

    def next_window_with_duration(
        self, sat: Satellite, t: float, min_duration: float
    ) -> Optional[VisibilityWindow]:
        """First window after t whose *remaining* duration >= min_duration.

        This is the paper's sink feasibility constraint
        ``AW(c_opt, GS) >= T*_sum``: the access window must be long enough
        to exchange the partial global model with the GS.
        """
        wins = self._by_sat.get((sat.plane, sat.slot), [])
        for w in wins:
            if w.t_end <= t:
                continue
            effective_start = max(w.t_start, t)
            if w.t_end - effective_start >= min_duration:
                return w
        return None

    def wait_time(self, sat: Satellite, t: float) -> Optional[float]:
        """t_wait(k): time from t until the satellite is next visible."""
        w = self.next_window(sat, t)
        if w is None:
            return None
        return max(0.0, w.t_start - t)
