"""Visibility prediction service used by the distributed scheduler.

The paper (§IV-B) predicts each satellite's visibility using the method
of Ali et al. [11].  Because every satellite knows the constellation
configuration and the GS position, each can deterministically compute the
same access-window table ``AW(k, GS)`` — this is what makes the sink
selection *distributed without coordination*: all satellites run the same
pure function of shared state and agree on the result.

``VisibilityPredictor`` precomputes windows over a horizon (one
vectorized ``visibility_table`` sweep per ground station) and answers:
  * next_window(sat, t): the first window with t_end > t,
  * next_window_with_duration(sat, t, min_duration): first window after t
    that is long enough (the AW(c_opt, GS) >= T*_sum constraint),
  * wait_time(sat, t): t_wait — time until the satellite next becomes
    visible (0 if currently inside a window).

Multi-GS support: pass a *sequence* of ground stations and the predictor
holds the union of every station's windows (each tagged with its
``gs_index``) — a satellite is schedulable whenever ANY station sees it.
Queries are O(log W) via per-satellite sorted start/cummax-end arrays
instead of the seed's linear scans.

Rolling horizon (``rolling=True``): instead of prebuilding the full
window table over ``1.5x`` the simulation horizon, the predictor builds
an initial chunk of ``horizon_s`` seconds and *extends* it
chunk-by-chunk (``extend_once`` / ``ensure_horizon``) as simulated time
advances — long multi-round runs pay for visibility prediction
incrementally, and the transfer planner extends-and-retries instead of
silently dropping a plane whose next window falls past the built
horizon.  Chunk boundaries are snapped to the coarse scan grid and
boundary-straddling windows are merged, so the incrementally grown
table is *identical* to a prebuilt table over the same range
(equivalence-tested).  ``max_horizon_s`` bounds the growth (a satellite
that never sees any station must not extend forever).
"""
from __future__ import annotations

import math
from typing import (
    TYPE_CHECKING,
    Callable,
    Dict,
    List,
    Optional,
    Sequence,
    Tuple,
    Union,
)

import numpy as np

if TYPE_CHECKING:
    from repro.obs.trace import TraceRecorder

from repro.orbits.constellation import (
    GroundStation,
    MultiShellWalker,
    Satellite,
    WalkerDelta,
)
from repro.orbits.visibility import (
    DEFAULT_MEM_BUDGET_MB,
    VisibilityWindow,
    WindowTable,
    visibility_table,
)

GroundStations = Union[GroundStation, Sequence[GroundStation]]


def as_gs_list(gs: GroundStations) -> List[GroundStation]:
    """Normalize a single station or a sequence into a list."""
    if isinstance(gs, GroundStation):
        return [gs]
    return list(gs)


def _merge_at_boundary(
    old: WindowTable, new: WindowTable, t_b: float, K: int
) -> WindowTable:
    """Stitch an extension chunk onto a station's table.

    A satellite visible at the chunk boundary produces a window clipped
    at ``t_b`` in the old table AND a window opening at ``t_b`` in the
    new chunk — the same physical pass.  Both chunks sample the exact
    boundary grid point, so the halves are matched by float equality
    and fused into the single window a prebuilt table would contain
    (this is what keeps the rolling table bit-identical to a prebuilt
    one).  Unmatched rows pass through untouched.
    """
    old_clip = np.flatnonzero(old.t_end == t_b)
    new_open = np.flatnonzero(new.t_start == t_b)
    if old_clip.size == 0 or new_open.size == 0:
        return WindowTable.concatenate([old, new]).sorted_by_start()
    by_sat = {
        int(old.plane[i]) * K + int(old.slot[i]): i for i in old_clip
    }
    t_end = old.t_end.copy()
    drop = np.zeros(len(new), dtype=bool)
    for j in new_open:
        i = by_sat.get(int(new.plane[j]) * K + int(new.slot[j]))
        if i is not None:
            t_end[i] = new.t_end[j]        # fuse the two halves
            drop[j] = True
    merged_old = WindowTable(
        plane=old.plane, slot=old.slot, t_start=old.t_start,
        t_end=t_end, gs_index=old.gs_index,
    )
    return WindowTable.concatenate(
        [merged_old, new.take(np.flatnonzero(~drop))]
    ).sorted_by_start()


class VisibilityPredictor:
    def __init__(
        self,
        walker: "WalkerDelta | MultiShellWalker",
        gs: GroundStations,
        horizon_s: float,
        t0: float = 0.0,
        coarse_step_s: float = 10.0,
        engine: str = "vectorized",
        rolling: bool = False,
        max_horizon_s: Optional[float] = None,
        mem_budget_mb: float = DEFAULT_MEM_BUDGET_MB,
    ):
        """Args:
          gs: one ground station, or a sequence for union-of-windows
            multi-GS scheduling.
          engine: "vectorized" (default) or "reference" — the scalar
            oracle, kept selectable for equivalence tests and benchmarks.
          rolling: build only an initial ``horizon_s`` chunk and let the
            table grow on demand (``extend_once``/``ensure_horizon``);
            requires the vectorized engine and a finite
            ``max_horizon_s`` (a never-visible satellite must not
            trigger unbounded extension).
          max_horizon_s: hard cap on the built horizon, measured from
            ``t0``; only meaningful with ``rolling=True``.
        """
        self.walker = walker
        gss = as_gs_list(gs)
        self.ground_stations: Tuple[GroundStation, ...] = tuple(gss)
        self.gs = gss[0]                       # primary station (back-compat)
        self.t0 = t0
        self.horizon_s = horizon_s
        self.coarse_step_s = coarse_step_s
        self.mem_budget_mb = float(mem_budget_mb)
        self.rolling = bool(rolling)
        if self.rolling:
            if engine != "vectorized":
                raise ValueError("rolling horizon needs the vectorized engine")
            if max_horizon_s is None or not np.isfinite(max_horizon_s):
                raise ValueError("rolling horizon needs a finite max_horizon_s")
            # chunk boundaries sit on the coarse scan grid, so every
            # incremental chunk samples exactly the grid points a
            # prebuilt table would — extension preserves bit-identity
            n = max(1, int(math.ceil(horizon_s / coarse_step_s - 1e-9)))
            self.chunk_s = n * coarse_step_s
            self.max_horizon_s = float(max_horizon_s)
        else:
            self.chunk_s = None
            self.max_horizon_s = None
        self._station_tables: List[WindowTable] = []
        # observability hook (repro.obs.TraceRecorder.attach): horizon
        # extensions + per-method query counters; None = untraced (the
        # query hot path pays one attribute check and nothing else)
        self.recorder: Optional["TraceRecorder"] = None

        if engine == "vectorized":
            end0 = (
                min(t0 + self.chunk_s, t0 + self.max_horizon_s)
                if self.rolling else t0 + horizon_s
            )
            self._station_tables = [
                visibility_table(
                    walker, g, t0, end0,
                    coarse_step_s=coarse_step_s, gs_index=i,
                    mem_budget_mb=self.mem_budget_mb,
                )
                for i, g in enumerate(gss)
            ]
            self._built_end = end0
            self.table = WindowTable.concatenate(
                self._station_tables
            ).sorted_by_start()
        elif engine == "reference":
            from repro.orbits.visibility import visibility_windows_reference

            rows = []
            for i, g in enumerate(gss):
                for w in visibility_windows_reference(
                    walker, g, t0, t0 + horizon_s,
                    coarse_step_s=coarse_step_s,
                ):
                    rows.append((w.plane, w.slot, w.t_start, w.t_end, i))
            arr = np.asarray(rows, dtype=np.float64).reshape(-1, 5)
            self._built_end = t0 + horizon_s
            self.table = WindowTable(
                plane=arr[:, 0].astype(np.int32),
                slot=arr[:, 1].astype(np.int32),
                t_start=arr[:, 2],
                t_end=arr[:, 3],
                gs_index=arr[:, 4].astype(np.int32),
            ).sorted_by_start()
        else:
            raise ValueError(f"unknown engine {engine!r}")
        self._reindex()

    def _reindex(self) -> None:
        """(Re)build the per-satellite query indexes from ``self.table``
        — called at construction and after every horizon extension."""
        # Per-satellite start-sorted slices of the table.  ``_cummax_end``
        # (running max of t_end in start order) makes "first window with
        # t_end > t" a single searchsorted even when multi-GS windows of
        # the same satellite overlap.
        self._by_sat: Dict[Tuple[int, int], Dict[str, np.ndarray]] = {}
        K = self.walker.config.sats_per_plane
        sat_ids = self.table.plane.astype(np.int64) * K + self.table.slot
        order = np.lexsort((self.table.t_start, sat_ids))
        sat_sorted = sat_ids[order]
        uniq, first_idx = np.unique(sat_sorted, return_index=True)
        bounds = list(first_idx) + [len(order)]
        for u, lo, hi in zip(uniq, bounds[:-1], bounds[1:]):
            idx = order[lo:hi]
            starts = self.table.t_start[idx]
            ends = self.table.t_end[idx]
            self._by_sat[(int(u) // K, int(u) % K)] = {
                "idx": idx,
                "starts": starts,
                "ends": ends,
                "cummax_end": np.maximum.accumulate(ends),
                "gs_index": self.table.gs_index[idx],
            }
        self._win_cache: Dict[Tuple[int, int], List[VisibilityWindow]] = {}
        self._plane_pads: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}

    # -- rolling horizon ---------------------------------------------------------
    @property
    def built_end(self) -> float:
        """End of the currently built window table (absolute seconds)."""
        return self._built_end

    def extend_once(self) -> bool:
        """Grow the window table by one chunk.  Returns False when the
        predictor is not rolling or ``max_horizon_s`` is reached —
        callers use the return value as their retry guard."""
        if not self.rolling:
            return False
        limit = self.t0 + self.max_horizon_s
        if self._built_end >= limit - 1e-6:
            return False
        new_end = min(self._built_end + self.chunk_s, limit)
        if self.recorder is not None:
            self.recorder.on_horizon_extend(self._built_end, new_end)
        for i, g in enumerate(self.ground_stations):
            chunk = visibility_table(
                self.walker, g, self._built_end, new_end,
                coarse_step_s=self.coarse_step_s, gs_index=i,
                mem_budget_mb=self.mem_budget_mb,
            )
            self._station_tables[i] = _merge_at_boundary(
                self._station_tables[i], chunk, self._built_end,
                self.walker.config.sats_per_plane,
            )
        self._built_end = new_end
        self.table = WindowTable.concatenate(
            self._station_tables
        ).sorted_by_start()
        self._reindex()
        return True

    def ensure_horizon(self, t_abs: float) -> bool:
        """Extend until the table covers ``t_abs`` (absolute seconds).
        Returns False if the cap stops growth short of ``t_abs``."""
        while self._built_end < t_abs:
            if not self.extend_once():
                return False
        return True

    def retry_extending(
        self, attempt: "Callable[[], Tuple[object, bool]]"
    ) -> object:
        """Run ``attempt() -> (result, retry)`` against the currently
        built table, growing the horizon one chunk and re-running while
        ``retry`` is truthy — the shared extend-and-retry discipline of
        every scheduling query near the rolling-horizon edge.  A
        planner signals ``retry`` whenever its answer depended on a
        window (or transfer *segment*) clipped at the built boundary —
        the true window end lies in the next chunk, so neither a
        rejection nor a boundary-truncated plan can be trusted.
        Returns the last attempt's result once it is stable or the
        horizon cannot grow (non-rolling predictors never extend)."""
        while True:
            result, retry = attempt()
            if not retry or not self.extend_once():
                return result

    def plane_window_supply(
        self, t0: float, t1: float
    ) -> np.ndarray:
        """(L, num_stations) seconds of predicted access-window overlap
        with ``[t0, t1]`` per (plane, station) — the window-supply
        signal that drives per-round dynamic cluster formation and
        station load-balancing."""
        if self.rolling:
            self.ensure_horizon(t1)        # best effort, capped
        L = self.walker.config.num_planes
        out = np.zeros((L, len(self.ground_stations)))
        ov = (
            np.minimum(self.table.t_end, t1)
            - np.maximum(self.table.t_start, t0)
        )
        m = ov > 0
        np.add.at(out, (self.table.plane[m], self.table.gs_index[m]), ov[m])
        return out

    # -- window access -----------------------------------------------------------
    @property
    def windows(self) -> List[VisibilityWindow]:
        return self.table.to_windows()

    def windows_of(self, sat: Satellite) -> List[VisibilityWindow]:
        if self.recorder is not None:
            self.recorder.on_predictor_query("windows_of")
        key = (sat.plane, sat.slot)
        if key not in self._win_cache:
            rec = self._by_sat.get(key)
            if rec is None:
                self._win_cache[key] = []
            else:
                self._win_cache[key] = [
                    self.table.window(i) for i in rec["idx"]
                ]
        return list(self._win_cache[key])

    def sat_arrays(self, plane: int, slot: int) -> Optional[Dict[str, np.ndarray]]:
        """Raw per-satellite window arrays (starts, ends, cummax_end,
        gs_index) in start order — the batch-query surface used by the
        vectorized scheduler."""
        if self.recorder is not None:
            self.recorder.on_predictor_query("sat_arrays")
        return self._by_sat.get((plane, slot))

    def _window_of(self, key: Tuple[int, int], j: int) -> VisibilityWindow:
        """The satellite's j-th window (start order) as ONE
        ``VisibilityWindow`` — reads the materialized ``windows_of``
        cache when a caller already paid for it, else constructs just
        this window from the table row.  The scalar queries
        (``next_window`` & co) return exactly one window per call, so
        materializing — and copying — the satellite's whole window list
        per query is pure overhead (the predictor_queries regression)."""
        wins = self._win_cache.get(key)
        if wins is not None:
            return wins[j]
        rec = self._by_sat[key]
        return self.table.window(int(rec["idx"][j]))

    def _first_index_ending_after(
        self, key: Tuple[int, int], t: float
    ) -> Optional[int]:
        """Index (in start order) of the first window with t_end > t."""
        rec = self._by_sat.get(key)
        if rec is None:
            return None
        # cummax_end is non-decreasing; the first index where it exceeds
        # t is exactly the first window whose own t_end exceeds t.
        j = int(rec["cummax_end"].searchsorted(t, side="right"))
        if j >= rec["starts"].size:
            return None
        return j

    # -- queries ----------------------------------------------------------------
    def current_window(
        self, sat: Satellite, t: float
    ) -> Optional[VisibilityWindow]:
        """Window containing t, if the satellite is visible right now."""
        if self.recorder is not None:
            self.recorder.on_predictor_query("current_window")
        key = (sat.plane, sat.slot)
        rec = self._by_sat.get(key)
        if rec is None:
            return None
        starts, ends = rec["starts"], rec["ends"]
        i = int(starts.searchsorted(t, side="right")) - 1
        while i >= 0 and rec["cummax_end"][i] >= t:
            if starts[i] <= t <= ends[i]:
                return self._window_of(key, i)
            i -= 1
        return None

    def next_window(
        self, sat: Satellite, t: float
    ) -> Optional[VisibilityWindow]:
        """First window with t_end > t (possibly the one containing t).

        A rolling predictor with no such window inside the built
        horizon extends and retries before giving up (None only once
        ``max_horizon_s`` is exhausted).  A window still clipped at the
        built boundary is completed first — its true end lies in the
        next chunk — so the result matches a prebuilt table."""
        if self.recorder is not None:
            self.recorder.on_predictor_query("next_window")
        key = (sat.plane, sat.slot)
        while True:
            j = self._first_index_ending_after(key, t)
            if j is not None:
                if (
                    self._by_sat[key]["ends"][j] == self._built_end
                    and self.extend_once()
                ):
                    continue               # boundary-clipped: complete it
                return self._window_of(key, j)
            if not self.extend_once():
                return None

    def next_window_with_duration(
        self, sat: Satellite, t: float, min_duration: float
    ) -> Optional[VisibilityWindow]:
        """First window after t whose *remaining* duration >= min_duration.

        This is the paper's sink feasibility constraint
        ``AW(c_opt, GS) >= T*_sum``: the access window must be long enough
        to exchange the partial global model with the GS.  Extends a
        rolling predictor when nothing fits inside the built horizon.
        """
        if self.recorder is not None:
            self.recorder.on_predictor_query("next_window_with_duration")
        key = (sat.plane, sat.slot)
        while True:
            j = self._first_index_ending_after(key, t)
            if j is not None:
                rec = self._by_sat[key]
                starts, ends = rec["starts"], rec["ends"]
                for i in range(j, starts.size):
                    if ends[i] <= t:
                        continue
                    effective_start = max(starts[i], t)
                    if ends[i] - effective_start >= min_duration:
                        if ends[i] == self._built_end and self.extend_once():
                            break          # clipped: complete it first
                        return self._window_of(key, i)
                else:
                    if not self.extend_once():
                        return None
                continue
            if not self.extend_once():
                return None

    def _plane_padded(self, plane: int) -> Tuple[np.ndarray, np.ndarray]:
        """(starts, cummax_end) as (K, W+1) inf-padded matrices — the
        batch surface for one-sweep per-plane window queries."""
        if plane not in self._plane_pads:
            K = self.walker.config.sats_per_plane
            recs = [self._by_sat.get((plane, s)) for s in range(K)]
            width = max(
                (r["starts"].size for r in recs if r is not None), default=0
            )
            starts = np.full((K, width + 1), np.inf)
            cummax = np.full((K, width + 1), np.inf)
            for s, rec in enumerate(recs):
                if rec is None:
                    continue
                w = rec["starts"].size
                starts[s, :w] = rec["starts"]
                cummax[s, :w] = rec["cummax_end"]
            self._plane_pads[plane] = (starts, cummax)
        return self._plane_pads[plane]

    def plane_next_window_starts(
        self, plane: int, t: float
    ) -> Tuple[np.ndarray, np.ndarray]:
        """For every slot of a plane at once: (t_start, window index) of
        its first window with t_end > t — the batched equivalent of K
        ``next_window`` calls (one sweep over inf-padded per-plane
        arrays instead of K scalar bisections).  Slots with no such
        window get t_start=inf (their index points at padding).
        """
        if self.recorder is not None:
            self.recorder.on_predictor_query("plane_next_window_starts")
        starts, cummax = self._plane_padded(plane)
        # cummax_end is non-decreasing per row, so the count of entries
        # <= t is exactly searchsorted(..., side="right")
        idx = np.sum(cummax <= t, axis=1)
        return starts[np.arange(starts.shape[0]), idx], idx

    def wait_time(self, sat: Satellite, t: float) -> Optional[float]:
        """t_wait(k): time from t until the satellite is next visible."""
        w = self.next_window(sat, t)
        if w is None:
            return None
        return max(0.0, w.t_start - t)
