"""Walker-delta LEO constellation geometry.

Implements the system model of FedLEO §III: a constellation ``K`` of
``L`` orbital planes, each with ``K`` equally spaced satellites, every
plane at altitude ``h_l`` with inclination ``alpha_l``.  Satellites move
on circular orbits; the ground station (GS) is fixed on the rotating
Earth.  All positions are computed in an Earth-centered inertial (ECI)
frame, vectorized over a time grid with numpy (the simulator substrate
is host-side; the learning substrate is JAX).

Physical model
--------------
  v_l = sqrt(GM / (R_E + h_l))                       (orbital speed)
  T_l = 2*pi / sqrt(GM) * (R_E + h_l)^(3/2)          (orbital period)

A Walker-delta constellation ``i: T/P/F`` spreads P planes' RAAN evenly
over 2*pi and phases satellites between adjacent planes by
``2*pi*F/(K*P)``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

# --- physical constants (SI) -------------------------------------------------
G = 6.674e-11                 # gravitational constant [m^3 kg^-1 s^-2]
M_EARTH = 5.972e24            # Earth mass [kg]
GM = G * M_EARTH              # standard gravitational parameter [m^3 s^-2]
R_EARTH = 6371.0e3            # Earth radius [m] (paper: R_E = 6371 km)
OMEGA_EARTH = 7.2921159e-5    # Earth rotation rate [rad/s]
C_LIGHT = 299_792_458.0       # speed of light [m/s]


def orbital_speed(altitude_m: float) -> float:
    """v_l = sqrt(GM / (R_E + h_l))  [m/s]."""
    return math.sqrt(GM / (R_EARTH + altitude_m))


def orbital_period(altitude_m: float) -> float:
    """T_l = 2*pi/sqrt(GM) * (R_E + h_l)^{3/2}  [s]."""
    return 2.0 * math.pi / math.sqrt(GM) * (R_EARTH + altitude_m) ** 1.5


def _rot_z(angle: np.ndarray) -> np.ndarray:
    """Rotation matrices about z; angle may be an array (..., ) -> (..., 3, 3)."""
    c, s = np.cos(angle), np.sin(angle)
    zeros = np.zeros_like(c)
    ones = np.ones_like(c)
    return np.stack(
        [
            np.stack([c, -s, zeros], axis=-1),
            np.stack([s, c, zeros], axis=-1),
            np.stack([zeros, zeros, ones], axis=-1),
        ],
        axis=-2,
    )


def _rot_x(angle: float) -> np.ndarray:
    c, s = math.cos(angle), math.sin(angle)
    return np.array([[1.0, 0.0, 0.0], [0.0, c, -s], [0.0, s, c]])


@dataclasses.dataclass(frozen=True)
class Satellite:
    """Identity of one satellite: plane index and in-plane slot index."""

    plane: int
    slot: int

    @property
    def name(self) -> str:
        return f"ID_{self.plane + 1},{self.slot + 1}"


@dataclasses.dataclass(frozen=True)
class GroundStation:
    """A ground station fixed on the rotating Earth.

    The paper's GS is in Rolla, MO, USA (lat 37.95 N, lon -91.77 E) with a
    minimum elevation angle of 10 degrees.
    """

    lat_deg: float = 37.9485
    lon_deg: float = -91.7715
    alt_m: float = 340.0
    min_elevation_deg: float = 10.0
    name: str = "Rolla-MO"

    def ecef(self) -> np.ndarray:
        """Position in the Earth-fixed frame (spherical Earth)."""
        lat = math.radians(self.lat_deg)
        lon = math.radians(self.lon_deg)
        r = R_EARTH + self.alt_m
        return np.array(
            [
                r * math.cos(lat) * math.cos(lon),
                r * math.cos(lat) * math.sin(lon),
                r * math.sin(lat),
            ]
        )

    def eci(self, t: np.ndarray, gst0: float = 0.0) -> np.ndarray:
        """ECI trajectory r_g(t): Earth-fixed point rotated by OMEGA_EARTH*t.

        Args:
          t: times [s], shape (T,) (or scalar).
          gst0: Greenwich sidereal angle at t=0 [rad].

        Returns:
          (T, 3) (or (3,)) ECI positions [m].
        """
        t = np.asarray(t, dtype=np.float64)
        theta = OMEGA_EARTH * t + gst0
        rot = _rot_z(theta)                      # (T, 3, 3)
        return rot @ self.ecef()


@dataclasses.dataclass(frozen=True)
class ConstellationConfig:
    """Walker-delta constellation parameters (paper §V-A defaults).

    40 satellites evenly on 5 orbits at 1500 km altitude, 80 deg
    inclination.
    """

    num_planes: int = 5
    sats_per_plane: int = 8
    altitude_m: float = 1500.0e3
    inclination_deg: float = 80.0
    phasing_factor: int = 1      # Walker F parameter
    raan_spread: float = 2.0 * math.pi  # delta pattern spreads RAAN over 2*pi

    @property
    def num_satellites(self) -> int:
        return self.num_planes * self.sats_per_plane

    @property
    def period_s(self) -> float:
        return orbital_period(self.altitude_m)

    @property
    def speed_ms(self) -> float:
        return orbital_speed(self.altitude_m)


class WalkerDelta:
    """Deterministic propagator for a Walker-delta constellation.

    Positions are exact closed-form circular-orbit solutions, so the
    "predictability of satellite orbiting patterns" the paper's scheduler
    exploits is available to every satellite by construction.
    """

    def __init__(self, config: ConstellationConfig):
        self.config = config
        L, K = config.num_planes, config.sats_per_plane
        self.radius = R_EARTH + config.altitude_m
        self.mean_motion = 2.0 * math.pi / config.period_s
        inc = math.radians(config.inclination_deg)

        # Per-plane rotation: R_z(RAAN_p) @ R_x(inclination).
        self._plane_rot = np.zeros((L, 3, 3))
        for p in range(L):
            raan = config.raan_spread * p / L
            self._plane_rot[p] = _rot_z(np.array(raan)) @ _rot_x(inc)

        # Initial in-plane phase per (plane, slot): slot spacing + Walker
        # inter-plane phasing  2*pi*F*p/(K*L).
        slots = np.arange(K)
        planes = np.arange(L)
        self._phase0 = (
            2.0 * math.pi * slots[None, :] / K
            + 2.0 * math.pi * config.phasing_factor * planes[:, None] / (K * L)
        )  # (L, K)

    @property
    def satellites(self) -> Sequence[Satellite]:
        return [
            Satellite(plane=p, slot=s)
            for p in range(self.config.num_planes)
            for s in range(self.config.sats_per_plane)
        ]

    def positions(self, t: np.ndarray) -> np.ndarray:
        """ECI positions r_k(t) for every satellite.

        Args:
          t: times [s], shape (T,) or scalar.

        Returns:
          array (L, K, T, 3) of ECI positions [m] (T axis squeezed for
          scalar input).
        """
        t_arr = np.atleast_1d(np.asarray(t, dtype=np.float64))
        theta = self._phase0[..., None] + self.mean_motion * t_arr  # (L,K,T)
        in_plane = self.radius * np.stack(
            [np.cos(theta), np.sin(theta), np.zeros_like(theta)], axis=-1
        )  # (L, K, T, 3)
        out = np.einsum("pij,pktj->pkti", self._plane_rot, in_plane)
        if np.isscalar(t) or np.ndim(t) == 0:
            out = out[:, :, 0, :]
        return out

    def positions_batch(
        self,
        planes: np.ndarray,
        slots: np.ndarray,
        t: np.ndarray,
    ) -> np.ndarray:
        """ECI positions for arbitrary (plane, slot, time) triples.

        All three arguments broadcast against each other; the result has
        the broadcast shape + (3,).  This is the gather-style evaluation
        the vectorized visibility/scheduling engine uses: one call covers
        every rise/set crossing (or every candidate sink) at once instead
        of K x windows scalar ``position_of`` calls.
        """
        planes = np.asarray(planes, dtype=np.intp)
        slots = np.asarray(slots, dtype=np.intp)
        t = np.asarray(t, dtype=np.float64)
        planes, slots, t = np.broadcast_arrays(planes, slots, t)
        theta = self._phase0[planes, slots] + self.mean_motion * t
        unit = np.stack(
            [np.cos(theta), np.sin(theta), np.zeros_like(theta)], axis=-1
        )
        rot = self._plane_rot[planes]                  # (..., 3, 3)
        return self.radius * np.einsum("...ij,...j->...i", rot, unit)

    def elevations_from(
        self, gs: GroundStation, t: np.ndarray
    ) -> np.ndarray:
        """Elevation (L, K, T) of every satellite above gs's horizon [rad],
        without materializing the (L, K, T, 3) position tensor.

        Every satellite sits at |r_sat| = radius, so only the dot product
        r_sat . r_gs is needed:

          r_sat . r_gs = radius * u(theta) . (R_p^T r_gs)

        with u(theta) the in-plane unit vector — project the GS
        trajectory into each plane frame once (L, T, 3) instead of
        rotating every satellite out (L, K, T, 3).  |d|^2 then follows
        from the law of cosines.  ~5x less memory traffic than the
        positions-based path; lives here so the plane-frame internals
        (_plane_rot, _phase0) stay encapsulated.
        """
        t = np.atleast_1d(np.asarray(t, dtype=np.float64))
        r_gs = gs.eci(t)                                 # (T, 3)
        g2 = float(np.dot(gs.ecef(), gs.ecef()))         # |r_gs|^2 const
        g_norm = math.sqrt(g2)
        # GS trajectory in each plane frame: R_p^T r_gs -> (L, T, 3)
        g_proj = np.einsum("pji,tj->pti", self._plane_rot, r_gs)
        theta = (
            self._phase0[:, :, None]
            + self.mean_motion * t[None, None, :]
        )                                                # (L, K, T)
        # r_sat . r_gs, with u(theta) = (cos, sin, 0) in the plane frame
        dot = self.radius * (
            np.cos(theta) * g_proj[:, None, :, 0]
            + np.sin(theta) * g_proj[:, None, :, 1]
        )
        d2 = self.radius**2 + g2 - 2.0 * dot             # |r_sat - r_gs|^2
        sin_el = (dot - g2) / (np.sqrt(d2) * g_norm)
        return np.arcsin(np.clip(sin_el, -1.0, 1.0))

    def position_of(self, sat: Satellite, t: np.ndarray) -> np.ndarray:
        """ECI position of one satellite at times t: (T, 3) or (3,)."""
        t_arr = np.atleast_1d(np.asarray(t, dtype=np.float64))
        theta = self._phase0[sat.plane, sat.slot] + self.mean_motion * t_arr
        in_plane = self.radius * np.stack(
            [np.cos(theta), np.sin(theta), np.zeros_like(theta)], axis=-1
        )
        out = in_plane @ self._plane_rot[sat.plane].T
        if np.isscalar(t) or np.ndim(t) == 0:
            out = out[0]
        return out

    def ring_distance(self, slot_a: int, slot_b: int) -> int:
        """ISL hop count between two in-plane slots on the bidirectional ring."""
        K = self.config.sats_per_plane
        d = abs(slot_a - slot_b) % K
        return min(d, K - d)

    def isl_length_m(self) -> float:
        """Chord length between adjacent satellites in the same plane."""
        K = self.config.sats_per_plane
        return 2.0 * self.radius * math.sin(math.pi / K)


@dataclasses.dataclass(frozen=True)
class MultiShellConfig:
    """Several Walker-delta shells flown as one constellation.

    Planes are numbered globally: shell 0 owns planes
    ``[0, shells[0].num_planes)``, shell 1 the next block, and so on.
    Every shell must share ``sats_per_plane`` so the (plane, slot) grid —
    and everything built on it (visibility tables, ring topologies,
    cluster planners) — stays rectangular.

    ``cross_max_range_m`` bounds the slant range of inter-shell links;
    ``cross_links_per_sat`` caps how many cross-shell neighbours each
    satellite may connect to (nearest-first at t=0).
    """

    shells: tuple[ConstellationConfig, ...]
    cross_max_range_m: float = 1500.0e3
    cross_links_per_sat: int = 1

    def __post_init__(self) -> None:
        if not self.shells:
            raise ValueError("MultiShellConfig needs at least one shell")
        ks = {s.sats_per_plane for s in self.shells}
        if len(ks) != 1:
            raise ValueError(
                f"all shells must share sats_per_plane, got {sorted(ks)}"
            )

    @property
    def num_planes(self) -> int:
        return sum(s.num_planes for s in self.shells)

    @property
    def sats_per_plane(self) -> int:
        return self.shells[0].sats_per_plane

    @property
    def num_satellites(self) -> int:
        return self.num_planes * self.sats_per_plane

    @property
    def altitude_m(self) -> float:
        """Reference altitude (first shell); per-shell values differ."""
        return self.shells[0].altitude_m

    @property
    def period_s(self) -> float:
        """Slowest shell's period — conservative for supply cadences."""
        return max(s.period_s for s in self.shells)

    @property
    def plane_offsets(self) -> tuple[int, ...]:
        """Global plane index where each shell's block starts."""
        offs, acc = [], 0
        for s in self.shells:
            offs.append(acc)
            acc += s.num_planes
        return tuple(offs)

    def shell_of_plane(self, plane: int) -> int:
        """Shell index owning a global plane index."""
        if not 0 <= plane < self.num_planes:
            raise ValueError(f"plane {plane} out of range")
        for i, off in enumerate(self.plane_offsets):
            if plane < off + self.shells[i].num_planes:
                return i
        raise AssertionError("unreachable")


class MultiShellWalker:
    """Propagator for a multi-shell constellation.

    Presents the same surface the scheduling stack consumes from
    :class:`WalkerDelta` — ``config``, ``positions_batch``,
    ``position_of``, ``elevations_from``, ``satellites`` — by
    dispatching on the global plane index to per-shell propagators.
    """

    def __init__(self, config: MultiShellConfig):
        self.config = config
        self._walkers = [WalkerDelta(s) for s in config.shells]
        self._offsets = np.asarray(config.plane_offsets, dtype=np.intp)
        # shell index per global plane, for vectorized dispatch
        self._shell_of = np.concatenate(
            [
                np.full(s.num_planes, i, dtype=np.intp)
                for i, s in enumerate(config.shells)
            ]
        )

    @property
    def satellites(self) -> Sequence[Satellite]:
        return [
            Satellite(plane=p, slot=s)
            for p in range(self.config.num_planes)
            for s in range(self.config.sats_per_plane)
        ]

    def positions_batch(
        self,
        planes: np.ndarray,
        slots: np.ndarray,
        t: np.ndarray,
    ) -> np.ndarray:
        """ECI positions for arbitrary global (plane, slot, time) triples."""
        planes = np.asarray(planes, dtype=np.intp)
        slots = np.asarray(slots, dtype=np.intp)
        t = np.asarray(t, dtype=np.float64)
        planes, slots, t = np.broadcast_arrays(planes, slots, t)
        out = np.empty(planes.shape + (3,), dtype=np.float64)
        shell = self._shell_of[planes]
        for i, w in enumerate(self._walkers):
            sel = shell == i
            if not np.any(sel):
                continue
            out[sel] = w.positions_batch(
                planes[sel] - self._offsets[i], slots[sel], t[sel]
            )
        return out

    def position_of(self, sat: Satellite, t: np.ndarray) -> np.ndarray:
        i = int(self._shell_of[sat.plane])
        local = Satellite(
            plane=sat.plane - int(self._offsets[i]), slot=sat.slot
        )
        return self._walkers[i].position_of(local, t)

    def elevations_from(
        self, gs: GroundStation, t: np.ndarray
    ) -> np.ndarray:
        """Elevation (L_total, K, T) stacked along the global plane axis."""
        return np.concatenate(
            [w.elevations_from(gs, t) for w in self._walkers], axis=0
        )


def make_walker(
    config: "ConstellationConfig | MultiShellConfig",
) -> "WalkerDelta | MultiShellWalker":
    """Propagator factory: dispatch on single- vs multi-shell config."""
    if isinstance(config, MultiShellConfig):
        return MultiShellWalker(config)
    return WalkerDelta(config)
