"""Inter-satellite-link topology: the constellation as a graph.

The paper (§IV-A) confines model propagation to each plane's
bidirectional ISL ring.  Mega-constellation shells additionally carry
*inter-plane* ISLs (optical cross-links), which let one well-placed sink
aggregate for a whole cluster of planes.  This module models the
constellation as a graph over ``L*K`` nodes (node id = plane*K + slot)
with typed edges:

  * ``ring``  — today's topology: each plane a bidirectional ring,
    planes disconnected from each other (the degenerate case).
  * ``grid``  — +Grid: the ring plus a link from every satellite to its
    same-phase neighbor in each adjacent plane.  The slot mapping is
    *phasing-offset aware*: a Walker delta phases plane p by
    ``2*pi*F*p/(K*L)``, so the nearest-phase slot in plane q is
    ``s + round(F*(p - q)/L) mod K``.  ``seam_cut=True`` drops the
    cross-links over the plane L-1 <-> plane 0 seam (counter-rotating
    planes in polar shells cannot sustain optical cross-links).
  * ``motif`` — configurable intra/inter link pattern: arbitrary
    intra-plane slot offsets (e.g. ``(1, 2)`` adds skip rings) and
    inter-plane plane offsets.

All-pairs metrics are computed with a vectorized label-correcting sweep
(batched Bellman-Ford over padded neighbor arrays — one gather + min
per sweep covering every (source, destination) pair at once; no Python
loop over nodes).  Because the graph carries exactly two edge weights
(intra-plane hop time, inter-plane hop time), shortest paths are
returned as *hop-count decompositions* ``(h_intra, h_inter)``: the
latency of a path is reconstructed as ``h_intra*t_intra +
h_inter*t_inter``, which keeps the pure-ring special case bit-identical
to ``ring_hops_matrix(K) * t_hop`` (no float accumulation drift).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import numpy as np

from repro.orbits.constellation import (
    R_EARTH,
    ConstellationConfig,
    MultiShellConfig,
)

INTRA, INTER = 0, 1      # edge types
UNREACHABLE = -1         # hop-count sentinel for disconnected pairs

# first-hop selection works on (N, D, block) float64 slabs instead of the
# full (N, D, N) candidate tensor (~3 GB at N=2376, D=4+)
_FIRST_HOP_BLOCK_BYTES = 64e6


def _count_dtype(num_nodes: int) -> "type[np.signedinteger]":
    """Smallest signed dtype holding hop counts (path edges <= N-1).

    int16 up to 2**14 nodes leaves headroom for ``h_a + h_b`` sums;
    beyond that int32.  Quarters the footprint of the four all-pairs
    count matrices at mega-constellation N versus int64.
    """
    return np.int16 if num_nodes <= 2**14 else np.int32


@dataclasses.dataclass(frozen=True)
class TopologyConfig:
    """ISL graph shape.  ``kind`` picks the preset link pattern; the
    offset tuples override it (``motif`` uses them as-is).

    intra_slot_offsets: in-plane links s -> s+o (mod K) for each offset.
    inter_plane_offsets: cross-plane links p -> p+d (mod L) for each
      offset, with phasing-aware nearest-slot mapping.
    seam_cut: drop inter-plane links that wrap the plane L-1 / plane 0
      seam.
    """

    kind: str = "ring"                                  # ring | grid | motif
    intra_slot_offsets: Optional[Tuple[int, ...]] = None
    inter_plane_offsets: Optional[Tuple[int, ...]] = None
    seam_cut: bool = False

    def __post_init__(self) -> None:
        if self.kind not in ("ring", "grid", "motif"):
            raise ValueError(f"unknown topology kind {self.kind!r}")

    @property
    def resolved_intra_offsets(self) -> Tuple[int, ...]:
        if self.intra_slot_offsets is not None:
            return tuple(self.intra_slot_offsets)
        return (1,)                                     # ring in every preset

    @property
    def resolved_inter_offsets(self) -> Tuple[int, ...]:
        if self.inter_plane_offsets is not None:
            return tuple(self.inter_plane_offsets)
        return () if self.kind == "ring" else (1,)

    @property
    def has_inter_links(self) -> bool:
        return len(self.resolved_inter_offsets) > 0


def phased_slot_shift(
    constellation: ConstellationConfig, plane_from: int, plane_to: int
) -> int:
    """Slot offset of the nearest-phase satellite in ``plane_to``.

    Walker phasing puts slot s of plane p at in-plane phase
    ``(2*pi/K) * (s + F*p/L)``; matching phases across planes gives
    ``s' = s + F*(p - q)/L``, rounded to the nearest integer slot.
    """
    F, L = constellation.phasing_factor, constellation.num_planes
    return int(round(F * (plane_from - plane_to) / L))


def _add_shell_edges(
    edges: Dict[Tuple[int, int], int],
    constellation: ConstellationConfig,
    cfg: TopologyConfig,
    node_offset: int,
) -> None:
    """Add one Walker shell's intra/inter-plane edges into ``edges``,
    with the shell's nodes shifted by ``node_offset`` (0 for a
    single-shell topology; the shell's global block start otherwise)."""
    L, K = constellation.num_planes, constellation.sats_per_plane

    def node(p: int, s: int) -> int:
        return node_offset + p * K + s

    def add(i: int, j: int, kind: int) -> None:
        if i == j:
            return
        key = (min(i, j), max(i, j))
        edges.setdefault(key, kind)

    for off in cfg.resolved_intra_offsets:
        for p in range(L):
            for s in range(K):
                add(node(p, s), node(p, (s + off) % K), INTRA)
    for d in cfg.resolved_inter_offsets:
        for p in range(L):
            q = (p + d) % L
            if q == p:
                continue
            # the signed offset keeps the stepping direction, so the
            # seam test is representation-independent: d=-1 wraps at
            # p=0 exactly where d=+1 wraps at p=L-1
            if cfg.seam_cut and not 0 <= p + d < L:
                continue            # link would wrap the polar seam
            shift = phased_slot_shift(constellation, p, q)
            for s in range(K):
                add(node(p, s), node(q, (s + shift) % K), INTER)


class ISLTopology:
    """The ISL graph of one constellation + topology config.

    Exposes padded neighbor arrays (the vectorized-path substrate), the
    typed adjacency matrix, and cached all-pairs hop matrices.
    """

    def __init__(
        self,
        constellation: "ConstellationConfig | MultiShellConfig",
        config: TopologyConfig = TopologyConfig(),
    ):
        self.constellation = constellation
        self.config = config
        L, K = constellation.num_planes, constellation.sats_per_plane
        self.num_planes, self.sats_per_plane = L, K
        self.num_nodes = L * K

        edges = self._build_edges()
        # typed adjacency: -1 none, INTRA, INTER (symmetric)
        adj = np.full((self.num_nodes, self.num_nodes), -1, dtype=np.int8)
        for (i, j), kind in edges.items():
            # an intra link (same plane) never coincides with an inter
            # link (different planes), so no type conflicts to resolve
            adj[i, j] = kind
            adj[j, i] = kind
        self.adjacency = adj

        # padded neighbor arrays: nbr[i, d] = d-th neighbor of i (self-
        # padded), nbr_type[i, d] = INTRA/INTER or -1 for padding.
        degree = int(np.max(np.sum(adj >= 0, axis=1), initial=0))
        nbr = np.tile(np.arange(self.num_nodes)[:, None], (1, max(degree, 1)))
        nbr_type = np.full_like(nbr, -1, dtype=np.int8)
        for i in range(self.num_nodes):
            js = np.flatnonzero(adj[i] >= 0)
            nbr[i, : js.size] = js
            nbr_type[i, : js.size] = adj[i, js]
        self.neighbors = nbr
        self.neighbor_types = nbr_type

        self._split_cache: Dict[
            Tuple[float, float], Tuple[np.ndarray, np.ndarray]
        ] = {}

    # -- construction ----------------------------------------------------------
    def node(self, plane: int, slot: int) -> int:
        return plane * self.sats_per_plane + slot

    def sat_of(self, node: int) -> Tuple[int, int]:
        return divmod(node, self.sats_per_plane)

    def _build_edges(self) -> Dict[Tuple[int, int], int]:
        edges: Dict[Tuple[int, int], int] = {}
        assert isinstance(self.constellation, ConstellationConfig)
        _add_shell_edges(edges, self.constellation, self.config, 0)
        return edges

    def edges(self, kind: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray]:
        """(i, j) node-index arrays of every undirected edge (i < j)."""
        mask = self.adjacency >= 0 if kind is None else self.adjacency == kind
        i, j = np.nonzero(np.triu(mask, k=1))
        return i, j

    # -- all-pairs metrics -----------------------------------------------------
    def hop_split(
        self, w_intra: float = 1.0, w_inter: float = 1.0
    ) -> Tuple[np.ndarray, np.ndarray]:
        """All-pairs shortest paths under per-type edge weights.

        Returns ``(h_intra, h_inter)`` int matrices: the number of intra-
        and inter-plane edges on the minimum-cost path (cost =
        ``h_intra*w_intra + h_inter*w_inter``), or ``UNREACHABLE`` for
        disconnected pairs.  Vectorized label-correcting sweeps: every
        sweep relaxes all (node, destination) pairs through all
        neighbors with one gather + argmin; sweeps stop at a fixed
        point (<= graph diameter iterations).
        """
        key = (float(w_intra), float(w_inter))
        if key in self._split_cache:
            return self._split_cache[key]
        try:
            split = self._hop_split_dijkstra(*key)
        except ImportError:          # no scipy in this environment
            split = self._hop_split_sweeps(*key)
        self._split_cache[key] = split
        return split

    def hop_split_rows(
        self,
        sources: np.ndarray,
        w_intra: float = 1.0,
        w_inter: float = 1.0,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Per-source shortest-path decompositions: lazy counterpart of
        :meth:`hop_split`.

        Runs Dijkstra only from ``sources`` and decomposes each
        predecessor chain with per-row pointer doubling, so the working
        set scales with (S, N) instead of (N, N) and the (N, D, N)
        first-hop tensor is never formed.  Returns ``(h_intra, h_inter)``
        of shape (S, N); the unreachable mask matches :meth:`hop_split`
        exactly and ``h_intra*w_intra + h_inter*w_inter`` equals the
        optimal cost (equal-cost paths may decompose differently from
        the all-pairs solver's tie-break).
        """
        src = np.atleast_1d(np.asarray(sources, dtype=np.intp))
        N = self.num_nodes
        ct = _count_dtype(N)
        try:
            from scipy.sparse import csr_matrix
            from scipy.sparse.csgraph import dijkstra
        except ImportError:          # no scipy: slice the full solver
            h_a, h_b = self.hop_split(w_intra, w_inter)
            return h_a[src].copy(), h_b[src].copy()

        i, j = np.nonzero(self.adjacency >= 0)          # directed both ways
        w_edge = np.where(
            self.adjacency[i, j] == INTRA, float(w_intra), float(w_inter)
        )
        dist, pred = dijkstra(
            csr_matrix((w_edge, (i, j)), shape=(N, N)),
            directed=False,
            indices=src,
            return_predecessors=True,
        )                                               # (S, N) each
        cols = np.arange(N)[None, :]
        valid = pred >= 0                               # scipy pads -9999
        jmp = np.where(valid, pred, cols).astype(np.int64, copy=False)
        step_type = self.adjacency[jmp, cols]           # edge pred[j] -> j
        step_a = ((step_type == INTRA) & valid).astype(ct)
        h_a, h_b = step_a, ((step_type == INTER) & valid).astype(ct)
        # pointer doubling along predecessor chains, per row
        for _ in range(int(np.ceil(np.log2(max(N, 2)))) + 1):
            h_a = h_a + np.take_along_axis(h_a, jmp, axis=1)
            h_b = h_b + np.take_along_axis(h_b, jmp, axis=1)
            jmp = np.take_along_axis(jmp, jmp, axis=1)
        unreachable = ~np.isfinite(dist)
        h_a = np.where(unreachable, UNREACHABLE, h_a).astype(ct, copy=False)
        h_b = np.where(unreachable, UNREACHABLE, h_b).astype(ct, copy=False)
        return h_a, h_b

    def _hop_split_dijkstra(
        self, w_intra: float, w_inter: float
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Fast path: scipy all-pairs Dijkstra for the distances, then
        one vectorized first-hop selection + pointer-doubling pass to
        decompose every shortest path into (intra, inter) edge counts.
        The counts — not scipy's float-accumulated distances — are the
        returned metric, so the latency reconstruction stays exact.
        """
        from scipy.sparse import csr_matrix
        from scipy.sparse.csgraph import dijkstra

        N = self.num_nodes
        nbr, ntype = self.neighbors, self.neighbor_types
        i, j = np.nonzero(self.adjacency >= 0)          # directed both ways
        w_edge = np.where(
            self.adjacency[i, j] == INTRA, float(w_intra), float(w_inter)
        )
        dist = dijkstra(
            csr_matrix((w_edge, (i, j)), shape=(N, N)), directed=False
        )

        # first hop of one optimal path per (node, destination): the
        # neighbor minimizing w(step) + dist(neighbor, dest) (argmin =
        # first index, deterministic).  Destination columns are
        # independent, so the (N, D, N) candidate tensor is evaluated in
        # budget-bounded column blocks — bit-identical argmins, bounded
        # transient instead of N*D*N*8 bytes at once.
        ct = _count_dtype(N)
        w_step = np.where(ntype == INTRA, float(w_intra), float(w_inter))
        w_step = np.where(ntype < 0, np.inf, w_step)    # (N, D)
        deg = nbr.shape[1]
        block = max(1, int(_FIRST_HOP_BLOCK_BYTES / max(1, N * deg * 8)))
        d = np.empty((N, N), dtype=np.int32)
        for c0 in range(0, N, block):
            c1 = min(N, c0 + block)
            cand = dist[:, c0:c1][nbr] + w_step[:, :, None]   # (N, D, C)
            d[:, c0:c1] = np.argmin(cand, axis=1)
        rows = np.arange(N)
        nxt = nbr[rows[:, None], d].astype(np.int32, copy=False)
        step_inter = (ntype == INTER)[rows[:, None], d].astype(ct)
        step_a = (1 - step_inter).astype(ct, copy=False)
        # fixpoint at the destination: no further steps, no counts
        nxt[rows, rows] = rows
        step_a[rows, rows] = 0
        step_inter[rows, rows] = 0

        # pointer doubling along the first-hop chains: after t rounds
        # each entry holds the counts of the first 2^t path edges
        h_a, h_b, jmp = step_a, step_inter, nxt
        cols = rows[None, :]
        for _ in range(int(np.ceil(np.log2(max(N, 2)))) + 1):
            h_a = h_a + h_a[jmp, cols]
            h_b = h_b + h_b[jmp, cols]
            jmp = jmp[jmp, cols]

        unreachable = ~np.isfinite(dist)
        h_a = np.where(unreachable, UNREACHABLE, h_a).astype(ct, copy=False)
        h_b = np.where(unreachable, UNREACHABLE, h_b).astype(ct, copy=False)
        np.fill_diagonal(h_a, 0)
        np.fill_diagonal(h_b, 0)
        return h_a, h_b

    def _hop_split_sweeps(
        self, w_intra: float, w_inter: float
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Fallback solver: frontier-restricted label-correcting sweeps
        (pure numpy; converges in max-path-edge-count iterations)."""
        key = (float(w_intra), float(w_inter))
        N = self.num_nodes
        nbr, ntype = self.neighbors, self.neighbor_types
        ct = _count_dtype(N)
        w_step = np.where(ntype == INTRA, float(w_intra), float(w_inter))
        w_step = np.where(ntype < 0, np.inf, w_step)    # (N, D)
        step_inter = (ntype == INTER).astype(ct)        # (N, D)

        h_a = np.full((N, N), UNREACHABLE, dtype=ct)
        h_b = np.full((N, N), UNREACHABLE, dtype=ct)
        np.fill_diagonal(h_a, 0)
        np.fill_diagonal(h_b, 0)
        # cost is always REBUILT from the counts (h_a*w_a + h_b*w_b),
        # never accumulated along paths — the final latency decomposition
        # is exact, and the pure-intra case reproduces hops * t_hop
        # bitwise.  The *relative* EPS margin absorbs the last-ulp gap
        # between a candidate (cost[j] + w) and its recomputed cost
        # ((h+1-split)*w sums) at any cost magnitude, so equal-cost
        # relaxations can't ping-pong forever.
        cost = np.where(h_a >= 0, h_a * key[0] + h_b * key[1], np.inf)
        rows = np.arange(N)
        EPS = 1e-9

        # label-correcting sweeps restricted to the frontier: an entry
        # (i, k) can only improve after some (neighbor-of-i, k) entry
        # improved, so later sweeps touch only the changed columns (the
        # long tail of many-cheap-edge paths) instead of all N
        cols = rows
        while cols.size:
            sub = cost[:, cols]                         # (N, C)
            cand = sub[nbr] + w_step[:, :, None]        # (N, D, C)
            d = np.argmin(cand, axis=1)                 # (N, C)
            best = np.take_along_axis(
                cand, d[:, None, :], axis=1
            )[:, 0, :]
            margin = sub - EPS * np.where(
                np.isfinite(sub), np.maximum(1.0, np.abs(sub)), 0.0
            )
            improve = best < margin
            if not np.any(improve):
                break
            ii, jj = np.nonzero(improve)
            col_idx = cols[jj]
            via = nbr[ii, d[ii, jj]]                    # chosen neighbor
            inter_step = step_inter[ii, d[ii, jj]]
            ha_new = h_a[via, col_idx] + 1 - inter_step
            hb_new = h_b[via, col_idx] + inter_step
            h_a[ii, col_idx] = ha_new
            h_b[ii, col_idx] = hb_new
            cost[ii, col_idx] = ha_new * key[0] + hb_new * key[1]
            cols = cols[np.unique(jj)]

        return h_a, h_b

    def plane_adjacency(self) -> np.ndarray:
        """(L, L) bool: planes joined by at least one inter-plane ISL,
        derived from the built edge set — the single source of the
        offset/seam semantics (cluster formation consumes this, so it
        can never desynchronize from routing)."""
        i, j = self.edges(INTER)
        K = self.sats_per_plane
        adj = np.zeros((self.num_planes, self.num_planes), dtype=bool)
        adj[i // K, j // K] = True
        adj[j // K, i // K] = True
        np.fill_diagonal(adj, False)
        return adj

    def hop_matrix(self) -> np.ndarray:
        """All-pairs ISL hop counts (unit edge weights); UNREACHABLE for
        disconnected pairs.  The ring topology's per-plane blocks equal
        ``ring_hops_matrix(K)`` exactly."""
        h_a, h_b = self.hop_split(1.0, 1.0)
        hops = h_a + h_b
        return np.where(h_a == UNREACHABLE, UNREACHABLE, hops)

    def is_connected(self) -> bool:
        return bool(np.all(self.hop_matrix() >= 0))

    def mean_link_length_m(self, kind: int) -> float:
        """Mean chord length [m] over the edges of one type at t=0 (the
        Walker geometry is rigid, so inter-plane spacing at t=0 is
        representative of the per-link mean over an orbit)."""
        from repro.orbits.constellation import make_walker

        i, j = self.edges(kind)
        if i.size == 0:
            raise ValueError(f"topology has no edges of kind {kind}")
        walker = make_walker(self.constellation)
        K = self.sats_per_plane
        r_i = walker.positions_batch(i // K, i % K, np.zeros(i.size))
        r_j = walker.positions_batch(j // K, j % K, np.zeros(j.size))
        return float(np.mean(np.linalg.norm(r_i - r_j, axis=-1)))


def _earth_clear(pos_a: np.ndarray, pos_b: np.ndarray) -> np.ndarray:
    """(Na, Nb) bool: which segments pos_a[i] -> pos_b[j] clear Earth.

    Closest approach of each chord to the geocenter must stay above
    ``R_EARTH``; endpoints are satellites, so only the interior of the
    segment can graze the sphere.
    """
    d = pos_b[None, :, :] - pos_a[:, None, :]            # (Na, Nb, 3)
    dd = np.einsum("abk,abk->ab", d, d)
    u = -np.einsum("ak,abk->ab", pos_a, d) / np.maximum(dd, 1.0)
    u = np.clip(u, 0.0, 1.0)
    closest = pos_a[:, None, :] + u[..., None] * d
    r_min2 = np.einsum("abk,abk->ab", closest, closest)
    return r_min2 > R_EARTH**2


class MultiShellTopology(ISLTopology):
    """ISL graph stitching several Walker shells into one node space.

    Each shell carries its own intra/inter-plane pattern (the shared
    :class:`TopologyConfig`, applied per shell with that shell's Walker
    phasing); shells are joined by cross-shell ISLs typed ``INTER``.
    Every satellite *proposes* links to its ``cross_links_per_sat``
    nearest cross-shell neighbors that are within
    ``cross_max_range_m`` and have Earth-unobstructed line of sight at
    t=0 (the rigid Walker geometry makes t=0 representative); the union
    of proposals forms the cross-shell edge set.  With a single shell
    the graph degenerates to exactly the :class:`ISLTopology` edge set.
    """

    def __init__(
        self,
        constellation: MultiShellConfig,
        config: TopologyConfig = TopologyConfig(),
    ):
        if not isinstance(constellation, MultiShellConfig):
            raise TypeError(
                f"MultiShellTopology needs a MultiShellConfig, got "
                f"{type(constellation).__name__}"
            )
        super().__init__(constellation, config)

    def _build_edges(self) -> Dict[Tuple[int, int], int]:
        from repro.orbits.constellation import make_walker

        cfg = self.constellation
        assert isinstance(cfg, MultiShellConfig)
        K = self.sats_per_plane
        edges: Dict[Tuple[int, int], int] = {}
        for shell, plane_off in zip(cfg.shells, cfg.plane_offsets):
            _add_shell_edges(edges, shell, self.config, plane_off * K)
        if len(cfg.shells) == 1 or cfg.cross_links_per_sat <= 0:
            return edges

        def add(i: int, j: int, kind: int) -> None:
            if i != j:
                edges.setdefault((min(i, j), max(i, j)), kind)

        walker = make_walker(cfg)
        nodes = np.arange(cfg.num_satellites)
        pos = walker.positions_batch(
            nodes // K, nodes % K, np.zeros(nodes.size)
        )                                                # (N, 3)
        shell_of_node = np.repeat(
            np.concatenate(
                [
                    np.full(s.num_planes, idx, dtype=np.intp)
                    for idx, s in enumerate(cfg.shells)
                ]
            ),
            K,
        )
        kcap = cfg.cross_links_per_sat
        for a in range(len(cfg.shells)):
            for b in range(a + 1, len(cfg.shells)):
                ia = np.flatnonzero(shell_of_node == a)
                ib = np.flatnonzero(shell_of_node == b)
                delta = pos[ib][None, :, :] - pos[ia][:, None, :]
                dist = np.sqrt(np.einsum("abk,abk->ab", delta, delta))
                feasible = (dist <= cfg.cross_max_range_m) & _earth_clear(
                    pos[ia], pos[ib]
                )
                dist = np.where(feasible, dist, np.inf)
                # nearest-first proposals from both sides
                near_b = np.argsort(dist, axis=1)[:, :kcap]   # (Na, kcap)
                for r in range(ia.size):
                    for c in near_b[r]:
                        if np.isfinite(dist[r, c]):
                            add(int(ia[r]), int(ib[c]), INTER)
                near_a = np.argsort(dist, axis=0)[:kcap, :]   # (kcap, Nb)
                for c in range(ib.size):
                    for r in near_a[:, c]:
                        if np.isfinite(dist[r, c]):
                            add(int(ia[r]), int(ib[c]), INTER)
        return edges


@functools.lru_cache(maxsize=16)
def get_isl_topology(
    constellation: "ConstellationConfig | MultiShellConfig",
    config: TopologyConfig,
) -> ISLTopology:
    """Cached ISLTopology (both configs are frozen/hashable): the
    strategy, the presets' link-length derivation and the benchmarks all
    share one graph — and its all-pairs metric cache — per scenario.
    Multi-shell configs dispatch to :class:`MultiShellTopology`."""
    if isinstance(constellation, MultiShellConfig):
        return MultiShellTopology(constellation, config)
    return ISLTopology(constellation, config)


TOPOLOGY_PRESETS: Dict[str, TopologyConfig] = {
    "ring": TopologyConfig(kind="ring"),
    "grid": TopologyConfig(kind="grid"),
    "grid-seam-cut": TopologyConfig(kind="grid", seam_cut=True),
    # skip ring halves the intra-plane diameter; still one plane offset
    "motif-skip2": TopologyConfig(kind="motif", intra_slot_offsets=(1, 2)),
}


def get_topology(name_or_config: "str | TopologyConfig") -> TopologyConfig:
    """Resolve a preset name (or pass a TopologyConfig through)."""
    if isinstance(name_or_config, TopologyConfig):
        return name_or_config
    if name_or_config not in TOPOLOGY_PRESETS:
        raise ValueError(
            f"unknown topology {name_or_config!r}; have "
            f"{sorted(TOPOLOGY_PRESETS)}"
        )
    return TOPOLOGY_PRESETS[name_or_config]
