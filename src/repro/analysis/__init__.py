"""Correctness tooling over the scheduling stack.

Two complementary checkers keep the paper's feasibility constraints
machine-checked instead of convention-checked:

  * ``repro.analysis.sanitizer`` — the runtime ``ScheduleSanitizer``:
    validates every committed ``TransferDecision``/``Reservation``
    against the eq. 13-16 RB-capacity and eq. 15 window-containment
    invariants while a simulation runs (``SimConfig.sanitize``).
  * ``repro.analysis.lint`` — the static AST lint pass
    (``python -m repro.analysis.lint``): repo-specific rules over
    ``src/`` (ledger encapsulation, deprecated-shim calls, unit-suffix
    discipline, wall-clock bans, annotation completeness).
"""
from repro.analysis.sanitizer import (
    ScheduleSanitizer,
    ScheduleViolation,
    Violation,
)

__all__ = ["ScheduleSanitizer", "ScheduleViolation", "Violation"]
