"""Runtime schedule sanitizer: the paper's feasibility constraints as
machine-checked invariants over a live ``CommsEnvironment`` session.

Five PRs of scheduler growth left the constraints enforced implicitly
across many code paths; the sanitizer re-derives each one independently
from the committed ``TransferDecision``/``Reservation`` stream, so a
planner bug cannot certify its own schedule:

  * **RB capacity** (eqs. 13-16): no station's concurrent resource-block
    occupancy ever exceeds the ledger capacity ``N`` — checked by an
    interval sweep over the sanitizer's OWN tracking of active legs,
    not by asking the ledger.
  * **Window containment** (eq. 15): every transfer leg lies inside a
    predictor visibility window of its satellite at the leg's tagged
    ``gs_index`` (and download spans inside their broadcast window).
  * **Segment discipline**: segmented (station-handover) uploads are
    time-ordered, non-overlapping, station-switching between
    consecutive legs, positive-payload per leg, inside their recorded
    access windows, and conserve the payload bits end to end.
  * **Reservation hygiene**: every ``commit`` is matched by completion
    or ``release`` — ``finish`` reports reservations still booked
    entirely beyond the end of the simulation (a leaked booking wastes
    capacity forever) unless the strategy declared them as its live
    async queue.
  * **Re-admission monotonicity** (eqs. 21-22 completion races):
    ``CommsEnvironment.readmit`` never makes any queued upload
    complete later than its original booking.

The sanitizer hooks the session at its three choke points — ``commit``
interception, the release path (the same event the ``on_release``
callbacks observe), and ``readmit`` — so it sees exactly the booking
stream the ledger does.  It only *reads* predictor state (never
extends a rolling horizon), so a sanitized run stays bit-identical to
an unsanitized one.

Wiring: ``SimConfig.sanitize`` (on by default — tier-1 tests and the
``--quick`` benchmark smokes run sanitized; the timed benchmark arms
construct their sessions with ``sanitize=False``).  ``strict=True``
(default) raises ``ScheduleViolation`` at the first broken invariant;
``strict=False`` collects violations for ``report()``.
"""
from __future__ import annotations

import dataclasses
from typing import (
    TYPE_CHECKING,
    Any,
    Dict,
    FrozenSet,
    Iterable,
    List,
    Optional,
    Sequence,
    Tuple,
)

if TYPE_CHECKING:                        # no runtime cycle with comms
    from repro.comms.environment import CommsEnvironment, Reservation

Leg = Tuple[int, float, float]           # (gs_index, t_start, t_end)


class ScheduleViolation(AssertionError):
    """A schedule broke one of the paper's feasibility invariants."""


@dataclasses.dataclass(frozen=True)
class Violation:
    """One broken invariant: which rule, where, and what happened.
    ``job`` attributes the violation to the owning multi-tenant job
    (the session's ``CommsEnvironment.job`` label; None standalone) —
    a leak report over N concurrent sessions names the job that leaked.
    """

    rule: str                            # e.g. "rb-capacity"
    message: str
    rid: Optional[int] = None            # offending reservation, if any
    job: Optional[str] = None            # owning multi-tenant job, if any

    def __str__(self) -> str:
        where = f" (reservation {self.rid})" if self.rid is not None else ""
        owner = f" job={self.job}" if self.job is not None else ""
        return f"[{self.rule}]{where}{owner} {self.message}"


@dataclasses.dataclass
class _TrackedReservation:
    """The sanitizer's own record of one commit."""

    rid: int
    decision: Any
    t_start: float                       # transfer start (absolute s)
    t_done: float                        # transfer completion (absolute s)
    released: bool = False


def _decision_span(decision: Any) -> Tuple[float, float]:
    """(t_start, t_done) of any decision type (``TransferDecision``
    carries them directly; sink decisions as ``t_upload_*``)."""
    if hasattr(decision, "t_upload_start"):
        return (
            float(decision.t_upload_start), float(decision.t_upload_done)
        )
    return float(decision.t_start), float(decision.t_done)


def _decision_sat(decision: Any) -> Tuple[int, int]:
    """(plane, slot) of the transferring satellite: the sink for
    cluster decisions, the plane sink slot for ``SinkDecision``, the
    window's satellite for a plain ``TransferDecision``."""
    sink = getattr(decision, "sink", None)
    if sink is not None:                 # ClusterSinkDecision
        return int(sink.plane), int(sink.slot)
    if hasattr(decision, "sink_slot"):   # SinkDecision
        return int(decision.plane), int(decision.sink_slot)
    w = decision.window
    return int(w.plane), int(w.slot)


def _max_overlap(
    intervals: Iterable[Tuple[float, float]], t0: float, t1: float
) -> int:
    """Maximum concurrency of ``intervals`` over the half-open span
    ``[t0, t1)`` (touching endpoints never count as concurrent)."""
    events: List[Tuple[float, int]] = []
    for a, b in intervals:
        lo, hi = max(a, t0), min(b, t1)
        if hi > lo:
            events.append((lo, 1))
            events.append((hi, -1))
    events.sort()                        # (-1) sorts before (+1) at ties
    cur = best = 0
    for _, delta in events:
        cur += delta
        best = max(best, cur)
    return best


class ScheduleSanitizer:
    """Invariant checker attached to one ``CommsEnvironment`` session.

    The session calls ``observe_commit`` / ``observe_release`` /
    ``observe_readmit`` from its lifecycle methods; a strategy (or
    benchmark) closes the books with ``finish``.  All checks re-derive
    the invariant from first principles — the ledger is never asked to
    certify its own bookings.
    """

    def __init__(
        self,
        env: "CommsEnvironment",
        *,
        strict: bool = True,
        eps: float = 1e-6,
    ):
        self.env = env
        self.strict = bool(strict)
        self.eps = float(eps)
        self.violations: List[Violation] = []
        self._tracked: Dict[int, _TrackedReservation] = {}
        # station -> active (t0, t1, rid) legs, the sanitizer's own
        # occupancy model (released spans are truncated out)
        self._active: Dict[int, List[Tuple[float, float, int]]] = {}

    @classmethod
    def attach(
        cls, env: "CommsEnvironment", *, strict: bool = True
    ) -> "ScheduleSanitizer":
        """Create a sanitizer and install it on the session."""
        san = cls(env, strict=strict)
        env.sanitizer = san
        return san

    # -- reporting -------------------------------------------------------------
    def report(self) -> List[Violation]:
        """Every violation observed so far (empty = clean)."""
        return list(self.violations)

    def _fail(self, rule: str, message: str,
              rid: Optional[int] = None) -> None:
        v = Violation(
            rule=rule, message=message, rid=rid,
            job=getattr(self.env, "job", None),
        )
        self.violations.append(v)
        if self.strict:
            raise ScheduleViolation(str(v))

    # -- commit-time checks ----------------------------------------------------
    def observe_commit(self, reservation: "Reservation") -> None:
        """Validate one committed decision and start tracking it."""
        decision = reservation.decision
        rid = reservation.rid
        if decision is None:
            return                       # bare reservation: nothing to check
        t_start, t_done = _decision_span(decision)
        if t_done < t_start - self.eps:
            self._fail(
                "segment-order",
                f"transfer completes before it starts "
                f"({t_start:.3f} -> {t_done:.3f})",
                rid,
            )
        self._check_segments(decision, rid)
        self._check_containment(decision, reservation.legs, rid)
        self._check_capacity(reservation.legs, rid)
        self._tracked[rid] = _TrackedReservation(
            rid=rid, decision=decision, t_start=t_start, t_done=t_done,
        )

    def _check_segments(self, decision: Any, rid: int) -> None:
        """Segmented-handover discipline: ordered, disjoint,
        station-switching, positive, window-respecting legs that
        conserve the payload bits."""
        segments = tuple(getattr(decision, "segments", ()) or ())
        if not segments:
            return
        prev = None
        for s in segments:
            if s.t_end <= s.t_start + self.eps:
                self._fail(
                    "segment-order",
                    f"leg on station {s.gs_index} has non-positive span "
                    f"[{s.t_start:.3f}, {s.t_end:.3f})",
                    rid,
                )
            if s.bits <= 0:
                self._fail(
                    "payload-conservation",
                    f"leg on station {s.gs_index} delivers "
                    f"{s.bits} bits (must be positive)",
                    rid,
                )
            if not (
                s.window_start - self.eps <= s.t_start
                and s.t_end <= s.window_end + self.eps
            ):
                self._fail(
                    "window-containment",
                    f"leg [{s.t_start:.3f}, {s.t_end:.3f}) lies outside "
                    f"its recorded access window "
                    f"[{s.window_start:.3f}, {s.window_end:.3f}]",
                    rid,
                )
            if prev is not None:
                if s.t_start < prev.t_end - self.eps:
                    self._fail(
                        "segment-order",
                        f"legs overlap: [{prev.t_start:.3f}, "
                        f"{prev.t_end:.3f}) then [{s.t_start:.3f}, "
                        f"{s.t_end:.3f})",
                        rid,
                    )
                if s.gs_index == prev.gs_index:
                    self._fail(
                        "segment-order",
                        f"consecutive legs stay on station {s.gs_index} "
                        "(a handover must switch stations)",
                        rid,
                    )
            prev = s
        payload = getattr(decision, "payload_bits", None)
        if payload is not None:
            total = float(sum(s.bits for s in segments))
            if abs(total - float(payload)) > max(1.0, float(payload)) * 1e-6:
                self._fail(
                    "payload-conservation",
                    f"segmented legs deliver {total:.1f} bits of a "
                    f"{float(payload):.1f}-bit payload",
                    rid,
                )

    def _containment_legs(
        self, decision: Any, legs: Tuple[Leg, ...]
    ) -> Tuple[Leg, ...]:
        """The spans to check against the window table: the booked RB
        legs, or — for download broadcasts, which book nothing — the
        decision span on its window's station."""
        if legs:
            return legs
        w = getattr(decision, "window", None)
        if w is None:
            return ()
        t_start, t_done = _decision_span(decision)
        return ((int(w.gs_index), t_start, t_done),)

    def _check_containment(
        self, decision: Any, legs: Tuple[Leg, ...], rid: int
    ) -> None:
        """Eq. 15: every leg inside a predictor visibility window of
        its satellite at the leg's tagged station."""
        spans = self._containment_legs(decision, legs)
        if not spans:
            return
        plane, slot = _decision_sat(decision)
        rec = self.env.predictor.sat_arrays(plane, slot)
        for gi, t0, t1 in spans:
            ok = False
            if rec is not None:
                m = (
                    (rec["gs_index"] == gi)
                    & (rec["starts"] <= t0 + self.eps)
                    & (rec["ends"] >= t1 - self.eps)
                )
                ok = bool(m.any())
            if not ok:
                self._fail(
                    "window-containment",
                    f"leg [{t0:.3f}, {t1:.3f}) of satellite "
                    f"({plane}, {slot}) lies inside no visibility window "
                    f"of station {gi}",
                    rid,
                )

    def _check_capacity(self, legs: Tuple[Leg, ...], rid: int) -> None:
        """Eqs. 13-16: adding these legs must keep every station's
        concurrent RB occupancy within the ledger capacity.  Legs are
        admitted one at a time so a decision overlapping itself on one
        station is caught too."""
        ledger = self.env.ledger
        # per-station capacity tuple, or None for unlimited/no ledger
        caps = None if ledger is None else ledger.capacity
        for gi, t0, t1 in legs:
            active = self._active.setdefault(int(gi), [])
            if caps is not None:
                cap = float(caps[int(gi)])
                occupancy = 1 + _max_overlap(
                    ((a, b) for a, b, _ in active), float(t0), float(t1)
                )
                if occupancy > cap + 1e-9:
                    self._fail(
                        "rb-capacity",
                        f"station {gi} would run {occupancy} concurrent "
                        f"RBs over [{t0:.3f}, {t1:.3f}) "
                        f"(capacity {cap:g})",
                        rid,
                    )
            active.append((float(t0), float(t1), rid))

    # -- release / readmit hooks -----------------------------------------------
    def observe_release(
        self, reservation: "Reservation", freed: Tuple[Leg, ...]
    ) -> None:
        """Mirror a release: freed spans leave the occupancy model and
        the reservation counts as resolved."""
        rec = self._tracked.get(reservation.rid)
        if rec is not None:
            rec.released = True
        for gi, f0, f1 in freed:
            active = self._active.get(int(gi))
            if active is None:
                continue
            kept: List[Tuple[float, float, int]] = []
            for a, b, rid in active:
                if rid != reservation.rid or b <= f0 or a >= f1:
                    kept.append((a, b, rid))
                    continue
                if a < f0:               # spent head stays booked
                    kept.append((a, f0, rid))
                if b > f1:
                    kept.append((f1, b, rid))
            self._active[int(gi)] = kept

    def observe_readmit(
        self,
        before: Sequence[Tuple[Any, float]],
        after: Sequence[Tuple[Any, float]],
    ) -> None:
        """Eqs. 21-22 monotonicity: re-admission never regresses any
        queued upload's completion (positionally aligned lists)."""
        for (key, t_old), (_key, t_new) in zip(before, after):
            if t_new > t_old + 1e-9:
                self._fail(
                    "readmit-regression",
                    f"re-admission moved upload {key!r} from completion "
                    f"{t_old:.3f} to {t_new:.3f} (later)",
                )

    # -- end of simulation -----------------------------------------------------
    def finish(
        self,
        t_end: float,
        open_rids: FrozenSet[int] = frozenset(),
        check_leaks: bool = True,
    ) -> List[Violation]:
        """Close the books at simulated time ``t_end``.

        A reservation is resolved when it was released or its transfer
        ran (started by ``t_end`` — the booked span is exactly the
        transfer, so a started transfer completes by construction).  A
        booking that never started and was never released leaked
        capacity — unless the strategy declared it as part of its live
        async queue (``open_rids``: uploads legitimately booked beyond
        the end of the simulation).  ``check_leaks=False`` skips the
        leak report (a run abandoned mid-round leaves its final
        half-planned bookings behind by design).  Returns every
        violation recorded over the session.
        """
        if check_leaks:
            for rid, rec in sorted(self._tracked.items()):
                if rec.released or rid in open_rids:
                    continue
                if rec.t_start > t_end + self.eps:
                    self._fail(
                        "reservation-leak",
                        f"booking [{rec.t_start:.3f}, {rec.t_done:.3f}) "
                        f"never started by sim end {t_end:.3f} and was "
                        "never released",
                        rid,
                    )
        return self.report()
