"""Repo-specific static lint over ``src/repro`` — run as
``python -m repro.analysis.lint [paths...]``.

AST-based rules encoding the conventions the scheduling stack depends
on (each with a narrow, justified allow-list):

  ledger-encapsulation   ``GSResourceLedger`` booking state is mutated
                         (``reserve``/``release``/``release_before``)
                         only inside ``CommsEnvironment`` (and the
                         ledger itself); everything else goes through
                         the session's ``commit``/``release`` so the
                         sanitizer and the ``on_release`` listeners see
                         every booking.
  deprecated-shim        no *new* ``src/`` calls to the PR-5 legacy
                         free-function shims in ``core/scheduling.py``
                         (``select_sink``, ``reserve_decision``, ...);
                         they remain only as a back-compat surface for
                         external callers and the equivalence tests.
  unit-suffix            numeric fields of scheduling dataclasses carry
                         their unit in the name (``_s``/``_bits``/
                         ``_hz``/``_bps``/...) or sit in the central
                         exemption table below with a justification —
                         mixed-unit bugs (seconds vs hours, bits vs
                         bytes) are the classic scheduling failure.
  wall-clock             no wall-clock reads (``time.time`` & friends)
                         in ``core/``, ``comms/``, ``orbits/`` or
                         ``obs/``: the simulation owns its clock;
                         wall-clock in the sim path destroys
                         reproducibility.  The single sanctioned shim
                         is ``repro/obs/_walltime.py`` (trace-file
                         provenance stamps only).
  annotation             every function in ``comms/``, ``core/`` and
                         ``obs/`` is fully annotated — the local,
                         dependency-free mirror of the CI mypy
                         ``disallow_untyped_defs`` gate.

Exit status 1 when any finding is reported, 0 on a clean tree.
"""
from __future__ import annotations

import ast
import dataclasses
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str                   # repo-relative, forward slashes
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


# --- rule 1: ledger encapsulation ---------------------------------------------
_LEDGER_MUTATORS = {"reserve", "release", "release_booking", "release_before"}
# files allowed to mutate ledger state directly: the ledger itself, the
# session that owns it, and the PR-5 legacy booking shim
# (``reserve_transfer`` in core/scheduling.py) kept solely for the
# session-vs-legacy equivalence tests
_LEDGER_ALLOWED_FILES = {
    "repro/comms/ledger.py",
    "repro/comms/environment.py",
}
_LEDGER_ALLOWED_FUNCS = {("repro/core/scheduling.py", "reserve_transfer")}


# --- rule 2: deprecated PR-5 shims --------------------------------------------
_DEPRECATED_SHIMS = {
    "earliest_transfer",
    "select_sink",
    "select_sink_cluster",
    "naive_sink_slot",
    "first_visible_download",
    "first_visible_download_sats",
    "reserve_transfer",
    "reserve_decision",
}
_SCHEDULING_MODULE = "repro.core.scheduling"


# --- rule 3: unit-suffix discipline -------------------------------------------
# files whose dataclasses carry scheduling quantities
_UNIT_FILES = {
    "repro/comms/environment.py",
    "repro/comms/isl.py",
    "repro/comms/link.py",
    "repro/comms/ledger.py",
    "repro/comms/routing.py",
    "repro/core/scheduling.py",
    "repro/core/engine.py",
}
_UNIT_SUFFIXES = (
    "_s", "_bits", "_hz", "_bps", "_hours", "_m", "_deg",
    "_dbm", "_dbi", "_k", "_db", "_fraction", "_factor",
    "_index", "_slot", "_mb",
)
_UNIT_PREFIXES = ("t_", "num_")
# central exemption table: unit-free or self-describing numeric fields.
# Add here ONLY with a justification — everything else must carry its
# unit in the name.
_UNIT_EXEMPT: Dict[str, str] = {
    "rid": "opaque reservation id",
    "seed": "RNG seed, dimensionless",
    "plane": "topology coordinate, not a quantity",
    "bits": "the field IS the unit (TransferSegment payload)",
    "candidates_considered": "plain count",
    "spectral_efficiency": "standard link-budget name (bit/s/Hz)",
    "noniid_alpha": "dimensionless mixing blend",
    "gs_rb_capacity": "resource-block count per station",
    "window_start": "absolute seconds; legacy TransferSegment field",
    "window_end": "absolute seconds; legacy TransferSegment field",
}
_NUMERIC_ANNOTATIONS = {
    "int", "float",
    "Optional[int]", "Optional[float]",
    "int | None", "float | None",
    "Optional[Union[int, Sequence[int]]]",
}


# --- rule 4: wall-clock ban ---------------------------------------------------
_SIM_PACKAGES = (
    "repro/core/", "repro/comms/", "repro/orbits/", "repro/obs/",
    "repro/compute/", "repro/multitenant/",
)
# the ONE sanctioned wall-clock shim: repro.obs._walltime stamps
# exported trace FILES with their recording time (file provenance, not
# simulation state) — everything in obs/ must route through it
_WALL_CLOCK_EXEMPT_FILES = ("repro/obs/_walltime.py",)
_WALL_CLOCK_CALLS = {
    ("time", "time"), ("time", "perf_counter"), ("time", "monotonic"),
    ("time", "process_time"), ("time", "time_ns"), ("datetime", "now"),
    ("datetime", "today"), ("datetime", "utcnow"),
}


# --- rule 5: annotation completeness ------------------------------------------
_ANNOTATION_PACKAGES = (
    "repro/comms/", "repro/configs/", "repro/core/", "repro/obs/",
    "repro/orbits/", "repro/compute/", "repro/multitenant/",
)


def _enclosing_functions(tree: ast.Module) -> Dict[ast.AST, str]:
    """Map every node to the name of its innermost enclosing def."""
    owner: Dict[ast.AST, str] = {}

    def visit(node: ast.AST, current: str) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            current = node.name
        for child in ast.iter_child_nodes(node):
            owner[child] = current
            visit(child, current)

    visit(tree, "<module>")
    return owner


def _check_ledger(
    rel: str, tree: ast.Module, findings: List[Finding]
) -> None:
    if rel in _LEDGER_ALLOWED_FILES:
        return
    owner = _enclosing_functions(tree)
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)):
            continue
        if node.func.attr not in _LEDGER_MUTATORS:
            continue
        receiver = ast.unparse(node.func.value)
        if "ledger" not in receiver.lower():
            continue
        if (rel, owner.get(node, "<module>")) in _LEDGER_ALLOWED_FUNCS:
            continue
        findings.append(Finding(
            rel, node.lineno, "ledger-encapsulation",
            f"direct ledger mutation `{receiver}.{node.func.attr}(...)` — "
            "book through CommsEnvironment.commit/release so the session "
            "(and its sanitizer) owns every reservation",
        ))


def _check_deprecated_shims(
    rel: str, tree: ast.Module, findings: List[Finding]
) -> None:
    if rel == "repro/core/scheduling.py":
        return
    # names bound to the shim functions, and names bound to the
    # scheduling MODULE (``import ... as S``, ``S = _sched()``)
    shim_names: Set[str] = set()
    module_names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            if node.module == _SCHEDULING_MODULE:
                for alias in node.names:
                    if alias.name in _DEPRECATED_SHIMS:
                        shim_names.add(alias.asname or alias.name)
        elif isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == _SCHEDULING_MODULE:
                    module_names.add(
                        alias.asname or alias.name.split(".")[0]
                    )
        elif isinstance(node, ast.Assign) and isinstance(
            node.value, ast.Call
        ):
            # the lazy-import idiom: S = _sched()
            f = node.value.func
            if isinstance(f, ast.Name) and f.id == "_sched":
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        module_names.add(tgt.id)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        name: Optional[str] = None
        if isinstance(f, ast.Name) and f.id in shim_names:
            name = f.id
        elif (
            isinstance(f, ast.Attribute)
            and isinstance(f.value, ast.Name)
            and f.value.id in module_names
            and f.attr in _DEPRECATED_SHIMS
        ):
            name = f.attr
        if name is not None:
            findings.append(Finding(
                rel, node.lineno, "deprecated-shim",
                f"call to legacy scheduling shim `{name}` — use the "
                "CommsEnvironment session API instead",
            ))


def _unit_ok(name: str) -> bool:
    if name in _UNIT_EXEMPT:
        return True
    if name.endswith(_UNIT_SUFFIXES):
        return True
    return name.startswith(_UNIT_PREFIXES)


def _check_unit_suffixes(
    rel: str, tree: ast.Module, findings: List[Finding]
) -> None:
    if rel not in _UNIT_FILES:
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        if not any("dataclass" in ast.unparse(d)
                   for d in node.decorator_list):
            continue
        for stmt in node.body:
            if not (isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)):
                continue
            ann = ast.unparse(stmt.annotation)
            if ann not in _NUMERIC_ANNOTATIONS:
                continue
            field = stmt.target.id
            if not _unit_ok(field):
                findings.append(Finding(
                    rel, stmt.lineno, "unit-suffix",
                    f"numeric field `{node.name}.{field}` carries no unit "
                    "suffix (_s/_bits/_hz/_bps/...) and is not in the "
                    "lint exemption table",
                ))


def _check_wall_clock(
    rel: str, tree: ast.Module, findings: List[Finding]
) -> None:
    if not rel.startswith(_SIM_PACKAGES):
        return
    if rel in _WALL_CLOCK_EXEMPT_FILES:
        return
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)):
            continue
        f = node.func
        if isinstance(f.value, ast.Name):
            if (f.value.id, f.attr) in _WALL_CLOCK_CALLS:
                findings.append(Finding(
                    rel, node.lineno, "wall-clock",
                    f"wall-clock read `{f.value.id}.{f.attr}()` in the "
                    "simulation path — the simulated clock is the only "
                    "clock here",
                ))


def _unannotated_args(
    fn: "ast.FunctionDef | ast.AsyncFunctionDef",
) -> List[str]:
    args = fn.args
    names: List[str] = []
    plain = args.posonlyargs + args.args
    for i, a in enumerate(plain):
        if i == 0 and a.arg in ("self", "cls"):
            continue
        if a.annotation is None:
            names.append(a.arg)
    for a in args.kwonlyargs:
        if a.annotation is None:
            names.append(a.arg)
    for a in (args.vararg, args.kwarg):
        if a is not None and a.annotation is None:
            names.append(a.arg)
    return names


def _check_annotations(
    rel: str, tree: ast.Module, findings: List[Finding]
) -> None:
    if not rel.startswith(_ANNOTATION_PACKAGES):
        return
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        missing = _unannotated_args(node)
        if missing:
            findings.append(Finding(
                rel, node.lineno, "annotation",
                f"`{node.name}` has unannotated parameter(s): "
                f"{', '.join(missing)}",
            ))
        if node.returns is None and node.name != "__init__":
            findings.append(Finding(
                rel, node.lineno, "annotation",
                f"`{node.name}` has no return annotation",
            ))


_CHECKS = (
    _check_ledger,
    _check_deprecated_shims,
    _check_unit_suffixes,
    _check_wall_clock,
    _check_annotations,
)


def _rel_path(path: Path, roots: Sequence[Path]) -> str:
    """Path relative to the nearest containing root (posix form), so
    rule allow-lists match regardless of where lint is invoked from."""
    resolved = path.resolve()
    for root in roots:
        try:
            return resolved.relative_to(root.resolve()).as_posix()
        except ValueError:
            continue
    return path.as_posix()


def lint_file(path: Path, roots: Sequence[Path]) -> List[Finding]:
    rel = _rel_path(path, roots)
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as exc:
        return [Finding(rel, exc.lineno or 0, "syntax",
                        f"unparseable: {exc.msg}")]
    findings: List[Finding] = []
    for check in _CHECKS:
        check(rel, tree, findings)
    return findings


def iter_python_files(paths: Iterable[Path]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        if p.is_dir():
            out.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            out.append(p)
    return out


def run_lint(paths: Sequence[Path]) -> Tuple[List[Finding], int]:
    """Lint every Python file under ``paths``.  Returns the findings
    and the number of files checked.  Rule allow-lists key on paths
    relative to the ``repro`` package, so any invocation directory
    works."""
    roots = []
    for p in paths:
        p = p.resolve()
        # anchor rel-paths at the directory CONTAINING `repro`
        for anc in (p, *p.parents):
            if anc.name == "repro":
                roots.append(anc.parent)
                break
        else:
            roots.append(p if p.is_dir() else p.parent)
    files = iter_python_files(paths)
    findings: List[Finding] = []
    for f in files:
        findings.extend(lint_file(f, roots))
    findings.sort(key=lambda x: (x.path, x.line, x.rule))
    return findings, len(files)


def main(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv:
        paths = [Path(a) for a in argv]
    else:
        # default: the repro package this module is part of
        paths = [Path(__file__).resolve().parent.parent]
    findings, n_files = run_lint(paths)
    for f in findings:
        print(f)
    status = 1 if findings else 0
    print(
        f"lint: {n_files} files, {len(findings)} finding(s)",
        file=sys.stderr,
    )
    return status


if __name__ == "__main__":
    sys.exit(main())
