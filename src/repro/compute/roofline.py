"""Per-(arch, shape, device) roofline step-time estimation, LRU-cached.

Three estimation modes (``SatelliteComputeProfile.mode``):

  analytic   FLOPs = (6 train / 2 inference) x N_active x tokens and an
             HBM-byte model from the arch config's param counts — no
             jax needed, the default.
  compiled   exact XLA ``cost_analysis`` FLOPs/bytes of the lowered
             smoke-config train step (``launch/dryrun``'s
             ``cost_analysis_dict``) at a reduced compile shape, scaled
             linearly in tokens to the profile's shape (the same
             linear-in-tokens assumption the analytic model makes).
  measured   wall-clock of one real jitted smoke step on this host
             (``repro.launch.calibrate`` — the sanctioned wall-clock
             home; this module stays inside the lint's simulation-path
             clock ban), same token scaling.

``step_time_s`` turns a ``StepCost`` into roofline time
max(flops / (peak x MFU), bytes / BW); ``seconds_per_sample`` divides
by the shape's global batch — the c_k/f_k replacement that
``FleetComputeModel`` feeds into eq. (11).  ``arch_payload_bits``
derives the comm payload z|N| from the arch's real param count.

Everything is cached with ``functools.lru_cache`` on hashable keys
(arch id, shape name, frozen ``DeviceProfile``), so pricing a
40-plane round costs one dict lookup per plane.
"""
from __future__ import annotations

import dataclasses
import functools

from repro.compute.profiles import DeviceProfile
from repro.configs.base import INPUT_SHAPES, ArchConfig, InputShape
from repro.configs.registry import get_config, get_smoke_config

# training streams each parameter ~3x per step (read weights, write
# grads, read+write optimizer moments amortized); inference reads once
_TRAIN_PARAM_PASSES = 3
_BF16_BYTES = 2
# per-token-per-layer activation traffic, in units of d_model elements
# (residual stream in + out, plus the block's two projections)
_ACTIVATION_FACTOR = 4

# compiled/measured modes run the smoke config at this reduced shape
# (CPU-tractable: a few seconds to lower + compile) and scale linearly
# in tokens to the profile's shape
_COMPILE_SEQ_LEN = 128
_COMPILE_BATCH = 4


@dataclasses.dataclass(frozen=True)
class StepCost:
    """One training/inference step's resource footprint."""

    flops: float
    hbm_bytes: float
    tokens: float                # tokens processed by the step


def _tokens(shape: InputShape) -> float:
    """Tokens per step: decode advances one position per sequence."""
    if shape.kind == "decode":
        return float(shape.global_batch)
    return float(shape.global_batch) * float(shape.seq_len)


def _resolve_config(arch_id: str, smoke: bool) -> ArchConfig:
    return get_smoke_config(arch_id) if smoke else get_config(arch_id)


@functools.lru_cache(maxsize=None)
def analytic_step_cost(
    arch_id: str, shape_name: str, smoke: bool = True
) -> StepCost:
    """FLOPs/bytes from the config's param counts (no jax import)."""
    cfg = _resolve_config(arch_id, smoke)
    shape = INPUT_SHAPES[shape_name]
    tokens = _tokens(shape)
    n_active = float(cfg.active_param_count_estimate())
    n_total = float(cfg.param_count_estimate())
    flops_per_token = (6.0 if shape.kind == "train" else 2.0) * n_active
    param_passes = _TRAIN_PARAM_PASSES if shape.kind == "train" else 1
    act_bytes = (
        tokens * cfg.d_model * cfg.num_layers
        * _ACTIVATION_FACTOR * _BF16_BYTES
    )
    return StepCost(
        flops=flops_per_token * tokens,
        hbm_bytes=n_total * _BF16_BYTES * param_passes + act_bytes,
        tokens=tokens,
    )


@functools.lru_cache(maxsize=None)
def compiled_step_cost(arch_id: str, shape_name: str) -> StepCost:
    """XLA cost_analysis of the lowered smoke train step, token-scaled.

    Lowers + compiles the smoke config at the reduced compile shape on
    a single-device mesh (jax is imported lazily — analytic-mode users
    never pay it), reads ``cost_analysis_dict`` and scales FLOPs and
    bytes linearly from compile-shape tokens to the profile shape's."""
    import repro.configs.base as base
    from repro.launch.dryrun import cost_analysis_dict, lower_pair
    from repro.launch.mesh import make_mesh_compat

    shape = INPUT_SHAPES[shape_name]
    small = dataclasses.replace(
        shape,
        name=f"_roofline_{shape_name}",
        seq_len=min(shape.seq_len, _COMPILE_SEQ_LEN),
        global_batch=min(shape.global_batch, _COMPILE_BATCH),
    )
    base.INPUT_SHAPES[small.name] = small
    try:
        mesh = make_mesh_compat((1, 1), ("data", "model"))
        lowered, _ = lower_pair(
            arch_id, small.name, mesh, cfg=get_smoke_config(arch_id)
        )
        cost = cost_analysis_dict(lowered.compile())
    finally:
        base.INPUT_SHAPES.pop(small.name, None)
    scale = _tokens(shape) / _tokens(small)
    analytic = analytic_step_cost(arch_id, shape_name, True)
    flops = float(cost.get("flops", 0.0)) or analytic.flops / scale
    hbm = float(cost.get("bytes accessed", 0.0)) or (
        analytic.hbm_bytes / scale
    )
    return StepCost(
        flops=flops * scale, hbm_bytes=hbm * scale, tokens=_tokens(shape)
    )


def step_cost(
    arch_id: str, shape_name: str, *, mode: str = "analytic",
    smoke: bool = True,
) -> StepCost:
    """The (arch, shape) step cost under the given estimation mode
    ("measured" prices like "compiled": its calibration replaces the
    roofline *time*, not the cost, in ``step_time_s``)."""
    if mode in ("compiled", "measured"):
        return compiled_step_cost(arch_id, shape_name)
    return analytic_step_cost(arch_id, shape_name, smoke)


@functools.lru_cache(maxsize=None)
def _measured_step_time_s(arch_id: str, shape_name: str) -> float:
    """Wall-clock of one real smoke step, token-scaled to the shape.

    The measurement itself lives in ``repro.launch.calibrate`` — the
    simulation packages (this one included) are wall-clock-banned."""
    from repro.launch.calibrate import measure_smoke_step_s

    shape = INPUT_SHAPES[shape_name]
    small_tokens = (
        float(min(shape.global_batch, _COMPILE_BATCH))
        * min(shape.seq_len, _COMPILE_SEQ_LEN)
    )
    t = measure_smoke_step_s(
        arch_id,
        seq_len=min(shape.seq_len, _COMPILE_SEQ_LEN),
        global_batch=min(shape.global_batch, _COMPILE_BATCH),
    )
    return t * _tokens(shape) / small_tokens


@functools.lru_cache(maxsize=None)
def step_time_s(
    arch_id: str,
    shape_name: str,
    device: DeviceProfile,
    *,
    mode: str = "analytic",
    smoke: bool = True,
) -> float:
    """Roofline step time on ``device``:
    max(flops / (peak x MFU), bytes / BW).  "measured" mode instead
    returns this host's calibrated wall-clock per step (the device
    argument is ignored — the host IS the device)."""
    if mode == "measured":
        return _measured_step_time_s(arch_id, shape_name)
    c = step_cost(arch_id, shape_name, mode=mode, smoke=smoke)
    t_compute = c.flops / (device.peak_flops * device.mfu_fraction)
    t_memory = c.hbm_bytes / device.hbm_bytes_per_s
    return max(t_compute, t_memory)


def seconds_per_sample(
    arch_id: str,
    shape_name: str,
    device: DeviceProfile,
    *,
    mode: str = "analytic",
    smoke: bool = True,
) -> float:
    """Per-sample training cost — the heterogeneous replacement for the
    paper's uniform c_k / f_k in eq. (11)."""
    shape = INPUT_SHAPES[shape_name]
    t = step_time_s(arch_id, shape_name, device, mode=mode, smoke=smoke)
    return t / float(shape.global_batch)


@functools.lru_cache(maxsize=None)
def arch_payload_bits(
    arch_id: str, *, bits_per_param: int = 32, smoke: bool = True
) -> float:
    """Comm payload z|N| from the arch's real param count (the same
    sizing rule as ``multitenant.registry_payload_bits``)."""
    cfg = _resolve_config(arch_id, smoke)
    return float(cfg.param_count_estimate()) * bits_per_param
