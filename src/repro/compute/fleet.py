"""FleetComputeModel: the per-satellite compute oracle the engines use.

Resolves a ``SatelliteComputeProfile`` against a constellation into the
two queries the FL engines need:

  seconds_per_sample(plane, slot)  roofline per-sample training cost,
                                   or None — "keep the paper's uniform
                                   c_k / f_k" (the degenerate tier)
  payload_bits(plane, slot)        the arch's real param-count payload,
                                   or None — "keep the task's payload"

``train_time_s`` composes the former with eq. (11)'s structure
(I x n_k x b_k x per-sample cost) so heterogeneous fleets and the
paper's uniform timing share one wall-clock formula.  All satellites of
the degenerate tier (``arch=None``) answer None to both queries, which
is how an all-default profile stays bit-identical to an unset
``SimConfig.compute``.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.compute import roofline
from repro.compute.profiles import (
    DEVICE_TIERS,
    SatAssignment,
    SatelliteComputeProfile,
)


class FleetComputeModel:
    """A profile resolved against one constellation's plane count."""

    def __init__(
        self, profile: SatelliteComputeProfile, num_planes: int
    ) -> None:
        self.profile = profile
        self.num_planes = int(num_planes)

    def assignment(self, plane: int, slot: int = 0) -> SatAssignment:
        return self.profile.assignment(plane, slot)

    @property
    def payload_aware(self) -> bool:
        """Whether any satellite's payload may differ from the task's."""
        return self.profile.payload_from_arch

    def seconds_per_sample(
        self, plane: int, slot: int = 0
    ) -> Optional[float]:
        """Per-sample training cost of satellite (plane, slot), or None
        for the degenerate (paper c_k / f_k) tier."""
        a = self.assignment(plane, slot)
        if a.arch is None:
            return None
        p = self.profile
        return roofline.seconds_per_sample(
            a.arch, p.shape, DEVICE_TIERS[a.device],
            mode=p.mode, smoke=p.smoke,
        )

    def train_time_s(
        self,
        plane: int,
        slot: int = 0,
        *,
        local_epochs: int,
        n_batches: int,
        batch_size: int,
    ) -> Optional[float]:
        """Eq. (11) with the roofline per-sample cost:
        I x n_k x b_k x seconds_per_sample.  The caller passes the
        batches/batch-size actually executed (``FederatedTask``'s
        executed-work accounting).  None = degenerate tier."""
        sps = self.seconds_per_sample(plane, slot)
        if sps is None:
            return None
        return float(local_epochs) * n_batches * batch_size * sps

    def payload_bits(self, plane: int, slot: int = 0) -> Optional[float]:
        """The arch-derived payload z|N| of satellite (plane, slot), or
        None — keep the task's uniform payload (always None unless the
        profile opts in via ``payload_from_arch``)."""
        p = self.profile
        if not p.payload_from_arch:
            return None
        a = self.assignment(plane, slot)
        if a.arch is None:
            return None
        return roofline.arch_payload_bits(
            a.arch, bits_per_param=p.bits_per_param, smoke=p.smoke
        )

    def plane_summary(self) -> List[Dict[str, object]]:
        """Per-plane assignment + resolved per-sample cost (benchmark
        display; slot-0 assignment stands in for the plane)."""
        rows: List[Dict[str, object]] = []
        for plane in range(self.num_planes):
            a = self.assignment(plane)
            rows.append({
                "plane": plane,
                "arch": a.arch,
                "device": a.device,
                "seconds_per_sample": self.seconds_per_sample(plane),
            })
        return rows
