"""Heterogeneous fleet compute: device tiers + roofline step times.

Turns the scheduler into the control plane of a real jax_pallas
training system: each plane (or satellite) carries a device tier
(``DeviceProfile``) and a model architecture from the
``configs/registry`` zoo; ``FleetComputeModel`` resolves per-satellite
train times (roofline over FLOPs/bytes, ``compute.roofline``) and
payload sizes (real param counts) that ``FederatedTask`` and every
engine consult behind ``SimConfig.compute``.  The uniform profile
(every assignment ``arch=None``) is the bit-identical degenerate case
of the paper's eq. (11) constant — equivalence-tested.
"""
from repro.compute.fleet import FleetComputeModel
from repro.compute.profiles import (
    DEVICE_TIERS,
    DeviceProfile,
    SatAssignment,
    SatelliteComputeProfile,
)
from repro.compute.roofline import (
    StepCost,
    arch_payload_bits,
    seconds_per_sample,
    step_cost,
    step_time_s,
)

__all__ = [
    "DEVICE_TIERS",
    "DeviceProfile",
    "FleetComputeModel",
    "SatAssignment",
    "SatelliteComputeProfile",
    "StepCost",
    "arch_payload_bits",
    "seconds_per_sample",
    "step_cost",
    "step_time_s",
]
