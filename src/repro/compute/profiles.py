"""Device tiers + per-satellite compute/arch assignment.

``DeviceProfile`` describes one class of on-board accelerator by its
roofline axes (peak FLOP/s, HBM bandwidth) plus the achievable MFU and
the payload quantization of the models it ships.  The tiers span the
plausible orbital range: a cubesat flight computer, a Coral-class edge
TPU, an Orin-class radiation-tolerant GPU, and a full TPU-v5e-class
accelerator (matching ``benchmarks/roofline.py``'s constants).

``SatelliteComputeProfile`` assigns every plane — with optional
per-satellite overrides — a ``SatAssignment``: a device tier and a
model architecture from ``configs/registry``.  ``arch=None`` means the
paper's uniform eq. (11) timing for that satellite, so the all-default
profile is the exact degenerate case of an unset ``SimConfig.compute``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ARCH_IDS


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """One on-board accelerator class, by its roofline axes."""

    name: str
    peak_flops: float            # peak FLOP/s (bf16-equivalent)
    hbm_bytes_per_s: float       # memory bandwidth, bytes/s
    mfu_fraction: float = 0.4    # achievable fraction of peak in training
    bits_per_param: int = 32     # payload quantization of shipped models

    def __post_init__(self) -> None:
        if self.peak_flops <= 0 or self.hbm_bytes_per_s <= 0:
            raise ValueError(
                f"device {self.name!r}: peak_flops and hbm_bytes_per_s "
                "must be > 0"
            )
        if not 0 < self.mfu_fraction <= 1:
            raise ValueError(
                f"device {self.name!r}: mfu_fraction must be in (0, 1]"
            )


# The orbital hardware ladder.  "orbital-tpu-v5e" matches the roofline
# constants in benchmarks/roofline.py (197 TFLOP/s bf16, 819 GB/s).
DEVICE_TIERS: Dict[str, DeviceProfile] = {
    "cubesat-cpu": DeviceProfile(
        "cubesat-cpu", peak_flops=8e9, hbm_bytes_per_s=12.8e9,
        mfu_fraction=0.6,
    ),
    "orbital-edge-tpu": DeviceProfile(
        "orbital-edge-tpu", peak_flops=2e12, hbm_bytes_per_s=25.6e9,
    ),
    "orbital-gpu": DeviceProfile(
        "orbital-gpu", peak_flops=40e12, hbm_bytes_per_s=204.8e9,
    ),
    "orbital-tpu-v5e": DeviceProfile(
        "orbital-tpu-v5e", peak_flops=197e12, hbm_bytes_per_s=819e9,
    ),
}

DEFAULT_DEVICE = "orbital-gpu"

# step-time estimation modes (compute.roofline):
#   analytic — FLOPs/bytes from the arch config's param counts,
#   compiled — XLA cost_analysis of the lowered smoke step (dryrun),
#   measured — wall-clock of one real jitted smoke step on this host
#              (repro.launch.calibrate; the optional calibration path).
MODES = ("analytic", "compiled", "measured")


@dataclasses.dataclass(frozen=True)
class SatAssignment:
    """One satellite's (or plane's) compute assignment.

    ``arch=None`` keeps the paper's uniform eq. (11) timing and payload
    for that satellite — the degenerate tier."""

    arch: Optional[str] = None
    device: str = DEFAULT_DEVICE

    def __post_init__(self) -> None:
        if self.arch is not None and self.arch not in ARCH_IDS:
            raise ValueError(
                f"unknown arch {self.arch!r}; have {sorted(ARCH_IDS)}"
            )
        if self.device not in DEVICE_TIERS:
            raise ValueError(
                f"unknown device tier {self.device!r}; "
                f"have {sorted(DEVICE_TIERS)}"
            )


@dataclasses.dataclass(frozen=True)
class SatelliteComputeProfile:
    """Fleet-wide assignment of device tiers + model archs.

    ``planes[p]`` is plane p's assignment; planes beyond the tuple get
    ``default``; ``sat_overrides`` pins individual (plane, slot)
    satellites.  ``shape`` names the ``INPUT_SHAPES`` training step the
    roofline prices; ``smoke=True`` sizes step costs and payloads from
    the scaled-down smoke configs (the realistic per-satellite shard —
    the full published configs exceed any single eq. 16 window), which
    the ``compiled``/``measured`` modes require (full-size configs
    cannot compile on a CPU host).  ``payload_from_arch`` additionally
    replaces the task's uniform payload with each arch's real
    param-count bits — off by default so enabling heterogeneous *time*
    alone leaves the comm model untouched."""

    planes: Tuple[SatAssignment, ...] = ()
    default: SatAssignment = SatAssignment()
    sat_overrides: Tuple[Tuple[int, int, SatAssignment], ...] = ()
    shape: str = "train_4k"
    mode: str = "analytic"
    smoke: bool = True
    payload_from_arch: bool = False
    bits_per_param: int = 32

    def __post_init__(self) -> None:
        if self.shape not in INPUT_SHAPES:
            raise ValueError(
                f"unknown input shape {self.shape!r}; "
                f"have {sorted(INPUT_SHAPES)}"
            )
        if self.mode not in MODES:
            raise ValueError(
                f"unknown mode {self.mode!r}; have {MODES}"
            )
        if self.mode in ("compiled", "measured") and not self.smoke:
            raise ValueError(
                f"mode {self.mode!r} requires smoke=True: full-size "
                "configs cannot compile/run on this host"
            )

    def assignment(self, plane: int, slot: int = 0) -> SatAssignment:
        """The effective assignment of satellite (plane, slot)."""
        for p, s, a in self.sat_overrides:
            if p == plane and s == slot:
                return a
        if 0 <= plane < len(self.planes):
            return self.planes[plane]
        return self.default

    @classmethod
    def uniform(cls, **kwargs: Any) -> "SatelliteComputeProfile":
        """The degenerate profile: every satellite keeps the paper's
        eq. (11) timing (all assignments ``arch=None``)."""
        return cls(**kwargs)

    @classmethod
    def per_plane(
        cls,
        plane_archs: Sequence[Optional[str]],
        *,
        device: str = DEFAULT_DEVICE,
        **kwargs: Any,
    ) -> "SatelliteComputeProfile":
        """One arch per plane on a shared device tier (None entries
        keep the paper timing for that plane)."""
        planes = tuple(
            SatAssignment(arch=a, device=device) for a in plane_archs
        )
        return cls(planes=planes, **kwargs)
