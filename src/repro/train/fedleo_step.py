"""FedLEO mapped onto the TPU pod fabric (DESIGN.md §3).

The paper's insight — hierarchical, communication-avoiding aggregation
with one scheduled uplink per group — becomes a first-class distributed
training feature:

  * Each *orbit replica* r keeps its own parameter copy (leading axis R
    sharded over the mesh ``pod``/``orbit`` axis) and runs ``tau`` local
    steps with gradient reduction confined to in-replica axes (the
    ``data`` axis inside the pod = intra-plane ISL traffic).  Implemented
    with jax.vmap over the replica axis: XLA partitions the replica dim,
    so NO cross-replica collective exists in the local step's HLO.
  * Every tau steps, ``fedleo_aggregate`` performs the sink + GS
    aggregation: a weighted mean over the replica axis (eqs. 9/4) — the
    single scheduled cross-pod all-reduce per FL round.

Compared against the fully synchronous baseline (per-step global
all-reduce), the collective bytes on the pod axis drop by ~tau x — this
is the quantity §Perf tracks.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import Optimizer
from repro.train.steps import TrainState, make_train_step

PyTree = Any


def replicate_for_orbits(tree: PyTree, num_orbits: int) -> PyTree:
    """Add the leading orbit-replica axis R to every leaf."""
    return jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (num_orbits,) + p.shape), tree
    )


def make_fedleo_local_step(
    model, optimizer: Optimizer, grad_clip: Optional[float] = 1.0,
    num_local_steps: int = 1,
) -> Callable:
    """Per-orbit local training: vmap(train_step) over the replica axis.

    state leaves: (R, ...); batch leaves: (R, B_local, ...).
    ``num_local_steps`` > 1 runs tau steps inside one call via lax.scan
    (batch gains a leading tau axis: (R, tau, B_local, ...)).
    """
    train_step = make_train_step(model, optimizer, grad_clip)

    def one_replica(state: TrainState, batches: Dict):
        if num_local_steps == 1:
            batch = jax.tree_util.tree_map(lambda b: b[0], batches)
            return train_step(state, batch)

        def body(st, batch):
            st, metrics = train_step(st, batch)
            return st, metrics

        state, metrics = jax.lax.scan(body, state, batches)
        metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        return state, metrics

    return jax.vmap(one_replica)


def make_fedleo_aggregate() -> Callable:
    """Sink + GS aggregation: weighted mean over the orbit-replica axis.

    weights: (R,) = m_{K_l} / m (eq. 4 over orbit partials; each replica
    already IS the orbit's partial model, eq. 9, because its local data
    parallelism averaged over the in-pod data axis).
    Optimizer state is aggregated the same way (standard local-SGD /
    DiLoCo practice) so replicas restart from a common point.
    """

    def aggregate(state: TrainState, weights: jnp.ndarray) -> TrainState:
        w = weights / jnp.sum(weights)
        r = w.shape[0]

        def mean_leaf(x):
            if x.ndim == 0 or x.shape[0] != r:
                return x
            wx = w.reshape((r,) + (1,) * (x.ndim - 1)).astype(jnp.float32)
            m = jnp.sum(wx * x.astype(jnp.float32), axis=0)
            return jnp.broadcast_to(m, x.shape).astype(x.dtype)

        agg_params = jax.tree_util.tree_map(mean_leaf, state.params)
        agg_opt = jax.tree_util.tree_map(mean_leaf, state.opt_state)
        return TrainState(params=agg_params, opt_state=agg_opt,
                          step=state.step)

    return aggregate
