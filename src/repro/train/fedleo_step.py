"""FedLEO mapped onto the TPU pod fabric (DESIGN.md §3).

The paper's insight — hierarchical, communication-avoiding aggregation
with one scheduled uplink per group — becomes a first-class distributed
training feature:

  * Each *orbit replica* r keeps its own parameter copy (leading axis R
    sharded over the mesh ``pod``/``orbit`` axis) and runs ``tau`` local
    steps with gradient reduction confined to in-replica axes (the
    ``data`` axis inside the pod = intra-plane ISL traffic).  Implemented
    with jax.vmap over the replica axis: XLA partitions the replica dim,
    so NO cross-replica collective exists in the local step's HLO.
  * Every tau steps, ``fedleo_aggregate`` performs the sink + GS
    aggregation: a weighted mean over the replica axis (eqs. 9/4) — the
    single scheduled cross-pod all-reduce per FL round.

Compared against the fully synchronous baseline (per-step global
all-reduce), the collective bytes on the pod axis drop by ~tau x — this
is the quantity §Perf tracks.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import Optimizer
from repro.train.steps import TrainState, make_train_step

PyTree = Any


def replicate_for_orbits(tree: PyTree, num_orbits: int) -> PyTree:
    """Add the leading orbit-replica axis R to every leaf."""
    return jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (num_orbits,) + p.shape), tree
    )


def make_fedleo_local_step(
    model, optimizer: Optimizer, grad_clip: Optional[float] = 1.0,
    num_local_steps: int = 1,
) -> Callable:
    """Per-orbit local training: vmap(train_step) over the replica axis.

    state leaves: (R, ...); batch leaves: (R, B_local, ...).
    ``num_local_steps`` > 1 runs tau steps inside one call via lax.scan
    (batch gains a leading tau axis: (R, tau, B_local, ...)).
    """
    train_step = make_train_step(model, optimizer, grad_clip)

    def one_replica(state: TrainState, batches: Dict):
        if num_local_steps == 1:
            batch = jax.tree_util.tree_map(lambda b: b[0], batches)
            return train_step(state, batch)

        def body(st, batch):
            st, metrics = train_step(st, batch)
            return st, metrics

        state, metrics = jax.lax.scan(body, state, batches)
        metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        return state, metrics

    return jax.vmap(one_replica)


def staleness_weights(
    weights: jnp.ndarray,
    staleness_s: jnp.ndarray,
    *,
    power: float = 0.5,
    time_scale_s: float = 3600.0,
) -> jnp.ndarray:
    """Discount replica weights by model staleness (async eq. 12 form):
    w_r / (1 + staleness/scale)^power.  A replica that trained on the
    freshest global model keeps its full sample weight; one acting on an
    hour-old model is discounted by ~2^-power.  Zero staleness returns
    ``weights`` unchanged."""
    age = jnp.maximum(staleness_s, 0.0) / time_scale_s
    return weights / (1.0 + age) ** power


def make_fedleo_aggregate(use_kernel: bool = False) -> Callable:
    """Sink + GS aggregation: weighted mean over the orbit-replica axis.

    weights: (R,) = m_{K_l} / m (eq. 4 over orbit partials; each replica
    already IS the orbit's partial model, eq. 9, because its local data
    parallelism averaged over the in-pod data axis).
    Optimizer state is aggregated the same way (standard local-SGD /
    DiLoCo practice) so replicas restart from a common point.

    ``use_kernel`` routes the reduction through the Pallas
    ``aggregate_flat`` kernel (one fused (R, N) launch over the whole
    pytree; interpret mode off-TPU) — parity-tested against this
    reference path.  An optional ``staleness_s`` (R,) argument discounts
    each replica's weight by its model age (``staleness_weights``)
    before normalizing; None keeps plain eq. (4) weighting.
    """

    def aggregate(
        state: TrainState,
        weights: jnp.ndarray,
        staleness_s: Optional[jnp.ndarray] = None,
    ) -> TrainState:
        if staleness_s is not None:
            weights = staleness_weights(weights, staleness_s)
        w = weights / jnp.sum(weights)
        r = w.shape[0]

        def is_replicated(x) -> bool:
            return x.ndim != 0 and x.shape[0] == r

        def mean_leaf(x):
            if not is_replicated(x):
                return x
            wx = w.reshape((r,) + (1,) * (x.ndim - 1)).astype(jnp.float32)
            m = jnp.sum(wx * x.astype(jnp.float32), axis=0)
            return jnp.broadcast_to(m, x.shape).astype(x.dtype)

        def mean_tree_kernel(tree: PyTree) -> PyTree:
            """One fused kernel launch over every replicated leaf; the
            rest (step counters, scalars) pass through untouched."""
            from repro.kernels.aggregate_ops import aggregate_pytree

            leaves, treedef = jax.tree_util.tree_flatten(tree)
            elig = [i for i, x in enumerate(leaves) if is_replicated(x)]
            if elig:
                agg = aggregate_pytree([leaves[i] for i in elig], w)
                for i, m in zip(elig, agg):
                    x = leaves[i]
                    leaves[i] = jnp.broadcast_to(
                        m, x.shape
                    ).astype(x.dtype)
            return jax.tree_util.tree_unflatten(treedef, leaves)

        if use_kernel:
            agg_params = mean_tree_kernel(state.params)
            agg_opt = mean_tree_kernel(state.opt_state)
        else:
            agg_params = jax.tree_util.tree_map(mean_leaf, state.params)
            agg_opt = jax.tree_util.tree_map(mean_leaf, state.opt_state)
        return TrainState(params=agg_params, opt_state=agg_opt,
                          step=state.step)

    return aggregate
