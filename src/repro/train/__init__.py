"""Training/serving steps for the assigned architectures."""
from repro.train.steps import (
    TrainState,
    make_init_fn,
    make_train_step,
    make_serve_step,
    lm_loss,
)
from repro.train.fedleo_step import (
    make_fedleo_local_step,
    make_fedleo_aggregate,
)

__all__ = [
    "TrainState",
    "make_init_fn",
    "make_train_step",
    "make_serve_step",
    "lm_loss",
    "make_fedleo_local_step",
    "make_fedleo_aggregate",
]
