"""train_step / serve_step builders for every architecture family.

``make_train_step(model, optimizer)`` returns a pure function
  (state, batch) -> (state, metrics)
suitable for jit/pjit lowering with ShapeDtypeStruct inputs (the
multi-pod dry-run path) and for real CPU smoke execution.

Batches are dicts:
  LM:      {"tokens": (B, S) int32, "extra": optional modality embeds}
  enc-dec: {"tokens": (B, S) int32, "source": (B, S_enc, D)}
Decode (serve_step): token (B, 1) + cache + position.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import Optimizer, clip_by_global_norm
from repro.optim.optimizers import apply_updates

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt_state: PyTree
    step: jnp.ndarray


def lm_loss(logits: jnp.ndarray, tokens: jnp.ndarray,
            num_prefix: int = 0) -> jnp.ndarray:
    """Next-token cross-entropy.  logits may include ``num_prefix``
    non-text (vision/audio) positions prepended; they are excluded."""
    if num_prefix:
        logits = logits[:, num_prefix:]
    pred = logits[:, :-1]
    tgt = tokens[:, 1:]
    logp = jax.nn.log_softmax(pred.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def make_init_fn(model) -> Callable:
    def init(rng) -> PyTree:
        return model.init(rng)

    return init


def make_train_step(
    model,
    optimizer: Optimizer,
    grad_clip: Optional[float] = 1.0,
) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    cfg = model.cfg

    def loss_fn(params, batch):
        if cfg.family == "audio":
            logits, aux = model.forward(params, batch["tokens"],
                                        batch["source"])
            num_prefix = 0
        elif cfg.family == "vlm":
            logits, aux = model.forward(params, batch["tokens"],
                                        extra_embeds=batch["extra"])
            num_prefix = batch["extra"].shape[1]
        else:
            logits, aux = model.forward(params, batch["tokens"])
            num_prefix = 0
        loss = lm_loss(logits, batch["tokens"], num_prefix)
        return loss + aux, loss

    def train_step(state: TrainState, batch: Dict):
        (total, ce), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, batch
        )
        if grad_clip is not None:
            grads = clip_by_global_norm(grads, grad_clip)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        params = apply_updates(state.params, updates)
        new_state = TrainState(params=params, opt_state=opt_state,
                               step=state.step + 1)
        return new_state, {"loss": ce, "total_loss": total}

    return train_step


def make_prefill_step(model) -> Callable:
    """Inference prefill: full-sequence forward, logits for the last
    position only (never materializes the (B, S, V) tensor)."""
    cfg = model.cfg

    def prefill_step(params, batch):
        if cfg.family == "audio":
            logits, _ = model.forward(params, batch["tokens"],
                                      batch["source"], last_only=True)
        elif cfg.family == "vlm":
            logits, _ = model.forward(params, batch["tokens"],
                                      extra_embeds=batch["extra"],
                                      last_only=True)
        else:
            logits, _ = model.forward(params, batch["tokens"],
                                      last_only=True)
        return logits[:, -1, :]

    return prefill_step


def make_serve_step(model) -> Callable:
    """Single-token decode: (params, token, cache, position) ->
    (next_token_logits, new_cache)."""

    def serve_step(params, token, cache, position):
        logits, new_cache = model.decode_step(params, token, cache, position)
        return logits[:, -1, :], new_cache

    return serve_step


def make_greedy_decode(model, num_steps: int) -> Callable:
    """Greedy autoregressive loop (lax.scan over serve_step)."""
    serve_step = make_serve_step(model)

    def decode(params, first_token, cache, start_pos):
        def body(carry, _):
            token, cache, pos = carry
            logits, cache = serve_step(params, token, cache, pos)
            nxt = jnp.argmax(logits, axis=-1, keepdims=True).astype(
                token.dtype
            )
            return (nxt, cache, pos + 1), nxt[:, 0]

        (_, cache, _), toks = jax.lax.scan(
            body, (first_token, cache, start_pos), None, length=num_steps
        )
        return jnp.moveaxis(toks, 0, 1), cache

    return decode
