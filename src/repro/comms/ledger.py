"""Per-station downlink resource-block ledger (contention accounting).

The paper's resource model (§IV-B, eqs. 13-16) gives each ground
station ``N`` downlink resource blocks of bandwidth ``B_D = B / N``:
every sink upload occupies ONE RB for the duration of its transfer.
The seed scheduler priced every transfer as if each station were
private to one satellite — under ``FedLEOGrid`` several cluster sinks
can land uploads on the same station's windows, so concurrent uploads
must now *compete* for the station's RB pool.

``GSResourceLedger`` is that shared capacity view: a per-station
timeline of reserved ``[t0, t1)`` occupancy intervals.  The transfer
planner (``core/scheduling.py``) prices every candidate window against
the *residual* capacity — ``earliest_fit`` returns the earliest start
inside a window at which a free RB exists for the whole transfer — and
the strategy reserves the chosen interval, so later transfer decisions
of the same round (and of later rounds; simulated time is monotone)
see the booked capacity.

Semantics:
  * Only sink *uploads* (satellite -> GS over one RB, eq. 16) reserve
    capacity.  The global-model *download* is a GS broadcast of the
    same ``w^t`` over the full uplink band (eq. 15) — simultaneous
    receivers share one transmission, so it is not RB-contended.
  * Occupancy intervals are half-open ``[t0, t1)``: a transfer may
    start at the exact instant another ends.
  * ``capacity=None`` means unlimited — the contention-free degenerate
    case, bit-identical to the pre-ledger planner (``earliest_fit``
    returns ``lo`` untouched).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple, Union

import numpy as np


class GSResourceLedger:
    """Per-station resource-block occupancy timeline.

    Args:
      num_stations: stations indexed by the predictor's ``gs_index``.
      capacity: concurrent-RB cap per station — one int for all, a
        per-station sequence, or None for unlimited (contention-free).
    """

    def __init__(
        self,
        num_stations: int,
        capacity: Union[int, Sequence[int], None],
    ):
        self.num_stations = int(num_stations)
        if capacity is None:
            caps: List[float] = [np.inf] * self.num_stations
        elif np.ndim(capacity) == 0:
            caps = [float(capacity)] * self.num_stations
        else:
            caps = [float(c) for c in capacity]
            if len(caps) != self.num_stations:
                raise ValueError(
                    f"{len(caps)} capacities for {self.num_stations} stations"
                )
        if any(c < 1 for c in caps):
            raise ValueError(f"station capacity must be >= 1, got {caps}")
        self.capacity: Tuple[float, ...] = tuple(caps)
        self._starts: List[List[float]] = [[] for _ in range(self.num_stations)]
        self._ends: List[List[float]] = [[] for _ in range(self.num_stations)]
        # parallel booking ids: the identity handle `reserve` returns,
        # unique across the ledger's lifetime (never reused), so two
        # sessions booking IDENTICAL [t0, t1) intervals on one station
        # stay distinguishable at release time
        self._bids: List[List[int]] = [[] for _ in range(self.num_stations)]
        self._next_bid: int = 0
        # busy-run cache per station: the planner calls earliest_fit
        # once per candidate window, but the ledger only changes at
        # reserve()/release_before() — recompute the sweep lazily
        self._busy: List[Optional[Tuple[np.ndarray, np.ndarray]]] = (
            [None] * self.num_stations
        )

    # -- bookkeeping -----------------------------------------------------------
    def reserve(self, gs_index: int, t0: float, t1: float) -> Optional[int]:
        """Book one RB of station ``gs_index`` over ``[t0, t1)``.

        Returns the booking id identifying THIS booking (hand it back to
        ``release_booking``), or None for zero-length reservations,
        which occupy nothing and need no release.
        """
        if t1 < t0:
            raise ValueError(f"reservation ends before it starts: [{t0}, {t1})")
        if t1 > t0:            # zero-length reservations occupy nothing
            bid = self._next_bid
            self._next_bid += 1
            self._starts[gs_index].append(float(t0))
            self._ends[gs_index].append(float(t1))
            self._bids[gs_index].append(bid)
            self._busy[gs_index] = None
            return bid
        return None

    def reservations(self, gs_index: int) -> Tuple[np.ndarray, np.ndarray]:
        """(starts, ends) of every booked interval, in booking order."""
        return (
            np.asarray(self._starts[gs_index], dtype=np.float64),
            np.asarray(self._ends[gs_index], dtype=np.float64),
        )

    def num_reserved(self) -> int:
        return sum(len(s) for s in self._starts)

    def release_booking(self, gs_index: int, booking_id: int) -> None:
        """Give the booking identified by ``booking_id`` back to the
        pool — the reservation-release half of the lifecycle
        (``CommsEnvironment.release``): freed capacity is visible to
        every later ``earliest_fit``/``free_runs`` query.

        Ids are unique across the ledger, so concurrent sessions that
        booked identical intervals can only ever release their OWN
        booking.  Raises ValueError when the id is not booked on the
        station (double release / never booked).
        """
        bids = self._bids[gs_index]
        try:
            i = bids.index(booking_id)
        except ValueError:
            raise ValueError(
                f"no booking id {booking_id} to release on station {gs_index}"
            ) from None
        del self._starts[gs_index][i]
        del self._ends[gs_index][i]
        del bids[i]
        self._busy[gs_index] = None

    def release(self, gs_index: int, t0: float, t1: float) -> None:
        """DEPRECATED back-compat shim: release the most recent booking
        exactly matching ``[t0, t1)``.  Interval identity is ambiguous
        under multi-tenancy (two sessions can book identical intervals
        on one station) and brittle to float drift in re-priced legs —
        key releases on the id ``reserve`` returned via
        ``release_booking`` instead.  Zero-length intervals were never
        stored and release as a no-op.
        """
        t0, t1 = float(t0), float(t1)
        if t1 <= t0:
            return
        s, e = self._starts[gs_index], self._ends[gs_index]
        for i in range(len(s) - 1, -1, -1):
            if s[i] == t0 and e[i] == t1:
                self.release_booking(gs_index, self._bids[gs_index][i])
                return
        raise ValueError(
            f"no booking [{t0}, {t1}) to release on station {gs_index}"
        )

    def release_before(self, t: float) -> None:
        """Drop intervals that ended at or before ``t`` (the simulated
        clock is monotone, so past bookings can never affect a fit)."""
        for i in range(self.num_stations):
            keep = [
                (a, b, bid)
                for a, b, bid in zip(
                    self._starts[i], self._ends[i], self._bids[i]
                )
                if b > t
            ]
            self._starts[i] = [a for a, _, _ in keep]
            self._ends[i] = [b for _, b, _ in keep]
            self._bids[i] = [bid for _, _, bid in keep]
            self._busy[i] = None

    # -- capacity queries ------------------------------------------------------
    def occupancy(self, gs_index: int, t: float) -> int:
        """Number of RBs of the station busy at instant ``t``."""
        s, e = self.reservations(gs_index)
        return int(np.count_nonzero((s <= t) & (t < e)))

    def busy_intervals(
        self, gs_index: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Maximal ``[a, b)`` intervals where occupancy >= capacity —
        vectorized sweep over the station's reservation events, cached
        between ledger mutations."""
        cached = self._busy[gs_index]
        if cached is not None:
            return cached
        out = self._busy_sweep(gs_index)
        self._busy[gs_index] = out
        return out

    def _busy_sweep(
        self, gs_index: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        cap = self.capacity[gs_index]
        s, e = self.reservations(gs_index)
        if s.size == 0 or not np.isfinite(cap) or s.size < cap:
            z = np.zeros(0)
            return z, z.copy()
        times = np.concatenate([s, e])
        deltas = np.concatenate(
            [np.ones(s.size, dtype=np.int64), -np.ones(e.size, dtype=np.int64)]
        )
        # ends sort before starts at equal times: half-open [t0, t1)
        order = np.lexsort((deltas, times))
        times, occ = times[order], np.cumsum(deltas[order])
        busy = occ >= cap                   # over segment [times[k], times[k+1])
        prev = np.concatenate([[False], busy[:-1]])
        run_start = np.flatnonzero(busy & ~prev)
        run_end = np.searchsorted(
            np.flatnonzero(~busy), run_start, side="left"
        )
        free_idx = np.flatnonzero(~busy)
        # a busy run ends at the first not-busy event after it; cumsum
        # ends at occupancy 0, so a terminal free event always exists
        a = times[run_start]
        b = times[free_idx[run_end]]
        keep = b > a                        # drop zero-length runs
        return a[keep], b[keep]

    def free_runs(
        self, gs_index: int, lo: float, hi: float
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Maximal ``[a, b)`` sub-intervals of ``[lo, hi)`` where at
        least one RB of the station is free (occupancy < capacity), in
        time order — the complement of ``busy_intervals`` clipped to
        the query range.  The segmented (handover) transfer planner
        prices candidate upload legs against these stretches.

        Unlimited capacity returns the whole ``[lo, hi)`` untouched
        (the contention-free degenerate case).
        """
        if hi <= lo:
            z = np.zeros(0)
            return z, z.copy()
        a, b = self.busy_intervals(gs_index)
        starts: List[float] = [float(lo)]
        ends: List[float] = []
        for ba, bb in zip(a, b):        # busy runs are sorted, disjoint
            if bb <= lo or ba >= hi:
                continue
            ends.append(float(max(lo, ba)))
            starts.append(float(min(hi, bb)))
        ends.append(float(hi))
        s = np.asarray(starts, dtype=np.float64)
        e = np.asarray(ends, dtype=np.float64)
        keep = e > s
        return s[keep], e[keep]

    def booked_seconds(self, gs_index: int, t0: float, t1: float) -> float:
        """Total reserved RB-seconds of the station overlapping
        ``[t0, t1]`` (concurrent reservations count multiply)."""
        s, e = self.reservations(gs_index)
        if s.size == 0:
            return 0.0
        ov = np.minimum(e, t1) - np.maximum(s, t0)
        return float(np.sum(ov[ov > 0]))

    def residual_fraction(self, t0: float, t1: float) -> np.ndarray:
        """Per-station fraction of RB capacity still unbooked over
        ``[t0, t1]`` — 1.0 for unlimited stations and empty ledgers
        (the degenerate cases), falling toward 0.0 as a station's RB
        pool saturates.  Cluster formation uses this to discount the
        predicted window supply of stations already loaded this round
        (contention-aware formation feedback)."""
        out = np.ones(self.num_stations, dtype=np.float64)
        span = t1 - t0
        if span <= 0:
            return out
        for i in range(self.num_stations):
            cap = self.capacity[i]
            if not np.isfinite(cap):
                continue
            out[i] = max(
                0.0, 1.0 - self.booked_seconds(i, t0, t1) / (cap * span)
            )
        return out

    def earliest_fit(
        self,
        gs_index: int,
        lo: float,
        hi_start: float,
        duration: float,
    ) -> Optional[float]:
        """Earliest ``t0`` in ``[lo, hi_start]`` such that a free RB
        exists over all of ``[t0, t0 + duration)``, or None.

        With unlimited capacity this is exactly ``lo`` (the pre-ledger
        planner's effective start) whenever ``lo <= hi_start``.
        """
        if lo > hi_start:
            return None
        if not np.isfinite(self.capacity[gs_index]):
            return lo
        a, b = self.busy_intervals(gs_index)
        t0 = float(lo)
        for ba, bb in zip(a, b):
            if bb <= t0:
                continue                    # busy run already over
            if ba >= t0 + duration:
                break                       # transfer fits before this run
            t0 = float(bb)                  # push past the saturated run
        return t0 if t0 <= hi_start else None
