"""Intra-plane inter-satellite-link (ISL) timing (paper eqs. 20-21).

Each ISL hop h between adjacent satellites is allocated one resource
block of bandwidth B_h, with spectral efficiency beta_h:

  t_h(k, k+1) = z|N| / (B_h beta_h)                                  (20)

and the relay time for a model to reach a sink over h hops is
h * z|N| / (B_h beta_h); the per-orbit relay cost is the max over the
relaying satellites (eq. 21).

Note (paper §IV-A): ISLs are physically FSO (Gbps-Tbps), but the paper
deliberately provisions them at RF-comparable rates so that FedLEO's
gains come from the architecture/schedule, not the PHY — we keep that
choice as the default.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ISLConfig:
    hop_bandwidth_hz: float = 125.0e3   # B_h: one RB of B=1 MHz / N=8
    spectral_efficiency: float = 4.0    # beta_h [bit/s/Hz]
    hop_propagation_s: float = 0.0      # chord/c, ~2ms at 1500 km; optional

    @property
    def hop_rate_bps(self) -> float:
        return self.hop_bandwidth_hz * self.spectral_efficiency


def isl_hop_time(cfg: ISLConfig, payload_bits: float) -> float:
    """Eq. (20): single-hop model exchange time between adjacent satellites."""
    return payload_bits / cfg.hop_rate_bps + cfg.hop_propagation_s


def relay_time(cfg: ISLConfig, payload_bits: float, num_hops: int) -> float:
    """Eq. (21) inner term: h-hop store-and-forward relay to the sink."""
    return num_hops * isl_hop_time(cfg, payload_bits)
