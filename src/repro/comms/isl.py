"""Intra-plane inter-satellite-link (ISL) timing (paper eqs. 20-21).

Each ISL hop h between adjacent satellites is allocated one resource
block of bandwidth B_h, with spectral efficiency beta_h:

  t_h(k, k+1) = z|N| / (B_h beta_h)                                  (20)

and the relay time for a model to reach a sink over h hops is
h * z|N| / (B_h beta_h); the per-orbit relay cost is the max over the
relaying satellites (eq. 21).

Note (paper §IV-A): ISLs are physically FSO (Gbps-Tbps), but the paper
deliberately provisions them at RF-comparable rates so that FedLEO's
gains come from the architecture/schedule, not the PHY — we keep that
choice as the default.
"""
from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Any, Optional, Union

if TYPE_CHECKING:
    from repro.orbits.constellation import ConstellationConfig, MultiShellConfig
    from repro.orbits.topology import ISLTopology, TopologyConfig

# Inter-plane cross-links are optical (FSO): provision them at 1 Gbps
# (250 MHz x 4 bit/s/Hz) instead of the paper's deliberately RF-rate
# intra-plane links — the PHY asymmetry the +Grid topology rides on.
FSO_HOP_BANDWIDTH_HZ = 250.0e6
FSO_SPECTRAL_EFFICIENCY = 4.0


@dataclasses.dataclass(frozen=True)
class ISLConfig:
    hop_bandwidth_hz: float = 125.0e3   # B_h: one RB of B=1 MHz / N=8
    spectral_efficiency: float = 4.0    # beta_h [bit/s/Hz]
    hop_propagation_s: float = 0.0      # chord/c, ~2ms at 1500 km; optional

    @property
    def hop_rate_bps(self) -> float:
        return self.hop_bandwidth_hz * self.spectral_efficiency

    @classmethod
    def from_constellation(
        cls,
        constellation: "ConstellationConfig | MultiShellConfig",
        link_type: str = "intra",
        topology: "Optional[Union[ISLTopology, TopologyConfig]]" = None,
        **overrides: Any,
    ) -> "ISLConfig":
        """ISLConfig with the real chord/c propagation delay for this
        constellation's geometry.

        link_type "intra": adjacent same-plane chord 2*R*sin(pi/K), RF
        provisioning (the paper's Table I rates).  link_type "inter":
        mean cross-plane link length of the (+Grid by default) topology,
        FSO provisioning.  ``overrides`` replace any resulting field.
        """
        from repro.orbits.constellation import C_LIGHT, R_EARTH

        fields: dict = {}
        if link_type == "intra":
            K = constellation.sats_per_plane
            radius = R_EARTH + constellation.altitude_m
            chord_m = 2.0 * radius * math.sin(math.pi / K)
        elif link_type == "inter":
            from repro.orbits.topology import (
                INTER,
                ISLTopology,
                TopologyConfig,
                get_isl_topology,
            )

            topo = (
                topology
                if isinstance(topology, ISLTopology)
                else get_isl_topology(
                    constellation, topology or TopologyConfig(kind="grid")
                )
            )
            chord_m = topo.mean_link_length_m(INTER)
            fields.update(
                hop_bandwidth_hz=FSO_HOP_BANDWIDTH_HZ,
                spectral_efficiency=FSO_SPECTRAL_EFFICIENCY,
            )
        else:
            raise ValueError(f"unknown link_type {link_type!r}")
        fields["hop_propagation_s"] = chord_m / C_LIGHT
        fields.update(overrides)
        return cls(**fields)


def isl_hop_time(cfg: ISLConfig, payload_bits: float) -> float:
    """Eq. (20): single-hop model exchange time between adjacent satellites."""
    return payload_bits / cfg.hop_rate_bps + cfg.hop_propagation_s


def relay_time(cfg: ISLConfig, payload_bits: float, num_hops: int) -> float:
    """Eq. (21) inner term: h-hop store-and-forward relay to the sink."""
    return num_hops * isl_hop_time(cfg, payload_bits)
