"""Graph routing over the ISL topology: per-edge link provisioning and
all-pairs relay latencies.

Physically, intra-plane and inter-plane ISLs are different hardware:
the paper provisions intra-plane links at RF-comparable rates (so
FedLEO's gains come from the schedule, not the PHY), while inter-plane
cross-links are optical (Gbps class).  ``ISLPlan`` carries one
``ISLConfig`` per edge type; ``RoutingTable`` turns a topology + plan +
payload into hop/latency matrices that the propagation planner and the
constellation-wide sink scheduler consume.

Latencies are reconstructed from the topology's hop-count decomposition
(``h_intra*t_intra + h_inter*t_inter``) rather than accumulated along
paths, so a topology without inter-plane links yields latencies
bit-identical to the legacy ring arithmetic ``hops * t_hop``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.comms.isl import ISLConfig, isl_hop_time
from repro.orbits.constellation import ConstellationConfig
from repro.orbits.topology import (
    ISLTopology,
    TopologyConfig,
    UNREACHABLE,
    get_isl_topology,
)


@dataclasses.dataclass(frozen=True)
class ISLPlan:
    """Per-edge-type link provisioning."""

    intra: ISLConfig = dataclasses.field(default_factory=ISLConfig)
    inter: Optional[ISLConfig] = None    # None -> same as intra

    @property
    def inter_cfg(self) -> ISLConfig:
        return self.inter if self.inter is not None else self.intra

    def hop_times(self, payload_bits: float) -> Tuple[float, float]:
        """(t_intra, t_inter): single-hop exchange time per edge type."""
        return (
            isl_hop_time(self.intra, payload_bits),
            isl_hop_time(self.inter_cfg, payload_bits),
        )


def flood_times(
    latency: np.ndarray,
    sources: Sequence[int],
    t_source: Sequence[float],
    cols: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Earliest receipt per destination when the model floods the graph
    from one or more sources (duplicates dropped — each node keeps the
    earliest copy, ties to the first listed source).

    The single implementation of the flood arithmetic: the ring
    ``broadcast_schedule`` (via ``graph_broadcast_schedule``) and the
    grid ``RoutingTable.broadcast_times`` both consume it.

    Returns (t_recv, pick) over ``cols`` (default: every column of
    ``latency``); pick[i] indexes ``sources``.
    """
    sources = np.asarray(list(sources), dtype=np.intp)
    t_src = np.asarray(list(t_source), dtype=np.float64)
    lat = latency[sources, :] if cols is None else latency[np.ix_(sources, cols)]
    cand = t_src[:, None] + lat                         # (S, n)
    pick = np.argmin(cand, axis=0)                      # first min wins ties
    return cand[pick, np.arange(cand.shape[1])], pick


def relay_arrivals(
    latency: np.ndarray,
    sink: int,
    t_ready: Sequence[float],
    rows: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Arrival time of each node's model at the sink (store-and-forward
    over the min-latency path; every model pays its full path latency —
    no cut-through)."""
    t_ready = np.asarray(list(t_ready), dtype=np.float64)
    col = latency[:, sink] if rows is None else latency[rows, sink]
    return t_ready + col


class RoutingTable:
    """All-pairs ISL routing metrics for one (topology, plan, payload).

    Attributes:
      hops:    (N, N) int total hop count on the min-latency path
               (UNREACHABLE for disconnected pairs).
      latency: (N, N) float relay seconds along the min-latency path
               (inf for disconnected pairs).
    """

    def __init__(
        self,
        topology: ISLTopology,
        plan: ISLPlan,
        payload_bits: float,
    ):
        self.topology = topology
        self.plan = plan
        self.payload_bits = float(payload_bits)
        t_a, t_b = plan.hop_times(payload_bits)
        self.t_hop_intra, self.t_hop_inter = t_a, t_b
        h_a, h_b = topology.hop_split(t_a, t_b)
        self.hops_intra, self.hops_inter = h_a, h_b
        unreachable = h_a == UNREACHABLE
        self.hops = np.where(unreachable, UNREACHABLE, h_a + h_b)
        self.latency = np.where(
            unreachable, np.inf, h_a * t_a + h_b * t_b
        )

    @property
    def num_nodes(self) -> int:
        return self.topology.num_nodes

    def nodes_of(self, sats: Sequence[Tuple[int, int]]) -> np.ndarray:
        K = self.topology.sats_per_plane
        arr = np.asarray(list(sats), dtype=np.intp).reshape(-1, 2)
        return arr[:, 0] * K + arr[:, 1]

    def submatrix(self, nodes: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(hops, latency) restricted to a node subset — paths may still
        transit nodes outside the subset (ISLs are dedicated links; a
        relay through a neighboring plane costs nothing extra here)."""
        ix = np.ix_(nodes, nodes)
        return self.hops[ix], self.latency[ix]

    # -- flood / relay ---------------------------------------------------------
    def broadcast_times(
        self,
        sources: Sequence[int],
        t_source: Sequence[float],
        nodes: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """``flood_times`` over this table's latency matrix.

        Returns (t_recv, hops, source_index) arrays over ``nodes``
        (default: every node).  Unreachable nodes get inf / UNREACHABLE.
        """
        sources = np.asarray(list(sources), dtype=np.intp)
        cols = (
            np.arange(self.num_nodes) if nodes is None
            else np.asarray(nodes, dtype=np.intp)
        )
        t_recv, pick = flood_times(self.latency, sources, t_source, cols)
        hops = self.hops[sources[pick], cols]
        return t_recv, hops, pick

    def relay_times(
        self,
        sink: int,
        t_ready: Sequence[float],
        nodes: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """``relay_arrivals`` over this table's latency matrix."""
        rows = (
            np.arange(self.num_nodes) if nodes is None
            else np.asarray(nodes, dtype=np.intp)
        )
        return relay_arrivals(self.latency, sink, t_ready, rows)


# cache hit/miss observers (repro.obs wires TraceRecorder counters in
# here); a listener must never raise and must not call back into
# get_routing_table
_CACHE_LISTENERS: List[Callable[[bool], None]] = []


def on_routing_cache(
    callback: Callable[[bool], None],
) -> Callable[[], None]:
    """Register ``callback(hit)`` to observe every ``get_routing_table``
    lookup (True = LRU cache hit).  Returns an unsubscribe function."""
    _CACHE_LISTENERS.append(callback)

    def unsubscribe() -> None:
        if callback in _CACHE_LISTENERS:
            _CACHE_LISTENERS.remove(callback)

    return unsubscribe


@functools.lru_cache(maxsize=32)
def _routing_table_cached(
    constellation: ConstellationConfig,
    topology: TopologyConfig,
    plan: ISLPlan,
    payload_bits: float,
) -> RoutingTable:
    return RoutingTable(
        get_isl_topology(constellation, topology), plan, payload_bits
    )


def get_routing_table(
    constellation: ConstellationConfig,
    topology: TopologyConfig,
    plan: ISLPlan,
    payload_bits: float,
) -> RoutingTable:
    """Cached ``RoutingTable`` per (constellation, topology, plan,
    payload) — every argument is frozen/hashable and the graph is
    static per scenario, so strategies and benchmark arms re-running
    the same topology share one table (and the hop-split computation
    behind it) instead of rebuilding it per run.  The table is
    read-only by convention; callers must not mutate its matrices.
    Registered ``on_routing_cache`` observers see each lookup's
    hit/miss outcome."""
    if not _CACHE_LISTENERS:
        return _routing_table_cached(
            constellation, topology, plan, payload_bits
        )
    before = _routing_table_cached.cache_info().hits
    table = _routing_table_cached(
        constellation, topology, plan, payload_bits
    )
    hit = _routing_table_cached.cache_info().hits > before
    for cb in list(_CACHE_LISTENERS):
        cb(hit)
    return table


# back-compat: expose the underlying LRU controls on the public name
get_routing_table.cache_info = _routing_table_cached.cache_info
get_routing_table.cache_clear = _routing_table_cached.cache_clear
