"""Graph routing over the ISL topology: per-edge link provisioning and
all-pairs relay latencies.

Physically, intra-plane and inter-plane ISLs are different hardware:
the paper provisions intra-plane links at RF-comparable rates (so
FedLEO's gains come from the schedule, not the PHY), while inter-plane
cross-links are optical (Gbps class).  ``ISLPlan`` carries one
``ISLConfig`` per edge type; ``RoutingTable`` turns a topology + plan +
payload into hop/latency matrices that the propagation planner and the
constellation-wide sink scheduler consume.

Latencies are reconstructed from the topology's hop-count decomposition
(``h_intra*t_intra + h_inter*t_inter``) rather than accumulated along
paths, so a topology without inter-plane links yields latencies
bit-identical to the legacy ring arithmetic ``hops * t_hop``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.comms.isl import ISLConfig, isl_hop_time
from repro.orbits.constellation import ConstellationConfig, MultiShellConfig
from repro.orbits.topology import (
    ISLTopology,
    TopologyConfig,
    UNREACHABLE,
    get_isl_topology,
)


@dataclasses.dataclass(frozen=True)
class ISLPlan:
    """Per-edge-type link provisioning."""

    intra: ISLConfig = dataclasses.field(default_factory=ISLConfig)
    inter: Optional[ISLConfig] = None    # None -> same as intra

    @property
    def inter_cfg(self) -> ISLConfig:
        return self.inter if self.inter is not None else self.intra

    def hop_times(self, payload_bits: float) -> Tuple[float, float]:
        """(t_intra, t_inter): single-hop exchange time per edge type."""
        return (
            isl_hop_time(self.intra, payload_bits),
            isl_hop_time(self.inter_cfg, payload_bits),
        )


def flood_times(
    latency: np.ndarray,
    sources: Sequence[int],
    t_source: Sequence[float],
    cols: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Earliest receipt per destination when the model floods the graph
    from one or more sources (duplicates dropped — each node keeps the
    earliest copy, ties to the first listed source).

    The single implementation of the flood arithmetic: the ring
    ``broadcast_schedule`` (via ``graph_broadcast_schedule``) and the
    grid ``RoutingTable.broadcast_times`` both consume it.

    Returns (t_recv, pick) over ``cols`` (default: every column of
    ``latency``); pick[i] indexes ``sources``.
    """
    sources = np.asarray(list(sources), dtype=np.intp)
    t_src = np.asarray(list(t_source), dtype=np.float64)
    lat = latency[sources, :] if cols is None else latency[np.ix_(sources, cols)]
    cand = t_src[:, None] + lat                         # (S, n)
    pick = np.argmin(cand, axis=0)                      # first min wins ties
    return cand[pick, np.arange(cand.shape[1])], pick


def relay_arrivals(
    latency: np.ndarray,
    sink: int,
    t_ready: Sequence[float],
    rows: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Arrival time of each node's model at the sink (store-and-forward
    over the min-latency path; every model pays its full path latency —
    no cut-through)."""
    t_ready = np.asarray(list(t_ready), dtype=np.float64)
    col = latency[:, sink] if rows is None else latency[rows, sink]
    return t_ready + col


class RoutingTable:
    """All-pairs ISL routing metrics for one (topology, plan, payload).

    Attributes:
      hops:    (N, N) int total hop count on the min-latency path
               (UNREACHABLE for disconnected pairs).
      latency: (N, N) float relay seconds along the min-latency path
               (inf for disconnected pairs).

    With ``lazy=True`` the (N, N) matrices are not built up front:
    queries that only touch source rows (``broadcast_times``,
    ``submatrix``, ``relay_times`` via the undirected symmetry
    ``latency[:, sink] == latency[sink, :]``) run per-source Dijkstra
    (``ISLTopology.hop_split_rows``) and cache the rows; directly
    reading ``.hops``/``.latency`` materializes the full matrices on
    first access.  The eager default is byte-for-byte the historical
    behavior.
    """

    _LAZY_ATTRS = ("hops_intra", "hops_inter", "hops", "latency")

    def __init__(
        self,
        topology: ISLTopology,
        plan: ISLPlan,
        payload_bits: float,
        lazy: bool = False,
    ):
        self.topology = topology
        self.plan = plan
        self.payload_bits = float(payload_bits)
        t_a, t_b = plan.hop_times(payload_bits)
        self.t_hop_intra, self.t_hop_inter = t_a, t_b
        self.lazy = bool(lazy)
        self._row_cache: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        if not self.lazy:
            self._materialize()

    def _materialize(self) -> None:
        t_a, t_b = self.t_hop_intra, self.t_hop_inter
        h_a, h_b = self.topology.hop_split(t_a, t_b)
        self.hops_intra, self.hops_inter = h_a, h_b
        unreachable = h_a == UNREACHABLE
        self.hops = np.where(unreachable, UNREACHABLE, h_a + h_b)
        self.latency = np.where(
            unreachable, np.inf, h_a * t_a + h_b * t_b
        )

    def __getattr__(self, name: str) -> np.ndarray:
        # only reached when normal lookup misses: a lazy table's full
        # matrices materialize on first direct access
        if name in RoutingTable._LAZY_ATTRS:
            self._materialize()
            return getattr(self, name)
        raise AttributeError(name)

    @property
    def materialized(self) -> bool:
        """True once the full (N, N) matrices exist."""
        return "latency" in self.__dict__

    def _row_metrics(
        self, sources: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(hops, latency) rows (S, N) for the given source nodes.

        Eager (or already-materialized) tables slice the full matrices
        — bit-identical to historical behavior; lazy tables run
        per-source Dijkstra and cache each row.
        """
        src = np.asarray(sources, dtype=np.intp)
        if self.materialized:
            return self.hops[src], self.latency[src]
        missing = [int(s) for s in src if int(s) not in self._row_cache]
        if missing:
            r_a, r_b = self.topology.hop_split_rows(
                np.asarray(missing, dtype=np.intp),
                self.t_hop_intra,
                self.t_hop_inter,
            )
            for k, s in enumerate(missing):
                self._row_cache[s] = (r_a[k], r_b[k])
        h_a = np.stack([self._row_cache[int(s)][0] for s in src])
        h_b = np.stack([self._row_cache[int(s)][1] for s in src])
        unreachable = h_a == UNREACHABLE
        hops = np.where(unreachable, UNREACHABLE, h_a + h_b)
        lat = np.where(
            unreachable,
            np.inf,
            h_a * self.t_hop_intra + h_b * self.t_hop_inter,
        )
        return hops, lat

    @property
    def num_nodes(self) -> int:
        return self.topology.num_nodes

    def nodes_of(self, sats: Sequence[Tuple[int, int]]) -> np.ndarray:
        K = self.topology.sats_per_plane
        arr = np.asarray(list(sats), dtype=np.intp).reshape(-1, 2)
        return arr[:, 0] * K + arr[:, 1]

    def submatrix(self, nodes: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(hops, latency) restricted to a node subset — paths may still
        transit nodes outside the subset (ISLs are dedicated links; a
        relay through a neighboring plane costs nothing extra here)."""
        nodes = np.asarray(nodes, dtype=np.intp)
        if not self.materialized:
            hops_rows, lat_rows = self._row_metrics(nodes)
            return hops_rows[:, nodes], lat_rows[:, nodes]
        ix = np.ix_(nodes, nodes)
        return self.hops[ix], self.latency[ix]

    # -- flood / relay ---------------------------------------------------------
    def broadcast_times(
        self,
        sources: Sequence[int],
        t_source: Sequence[float],
        nodes: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """``flood_times`` over this table's latency matrix.

        Returns (t_recv, hops, source_index) arrays over ``nodes``
        (default: every node).  Unreachable nodes get inf / UNREACHABLE.
        """
        sources = np.asarray(list(sources), dtype=np.intp)
        cols = (
            np.arange(self.num_nodes) if nodes is None
            else np.asarray(nodes, dtype=np.intp)
        )
        hops_rows, lat_rows = self._row_metrics(sources)
        t_recv, pick = flood_times(
            lat_rows, np.arange(sources.size), t_source, cols
        )
        hops = hops_rows[pick, cols]
        return t_recv, hops, pick

    def relay_times(
        self,
        sink: int,
        t_ready: Sequence[float],
        nodes: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """``relay_arrivals`` over this table's latency matrix."""
        rows = (
            np.arange(self.num_nodes) if nodes is None
            else np.asarray(nodes, dtype=np.intp)
        )
        if not self.materialized:
            # undirected graph: latency[:, sink] == latency[sink, :]
            _, lat_sink = self._row_metrics(np.asarray([sink]))
            t_arr = np.asarray(list(t_ready), dtype=np.float64)
            return t_arr + lat_sink[0, rows]
        return relay_arrivals(self.latency, sink, t_ready, rows)


# Node count at which the planners' auto mode switches to lazy
# per-source routing rows: a 40x22 table (880 nodes, ~6 MB of float64
# per matrix) is cheap to materialize, while starlink-gen1 (1584) and
# beyond pay real memory and hop-split time for all-pairs matrices a
# planning round never fully reads.
LAZY_AUTO_NODE_THRESHOLD = 1024


def resolve_lazy_routing(
    constellation: "ConstellationConfig | MultiShellConfig",
    lazy: Optional[bool] = None,
) -> bool:
    """The planners' lazy-routing choice: an explicit ``lazy`` wins;
    None means auto — lazy at mega-scale (>= ``LAZY_AUTO_NODE_THRESHOLD``
    satellites), eager below it.  Lazy and eager tables answer every
    query identically (row-sliced vs. matrix-sliced of the same
    Dijkstra), so the planners' schedules do not depend on the choice
    (equivalence-tested)."""
    if lazy is not None:
        return bool(lazy)
    return constellation.num_satellites >= LAZY_AUTO_NODE_THRESHOLD


# cache hit/miss observers (repro.obs wires TraceRecorder counters in
# here); a listener must never raise and must not call back into
# get_routing_table
_CACHE_LISTENERS: List[Callable[[bool], None]] = []


def on_routing_cache(
    callback: Callable[[bool], None],
) -> Callable[[], None]:
    """Register ``callback(hit)`` to observe every ``get_routing_table``
    lookup (True = LRU cache hit).  Returns an unsubscribe function."""
    _CACHE_LISTENERS.append(callback)

    def unsubscribe() -> None:
        if callback in _CACHE_LISTENERS:
            _CACHE_LISTENERS.remove(callback)

    return unsubscribe


@functools.lru_cache(maxsize=32)
def _routing_table_cached(
    constellation: "ConstellationConfig | MultiShellConfig",
    topology: TopologyConfig,
    plan: ISLPlan,
    payload_bits: float,
    lazy: bool = False,
) -> RoutingTable:
    return RoutingTable(
        get_isl_topology(constellation, topology), plan, payload_bits,
        lazy=lazy,
    )


def get_routing_table(
    constellation: "ConstellationConfig | MultiShellConfig",
    topology: TopologyConfig,
    plan: ISLPlan,
    payload_bits: float,
    lazy: bool = False,
) -> RoutingTable:
    """Cached ``RoutingTable`` per (constellation, topology, plan,
    payload) — every argument is frozen/hashable and the graph is
    static per scenario, so strategies and benchmark arms re-running
    the same topology share one table (and the hop-split computation
    behind it) instead of rebuilding it per run.  The table is
    read-only by convention; callers must not mutate its matrices.
    Registered ``on_routing_cache`` observers see each lookup's
    hit/miss outcome.  ``lazy=True`` defers the (N, N) matrices to
    per-source rows (see ``RoutingTable``) and caches separately from
    the eager table."""
    if not _CACHE_LISTENERS:
        return _routing_table_cached(
            constellation, topology, plan, payload_bits, lazy
        )
    before = _routing_table_cached.cache_info().hits
    table = _routing_table_cached(
        constellation, topology, plan, payload_bits, lazy
    )
    hit = _routing_table_cached.cache_info().hits > before
    for cb in list(_CACHE_LISTENERS):
        cb(hit)
    return table


# back-compat: expose the underlying LRU controls on the public name
get_routing_table.cache_info = _routing_table_cached.cache_info
get_routing_table.cache_clear = _routing_table_cached.cache_clear
