"""CommsEnvironment: the scheduling *session* of the simulation.

The paper's scheduler (eqs. 13-22) grew across PRs 1-4 as free
functions in ``core/scheduling.py`` that each re-thread the same
``(walker, predictor, gs, ledger, handover, ...)`` tuple through every
strategy; ``core/baselines.py`` even carried a ``_SELF_LEDGER``
sentinel to guess which ledger a call meant.  Nothing *owned* the
reservations, so nothing could observe a release and re-plan — the
structural blocker for ledger-aware async re-admission (FedSpace,
So et al. 2022; AsyncFLEO, Elmahallawy & Luo 2024: asynchronous LEO FL
hinges on re-pricing queued uploads as link state changes).

``CommsEnvironment`` is the stateful session that owns those parts:

  * the ``VisibilityPredictor`` (access-window table, rolling horizon),
  * the ``GSResourceLedger`` (per-station RB occupancy),
  * the link/ISL budgets and the station-handover policy,

constructed once per simulation (``CommsEnvironment.from_sim``) and
shared by every planning call of a strategy.  The API:

  planning    ``plan_upload`` / ``plan_download`` -> TransferDecision,
              ``select_sink`` / ``select_sink_cluster``,
              ``first_visible_download(_sats)``, ``naive_sink_slot``
  lifecycle   ``commit(decision) -> Reservation``,
              ``release(reservation, at=...)`` — frees the booked RB
              intervals and fires every registered ``on_release``
              callback, and
  events      ``on_release(callback)`` — observe capacity releases;
              ``readmit(pending, t_now)`` — the event-driven async
              re-admission engine built on top of them.

The planners only *read* residual capacity; ``commit`` is the one
booking rule (per-leg intervals for segmented uploads).  All methods
delegate to the same private machinery in ``core/scheduling.py`` that
the legacy free functions now shim, so an environment-planned schedule
is bit-identical to the pre-session scheduler when no release events
fire (equivalence-tested).
"""
from __future__ import annotations

import dataclasses
from typing import (
    TYPE_CHECKING,
    Any,
    Callable,
    FrozenSet,
    List,
    Optional,
    Sequence,
    Tuple,
)

import numpy as np

from repro.comms.isl import ISLConfig, isl_hop_time
from repro.comms.ledger import GSResourceLedger
from repro.comms.link import LinkConfig, downlink_time, uplink_time
from repro.orbits.constellation import (
    GroundStation,
    MultiShellWalker,
    Satellite,
    WalkerDelta,
    make_walker,
)
from repro.orbits.prediction import (
    GroundStations,
    VisibilityPredictor,
    as_gs_list,
)
from repro.orbits.visibility import DEFAULT_MEM_BUDGET_MB, VisibilityWindow

if TYPE_CHECKING:
    from repro.analysis.sanitizer import ScheduleSanitizer, Violation
    from repro.core.engine import SimConfig
    from repro.obs.trace import TraceRecorder
    from repro.core.scheduling import (
        ClusterSinkDecision,
        HandoverSpec,
        SinkDecision,
    )

_UNSET: Any = object()

# (gs_index, slant_range_m) -> (window seconds needed, transfer seconds)
TransferTime = Callable[[int, float], Tuple[float, float]]
# window predicate: True = exclude this window from the search
SkipWindow = Optional[Callable[[VisibilityWindow], bool]]


def _sched() -> Any:
    """Lazy handle on ``repro.core.scheduling`` (the shared planning
    machinery).  Imported at call time: the core modules import this
    module at their top level, so a module-level import here would be
    circular."""
    from repro.core import scheduling

    return scheduling


# --- typed decisions / reservations -------------------------------------------
Leg = Tuple[int, float, float]          # (gs_index, t_start, t_end)


@dataclasses.dataclass(frozen=True)
class TransferDecision:
    """One planned point-to-point transfer: start, completion, the
    access window it rides (the first leg's window when the upload was
    split across station handovers) and the handover legs (empty for a
    single-window transfer).  ``direction`` is "up" (satellite -> GS,
    RB-contended) or "down" (GS broadcast, never contended)."""

    direction: str
    t_start: float
    t_done: float
    window: VisibilityWindow
    segments: Tuple[Any, ...] = ()      # TransferSegment legs
    payload_bits: Optional[float] = None

    @property
    def legs(self) -> Tuple[Leg, ...]:
        """The RB intervals this transfer occupies when committed —
        one per handover leg, or the single ``[t_start, t_done)`` span.
        Downloads are full-band broadcasts (eq. 15) and occupy none."""
        if self.direction != "up":
            return ()
        if self.segments:
            return tuple(
                (s.gs_index, s.t_start, s.t_end) for s in self.segments
            )
        return ((self.window.gs_index, self.t_start, self.t_done),)


@dataclasses.dataclass
class Reservation:
    """A committed booking: the ledger intervals one decision occupies.
    Handed back to ``release`` to free the capacity again.

    ``bids`` are the per-leg ledger booking ids (None for legs that
    occupied nothing — downloads, no ledger): under multi-tenancy two
    sessions can book IDENTICAL intervals on one shared station, so
    releases are keyed on id, never on interval equality."""

    rid: int
    legs: Tuple[Leg, ...]
    decision: Any = None
    released: bool = False
    bids: Tuple[Optional[int], ...] = ()


@dataclasses.dataclass(frozen=True)
class PendingUpload:
    """One queued (planned + committed, not yet transmitted) upload of
    an asynchronous strategy — the unit ``readmit`` re-prices."""

    key: Any                    # caller's identity (client id, plane, ...)
    sat: Satellite
    t_ready: float              # model ready for upload (absolute s)
    payload_bits: float
    decision: TransferDecision
    reservation: Reservation


def _decision_legs(decision: Any) -> Tuple[Leg, ...]:
    """Booked intervals of any decision type: ``TransferDecision``
    (its ``legs``), or a ``SinkDecision``/``ClusterSinkDecision``
    (per-segment legs, else the single upload span)."""
    if isinstance(decision, TransferDecision):
        return decision.legs
    segments = getattr(decision, "segments", ())
    if segments:
        return tuple((s.gs_index, s.t_start, s.t_end) for s in segments)
    return (
        (
            decision.window.gs_index,
            decision.t_upload_start,
            decision.t_upload_done,
        ),
    )


class CommsEnvironment:
    """Stateful scheduling session: predictor + ledger + link budgets +
    handover policy behind one typed planning/booking API.

    Args:
      walker: the constellation geometry.
      predictor: the access-window table — THE authority on the ground
        segment (every window carries its station's ``gs_index``).
      link: GS link budget (required by the upload/download/sink
        planners; may be None for a bare transfer-planning session).
      isl: intra-plane ISL budget (ring hop metric of ``select_sink``).
      ledger: shared per-station RB occupancy, or None for the
        contention-free degenerate case.
      handover: default mid-window station-handover policy
        (``SimConfig.gs_handover``); per-call override available.
      gs: optional ground station(s) the caller *believes* the session
        covers — validated against the predictor's ground segment (the
        check formerly duplicated at every free-function entry point).
    """

    def __init__(
        self,
        *,
        walker: "WalkerDelta | MultiShellWalker",
        predictor: VisibilityPredictor,
        link: Optional[LinkConfig] = None,
        isl: Optional[ISLConfig] = None,
        ledger: Optional[GSResourceLedger] = None,
        handover: bool = False,
        gs: Optional[GroundStations] = None,
    ):
        if gs is not None:
            assert tuple(as_gs_list(gs)) == predictor.ground_stations, \
                "predictor was built over a different ground segment"
        if ledger is not None and ledger.num_stations != len(
            predictor.ground_stations
        ):
            raise ValueError(
                f"ledger covers {ledger.num_stations} stations, predictor "
                f"{len(predictor.ground_stations)}"
            )
        self.walker = walker
        self.predictor = predictor
        self.link = link
        self.isl = isl
        self.ledger = ledger
        self.handover = bool(handover)
        self._release_listeners: List[Callable] = []
        self._commit_listeners: List[Callable] = []
        self._next_rid = 0
        # multi-tenant job label: set by ``derive(job=...)`` (the
        # JobScheduler's per-job sessions) so the sanitizer and trace
        # recorder can attribute bookings/leaks to the owning job.
        # None for single-tenant sessions — every hook site treats
        # None as "no tag" and stays bit-identical.
        self.job: Optional[str] = None
        # invariant checker (repro.analysis.sanitizer), installed by
        # from_sim/derive(sanitize=True) or ScheduleSanitizer.attach
        self.sanitizer: Optional["ScheduleSanitizer"] = None
        # observability recorder (repro.obs), installed by
        # from_sim(trace=True)/derive(trace=True) or
        # TraceRecorder.attach.  Read-only observer: every hook site
        # guards on None, so the untraced path pays one branch and the
        # traced path stays bit-identical (the recorder never mutates
        # scheduling state).
        self.recorder: Optional["TraceRecorder"] = None

    @classmethod
    def from_sim(cls, sim: "SimConfig",
                 walker: "WalkerDelta | MultiShellWalker | None" = None
                 ) -> "CommsEnvironment":
        """The session of one ``SimConfig``: predictor over the sim's
        ground segment (rolling when ``rolling_horizon_hours`` is set),
        a shared RB ledger when ``gs_rb_capacity`` caps station
        capacity, and the sim's handover policy."""
        if walker is None:
            walker = make_walker(sim.constellation)
        gs_list = list(sim.all_ground_stations)
        max_horizon_s = sim.horizon_hours * 3600.0 * 1.5
        mem_budget_mb = getattr(sim, "mem_budget_mb", DEFAULT_MEM_BUDGET_MB)
        if sim.rolling_horizon_hours is not None:
            predictor = VisibilityPredictor(
                walker,
                gs_list,
                horizon_s=sim.rolling_horizon_hours * 3600.0,
                coarse_step_s=sim.coarse_step_s,
                rolling=True,
                max_horizon_s=max_horizon_s,
                mem_budget_mb=mem_budget_mb,
            )
        else:
            predictor = VisibilityPredictor(
                walker, gs_list, horizon_s=max_horizon_s,
                coarse_step_s=sim.coarse_step_s,
                mem_budget_mb=mem_budget_mb,
            )
        ledger = (
            GSResourceLedger(len(gs_list), sim.gs_rb_capacity)
            if sim.gs_rb_capacity is not None else None
        )
        env = cls(
            walker=walker, predictor=predictor, link=sim.link, isl=sim.isl,
            ledger=ledger, handover=sim.gs_handover, gs=gs_list,
        )
        if getattr(sim, "sanitize", False):
            from repro.analysis.sanitizer import ScheduleSanitizer

            ScheduleSanitizer.attach(env)
        if getattr(sim, "trace", False):
            from repro.obs.trace import TraceRecorder

            TraceRecorder.attach(env)
        return env

    @property
    def ground_stations(self) -> Tuple[GroundStation, ...]:
        return self.predictor.ground_stations

    def derive(self, *, ledger: Any = _UNSET, handover: Any = _UNSET,
               link: Any = _UNSET, isl: Any = _UNSET,
               sanitize: bool = False,
               trace: bool = False,
               job: Optional[str] = None) -> "CommsEnvironment":
        """Sibling session sharing this one's walker/predictor/budgets
        but with its OWN booking state: by default the new session gets
        a fresh, empty ledger of the parent's capacity (no ledger stays
        no ledger), so derived arms never see each other's bookings —
        how benchmarks price the same window table under different
        contention regimes.  Pass ``ledger=...`` to override — the
        multi-tenant JobScheduler passes the SHARED ledger so every
        job's session competes for the same RB pools (booking ids keep
        identical intervals distinguishable).  ``sanitize=True``
        attaches a fresh ``ScheduleSanitizer``; ``trace=True`` a fresh
        ``TraceRecorder`` (detach it before reusing the shared
        predictor untraced); ``job`` labels the session for per-job
        leak attribution and trace tagging."""
        if ledger is _UNSET:
            ledger = (
                GSResourceLedger(self.ledger.num_stations,
                                 self.ledger.capacity)
                if self.ledger is not None else None
            )
        env = CommsEnvironment(
            walker=self.walker,
            predictor=self.predictor,
            link=self.link if link is _UNSET else link,
            isl=self.isl if isl is _UNSET else isl,
            ledger=ledger,
            handover=self.handover if handover is _UNSET else handover,
        )
        env.job = job
        if sanitize:
            from repro.analysis.sanitizer import ScheduleSanitizer

            ScheduleSanitizer.attach(env)
        if trace:
            from repro.obs.trace import TraceRecorder

            TraceRecorder.attach(env)
        return env

    # -- transfer planning -----------------------------------------------------
    def plan_transfer(
        self,
        *,
        sat: Satellite,
        t: float,
        transfer_time: TransferTime,    # (gs_index, distance) -> (need, done)
        skip_window: SkipWindow = None,
        handover_spec: Optional["HandoverSpec"] = None,
        contended: bool = True,
    ) -> Optional[Tuple]:
        """Generic earliest-completing transfer of one satellite after
        ``t`` against this session's window table and (when
        ``contended``) residual RB capacity — the raw tuple surface the
        legacy ``earliest_transfer`` shim exposes.  Prefer
        ``plan_upload``/``plan_download``."""
        S = _sched()
        return S._earliest_transfer_impl(
            walker=self.walker, predictor=self.predictor, sat=sat, t=t,
            transfer_time=transfer_time, skip_window=skip_window,
            ledger=self.ledger if contended else None,
            handover=handover_spec,
        )

    def plan_upload(
        self,
        sat: Satellite,
        t_ready: float,
        payload_bits: float,
        *,
        skip_window: SkipWindow = None,
        handover: Optional[bool] = None,
    ) -> Optional[TransferDecision]:
        """Earliest-completing sink upload (one RB, eq. 16) after
        ``t_ready``: priced against residual station capacity and — per
        the session's handover policy — raced against a segmented
        station-switching plan.  Plan only; ``commit`` books it."""
        S = _sched()
        assert self.link is not None, "session has no GS link budget"
        tt = S.symmetric_transfer(downlink_time, self.link, payload_bits)
        use_handover = self.handover if handover is None else handover
        spec = (
            S.HandoverSpec(self.link, payload_bits) if use_handover else None
        )
        hit = self.plan_transfer(
            sat=sat, t=t_ready, transfer_time=tt, skip_window=skip_window,
            handover_spec=spec,
        )
        if hit is None:
            decision = None
        else:
            if spec is not None:
                t0, t_done, w, segments = hit
            else:
                t0, t_done, w = hit
                segments = ()
            decision = TransferDecision(
                "up", t0, t_done, w, tuple(segments),
                payload_bits=float(payload_bits),
            )
        if self.recorder is not None:
            self.recorder.on_plan("up", sat, t_ready, decision)
        return decision

    def plan_download(
        self,
        sat: Satellite,
        t: float,
        payload_bits: float,
        *,
        skip_window: SkipWindow = None,
    ) -> Optional[TransferDecision]:
        """Earliest-completing global-model download after ``t``: a
        full-band GS broadcast (eq. 15) — never RB-contended, never
        segmented."""
        S = _sched()
        assert self.link is not None, "session has no GS link budget"
        tt = S.symmetric_transfer(uplink_time, self.link, payload_bits)
        hit = self.plan_transfer(
            sat=sat, t=t, transfer_time=tt, skip_window=skip_window,
            contended=False,
        )
        if hit is None:
            decision = None
        else:
            t0, t_done, w = hit
            decision = TransferDecision(
                "down", t0, t_done, w, payload_bits=float(payload_bits)
            )
        if self.recorder is not None:
            self.recorder.on_plan("down", sat, t, decision)
        return decision

    # -- sink selection --------------------------------------------------------
    def select_sink(
        self,
        *,
        plane: int,
        t_train_done: Sequence[float],
        payload_bits: float,
        require_next_download: bool = False,
        isl: Optional[ISLConfig] = None,
        handover: Optional[bool] = None,
    ) -> Optional["SinkDecision"]:
        """Deterministic sink selection for one orbital plane (eqs.
        21-22 with the ring hop metric) — ``SinkDecision`` or None."""
        S = _sched()
        isl = isl if isl is not None else self.isl
        assert isl is not None, "session has no intra-plane ISL budget"
        K = self.walker.config.sats_per_plane
        t_hop = isl_hop_time(isl, payload_bits)
        cd = self.select_sink_cluster(
            sats=[(plane, s) for s in range(K)],
            relay_latency=S.ring_hops_matrix(K) * t_hop,
            t_train_done=t_train_done, payload_bits=payload_bits,
            require_next_download=require_next_download, handover=handover,
        )
        if cd is None:
            return None
        return S.SinkDecision(
            plane=plane,
            sink_slot=cd.sink.slot,
            window=cd.window,
            t_models_at_sink=cd.t_models_at_sink,
            t_upload_start=cd.t_upload_start,
            t_upload_done=cd.t_upload_done,
            t_wait=cd.t_wait,
            candidates_considered=cd.candidates_considered,
            segments=cd.segments,
            payload_bits=cd.payload_bits,
        )

    def select_sink_cluster(
        self,
        *,
        sats: Sequence[Tuple[int, int]],
        relay_latency: np.ndarray,
        t_train_done: Sequence[float],
        payload_bits: float,
        require_next_download: bool = False,
        handover: Optional[bool] = None,
    ) -> Optional["ClusterSinkDecision"]:
        """Constellation-wide sink selection over an arbitrary satellite
        set (eq. 21/22 over a relay-latency matrix) —
        ``ClusterSinkDecision`` or None."""
        S = _sched()
        assert self.link is not None, "session has no GS link budget"
        return S._select_sink_cluster_impl(
            walker=self.walker, predictor=self.predictor, link=self.link,
            sats=sats, relay_latency=relay_latency,
            t_train_done=t_train_done, payload_bits=payload_bits,
            require_next_download=require_next_download, ledger=self.ledger,
            handover=self.handover if handover is None else handover,
        )

    def naive_sink_slot(self, plane: int, t_ready: float) -> Optional[int]:
        """The naive-sink ablation's slot choice (first visitor after
        ``t_ready``, window duration ignored)."""
        return _sched()._naive_sink_slot_impl(self.predictor, plane, t_ready)

    def first_visible_download(
        self, plane: int, t: float, payload_bits: float
    ) -> Optional[tuple]:
        """Earliest (slot, t_received) at which ANY satellite of the
        plane can finish downloading w^t after ``t`` (§IV-A step 1)."""
        K = self.walker.config.sats_per_plane
        return self.first_visible_download_sats(
            [(plane, s) for s in range(K)], t, payload_bits
        )

    def first_visible_download_sats(
        self, sats: Sequence[Tuple[int, int]], t: float, payload_bits: float
    ) -> Optional[tuple]:
        """Earliest (index into ``sats``, t_received) download over an
        arbitrary satellite set (a cluster of planes)."""
        S = _sched()
        assert self.link is not None, "session has no GS link budget"
        return S._first_visible_download_sats_impl(
            walker=self.walker, predictor=self.predictor, link=self.link,
            sats=sats, t=t, payload_bits=payload_bits,
        )

    # -- reservation lifecycle -------------------------------------------------
    def set_rid_base(self, base: int) -> None:
        """Namespace this session's reservation ids from ``base``.
        Concurrent sessions over one shared ledger each count rids from
        0 by default; the multi-tenant scheduler gives every job
        session a disjoint range so merged traces and cross-session
        tooling never conflate two jobs' bookings.  Must be called
        before the first commit."""
        if self._next_rid != 0:
            raise ValueError(
                "rid base must be set before the session's first commit"
            )
        self._next_rid = int(base)

    def commit(self, decision: Any) -> Reservation:
        """Book one chosen decision on the session ledger — each
        handover leg on its own station for exactly the leg span, or
        the single upload interval — and return the ``Reservation``
        that ``release`` takes back.  THE one booking rule; without a
        ledger (or for downloads) the reservation carries its legs but
        occupies nothing."""
        legs = _decision_legs(decision)
        self._next_rid += 1
        reservation = Reservation(
            rid=self._next_rid, legs=legs, decision=decision
        )
        if self.sanitizer is not None:
            # validate BEFORE booking: a strict sanitizer rejects the
            # decision with the ledger untouched
            self.sanitizer.observe_commit(reservation)
        if self.ledger is not None:
            reservation.bids = tuple(
                self.ledger.reserve(gi, t0, t1) for gi, t0, t1 in legs
            )
        if self.recorder is not None:
            # record AFTER booking: a sanitizer-rejected commit leaves
            # no trace event
            self.recorder.on_commit(reservation)
        for cb in list(self._commit_listeners):
            cb(reservation)
        return reservation

    def release(
        self, reservation: Reservation, at: Optional[float] = None
    ) -> Tuple[Leg, ...]:
        """Give a committed reservation's capacity back to the ledger
        and fire every registered ``on_release`` callback with the
        freed intervals.

        ``at=None`` frees every leg in full.  With ``at``, only the
        part from ``at`` on is freed: legs already over keep their
        booking (the RB was truly spent), a straddling leg is truncated
        to ``[t0, at)``.  Double release is a no-op.  Returns the freed
        ``(gs_index, t0, t1)`` intervals."""
        if reservation.released:
            return ()
        freed: List[Leg] = []
        kept: List[Leg] = []
        kept_bids: List[Optional[int]] = []
        # legacy reservations built by hand (tests, external callers)
        # carry no booking ids — fall back to the deprecated
        # interval-matched release for those legs only
        bids: Tuple[Optional[int], ...] = reservation.bids
        if len(bids) != len(reservation.legs):
            bids = (None,) * len(reservation.legs)
        for (gi, t0, t1), bid in zip(reservation.legs, bids):
            if at is not None and t1 <= at:
                kept.append((gi, t0, t1))       # already transmitted
                kept_bids.append(bid)
                continue
            f0 = t0 if at is None else max(t0, at)
            head_bid: Optional[int] = None
            if self.ledger is not None:
                if bid is not None:
                    self.ledger.release_booking(gi, bid)
                else:
                    self.ledger.release(gi, t0, t1)
                if f0 > t0:                     # keep the spent head
                    head_bid = self.ledger.reserve(gi, t0, f0)
            if f0 > t0:
                kept.append((gi, t0, f0))
                kept_bids.append(head_bid)
            freed.append((gi, f0, t1))
        reservation.legs = tuple(kept)
        reservation.bids = tuple(kept_bids)
        reservation.released = True
        if self.sanitizer is not None:
            self.sanitizer.observe_release(reservation, tuple(freed))
        if self.recorder is not None and freed:
            self.recorder.on_release(reservation, tuple(freed))
        if freed and self.ledger is not None:
            for cb in list(self._release_listeners):
                cb(reservation, tuple(freed))
        return tuple(freed)

    def release_before(self, t: float) -> None:
        """Drop bookings that ended at or before ``t`` (the simulated
        clock is monotone; past intervals can never affect a fit).
        Deliberately does NOT fire ``on_release`` — expiring into the
        past frees no *future* capacity to re-plan against."""
        if self.ledger is not None:
            self.ledger.release_before(t)

    def on_release(self, callback: Callable) -> Callable[[], None]:
        """Register ``callback(reservation, freed_legs)`` to run on
        every capacity release; returns an unsubscribe function."""
        self._release_listeners.append(callback)

        def unsubscribe() -> None:
            if callback in self._release_listeners:
                self._release_listeners.remove(callback)

        return unsubscribe

    def on_commit(self, callback: Callable) -> Callable[[], None]:
        """Register ``callback(reservation)`` to run after every
        committed booking; returns an unsubscribe function.  The
        multi-tenant fair scheduler meters each job's consumed
        RB-seconds through this hook."""
        self._commit_listeners.append(callback)

        def unsubscribe() -> None:
            if callback in self._commit_listeners:
                self._commit_listeners.remove(callback)

        return unsubscribe

    # -- event-driven async re-admission --------------------------------------
    def readmit(
        self,
        pending: Sequence[PendingUpload],
        t_now: float,
        policy: str = "monotone",
    ) -> Tuple[List[PendingUpload], int]:
        """Re-admit queued uploads after their reservations release.

        Async strategies book every upload at schedule time — under
        scarce RB capacity a queued upload sits wherever the booking
        order left it, even after an earlier reservation (or handover
        leg) releases the capacity that blocked it.  ``readmit`` runs
        the event-driven repair: in model-ready order, each queued
        upload's own reservation is released, the upload is re-priced
        against everything else still booked (the freed capacity now
        visible), and the new plan is ADOPTED only when it completes
        strictly earlier — otherwise the original booking is restored
        verbatim (its slot is provably still free: only its own
        reservation was out).  Every adoption releases that upload's
        old slot in turn — each release firing the ``on_release`` hooks
        — so improvements cascade; passes repeat until a full pass
        adopts nothing.

        Per-entry monotonicity makes the repair safe by construction:
        no upload ever completes later than its original booking, so
        neither the queued makespan nor any single completion can
        regress (the same adopt-only-if-strictly-better discipline as
        the segmented handover planner).  Uploads already transmitting
        (``t_start <= t_now``) are never touched; with no ledger this
        is a no-op and schedules stay bit-identical.

        ``policy="repack"`` layers a regret-based, swap-accepting
        global re-packer ON TOP of the monotone repair: per-entry
        repair is a local optimum of the admission order, so after it
        dries up the re-packer tries ORDER swaps — for an
        admission-ordered pair, both bookings come out and the later
        entry prices FIRST.  A swap is adopted only when neither new
        completion regresses its post-monotone value (the floor) and
        at least one strictly improves; otherwise both original
        bookings are restored verbatim (their slots are provably still
        free — only those two reservations were out).  Pairs are tried
        in descending combined regret (committed completion minus the
        entry's contention-free ideal — how much contention costs it),
        and every adopted swap re-runs the monotone cascade, so the
        monotone result remains a per-entry floor: no queued completion
        may regress vs. the pure monotone pass.

        Returns ``(updated pending, number of re-priced uploads)``;
        the updated list preserves the input order.
        """
        if policy not in ("monotone", "repack"):
            raise ValueError(f"unknown readmit policy {policy!r}")
        pending = list(pending)
        if self.ledger is None:
            return pending, 0
        before = [(p.key, p.decision.t_done) for p in pending]
        # model-ready order, stable on the original admission order
        order = sorted(
            range(len(pending)), key=lambda i: (pending[i].t_ready, i)
        )
        repriced = 0
        while True:
            improved = True
            while improved:         # adoptions strictly shrink some
                improved = False    # completion: passes terminate
                for i in order:
                    p = pending[i]
                    if p.decision.t_start <= t_now or p.reservation.released:
                        continue
                    self.release(p.reservation)
                    # re-plan from the later of model readiness and NOW
                    # — a queued upload must never be re-priced into a
                    # window that has already elapsed (release_before
                    # may have purged past bookings, leaving
                    # phantom-free history)
                    dec = self.plan_upload(
                        p.sat, max(p.t_ready, t_now), p.payload_bits
                    )
                    if (
                        dec is not None
                        and dec.t_done < p.decision.t_done - 1e-9
                    ):
                        pending[i] = dataclasses.replace(
                            p, decision=dec, reservation=self.commit(dec)
                        )
                        repriced += 1
                        improved = True
                    else:
                        # restore: the earliest completion with its own
                        # slot free again can never be later than that
                        # same slot
                        pending[i] = dataclasses.replace(
                            p, reservation=self.commit(p.decision)
                        )
            if policy != "repack":
                break
            swapped = self._repack_swap_pass(pending, order, t_now)
            repriced += swapped
            if swapped == 0:
                break
        if self.sanitizer is not None:
            self.sanitizer.observe_readmit(
                before, [(p.key, p.decision.t_done) for p in pending]
            )
        if self.recorder is not None:
            self.recorder.on_readmit(t_now, len(pending), repriced)
        return pending, repriced

    def _uncontended_completion(
        self, p: PendingUpload, t_now: float
    ) -> Optional[float]:
        """Contention-free single-window completion of one queued
        upload — the regret baseline: how early it would finish if the
        shared ledger did not exist."""
        S = _sched()
        assert self.link is not None, "session has no GS link budget"
        tt = S.symmetric_transfer(downlink_time, self.link, p.payload_bits)
        hit = self.plan_transfer(
            sat=p.sat, t=max(p.t_ready, t_now), transfer_time=tt,
            contended=False,
        )
        return None if hit is None else float(hit[1])

    def _repack_swap_pass(
        self,
        pending: List[PendingUpload],
        order: Sequence[int],
        t_now: float,
    ) -> int:
        """One sweep of the regret-based swap search (``readmit``'s
        repack policy).  Tries admission-ordered pairs in descending
        combined regret; on the FIRST adopted swap, updates the two
        entries in place and returns the number of re-priced uploads
        (2) so the caller re-runs the monotone cascade.  Returns 0 when
        no swap is admissible (the sweep is dry)."""
        eligible = [
            i for i in order
            if pending[i].decision.t_start > t_now
            and not pending[i].reservation.released
        ]
        if len(eligible) < 2:
            return 0
        regret = {}
        for i in eligible:
            ideal = self._uncontended_completion(pending[i], t_now)
            regret[i] = (
                max(0.0, pending[i].decision.t_done - ideal)
                if ideal is not None else 0.0
            )
        pos = {i: k for k, i in enumerate(eligible)}
        pairs = sorted(
            (
                (a, b)
                for a in eligible for b in eligible
                if pos[a] < pos[b]      # a admitted before b
            ),
            key=lambda ab: (-(regret[ab[0]] + regret[ab[1]]), ab),
        )
        for a, b in pairs:
            if regret[a] <= 1e-9 and regret[b] <= 1e-9:
                continue                # neither entry pays contention
            pa, pb = pending[a], pending[b]
            floor_a, floor_b = pa.decision.t_done, pb.decision.t_done
            self.release(pa.reservation)
            self.release(pb.reservation)
            # swapped admission: the LATER entry prices first
            dec_b = self.plan_upload(
                pb.sat, max(pb.t_ready, t_now), pb.payload_bits
            )
            res_b = self.commit(dec_b) if dec_b is not None else None
            dec_a = (
                self.plan_upload(pa.sat, max(pa.t_ready, t_now),
                                 pa.payload_bits)
                if dec_b is not None else None
            )
            adopt = (
                dec_a is not None and dec_b is not None
                and dec_a.t_done <= floor_a + 1e-9      # monotone floor
                and dec_b.t_done <= floor_b + 1e-9
                and (dec_a.t_done < floor_a - 1e-9
                     or dec_b.t_done < floor_b - 1e-9)
            )
            if adopt:
                pending[b] = dataclasses.replace(
                    pb, decision=dec_b, reservation=res_b
                )
                pending[a] = dataclasses.replace(
                    pa, decision=dec_a, reservation=self.commit(dec_a)
                )
                return 2
            # roll back: free any trial booking, restore the originals
            # verbatim (only these two reservations were out, so their
            # slots are still free)
            if res_b is not None:
                self.release(res_b)
            pending[a] = dataclasses.replace(
                pa, reservation=self.commit(pa.decision)
            )
            pending[b] = dataclasses.replace(
                pb, reservation=self.commit(pb.decision)
            )
        return 0

    def finish_session(
        self,
        t_end: float,
        *,
        open_rids: FrozenSet[int] = frozenset(),
        check_leaks: bool = True,
    ) -> List["Violation"]:
        """Close the sanitizer's books at simulated time ``t_end`` and
        return every violation it recorded (empty when unsanitized or
        clean).  ``open_rids`` exempts reservations a strategy still
        legitimately holds (an async queue booked beyond sim end);
        ``check_leaks=False`` skips the leak report entirely (runs
        abandoned mid-round leave half-planned bookings by design)."""
        if self.sanitizer is None:
            return []
        return self.sanitizer.finish(
            t_end, open_rids=open_rids, check_leaks=check_leaks
        )
