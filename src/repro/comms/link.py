"""Satellite <-> GS RF link budget (paper eqs. 5-8 and 13-16).

All formulas follow the paper:

  SNR(k, GS) = P_t G_k G_GS / (K_B T B L_{k,GS})                       (5)
  L_{k,GS}   = (4 pi d f / c)^2                                        (6)
  t_c        = t_t + t_p + t_k + t_GS,  t_t = z|N|/R,  t_p = d/c       (7)
  R          ~ B log2(1 + SNR)                                         (8)

and the resource-block split of §IV-B: the uplink (GS -> satellites,
global-model broadcast) uses the full bandwidth B = N * B_D while each
sink satellite competes for one RB of bandwidth B_D on the downlink
(eqs. 13-16).

Table I parameters are the defaults.
"""
from __future__ import annotations

import dataclasses
import math

K_BOLTZMANN = 1.380649e-23
C_LIGHT = 299_792_458.0


@dataclasses.dataclass(frozen=True)
class LinkConfig:
    """RF link parameters (paper Table I, upper part)."""

    tx_power_dbm: float = 40.0          # P_t (satellite & GS)
    antenna_gain_dbi: float = 6.98      # G_k and G_GS
    carrier_freq_hz: float = 2.4e9      # f
    noise_temp_k: float = 354.81        # T
    bandwidth_hz: float = 1.0e6         # B (full uplink bandwidth)
    num_resource_blocks: int = 8        # N, with B = N * B_D
    data_rate_bps: float = 16.0e6       # R: max transmission data rate
    processing_delay_s: float = 0.0     # t_k + t_GS (omitted per paper)

    @property
    def rb_bandwidth_hz(self) -> float:
        """B_D: per-resource-block downlink bandwidth."""
        return self.bandwidth_hz / self.num_resource_blocks

    @property
    def tx_power_w(self) -> float:
        return 10.0 ** ((self.tx_power_dbm - 30.0) / 10.0)

    @property
    def antenna_gain_linear(self) -> float:
        return 10.0 ** (self.antenna_gain_dbi / 10.0)


def free_space_path_loss(distance_m: float, freq_hz: float) -> float:
    """Eq. (6): L = (4 pi d f / c)^2 (linear)."""
    return (4.0 * math.pi * distance_m * freq_hz / C_LIGHT) ** 2


def snr_linear(
    cfg: LinkConfig, distance_m: float, bandwidth_hz: float | None = None
) -> float:
    """Eq. (5): SNR = P_t G_k G_GS / (K_B T B L) (linear)."""
    b = cfg.bandwidth_hz if bandwidth_hz is None else bandwidth_hz
    loss = free_space_path_loss(distance_m, cfg.carrier_freq_hz)
    noise = K_BOLTZMANN * cfg.noise_temp_k * b
    return (cfg.tx_power_w * cfg.antenna_gain_linear**2) / (noise * loss)


def snr_db(
    cfg: LinkConfig, distance_m: float, bandwidth_hz: float | None = None
) -> float:
    """Eqs. (13)/(14) expressed in dB."""
    return 10.0 * math.log10(snr_linear(cfg, distance_m, bandwidth_hz))


def shannon_rate(
    cfg: LinkConfig, distance_m: float, bandwidth_hz: float | None = None
) -> float:
    """Eq. (8): R ~ B log2(1 + SNR), capped by the configured max rate."""
    b = cfg.bandwidth_hz if bandwidth_hz is None else bandwidth_hz
    rate = b * math.log2(1.0 + snr_linear(cfg, distance_m, b))
    return min(rate, cfg.data_rate_bps)


def transmission_time(payload_bits: float, rate_bps: float) -> float:
    """t_t = z|N| / R."""
    return payload_bits / rate_bps


def propagation_time(distance_m: float) -> float:
    """t_p = d / c."""
    return distance_m / C_LIGHT


def model_exchange_time(
    cfg: LinkConfig,
    payload_bits: float,
    distance_m: float,
    bandwidth_hz: float | None = None,
) -> float:
    """Eq. (7): t_c = t_t + t_p + t_k + t_GS over a link of given bandwidth."""
    rate = shannon_rate(cfg, distance_m, bandwidth_hz)
    return (
        transmission_time(payload_bits, rate)
        + propagation_time(distance_m)
        + cfg.processing_delay_s
    )


def uplink_time(cfg: LinkConfig, payload_bits: float, distance_m: float) -> float:
    """Eq. (15): t_c^U — GS broadcast of the global model over full B."""
    return model_exchange_time(cfg, payload_bits, distance_m, cfg.bandwidth_hz)


def downlink_time(cfg: LinkConfig, payload_bits: float, distance_m: float) -> float:
    """Eq. (16): t_c^D — sink upload of the partial model over one RB (B_D)."""
    return model_exchange_time(cfg, payload_bits, distance_m, cfg.rb_bandwidth_hz)
