"""Link-budget and latency models (FedLEO §III-B, §IV-B)."""
from repro.comms.link import (
    LinkConfig,
    free_space_path_loss,
    snr_linear,
    snr_db,
    shannon_rate,
    transmission_time,
    propagation_time,
    model_exchange_time,
    uplink_time,
    downlink_time,
)
from repro.comms.isl import ISLConfig, isl_hop_time, relay_time
from repro.comms.ledger import GSResourceLedger
from repro.comms.routing import ISLPlan, RoutingTable
from repro.comms.environment import (
    CommsEnvironment,
    PendingUpload,
    Reservation,
    TransferDecision,
)

__all__ = [
    "CommsEnvironment",
    "PendingUpload",
    "Reservation",
    "TransferDecision",
    "GSResourceLedger",
    "ISLPlan",
    "RoutingTable",
    "LinkConfig",
    "free_space_path_loss",
    "snr_linear",
    "snr_db",
    "shannon_rate",
    "transmission_time",
    "propagation_time",
    "model_exchange_time",
    "uplink_time",
    "downlink_time",
    "ISLConfig",
    "isl_hop_time",
    "relay_time",
]
