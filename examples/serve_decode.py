"""Serving example: batched autoregressive decoding with a KV cache.

Loads a reduced gemma-family model, prefils a prompt batch, then decodes
greedily with the single-token serve_step (the path the decode_32k /
long_500k dry-run shapes lower).  Also demonstrates the sliding-window
cache (long-context mode).

  PYTHONPATH=src python examples/serve_decode.py
"""
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import build_model, get_smoke_config
from repro.train.steps import make_serve_step


def main():
    cfg = get_smoke_config("gemma-7b")
    batch, prompt_len, gen_len = 4, 16, 32

    for window in (None, 8):
        model = build_model(cfg, sliding_window=window)
        params = model.init(jax.random.PRNGKey(0))
        serve = jax.jit(make_serve_step(model))

        rng = np.random.default_rng(0)
        prompt = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32
        )
        max_len = prompt_len + gen_len
        cache = model.init_cache(batch, max_len)
        cache_bytes = sum(
            leaf.size * leaf.dtype.itemsize
            for leaf in jax.tree_util.tree_leaves(cache)
        )

        # teacher-forced prefill through the decode path
        tok = prompt[:, :1]
        for t in range(prompt_len):
            logits, cache = serve(params, prompt[:, t: t + 1], cache,
                                  jnp.asarray(t, jnp.int32))
        # greedy generation
        t0 = time.time()
        out = []
        tok = jnp.argmax(logits, axis=-1, keepdims=True).astype(jnp.int32)
        for t in range(prompt_len, max_len):
            out.append(tok)
            logits, cache = serve(params, tok, cache,
                                  jnp.asarray(t, jnp.int32))
            tok = jnp.argmax(logits, axis=-1, keepdims=True).astype(
                jnp.int32
            )
        dt = time.time() - t0
        gen = jnp.concatenate(out, axis=1)
        mode = f"sliding-window({window})" if window else "full-cache"
        print(f"[{mode}] cache={cache_bytes / 1e6:.2f} MB  "
              f"generated {gen.shape} tokens  "
              f"{batch * gen_len / dt:.1f} tok/s")
        print("  sample:", np.asarray(gen[0, :12]).tolist())


if __name__ == "__main__":
    main()
