"""FedLEO vs the SOTA baselines (paper Table II) on one constellation.

  PYTHONPATH=src python examples/sota_comparison.py [--fast]
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

from repro.core import FedLEO, FederatedTask, SimConfig, TrainHyperparams
from repro.core.baselines import ALL_BASELINES
from repro.data import make_classification_dataset, partition_noniid_by_orbit
from repro.models.cnn import apply_cnn, init_cnn
from repro.optim import get_optimizer

FAST = "--fast" in sys.argv


def make_task():
    train = make_classification_dataset("mnist-like",
                                        num_samples=800 if FAST else 1600,
                                        seed=0)
    test = make_classification_dataset("mnist-like", num_samples=400,
                                       seed=99)
    clients = partition_noniid_by_orbit(train, 5, 8)
    hp = TrainHyperparams(local_epochs=100, learning_rate=0.05,
                          batch_size=16)
    return FederatedTask(
        init_fn=lambda r: init_cnn(r, (28, 28, 1), 10, widths=(8, 16),
                                   hidden=32),
        apply_fn=apply_cnn, clients=clients, test_set=test,
        optimizer=get_optimizer("sgd", 0.05), hp=hp,
        sim_epochs=4 if FAST else 8,
        payload_bits_override=int(4e6 * 32),
    )


def main():
    sim = SimConfig(horizon_hours=72.0)
    sync_rounds = 2 if FAST else 4
    async_rounds = 10 if FAST else 30

    print(f"{'method':16s} {'accuracy':>9s} {'sim hours':>10s} rounds")
    res = FedLEO(make_task(), sim).run(max_rounds=sync_rounds)
    print(f"{'FedLEO':16s} {res.final_accuracy:9.4f} "
          f"{res.final_time_hours:10.2f} {len(res.history):6d}")

    for name in ("FedAvg", "FedISL-ideal", "FedHAP", "FedAsync",
                 "AsyncFLEO"):
        cls = ALL_BASELINES[name]
        n = async_rounds if name in ("FedAsync", "AsyncFLEO") else sync_rounds
        res = cls(make_task(), sim).run(max_rounds=n)
        print(f"{name:16s} {res.final_accuracy:9.4f} "
              f"{res.final_time_hours:10.2f} {len(res.history):6d}")


if __name__ == "__main__":
    main()
