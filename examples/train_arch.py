"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with the FedLEO hierarchical aggregation schedule (the paper's technique
as a first-class distributed-training feature, DESIGN.md §3).

Two orbit replicas run local SGD; every tau steps the sink/GS weighted
aggregation folds them together — on a pod this is the single scheduled
cross-pod collective per FL round.

  PYTHONPATH=src python examples/train_arch.py                 # ~100M model
  PYTHONPATH=src python examples/train_arch.py --steps 50      # shorter
"""
import argparse
import dataclasses
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import ArchConfig
from repro.data.synthetic import make_token_dataset
from repro.optim import get_optimizer
from repro.train.fedleo_step import (
    make_fedleo_aggregate,
    make_fedleo_local_step,
)
from repro.train.steps import TrainState, make_train_step


def hundred_m_config(small: bool = False) -> ArchConfig:
    """~100M-parameter dense LM (gemma-family wiring); ``small`` gives a
    ~25M variant for quick CPU runs."""
    if small:
        return dataclasses.replace(
            get_smoke_config("gemma-7b"),
            num_layers=4, d_model=512, num_heads=8, num_kv_heads=8,
            head_dim=64, d_ff=2048, vocab_size=8192,
            tie_embeddings=False,
        )
    return dataclasses.replace(
        get_smoke_config("gemma-7b"),
        num_layers=8,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab_size=32768,   # ~100M total with untied embeddings
        tie_embeddings=False,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--orbits", type=int, default=2)
    ap.add_argument("--tau", type=int, default=10)
    ap.add_argument("--small", action="store_true",
                    help="~25M variant for quick CPU runs")
    args = ap.parse_args()

    from repro.configs import build_model
    from repro.models.nn import count_params

    cfg = hundred_m_config(small=args.small)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = count_params(params)
    print(f"model: {n / 1e6:.1f}M params, {cfg.num_layers}L "
          f"d_model={cfg.d_model}")

    opt = get_optimizer("adam", 3e-4)
    state = TrainState(params=params, opt_state=opt.init(params),
                       step=jnp.zeros((), jnp.int32))
    R = args.orbits
    state = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (R,) + x.shape), state
    )

    ds = make_token_dataset(num_sequences=128, seq_len=args.seq,
                            vocab_size=cfg.vocab_size, seed=0)
    rng = np.random.default_rng(0)

    local_step = jax.jit(make_fedleo_local_step(model, opt))
    aggregate = jax.jit(make_fedleo_aggregate())
    weights = jnp.ones((R,))

    t0 = time.time()
    losses = []
    for i in range(args.steps):
        rows = rng.integers(0, len(ds.x), size=(R, args.batch))
        batch = {"tokens": jnp.asarray(ds.x[rows])[:, None]}
        state, metrics = local_step(state, batch)
        losses.append(float(jnp.mean(metrics["loss"])))
        if (i + 1) % args.tau == 0:
            state = aggregate(state, weights)
        if (i + 1) % 20 == 0:
            dt = time.time() - t0
            print(f"step {i + 1:4d}  loss={np.mean(losses[-20:]):.4f}  "
                  f"({(i + 1) / dt:.2f} steps/s)")
    assert losses[-1] < losses[0], "no learning progress"
    print(f"done: loss {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f} "
          f"in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
