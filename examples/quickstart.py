"""Quickstart: FedLEO on a simulated 40-satellite constellation.

Runs the paper's core experiment end-to-end in ~2 minutes on CPU:
a Walker-delta constellation (5 orbits x 8 satellites, 1500 km, 80 deg),
the Rolla MO ground station, non-IID MNIST-like data (2 orbits hold
4 classes, 3 orbits the other 6), intra-plane model propagation and
sink-satellite scheduling — then prints the accuracy-vs-simulated-time
trace and each round's schedule decomposition.

  PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

from repro.core import FedLEO, FederatedTask, SimConfig, TrainHyperparams
from repro.data import make_classification_dataset, partition_noniid_by_orbit
from repro.models.cnn import apply_cnn, init_cnn
from repro.optim import get_optimizer


def main():
    # --- data: non-IID split across orbits (paper §V-A) ---------------------
    train = make_classification_dataset("mnist-like", num_samples=1600,
                                        seed=0)
    test = make_classification_dataset("mnist-like", num_samples=400,
                                       seed=99)
    clients = partition_noniid_by_orbit(train, num_planes=5,
                                        sats_per_plane=8)

    # --- the federated task (paper Table I hyperparameters) ------------------
    hp = TrainHyperparams(local_epochs=100, learning_rate=0.05,
                          batch_size=16)
    task = FederatedTask(
        init_fn=lambda r: init_cnn(r, (28, 28, 1), 10, widths=(8, 16),
                                   hidden=32),
        apply_fn=apply_cnn,
        clients=clients,
        test_set=test,
        optimizer=get_optimizer("sgd", 0.05),
        hp=hp,
        sim_epochs=8,                      # executed epochs (clock uses 100)
        payload_bits_override=int(4e6 * 32),  # charge a 4M-param model
    )

    # --- run FedLEO ----------------------------------------------------------
    sim = SimConfig(horizon_hours=72.0)
    result = FedLEO(task, sim).run(max_rounds=4, verbose=True)

    print("\nschedule decomposition (round 1):")
    for p in result.history[0].events["planes"]:
        print(
            f"  plane {p['plane']}: source=slot{p['source_slot']} "
            f"sink=slot{p['sink_slot']} "
            f"models@sink={p['t_models_at_sink'] / 3600:.2f}h "
            f"wait={p['t_wait_sink'] / 3600:.2f}h "
            f"uploaded={p['t_upload_done'] / 3600:.2f}h"
        )
    print(f"\nfinal: accuracy={result.final_accuracy:.4f} "
          f"in {result.final_time_hours:.1f} simulated hours")


if __name__ == "__main__":
    main()
