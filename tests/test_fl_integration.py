"""End-to-end FL integration: FedLEO + baselines on the simulated
constellation with real JAX training (reduced sizes for CPU)."""
import jax
import numpy as np
import pytest

from repro.core import FedLEO, FederatedTask, SimConfig, TrainHyperparams
from repro.core.baselines import ALL_BASELINES, FedAvgStar
from repro.data import make_classification_dataset, partition_noniid_by_orbit
from repro.models.cnn import apply_cnn, init_cnn
from repro.optim import get_optimizer


@pytest.fixture(scope="module")
def small_task_factory():
    ds = make_classification_dataset("mnist-like", num_samples=800, seed=0)
    test = make_classification_dataset("mnist-like", num_samples=200,
                                       seed=99)
    clients = partition_noniid_by_orbit(ds, 5, 8)
    hp = TrainHyperparams(local_epochs=100, learning_rate=0.05,
                          batch_size=16)

    def factory():
        return FederatedTask(
            init_fn=lambda r: init_cnn(r, (28, 28, 1), 10, widths=(8,),
                                       hidden=32),
            apply_fn=apply_cnn,
            clients=clients,
            test_set=test,
            optimizer=get_optimizer("sgd", 0.05),
            hp=hp,
            sim_epochs=6,
        )

    return factory


@pytest.mark.slow
def test_fedleo_converges_and_timing(small_task_factory):
    sim = SimConfig(horizon_hours=72.0)
    strat = FedLEO(small_task_factory(), sim)
    res = strat.run(max_rounds=4)
    assert len(res.history) == 4
    accs = [h.metrics["accuracy"] for h in res.history]
    assert accs[-1] > 0.5, f"no learning: {accs}"
    assert accs[-1] > accs[0]
    times = [h.t_hours for h in res.history]
    assert all(b > a for a, b in zip(times, times[1:]))
    # per-round events carry the schedule decomposition
    ev = res.history[0].events["planes"]
    assert len(ev) == 5
    for plane_ev in ev:
        assert plane_ev["t_upload_done"] >= plane_ev["t_models_at_sink"]


@pytest.mark.slow
def test_fedleo_faster_than_fedavg(small_task_factory):
    """The paper's headline claim: FedLEO round latency beats the star
    topology (eq. 12 vs eq. 10)."""
    sim = SimConfig(horizon_hours=72.0)
    t_leo = FedLEO(small_task_factory(), sim).run(max_rounds=2)
    t_avg = FedAvgStar(small_task_factory(), sim).run(max_rounds=2)
    assert t_leo.final_time_hours < t_avg.final_time_hours


def test_fedleo_sink_respects_window(small_task_factory):
    sim = SimConfig(horizon_hours=72.0)
    strat = FedLEO(small_task_factory(), sim)
    res = strat.run(max_rounds=1)
    for plane_ev in res.history[0].events["planes"]:
        assert plane_ev["t_wait_sink"] >= 0.0


@pytest.mark.parametrize("name", ["FedAsync", "AsyncFLEO", "FedISL-ideal"])
def test_baselines_run(small_task_factory, name):
    sim = SimConfig(horizon_hours=72.0)
    strat = ALL_BASELINES[name](small_task_factory(), sim)
    res = strat.run(max_rounds=3)
    assert len(res.history) >= 1
    assert np.isfinite(res.final_accuracy)


def test_noniid_alpha_changes_global_model(small_task_factory):
    sim0 = SimConfig(horizon_hours=72.0, noniid_alpha=0.0, seed=1)
    sim1 = SimConfig(horizon_hours=72.0, noniid_alpha=1.0, seed=1)
    r0 = FedLEO(small_task_factory(), sim0).run(max_rounds=1)
    r1 = FedLEO(small_task_factory(), sim1).run(max_rounds=1)
    # different weighting -> different aggregated accuracy trace
    assert r0.history[0].metrics["loss"] != pytest.approx(
        r1.history[0].metrics["loss"], abs=1e-9
    )
