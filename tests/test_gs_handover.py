"""Mid-window station handover: segmented sink uploads.

Load-bearing guarantees of the handover layer:
  * ``gs_handover=False`` (the default) leaves every scheduler
    bit-identical to the unsegmented contention-aware planner, and a
    single-station ground segment makes handover a no-op even when
    enabled (consecutive legs must switch stations);
  * segmented plans conserve the payload bits across legs, serialize
    the legs in time, alternate stations, and stay inside their access
    windows;
  * an upload that outlasts EVERY single pass — infeasible for the
    single-window planner — becomes feasible through handover, at the
    planner level and end-to-end through the engine;
  * under scarce RB capacity handover never completes later than the
    single-window planner (given the same ledger state);
  * a segment straddling a rolling-horizon boundary extends the window
    table (segment-aware extend-and-retry) instead of silently
    truncating the plan;
  * the ledger's residual station capacity feeds back into dynamic
    cluster formation (contention-aware formation feedback).
"""
import numpy as np
import pytest

from repro.comms import GSResourceLedger, LinkConfig
from repro.comms.link import downlink_time
from repro.core.fedleo import form_clusters, supply_driven_clusters
from repro.core.scheduling import (
    HandoverSpec,
    SinkDecision,
    TransferSegment,
    earliest_transfer,
    plan_segmented_transfer,
    reserve_decision,
    select_sink,
    symmetric_transfer,
)
from repro.orbits import (
    ConstellationConfig,
    GroundStation,
    ISLTopology,
    TopologyConfig,
    VisibilityPredictor,
    WalkerDelta,
)
from repro.orbits.constellation import Satellite
from repro.orbits.visibility import VisibilityWindow

PAYLOAD = 3.2e7         # fits inside a single pass on one RB
BIG_PAYLOAD = 6.0e8     # outlasts EVERY single pass on one RB
# engine payload: the full-band download still fits a window, but the
# 1-RB upload outlasts every pass — only a segmented upload completes
ENGINE_PAYLOAD = 3.5e8


@pytest.fixture(scope="module")
def world():
    """Two nearby stations -> overlapping/adjacent access windows, the
    geometry mid-window handover exploits."""
    cfg = ConstellationConfig(num_planes=2, sats_per_plane=6)
    walker = WalkerDelta(cfg)
    a = GroundStation()
    b = GroundStation(lat_deg=a.lat_deg + 4.0, lon_deg=a.lon_deg + 3.0,
                      name="GS-B")
    gss = [a, b]
    pred = VisibilityPredictor(walker, gss, horizon_s=24 * 3600.0)
    return cfg, walker, gss, pred


# --- segmented planner ---------------------------------------------------------
def _check_plan_invariants(plan, payload, link):
    assert len(plan.segments) >= 1
    assert abs(plan.total_bits - payload) < 1e-3        # bits conserved
    for leg in plan.segments:
        assert leg.bits > 0
        assert leg.window_start <= leg.t_start < leg.t_end
        assert leg.t_end <= leg.window_end + 1e-9       # inside the window
    for a, b in zip(plan.segments, plan.segments[1:]):
        assert a.t_end <= b.t_start + 1e-9              # serialized
        assert a.gs_index != b.gs_index                 # true handover
    assert plan.t_start == plan.segments[0].t_start
    assert plan.t_done == plan.segments[-1].t_end


def test_oversized_upload_rescued_by_handover(world):
    """A payload too large for ANY single pass on one RB is infeasible
    for the single-window planner but completes as a segmented plan."""
    cfg, walker, gss, pred = world
    link = LinkConfig()
    sat = Satellite(0, 0)
    tt = symmetric_transfer(downlink_time, link, BIG_PAYLOAD)
    base = earliest_transfer(walker=walker, predictor=pred, sat=sat,
                             t=0.0, transfer_time=tt)
    assert base is None                                 # the old failure mode
    plan = plan_segmented_transfer(
        walker=walker, predictor=pred, sat=sat, t_ready=0.0,
        link=link, payload_bits=BIG_PAYLOAD,
    )
    assert plan is not None
    assert len(plan.segments) >= 2
    _check_plan_invariants(plan, BIG_PAYLOAD, link)

    # the handover-aware entry point surfaces the same plan
    hit = earliest_transfer(walker=walker, predictor=pred, sat=sat,
                            t=0.0, transfer_time=tt,
                            handover=HandoverSpec(link, BIG_PAYLOAD))
    assert hit is not None
    t0, t_done, w, segments = hit
    assert segments == plan.segments
    assert (t0, t_done) == (plan.t_start, plan.t_done)
    assert (w.t_start, w.t_end, w.gs_index) == (
        segments[0].window_start, segments[0].window_end,
        segments[0].gs_index,
    )


def test_small_payload_handover_identical(world):
    """When every transfer fits a single window the segmented race is
    never adopted: handover-on == handover-off, leg tuple empty."""
    cfg, walker, gss, pred = world
    link = LinkConfig()
    tt = symmetric_transfer(downlink_time, link, PAYLOAD)
    for plane in range(cfg.num_planes):
        for slot in range(cfg.sats_per_plane):
            sat = Satellite(plane, slot)
            base = earliest_transfer(walker=walker, predictor=pred,
                                     sat=sat, t=3600.0, transfer_time=tt)
            ho = earliest_transfer(walker=walker, predictor=pred,
                                   sat=sat, t=3600.0, transfer_time=tt,
                                   handover=HandoverSpec(link, PAYLOAD))
            assert base is not None
            assert ho == (base[0], base[1], base[2], ())


def test_handover_never_later_under_scarcity(world):
    """Same pre-seeded 1-RB ledger state: the handover scheduler's
    completion is never later than the single-window scheduler's."""
    from repro.comms import ISLConfig

    cfg, walker, gss, pred = world
    link, isl = LinkConfig(), ISLConfig()
    K = cfg.sats_per_plane
    t_done = [3600.0 + 60.0 * s for s in range(K)]

    def seeded_ledger():
        led = GSResourceLedger(len(gss), 1)
        led.reserve(0, 0.0, 30_000.0)       # station 0 saturated early on
        return led

    for plane in range(cfg.num_planes):
        a = select_sink(walker=walker, gs=gss, predictor=pred, link=link,
                        isl=isl, plane=plane, t_train_done=t_done,
                        payload_bits=PAYLOAD, ledger=seeded_ledger())
        b = select_sink(walker=walker, gs=gss, predictor=pred, link=link,
                        isl=isl, plane=plane, t_train_done=t_done,
                        payload_bits=PAYLOAD, ledger=seeded_ledger(),
                        handover=True)
        assert a is not None and b is not None
        assert b.t_upload_done <= a.t_upload_done + 1e-9


def test_reserve_decision_books_each_leg():
    """A segmented decision books one reservation per leg on the leg's
    own station; an unsegmented one books the single upload interval."""
    w = VisibilityWindow(0, 0, 0.0, 100.0, 0)
    segs = (
        TransferSegment(0, 10.0, 50.0, 1e6, 0.0, 100.0),
        TransferSegment(1, 60.0, 80.0, 5e5, 40.0, 150.0),
    )
    led = GSResourceLedger(2, 1)
    reserve_decision(led, SinkDecision(
        plane=0, sink_slot=0, window=w, t_models_at_sink=0.0,
        t_upload_start=10.0, t_upload_done=80.0, t_wait=0.0,
        candidates_considered=1, segments=segs,
    ))
    s0, e0 = led.reservations(0)
    s1, e1 = led.reservations(1)
    assert (list(s0), list(e0)) == ([10.0], [50.0])
    assert (list(s1), list(e1)) == ([60.0], [80.0])

    led2 = GSResourceLedger(2, 1)
    reserve_decision(led2, SinkDecision(
        plane=0, sink_slot=0, window=w, t_models_at_sink=0.0,
        t_upload_start=10.0, t_upload_done=80.0, t_wait=0.0,
        candidates_considered=1,
    ))
    s0, e0 = led2.reservations(0)
    assert (list(s0), list(e0)) == ([10.0], [80.0])
    assert led2.num_reserved() == 1


def test_ledger_free_runs_complement():
    led = GSResourceLedger(1, 1)
    led.reserve(0, 10.0, 20.0)
    led.reserve(0, 30.0, 40.0)
    s, e = led.free_runs(0, 0.0, 50.0)
    assert (list(s), list(e)) == ([0.0, 20.0, 40.0], [10.0, 30.0, 50.0])
    s, e = led.free_runs(0, 12.0, 18.0)
    assert s.size == 0                      # fully saturated stretch
    # unlimited capacity: the query range comes back whole
    led_u = GSResourceLedger(1, None)
    led_u.reserve(0, 0.0, 1e9)
    s, e = led_u.free_runs(0, 5.0, 25.0)
    assert (list(s), list(e)) == ([5.0], [25.0])
    s, e = led.free_runs(0, 7.0, 7.0)
    assert s.size == 0                      # empty range


# --- rolling horizon: segment-aware extend-and-retry ---------------------------
def test_segment_straddling_boundary_triggers_extension(world):
    """A rolling table whose built boundary cuts straight through the
    plan's first window must extend (ensure more horizon) and produce
    the exact plan a prebuilt table yields — never a truncated one."""
    cfg, walker, gss, pred = world
    link = LinkConfig()
    sat = Satellite(0, 0)
    plan_pre = plan_segmented_transfer(
        walker=walker, predictor=pred, sat=sat, t_ready=0.0,
        link=link, payload_bits=BIG_PAYLOAD,
    )
    assert plan_pre is not None and len(plan_pre.segments) >= 2
    lead = plan_pre.segments[0]
    # boundary inside the first leg's window, snapped to the scan grid
    b = 10.0 * round((lead.window_start + lead.window_end) / 2.0 / 10.0)
    assert lead.window_start < b < lead.window_end
    roll = VisibilityPredictor(walker, gss, horizon_s=b, rolling=True,
                               max_horizon_s=24 * 3600.0)
    assert roll.built_end == b
    plan_roll = plan_segmented_transfer(
        walker=walker, predictor=roll, sat=sat, t_ready=0.0,
        link=link, payload_bits=BIG_PAYLOAD,
    )
    assert roll.built_end > b               # the boundary forced extension
    assert plan_roll is not None
    assert plan_roll.segments == plan_pre.segments

    # the handover-aware entry point must agree with the prebuilt
    # table too (single-window and segmented races on the same table)
    roll2 = VisibilityPredictor(walker, gss, horizon_s=b, rolling=True,
                                max_horizon_s=24 * 3600.0)
    tt = symmetric_transfer(downlink_time, link, BIG_PAYLOAD)
    spec = HandoverSpec(link, BIG_PAYLOAD)
    hit_roll = earliest_transfer(walker=walker, predictor=roll2, sat=sat,
                                 t=0.0, transfer_time=tt, handover=spec)
    hit_pre = earliest_transfer(walker=walker, predictor=pred, sat=sat,
                                t=0.0, transfer_time=tt, handover=spec)
    assert hit_roll == hit_pre


# --- contention-aware formation feedback ---------------------------------------
def test_residual_fraction_discounts_booked_capacity():
    led = GSResourceLedger(2, 1)
    assert list(led.residual_fraction(0.0, 100.0)) == [1.0, 1.0]
    led.reserve(0, 0.0, 100.0)
    assert list(led.residual_fraction(0.0, 100.0)) == [0.0, 1.0]
    assert list(led.residual_fraction(0.0, 200.0)) == [0.5, 1.0]
    led4 = GSResourceLedger(1, 4)
    led4.reserve(0, 0.0, 100.0)
    assert list(led4.residual_fraction(0.0, 100.0)) == [0.75]
    led_u = GSResourceLedger(1, None)
    led_u.reserve(0, 0.0, 1e9)
    assert list(led_u.residual_fraction(0.0, 100.0)) == [1.0]


def test_formation_feedback_matches_discounted_supply_oracle():
    """supply_driven_clusters with a ledger == form_clusters over the
    residual-discounted supply (exact oracle), and without a ledger it
    stays the plain window-supply grouping."""
    cfg = ConstellationConfig(num_planes=6, sats_per_plane=4)
    walker = WalkerDelta(cfg)
    from repro.configs.constellations import GROUND_STATION_PRESETS

    gss = [GroundStation(), GROUND_STATION_PRESETS["punta-arenas"]]
    pred = VisibilityPredictor(walker, gss, horizon_s=12 * 3600.0)
    topo = ISLTopology(cfg, TopologyConfig(kind="grid"))
    lookahead = topo.constellation.period_s

    led = GSResourceLedger(len(gss), 1)
    led.reserve(0, 0.0, lookahead)          # station 0 saturated all round

    supply = pred.plane_window_supply(0.0, lookahead)
    residual = led.residual_fraction(0.0, lookahead)
    oracle = form_clusters(
        (supply * residual[None, :]).sum(axis=1), 3,
        seam_cut=topo.config.seam_cut, adjacency=topo.plane_adjacency(),
    )
    got = supply_driven_clusters(pred, topo, 3, 0.0, ledger=led)
    assert got == oracle

    plain = form_clusters(
        supply.sum(axis=1), 3,
        seam_cut=topo.config.seam_cut, adjacency=topo.plane_adjacency(),
    )
    assert supply_driven_clusters(pred, topo, 3, 0.0) == plain
    assert supply_driven_clusters(
        pred, topo, 3, 0.0, ledger=GSResourceLedger(len(gss), 1)
    ) == plain                              # empty ledger: degenerate


# --- end-to-end engine equivalence and rescue ----------------------------------
def _small_task(num_planes, sats_per_plane, payload_bits=None):
    from repro.core import FederatedTask, TrainHyperparams
    from repro.data import make_classification_dataset, partition_iid
    from repro.models.cnn import apply_cnn, init_cnn
    from repro.optim import get_optimizer

    n = num_planes * sats_per_plane * 4
    ds = make_classification_dataset("mnist-like", num_samples=n, seed=0)
    test = make_classification_dataset("mnist-like", num_samples=64, seed=7)
    clients = partition_iid(ds, num_planes, sats_per_plane)
    hp = TrainHyperparams(local_epochs=100, learning_rate=0.05,
                          batch_size=16)
    return FederatedTask(
        init_fn=lambda r: init_cnn(r, (28, 28, 1), 10, widths=(4,),
                                   hidden=16),
        apply_fn=apply_cnn, clients=clients, test_set=test,
        optimizer=get_optimizer("sgd", 0.05), hp=hp, sim_epochs=1,
        payload_bits_override=payload_bits,
    )


def _histories_equal(ra, rb):
    assert len(ra.history) == len(rb.history)
    for ha, hb in zip(ra.history, rb.history):
        assert ha.t_hours == hb.t_hours
        assert ha.events == hb.events
        assert ha.metrics == hb.metrics


def test_single_gs_handover_end_to_end_identical():
    """With ONE ground station no multi-leg plan exists, so enabling
    handover must not perturb a single decision, time, or metric —
    FedLEO, FedLEOGrid, and a star baseline, under 1-RB contention."""
    import dataclasses

    from repro.core import FedLEO, FedLEOGrid, SimConfig
    from repro.core.baselines import FedSatSched

    cfg = ConstellationConfig(num_planes=2, sats_per_plane=4)
    base = SimConfig(constellation=cfg, horizon_hours=48.0,
                     gs_rb_capacity=1)
    ho = dataclasses.replace(base, gs_handover=True)
    assert SimConfig().gs_handover is False             # default off

    _histories_equal(FedLEO(_small_task(2, 4), base).run(max_rounds=2),
                     FedLEO(_small_task(2, 4), ho).run(max_rounds=2))

    grid = dataclasses.replace(base, topology=TopologyConfig(kind="grid"))
    grid_ho = dataclasses.replace(grid, gs_handover=True)
    _histories_equal(
        FedLEOGrid(_small_task(2, 4), grid, cluster_planes=2)
        .run(max_rounds=2),
        FedLEOGrid(_small_task(2, 4), grid_ho, cluster_planes=2)
        .run(max_rounds=2),
    )

    _histories_equal(FedSatSched(_small_task(2, 4), base).run(max_rounds=1),
                     FedSatSched(_small_task(2, 4), ho).run(max_rounds=1))


def test_multi_gs_small_payload_handover_identical_end_to_end():
    """Two stations, contention on, but every upload fits a single
    window: the segmented race must never be adopted, so handover-on
    is bit-identical to handover-off through the engine."""
    import dataclasses

    from repro.core import FedLEO, SimConfig

    cfg = ConstellationConfig(num_planes=2, sats_per_plane=4)
    a = GroundStation()
    b = GroundStation(lat_deg=a.lat_deg + 4.0, lon_deg=a.lon_deg + 3.0,
                      name="GS-B")
    base = SimConfig(constellation=cfg, horizon_hours=48.0,
                     ground_stations=(a, b), gs_rb_capacity=1)
    ho = dataclasses.replace(base, gs_handover=True)
    _histories_equal(FedLEO(_small_task(2, 4), base).run(max_rounds=2),
                     FedLEO(_small_task(2, 4), ho).run(max_rounds=2))


def test_grid_rolling_handover_matches_prebuilt():
    """FedLEOGrid with rolling horizon + 1-RB contention + handover:
    rounds complete through segmented uploads and the rolling run is
    bit-identical to the prebuilt-table run."""
    import dataclasses

    from repro.core import FedLEOGrid, SimConfig

    cfg = ConstellationConfig(num_planes=2, sats_per_plane=4)
    a = GroundStation()
    b = GroundStation(lat_deg=a.lat_deg + 4.0, lon_deg=a.lon_deg + 3.0,
                      name="GS-B")
    sim = SimConfig(constellation=cfg, horizon_hours=48.0,
                    ground_stations=(a, b),
                    topology=TopologyConfig(kind="grid"),
                    gs_rb_capacity=1, rolling_horizon_hours=6.0,
                    gs_handover=True)
    rolling = FedLEOGrid(_small_task(2, 4, payload_bits=ENGINE_PAYLOAD),
                         sim, cluster_planes=2).run(max_rounds=2)
    assert len(rolling.history) == 2
    legs = [c["handover_legs"]
            for h in rolling.history for c in h.events["clusters"]]
    assert max(legs) >= 2                   # uploads really segmented
    prebuilt = FedLEOGrid(
        _small_task(2, 4, payload_bits=ENGINE_PAYLOAD),
        dataclasses.replace(sim, rolling_horizon_hours=None),
        cluster_planes=2,
    ).run(max_rounds=2)
    _histories_equal(rolling, prebuilt)


def test_engine_handover_rescues_oversized_payload():
    """End-to-end: a model too large for any single pass stalls the
    handover-off engine on round 1 but completes through segmented
    uploads when gs_handover is on (legs recorded in round events)."""
    import dataclasses

    from repro.core import FedLEO, SimConfig

    cfg = ConstellationConfig(num_planes=2, sats_per_plane=4)
    a = GroundStation()
    b = GroundStation(lat_deg=a.lat_deg + 4.0, lon_deg=a.lon_deg + 3.0,
                      name="GS-B")
    base = SimConfig(constellation=cfg, horizon_hours=48.0,
                     ground_stations=(a, b), gs_rb_capacity=1)
    ho = dataclasses.replace(base, gs_handover=True)

    stalled = FedLEO(_small_task(2, 4, payload_bits=ENGINE_PAYLOAD),
                     base).run(max_rounds=1)
    assert len(stalled.history) == 0        # no feasible single-window upload

    res = FedLEO(_small_task(2, 4, payload_bits=ENGINE_PAYLOAD),
                 ho).run(max_rounds=1)
    assert len(res.history) == 1
    legs = [p["handover_legs"] for p in res.history[0].events["planes"]]
    assert max(legs) >= 2                   # at least one upload segmented
    assert np.isfinite(res.final_accuracy)
