"""Multi-tenant scheduling tests (ISSUE 9): ledger booking identity,
JobScheduler admission/tiers/fairness, single-job transparency, the
repack floor, lazy-routing planner equivalence, and the falsy-zero
engine regressions.
"""
from types import SimpleNamespace

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.comms import LinkConfig
from repro.comms.environment import CommsEnvironment, PendingUpload
from repro.comms.ledger import GSResourceLedger
from repro.multitenant import (
    QUEUED,
    REJECTED,
    RID_STRIDE,
    RUNNING,
    JobScheduler,
    JobSpec,
    projected_demand_rb_s,
)


# ---------------------------------------------------------------------------
# ledger booking identity (the release-identity bugfix)
# ---------------------------------------------------------------------------

class TestBookingIdentity:
    def test_identical_intervals_distinguishable(self):
        led = GSResourceLedger(1, 4)
        b1 = led.reserve(0, 10.0, 20.0)
        b2 = led.reserve(0, 10.0, 20.0)
        assert b1 is not None and b2 is not None and b1 != b2
        assert led.num_reserved() == 2
        led.release_booking(0, b1)
        assert led.num_reserved() == 1
        assert led.occupancy(0, 15.0) == 1
        led.release_booking(0, b2)
        assert led.num_reserved() == 0

    def test_release_booking_unknown_raises(self):
        led = GSResourceLedger(1, 4)
        bid = led.reserve(0, 0.0, 5.0)
        led.release_booking(0, bid)
        with pytest.raises(ValueError, match="no booking id"):
            led.release_booking(0, bid)

    def test_legacy_interval_release_shim(self):
        led = GSResourceLedger(1, 4)
        led.reserve(0, 3.0, 7.0)
        led.release(0, 3.0, 7.0)
        assert led.num_reserved() == 0
        with pytest.raises(ValueError):
            led.release(0, 3.0, 7.0)

    def test_booking_ids_never_reused(self):
        led = GSResourceLedger(1, 4)
        b1 = led.reserve(0, 0.0, 1.0)
        led.release_booking(0, b1)
        b2 = led.reserve(0, 0.0, 1.0)
        assert b2 != b1

    def test_release_before_keeps_ids_aligned(self):
        led = GSResourceLedger(1, 4)
        led.reserve(0, 0.0, 5.0)
        keep = led.reserve(0, 10.0, 15.0)
        led.reserve(0, 2.0, 6.0)
        led.release_before(8.0)
        assert led.num_reserved() == 1
        led.release_booking(0, keep)
        assert led.num_reserved() == 0


# ---------------------------------------------------------------------------
# scheduler harness: bare sessions (no predictor build) + fake runners
# ---------------------------------------------------------------------------

class _StubPredictor:
    """Just enough predictor surface for a planning-free session."""

    def __init__(self, num_stations: int):
        self.ground_stations = tuple(
            SimpleNamespace(name=f"gs{i}") for i in range(num_stations)
        )


class _StubDecision:
    """Books one interval through ``CommsEnvironment.commit`` via the
    single-upload-span fallback of ``_decision_legs``."""

    def __init__(self, gs_index: int, t0: float, t1: float):
        self.window = SimpleNamespace(gs_index=gs_index)
        self.t_upload_start = t0
        self.t_upload_done = t1


def _bare_env(num_stations: int = 1, capacity: float = 1,
              link: "LinkConfig | None" = None) -> CommsEnvironment:
    return CommsEnvironment(
        walker=None, predictor=_StubPredictor(num_stations), link=link,
        ledger=GSResourceLedger(num_stations, capacity),
    )


class FakeRunner:
    """Deterministic RoundRunner: each round advances the clock by the
    next duration; optionally books a fixed interval per round (to
    exercise RB-seconds metering and admission residuals)."""

    def __init__(self, env, name, durations, *, book_interval=None,
                 rb_s_per_round=None, release_on_finish=False, log=None):
        self.env = env
        self.release_floor_fn = None
        self.name = name
        self._durations = list(durations)
        self._book_interval = book_interval      # (gs, t0, t1) absolute
        self._rb_s = rb_s_per_round              # (gs, seconds) from t
        self._release_on_finish = release_on_finish
        self._log = log
        self._reservations = []

    def run_round(self, t, verbose=False):
        if self._log is not None:
            self._log.append(self.name)
        if not self._durations:
            return None
        d = self._durations.pop(0)
        if self._book_interval is not None:
            gs, a, b = self._book_interval
            self._reservations.append(self.env.commit(_StubDecision(gs, a, b)))
        if self._rb_s is not None:
            gs, seconds = self._rb_s
            self._reservations.append(
                self.env.commit(_StubDecision(gs, t, t + seconds))
            )
        return t + d

    def finish(self, t):
        if self._release_on_finish:
            for res in self._reservations:
                self.env.release(res)
        self.env.finish_session(t, check_leaks=False)


def _sim():
    from repro.core.engine import SimConfig

    return SimConfig()


class TestJobScheduler:
    def test_single_fake_job_completes(self):
        sched = JobScheduler(_sim(), base_env=_bare_env())
        sched.submit(
            JobSpec(name="a", rounds=3),
            lambda env: FakeRunner(env, "a", [10.0, 10.0, 10.0]),
        )
        rec = sched.run()[0]
        assert rec.status == "finished"
        assert rec.rounds_done == 3
        assert rec.round_completions_s == [10.0, 20.0, 30.0]

    def test_stalled_round_marks_job_stalled(self):
        sched = JobScheduler(_sim(), base_env=_bare_env())
        sched.submit(
            JobSpec(name="a", rounds=5),
            lambda env: FakeRunner(env, "a", [10.0]),   # dries up early
        )
        rec = sched.run()[0]
        assert rec.status == "stalled"
        assert rec.rounds_done == 1

    def test_tiers_are_strict_priority(self):
        log = []
        sched = JobScheduler(_sim(), base_env=_bare_env())
        sched.submit(
            JobSpec(name="bg", rounds=3, tier=1),
            lambda env: FakeRunner(env, "bg", [10.0] * 3, log=log),
        )
        sched.submit(
            JobSpec(name="fg", rounds=3, tier=0),
            lambda env: FakeRunner(env, "fg", [10.0] * 3, log=log),
        )
        sched.run()
        assert log == ["fg", "fg", "fg", "bg", "bg", "bg"]

    def test_weighted_max_min_fairness_over_rb_seconds(self):
        # equal RB booking per round: a weight-3 job gets three rounds
        # for every one of a weight-1 job
        log = []
        sched = JobScheduler(_sim(), base_env=_bare_env(capacity=10))
        sched.submit(
            JobSpec(name="a", rounds=2, weight=1.0),
            lambda env: FakeRunner(env, "a", [10.0] * 2,
                                   rb_s_per_round=(0, 100.0), log=log),
        )
        sched.submit(
            JobSpec(name="b", rounds=6, weight=3.0),
            lambda env: FakeRunner(env, "b", [10.0] * 6,
                                   rb_s_per_round=(0, 100.0), log=log),
        )
        recs = sched.run()
        assert log == ["a", "b", "b", "b", "a", "b", "b", "b"]
        assert recs[0].served_rb_s == pytest.approx(200.0)
        assert recs[1].served_rb_s == pytest.approx(600.0)

    def test_edf_orders_by_deadline_within_tier(self):
        # under EDF the tighter-deadline job runs ALL its rounds first,
        # regardless of served RB-seconds; deadline-less jobs go last
        log = []
        sched = JobScheduler(_sim(), base_env=_bare_env(capacity=10),
                             fairness="edf")
        sched.submit(
            JobSpec(name="loose", rounds=2, deadline_s=9000.0),
            lambda env: FakeRunner(env, "loose", [10.0] * 2,
                                   rb_s_per_round=(0, 1.0), log=log),
        )
        sched.submit(
            JobSpec(name="none", rounds=2),
            lambda env: FakeRunner(env, "none", [10.0] * 2,
                                   rb_s_per_round=(0, 1.0), log=log),
        )
        sched.submit(
            JobSpec(name="tight", rounds=2, deadline_s=3000.0),
            lambda env: FakeRunner(env, "tight", [10.0] * 2,
                                   rb_s_per_round=(0, 1.0), log=log),
        )
        sched.run()
        assert log == ["tight", "tight", "loose", "loose",
                       "none", "none"]

    def test_edf_respects_tiers(self):
        # strict tier precedence survives the EDF key: a tier-1 job
        # with the earliest deadline still waits for tier 0
        log = []
        sched = JobScheduler(_sim(), base_env=_bare_env(),
                             fairness="edf")
        sched.submit(
            JobSpec(name="bg", rounds=2, tier=1, deadline_s=100.0),
            lambda env: FakeRunner(env, "bg", [10.0] * 2, log=log),
        )
        sched.submit(
            JobSpec(name="fg", rounds=2, tier=0, deadline_s=9000.0),
            lambda env: FakeRunner(env, "fg", [10.0] * 2, log=log),
        )
        sched.run()
        assert log == ["fg", "fg", "bg", "bg"]

    def test_unknown_fairness_rejected(self):
        with pytest.raises(ValueError):
            JobScheduler(_sim(), base_env=_bare_env(), fairness="fifo")

    def test_single_job_identical_under_both_fairness_keys(self):
        # with one job the within-tier key is irrelevant: identical
        # round trace either way
        results = {}
        for fairness in ("maxmin", "edf"):
            sched = JobScheduler(_sim(), base_env=_bare_env(capacity=10),
                                 fairness=fairness)
            sched.submit(
                JobSpec(name="a", rounds=3, deadline_s=5000.0),
                lambda env: FakeRunner(env, "a", [10.0] * 3,
                                       rb_s_per_round=(0, 2.0)),
            )
            recs = sched.run()
            results[fairness] = (recs[0].rounds_done,
                                 tuple(recs[0].round_completions_s),
                                 recs[0].served_rb_s)
        assert results["maxmin"] == results["edf"]

    def test_rid_namespaces_disjoint_across_jobs(self):
        rids = {"a": [], "b": []}
        sched = JobScheduler(_sim(), base_env=_bare_env(capacity=10))

        def factory(name):
            def make(env):
                env.on_commit(lambda res: rids[name].append(res.rid))
                return FakeRunner(env, name, [10.0] * 2,
                                  rb_s_per_round=(0, 5.0))
            return make

        sched.submit(JobSpec(name="a", rounds=2), factory("a"))
        sched.submit(JobSpec(name="b", rounds=2), factory("b"))
        sched.run()
        assert all(r < RID_STRIDE for r in rids["a"])
        assert all(RID_STRIDE <= r < 2 * RID_STRIDE for r in rids["b"])

    def test_shared_ledger_sees_both_jobs(self):
        base = _bare_env(capacity=10)
        sched = JobScheduler(_sim(), base_env=base)
        for name in ("a", "b"):
            sched.submit(
                JobSpec(name=name, rounds=1),
                lambda env, n=name: FakeRunner(
                    env, n, [10.0], book_interval=(0, 50.0, 60.0)
                ),
            )
        sched.run()
        assert base.ledger.occupancy(0, 55.0) == 2


class TestAdmission:
    def _sched(self):
        return JobScheduler(_sim(), base_env=_bare_env(link=LinkConfig()))

    def test_demand_projection(self):
        link = LinkConfig()
        rb_rate = link.data_rate_bps / link.num_resource_blocks
        spec = JobSpec(name="j", rounds=3, uploads_per_round=5,
                       payload_bits=rb_rate * 40.0)
        assert projected_demand_rb_s(spec, link) == pytest.approx(
            3 * 5 * 40.0
        )

    def test_no_deadline_always_admitted(self):
        sched = self._sched()
        assert sched.admission_verdict(
            JobSpec(name="j", rounds=1), 0.0
        ) == RUNNING

    def test_past_deadline_rejected(self):
        sched = self._sched()
        spec = JobSpec(name="j", rounds=1, deadline_s=50.0,
                       payload_bits=1e6)
        assert sched.admission_verdict(spec, 100.0) == REJECTED

    def test_infeasible_demand_rejected_even_on_empty_ledger(self):
        sched = self._sched()
        link = sched.base_env.link
        rb_rate = link.data_rate_bps / link.num_resource_blocks
        # needs 2000 RB-seconds before t=1000 on a 1-RB station
        spec = JobSpec(name="j", rounds=1, deadline_s=1000.0,
                       payload_bits=rb_rate * 2000.0)
        assert sched.admission_verdict(spec, 0.0) == REJECTED

    def test_booked_residual_queues(self):
        sched = self._sched()
        link = sched.base_env.link
        rb_rate = link.data_rate_bps / link.num_resource_blocks
        spec = JobSpec(name="j", rounds=1, deadline_s=1000.0,
                       payload_bits=rb_rate * 600.0)
        assert sched.admission_verdict(spec, 0.0) == RUNNING
        sched.ledger.reserve(0, 0.0, 900.0)     # residual: 100 < 600
        assert sched.admission_verdict(spec, 0.0) == QUEUED

    def test_queued_job_admitted_when_capacity_releases(self):
        sched = self._sched()
        link = sched.base_env.link
        rb_rate = link.data_rate_bps / link.num_resource_blocks
        # job a books [2000, 3500) and releases it on finish (t=100)
        sched.submit(
            JobSpec(name="a", rounds=1),
            lambda env: FakeRunner(env, "a", [100.0],
                                   book_interval=(0, 2000.0, 3500.0),
                                   release_on_finish=True),
        )
        # job b arrives mid-flight needing 2500 RB-s by t=3000: empty
        # supply (2950) is enough, the residual under a's booking
        # (1950) is not -> queued, then admitted at a's finish
        sched.submit(
            JobSpec(name="b", arrival_s=50.0, rounds=1, deadline_s=3000.0,
                    payload_bits=rb_rate * 2500.0),
            lambda env: FakeRunner(env, "b", [10.0]),
        )
        recs = {r.name: r for r in sched.run()}
        assert recs["a"].status == "finished"
        assert recs["b"].status == "finished"
        assert recs["b"].admitted_at_s == pytest.approx(100.0)

    def test_starved_queue_rejected(self):
        sched = self._sched()
        link = sched.base_env.link
        rb_rate = link.data_rate_bps / link.num_resource_blocks
        sched.ledger.reserve(0, 0.0, 900.0)
        sched.submit(
            JobSpec(name="j", rounds=1, deadline_s=1000.0,
                    payload_bits=rb_rate * 600.0),
            lambda env: FakeRunner(env, "j", [10.0]),
        )
        rec = sched.run()[0]
        assert rec.status == REJECTED

    def test_duplicate_job_name_rejected_at_submit(self):
        sched = self._sched()
        sched.submit(JobSpec(name="j", rounds=1),
                     lambda env: FakeRunner(env, "j", [1.0]))
        with pytest.raises(ValueError, match="duplicate job name"):
            sched.submit(JobSpec(name="j", rounds=1),
                         lambda env: FakeRunner(env, "j", [1.0]))


# ---------------------------------------------------------------------------
# interleaved multi-session property over one shared ledger
# ---------------------------------------------------------------------------

# a coarse grid makes identical intervals across sessions common — the
# exact collision case the booking ids exist for
_OPS = st.lists(
    st.tuples(
        st.integers(min_value=0, max_value=2),      # session
        st.integers(min_value=0, max_value=5),      # t0
        st.integers(min_value=1, max_value=3),      # duration
    ),
    min_size=1,
    max_size=12,
)


@given(ops=_OPS, order_seed=st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=50, deadline=None)
def test_shared_ledger_multisession_roundtrip(ops, order_seed):
    """Any number of sessions booking (possibly identical) intervals on
    one shared ledger round-trips to empty under ANY release order, and
    the cached busy sweep tracks cross-session mutations."""
    base = _bare_env(capacity=1)
    shared = base.ledger
    sessions = [
        base.derive(ledger=shared, job=f"job{i}") for i in range(3)
    ]
    booked = []                     # (session, reservation, (t0, t1))
    for s_idx, t0, dur in ops:
        env = sessions[s_idx]
        res = env.commit(_StubDecision(0, float(t0), float(t0 + dur)))
        booked.append((env, res, (float(t0), float(t0 + dur))))

    def union(intervals):
        out = []
        for a, b in sorted(intervals):
            if out and a <= out[-1][1]:
                out[-1] = (out[-1][0], max(out[-1][1], b))
            else:
                out.append((a, b))
        return out

    rng = np.random.default_rng(order_seed)
    order = rng.permutation(len(booked))
    remaining = [iv for _, _, iv in booked]
    for i in order:
        env, res, iv = booked[i]
        env.release(res)
        remaining.remove(iv)
        # capacity 1: busy intervals == union of remaining bookings;
        # recomputed through the cache after a cross-session release
        a, b = shared.busy_intervals(0)
        assert list(zip(a.tolist(), b.tolist())) == union(remaining)
    assert shared.num_reserved() == 0


# ---------------------------------------------------------------------------
# repack policy: monotone result is the per-entry floor
# ---------------------------------------------------------------------------

def test_readmit_unknown_policy_raises():
    env = _bare_env()
    with pytest.raises(ValueError, match="policy"):
        env.readmit([], 0.0, policy="bogus")


@pytest.fixture(scope="module")
def contended_async_base():
    from repro.core.engine import SimConfig

    sim = SimConfig(gs_rb_capacity=1, sanitize=False)
    return sim, CommsEnvironment.from_sim(sim)


def _async_scenario(base_env, payload_bits=2e8):
    """price_async_round's release scenario on a fresh session: four
    planes book uploads at schedule time, the earliest-starting one
    aborts and releases."""
    from repro.orbits.constellation import Satellite

    env = base_env.derive()
    pending = []
    for plane in range(4):
        sat = Satellite(plane, 0)
        dec = env.plan_upload(sat, 0.0, payload_bits)
        assert dec is not None
        res = env.commit(dec)
        pending.append(
            PendingUpload(plane, sat, 0.0, payload_bits, dec, res)
        )
    victim = min(
        range(len(pending)),
        key=lambda i: (pending[i].decision.t_start, i),
    )
    env.release(pending[victim].reservation)
    return env, [p for i, p in enumerate(pending) if i != victim]


def test_repack_never_regresses_monotone(contended_async_base):
    _, base_env = contended_async_base
    env_m, pend_m = _async_scenario(base_env)
    env_r, pend_r = _async_scenario(base_env)
    mono, _ = env_m.readmit(pend_m, 0.0, policy="monotone")
    rep, _ = env_r.readmit(pend_r, 0.0, policy="repack")
    t_mono = {p.key: p.decision.t_done for p in mono}
    t_rep = {p.key: p.decision.t_done for p in rep}
    assert set(t_mono) == set(t_rep)
    for key, floor in t_mono.items():
        assert t_rep[key] <= floor + 1e-6, (
            f"plane {key}: repack {t_rep[key]} regressed past its "
            f"monotone floor {floor}"
        )


def test_repack_single_entry_matches_monotone(contended_async_base):
    """Degenerate case: with one queued upload there is nothing to
    swap — repack must equal monotone exactly."""
    from repro.orbits.constellation import Satellite

    _, base_env = contended_async_base
    outs = []
    for policy in ("monotone", "repack"):
        env = base_env.derive()
        sat = Satellite(0, 0)
        blocker = env.commit(env.plan_upload(sat, 0.0, 2e8))
        dec = env.plan_upload(Satellite(1, 0), 0.0, 2e8)
        res = env.commit(dec)
        env.release(blocker)
        pend, _ = env.readmit(
            [PendingUpload(1, Satellite(1, 0), 0.0, 2e8, dec, res)],
            0.0, policy=policy,
        )
        outs.append(pend[0].decision.t_done)
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# lazy routing resolution + planner equivalence
# ---------------------------------------------------------------------------

def test_resolve_lazy_routing_explicit_wins_and_auto_scales():
    from repro.comms.routing import (
        LAZY_AUTO_NODE_THRESHOLD,
        resolve_lazy_routing,
    )
    from repro.orbits import ConstellationConfig

    small = ConstellationConfig()                       # 5x8 = 40
    big = ConstellationConfig(num_planes=64, sats_per_plane=16)
    assert big.num_satellites >= LAZY_AUTO_NODE_THRESHOLD
    assert resolve_lazy_routing(small) is False
    assert resolve_lazy_routing(big) is True
    assert resolve_lazy_routing(big, lazy=False) is False
    assert resolve_lazy_routing(small, lazy=True) is True


def test_planner_schedule_equivalent_eager_vs_lazy():
    """The ISSUE 9 wiring assert: a FedLEOGrid cluster plan priced
    through a lazy routing table is identical to the eager one."""
    import dataclasses

    from repro.comms.routing import ISLPlan, get_routing_table
    from repro.core.engine import SimConfig
    from repro.core.fedleo import plan_cluster_round

    sim = SimConfig()
    # grid topology: a multi-plane cluster needs inter-plane ISLs
    sim = dataclasses.replace(
        sim, topology=dataclasses.replace(sim.topology, kind="grid")
    )
    env = CommsEnvironment.from_sim(sim)
    payload = 1e8
    plan = ISLPlan(intra=sim.isl, inter=sim.isl_inter)
    plans = {}
    for lazy in (False, True):
        routing = get_routing_table(
            sim.constellation, sim.topology, plan, payload, lazy=lazy
        )
        assert routing.lazy is lazy
        train = np.full(2 * sim.constellation.sats_per_plane, 600.0)
        plans[lazy] = plan_cluster_round(
            env=env, routing=routing, planes=(0, 1), t=0.0,
            payload_bits=payload, train_times=train,
        )
    a, b = plans[False].decision, plans[True].decision
    assert a.t_upload_done == b.t_upload_done
    assert a.t_upload_start == b.t_upload_start


# ---------------------------------------------------------------------------
# engine falsy-zero regressions + real single-job transparency
# ---------------------------------------------------------------------------

def _tiny_task(**overrides):
    from repro.core import FederatedTask, TrainHyperparams
    from repro.data import (
        make_classification_dataset,
        partition_noniid_by_orbit,
    )
    from repro.models.cnn import apply_cnn, init_cnn
    from repro.optim import get_optimizer

    ds = make_classification_dataset("mnist-like", num_samples=200, seed=0)
    test = make_classification_dataset("mnist-like", num_samples=80,
                                       seed=99)
    clients = partition_noniid_by_orbit(ds, 5, 8)
    hp = TrainHyperparams(local_epochs=20, learning_rate=0.05,
                          batch_size=16)
    return FederatedTask(
        init_fn=lambda r: init_cnn(r, (28, 28, 1), 10, widths=(8,),
                                   hidden=16),
        apply_fn=apply_cnn,
        clients=clients,
        test_set=test,
        optimizer=get_optimizer("sgd", 0.05),
        hp=hp,
        sim_epochs=2,
        **overrides,
    )


def test_max_sim_hours_zero_runs_no_rounds():
    """Regression: ``max_sim_hours or horizon`` silently replaced an
    explicit 0 with the full horizon."""
    from repro.core.baselines import FedAvgStar
    from repro.core.engine import SimConfig

    res = FedAvgStar(_tiny_task(), SimConfig()).run(
        max_rounds=3, max_sim_hours=0.0
    )
    assert res.history == []


def test_payload_override_zero_respected():
    """Regression: ``payload_bits_override or computed`` dropped an
    explicit 0-bit override."""
    assert _tiny_task(payload_bits_override=0).payload_bits == 0


@pytest.mark.slow
def test_single_job_scheduler_bit_identical_to_standalone():
    """ISSUE 9 acceptance: one job through the JobScheduler is the
    standalone ``FLStrategy.run``, bit for bit."""
    from repro.core.baselines import FedAvgStar
    from repro.core.engine import SimConfig

    sim = SimConfig()
    result = FedAvgStar(_tiny_task(), sim).run(max_rounds=2)

    sched = JobScheduler(sim)
    runners = []

    def factory(env):
        s = FedAvgStar(_tiny_task(), sim, env)
        runners.append(s)
        return s

    sched.submit(JobSpec(name="solo", rounds=2), factory)
    rec = sched.run()[0]
    assert rec.status == "finished"
    assert len(result.history) == len(runners[0].history) == 2
    for a, b in zip(result.history, runners[0].history):
        assert a.t_hours == b.t_hours
        assert a.round_index == b.round_index
        assert a.metrics == b.metrics
