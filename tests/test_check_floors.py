"""benchmarks.check_floors: trajectory parsing tolerance and one test
per floor rule (contention, handover, async, predictor latency, trace
overhead, mega-scale build ratio + memory budget), plus the near-floor
early-warning band."""
import json

import pytest

from benchmarks import check_floors
from benchmarks.check_floors import (
    MEGA_BUILD_RATIO_FLOOR,
    NEAR_FLOOR_MARGIN,
    TRACE_OVERHEAD_FLOOR,
    US_PER_QUERY_FLOOR,
    check,
    check_mega,
    check_predictor,
    load_latest_contention,
    load_latest_mega,
    load_latest_predictor,
    near_floor_warnings,
)


def _rec(**over):
    """A gs_contention record that satisfies every floor."""
    base = {
        "bench": "gs_contention",
        "ground_stations": ["rolla", "punta-arenas"],
        "ring_contended_s": 100.0, "grid_contended_s": 50.0,
        "ring_scarce_s": 200.0, "grid_scarce_s": 120.0,
        "ring_handover_s": 180.0, "grid_handover_s": 110.0,
        "async_scarce_s": 300.0, "async_readmit_s": 280.0,
        "async_scarce_mean_s": 250.0, "async_readmit_mean_s": 240.0,
        "trace_overhead_fraction": 0.01,
        "plan_wall_plain_s": 1.0, "plan_wall_traced_s": 1.01,
    }
    base.update(over)
    return base


# --- trajectory parsing ---------------------------------------------------------
def _write_lines(path, lines):
    with open(path, "w") as f:
        f.write("\n".join(lines))


def test_load_latest_skips_corrupt_tail(tmp_path):
    path = str(tmp_path / "BENCH.json")
    good = _rec()
    _write_lines(path, [
        json.dumps(_rec(ring_contended_s=1.0)),     # older: superseded
        "not json at all",
        json.dumps(good),
        '{"bench": "gs_contention", "trunc',        # killed mid-write
    ])
    records = load_latest_contention(path)
    assert len(records) == 1
    assert records[0]["ring_contended_s"] == good["ring_contended_s"]


def test_load_latest_keys_by_gs_set_and_ignores_other_benches(tmp_path):
    path = str(tmp_path / "BENCH.json")
    _write_lines(path, [
        json.dumps(_rec(ground_stations=["rolla"])),
        json.dumps(_rec()),
        json.dumps({"bench": "topology_scaling", "ring_round_s": 1.0}),
        json.dumps([1, 2, 3]),                      # non-dict line
    ])
    records = load_latest_contention(path)
    assert len(records) == 2
    assert load_latest_predictor(path) is None


def test_load_missing_file_is_empty():
    assert load_latest_contention("/nonexistent/BENCH.json") == []
    assert load_latest_predictor("/nonexistent/BENCH.json") is None


def test_main_warns_and_exits_zero_without_trajectory(
    tmp_path, monkeypatch, capsys
):
    missing = str(tmp_path / "never_written.json")
    monkeypatch.setattr(check_floors, "BENCH_TRAJECTORY", missing)
    check_floors.main()                             # must NOT raise
    assert "WARNING" in capsys.readouterr().err


def test_main_passes_on_healthy_trajectory(tmp_path, monkeypatch, capsys):
    path = str(tmp_path / "BENCH.json")
    _write_lines(path, [
        json.dumps(_rec()),
        json.dumps({"bench": "predictor_queries", "us_per_query": 3.0}),
    ])
    monkeypatch.setattr(check_floors, "BENCH_TRAJECTORY", path)
    check_floors.main()
    assert "all gs_contention floors hold" in capsys.readouterr().out


def test_main_fails_on_violation(tmp_path, monkeypatch, capsys):
    path = str(tmp_path / "BENCH.json")
    _write_lines(path, [json.dumps(_rec(grid_contended_s=150.0))])
    monkeypatch.setattr(check_floors, "BENCH_TRAJECTORY", path)
    with pytest.raises(SystemExit):
        check_floors.main()
    assert "FLOOR VIOLATION" in capsys.readouterr().err


# --- one test per floor rule ----------------------------------------------------
def test_floor_grid_beats_ring_under_contention():
    assert check([_rec()]) == []
    fails = check([_rec(grid_contended_s=150.0)])
    assert any("under RB contention" in f for f in fails)
    assert any("grid" in f for f in check([_rec(grid_contended_s=None)]))


def test_floor_handover_never_worse_than_scarce():
    fails = check([_rec(ring_handover_s=250.0)])
    assert any("ring handover" in f for f in fails)
    fails = check([_rec(grid_handover_s=130.0)])
    assert any("grid handover" in f for f in fails)
    # vacuous when the scarce side was not measured
    assert check([_rec(ring_scarce_s=None)]) == []


def test_floor_async_readmit_never_worse():
    fails = check([_rec(async_readmit_s=301.0)])
    assert any("async re-admission" in f for f in fails)
    fails = check([_rec(async_readmit_mean_s=260.0)])
    assert any("mean" in f for f in fails)
    # pre-PR-5 records carry no async arms: rule is skipped entirely
    old = _rec()
    for k in list(old):
        if k.startswith("async"):
            del old[k]
    assert check([old]) == []


def test_floor_trace_overhead():
    fails = check([_rec(trace_overhead_fraction=0.2)])
    assert any("tracing overhead" in f for f in fails)
    # exactly at the floor passes; absent column (schema < 2) skips
    assert check([_rec(trace_overhead_fraction=TRACE_OVERHEAD_FLOOR)]) == []
    assert check([_rec(trace_overhead_fraction=None)]) == []


def test_floor_predictor_query_latency():
    assert check_predictor(None) == []
    assert check_predictor({"us_per_query": 3.0}) == []
    assert check_predictor({"us_per_query": US_PER_QUERY_FLOOR}) == []
    fails = check_predictor({"us_per_query": US_PER_QUERY_FLOOR + 1.0})
    assert any("us/query" in f for f in fails)


def test_no_records_is_a_failure():
    assert check([]) != []


# --- mega-scale floors ----------------------------------------------------------
def _mega(**over):
    """A mega_scale record that satisfies every floor with margin."""
    base = {
        "bench": "mega_scale",
        "constellation": "starlink-gen1",
        "mem_budget_mb": 256.0,
        "predictor_build_ratio_vs_40x22": 1.6,
        "predictor_peak_mb": 170.0,
        "plan_round_s": 13627.3,
    }
    base.update(over)
    return base


def test_load_latest_mega_keys_by_constellation(tmp_path):
    path = str(tmp_path / "BENCH.json")
    _write_lines(path, [
        json.dumps(_mega(predictor_peak_mb=999.0)),   # superseded
        json.dumps(_mega()),
        json.dumps(_mega(constellation="starlink-2shell")),
        json.dumps(_rec()),                           # other bench: ignored
    ])
    records = load_latest_mega(path)
    assert [r["constellation"] for r in records] == \
        ["starlink-2shell", "starlink-gen1"]
    assert records[1]["predictor_peak_mb"] == 170.0
    assert load_latest_mega("/nonexistent/BENCH.json") == []


def test_floor_mega_build_ratio():
    assert check_mega([_mega()]) == []
    assert check_mega([]) == []                       # mega smoke optional
    fails = check_mega([_mega(
        predictor_build_ratio_vs_40x22=MEGA_BUILD_RATIO_FLOOR + 0.1
    )])
    assert any("40x22" in f for f in fails)
    # exactly at the floor passes; absent column is vacuous
    assert check_mega([_mega(
        predictor_build_ratio_vs_40x22=MEGA_BUILD_RATIO_FLOOR
    )]) == []
    assert check_mega([_mega(predictor_build_ratio_vs_40x22=None)]) == []


def test_floor_mega_ratio_scoped_to_gen1():
    """The 4x wall-clock ratio was calibrated at 1.8x the baseline's
    satellites; a 2.7x two-shell row must not trip (or warn on) it."""
    big = _mega(
        constellation="starlink-2shell",
        predictor_build_ratio_vs_40x22=MEGA_BUILD_RATIO_FLOOR + 1.0,
    )
    assert check_mega([big]) == []
    near = _mega(
        constellation="starlink-2shell",
        predictor_build_ratio_vs_40x22=MEGA_BUILD_RATIO_FLOOR * 0.9,
    )
    assert near_floor_warnings([], None, [near]) == []


def test_floor_mega_peak_under_budget():
    fails = check_mega([_mega(predictor_peak_mb=300.0)])
    assert any("mem_budget_mb" in f for f in fails)
    assert check_mega([_mega(predictor_peak_mb=256.0)]) == []


def test_floor_mega_plan_round_completed():
    fails = check_mega([_mega(plan_round_s=None)])
    assert any("planning round" in f for f in fails)


# --- near-floor warning band ----------------------------------------------------
def test_near_floor_warns_inside_margin_only():
    edge = US_PER_QUERY_FLOOR * (1.0 - NEAR_FLOOR_MARGIN)
    warns = near_floor_warnings([], {"us_per_query": edge + 0.1}, [])
    assert any("us/query" in w for w in warns)
    # at or below the band edge: quiet; above the floor: a violation,
    # not a warning (check_predictor owns it)
    assert near_floor_warnings([], {"us_per_query": edge}, []) == []
    assert near_floor_warnings(
        [], {"us_per_query": US_PER_QUERY_FLOOR + 1.0}, []
    ) == []


def test_near_floor_covers_all_gated_metrics():
    rec = _rec(trace_overhead_fraction=TRACE_OVERHEAD_FLOOR * 0.9)
    mega = _mega(
        predictor_build_ratio_vs_40x22=MEGA_BUILD_RATIO_FLOOR * 0.9,
        predictor_peak_mb=0.9 * 256.0,
    )
    warns = near_floor_warnings([rec], None, [mega])
    assert len(warns) == 3
    assert any("tracing overhead" in w for w in warns)
    assert any("build ratio" in w for w in warns)
    assert any("mem_budget_mb" in w for w in warns)
    # comfortably clear of every floor: no warnings at all
    assert near_floor_warnings([_rec()], None, [_mega()]) == []


def test_main_prints_warning_but_exits_zero(tmp_path, monkeypatch, capsys):
    path = str(tmp_path / "BENCH.json")
    _write_lines(path, [
        json.dumps(_rec()),
        json.dumps(_mega(
            predictor_build_ratio_vs_40x22=MEGA_BUILD_RATIO_FLOOR * 0.9
        )),
    ])
    monkeypatch.setattr(check_floors, "BENCH_TRAJECTORY", path)
    check_floors.main()                               # must NOT raise
    captured = capsys.readouterr()
    assert "FLOOR WARNING" in captured.err
    assert "all gs_contention floors hold" in captured.out


def test_main_fails_on_mega_violation(tmp_path, monkeypatch, capsys):
    path = str(tmp_path / "BENCH.json")
    _write_lines(path, [
        json.dumps(_rec()),
        json.dumps(_mega(predictor_peak_mb=400.0)),
    ])
    monkeypatch.setattr(check_floors, "BENCH_TRAJECTORY", path)
    with pytest.raises(SystemExit):
        check_floors.main()
    assert "FLOOR VIOLATION" in capsys.readouterr().err


# --- hetero-fleet floors --------------------------------------------------------
def _hetero(**over):
    """A hetero_fleet record that satisfies every floor."""
    base = {
        "bench": "hetero_fleet",
        "constellation": "starlink-40x22",
        "fast_round_s": 19000.0,
        "hetero_round_s": 21000.0,
        "slow_round_s": 21500.0,
        "uniform_equal": True,
        "aggregate_parity_max_err": 0.0,
    }
    base.update(over)
    return base


def test_load_latest_hetero(tmp_path):
    path = str(tmp_path / "BENCH.json")
    _write_lines(path, [
        json.dumps(_hetero(hetero_round_s=999.0)),    # superseded
        json.dumps(_rec()),                           # other bench: ignored
        json.dumps(_hetero()),
    ])
    rec = check_floors.load_latest_hetero(path)
    assert rec["hetero_round_s"] == 21000.0
    assert check_floors.load_latest_hetero("/nonexistent/BENCH.json") is None


def test_floor_hetero_ordering_and_parity():
    from benchmarks.check_floors import HETERO_PARITY_TOL, check_hetero

    assert check_hetero(None) == []                   # smoke optional
    assert check_hetero(_hetero()) == []
    fails = check_hetero(_hetero(fast_round_s=22000.0))
    assert any("all-fast" in f for f in fails)
    fails = check_hetero(_hetero(slow_round_s=20000.0))
    assert any("all-slow" in f for f in fails)
    fails = check_hetero(_hetero(uniform_equal=False))
    assert any("bit-identical" in f for f in fails)
    fails = check_hetero(_hetero(
        aggregate_parity_max_err=HETERO_PARITY_TOL * 10
    ))
    assert any("parity" in f for f in fails)
    # equal fast/hetero/slow rounds pass (degenerate uniform fleets)
    assert check_hetero(_hetero(
        fast_round_s=21000.0, slow_round_s=21000.0
    )) == []
    assert any("did not complete" in f
               for f in check_hetero(_hetero(hetero_round_s=None)))


def test_main_fails_on_hetero_violation(tmp_path, monkeypatch, capsys):
    path = str(tmp_path / "BENCH.json")
    _write_lines(path, [
        json.dumps(_rec()),
        json.dumps(_hetero(uniform_equal=False)),
    ])
    monkeypatch.setattr(check_floors, "BENCH_TRAJECTORY", path)
    with pytest.raises(SystemExit):
        check_floors.main()
    assert "FLOOR VIOLATION" in capsys.readouterr().err
