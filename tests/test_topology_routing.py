"""Inter-plane ISL topology & graph routing subsystem tests.

The load-bearing guarantees:
  * the graph layer reproduces the paper's ring EXACTLY when inter-plane
    links are disabled (hop metric, flood/relay schedules, sink
    decisions — bit-identical, not approximately equal);
  * the +Grid slot mapping is phasing-offset aware;
  * the polar seam cut removes the wrap-around cross-links without
    disconnecting the graph;
  * FedLEOGrid runs end-to-end, including on the starlink-40x22 preset.
"""
import numpy as np
import pytest

from repro.comms.isl import ISLConfig, isl_hop_time
from repro.comms.link import LinkConfig
from repro.comms.routing import ISLPlan, RoutingTable
from repro.core.propagation import (
    broadcast_schedule,
    graph_broadcast_schedule,
    graph_relay_schedule,
    relay_schedule,
    ring_hops_matrix,
)
from repro.core.scheduling import naive_sink_slot, select_sink, select_sink_cluster
from repro.orbits import (
    INTER,
    ConstellationConfig,
    GroundStation,
    ISLTopology,
    Satellite,
    TopologyConfig,
    VisibilityPredictor,
    WalkerDelta,
    phased_slot_shift,
)

PAYLOAD = 1.28e8


@pytest.fixture(scope="module")
def small_cfg():
    return ConstellationConfig(num_planes=3, sats_per_plane=6)


@pytest.fixture(scope="module")
def starlink_cfg():
    from repro.configs.constellations import get_constellation

    return get_constellation("starlink-40x22")


# --- graph vs ring equivalence ------------------------------------------------
def test_ring_topology_blocks_match_ring_hops_matrix(small_cfg):
    topo = ISLTopology(small_cfg, TopologyConfig(kind="ring"))
    K = small_cfg.sats_per_plane
    hops = topo.hop_matrix()
    expect = ring_hops_matrix(K)
    for p in range(small_cfg.num_planes):
        blk = hops[p * K:(p + 1) * K, p * K:(p + 1) * K]
        assert np.array_equal(blk, expect)
    # planes are disconnected under the ring
    assert np.all(hops[0:K, K:2 * K] == -1)


def test_grid_without_inter_links_is_ring(small_cfg):
    ring = ISLTopology(small_cfg, TopologyConfig(kind="ring"))
    cut = ISLTopology(
        small_cfg, TopologyConfig(kind="grid", inter_plane_offsets=())
    )
    assert np.array_equal(ring.hop_matrix(), cut.hop_matrix())


def test_graph_schedules_bit_identical_to_ring(small_cfg):
    """Flood + relay over the graph == the ring planner, bitwise."""
    K = small_cfg.sats_per_plane
    isl = ISLConfig()
    t_hop = isl_hop_time(isl, PAYLOAD)
    topo = ISLTopology(
        small_cfg, TopologyConfig(kind="grid", inter_plane_offsets=())
    )
    rt = RoutingTable(topo, ISLPlan(intra=isl), PAYLOAD)

    plane = 1
    nodes = np.arange(plane * K, (plane + 1) * K)
    hops, lat = rt.submatrix(nodes)
    assert np.array_equal(lat, ring_hops_matrix(K) * t_hop)

    ring_ev = broadcast_schedule(K, [2], [100.0], PAYLOAD, isl)
    graph_ev = graph_broadcast_schedule(hops, lat, [2], [100.0])
    for a, b in zip(ring_ev, graph_ev):
        assert a == b          # dataclass equality: exact floats

    t_ready = [100.0 * (i + 1) for i in range(K)]
    ring_rel = relay_schedule(K, 3, t_ready, PAYLOAD, isl)
    graph_rel = graph_relay_schedule(hops, lat, 3, t_ready)
    for a, b in zip(ring_rel, graph_rel):
        assert a == b


def test_select_sink_cluster_matches_select_sink(small_cfg):
    """One-plane cluster + ring latency == the paper's per-plane sink."""
    walker = WalkerDelta(small_cfg)
    gs = GroundStation()
    pred = VisibilityPredictor(walker, gs, horizon_s=36 * 3600)
    link, isl = LinkConfig(), ISLConfig()
    K = small_cfg.sats_per_plane
    t_hop = isl_hop_time(isl, PAYLOAD)
    t_done = [3600.0 + 60.0 * s for s in range(K)]

    ring_dec = select_sink(
        walker=walker, gs=gs, predictor=pred, link=link, isl=isl,
        plane=0, t_train_done=t_done, payload_bits=PAYLOAD,
    )
    grid_dec = select_sink_cluster(
        walker=walker, gs=gs, predictor=pred, link=link,
        sats=[(0, s) for s in range(K)],
        relay_latency=ring_hops_matrix(K) * t_hop,
        t_train_done=t_done, payload_bits=PAYLOAD,
    )
    assert ring_dec is not None and grid_dec is not None
    assert grid_dec.sink == Satellite(0, ring_dec.sink_slot)
    assert grid_dec.t_models_at_sink == ring_dec.t_models_at_sink
    assert grid_dec.t_upload_start == ring_dec.t_upload_start
    assert grid_dec.t_upload_done == ring_dec.t_upload_done
    assert grid_dec.t_wait == ring_dec.t_wait
    assert grid_dec.window == ring_dec.window


def test_naive_sink_slot_matches_scalar_sweep(small_cfg):
    walker = WalkerDelta(small_cfg)
    pred = VisibilityPredictor(walker, GroundStation(), horizon_s=36 * 3600)
    for plane in range(small_cfg.num_planes):
        for t in (0.0, 3600.0, 20 * 3600.0):
            # scalar reference: K next_window calls
            best, best_start = None, None
            for s in range(small_cfg.sats_per_plane):
                w = pred.next_window(Satellite(plane, s), t)
                if w is not None and (
                    best_start is None or max(w.t_start, t) < best_start
                ):
                    best, best_start = s, max(w.t_start, t)
            assert naive_sink_slot(pred, plane, t) == best


# --- +Grid structure ----------------------------------------------------------
def test_phasing_offset_slot_mapping(starlink_cfg):
    """Every inter-plane link pairs nearest-phase slots: the in-plane
    phase difference across the link is at most half a slot."""
    topo = ISLTopology(starlink_cfg, TopologyConfig(kind="grid"))
    walker = WalkerDelta(starlink_cfg)
    K = starlink_cfg.sats_per_plane
    i, j = topo.edges(INTER)
    assert i.size == starlink_cfg.num_planes * K      # one eastward link each
    phase = walker._phase0                            # (L, K) radians
    dphi = phase[i // K, i % K] - phase[j // K, j % K]
    dphi = (dphi + np.pi) % (2 * np.pi) - np.pi       # wrap to (-pi, pi]
    slot_angle = 2 * np.pi / K
    assert np.all(np.abs(dphi) <= slot_angle / 2 + 1e-9)
    # the mapping is phasing-aware: F=13, L=40 shifts the seam pairing
    assert phased_slot_shift(starlink_cfg, 0, 1) == 0
    assert phased_slot_shift(starlink_cfg, starlink_cfg.num_planes - 1, 0) \
        == round(13 * 39 / 40)


def test_seam_cut_removes_wrap_links_but_stays_connected():
    cfg = ConstellationConfig(num_planes=5, sats_per_plane=8,
                              phasing_factor=2)
    K = cfg.sats_per_plane
    full = ISLTopology(cfg, TopologyConfig(kind="grid"))
    cut = ISLTopology(cfg, TopologyConfig(kind="grid", seam_cut=True))

    def seam_edges(topo):
        i, j = topo.edges(INTER)
        pi, pj = i // K, j // K
        return np.sum((np.minimum(pi, pj) == 0)
                      & (np.maximum(pi, pj) == cfg.num_planes - 1))

    assert seam_edges(full) == K
    assert seam_edges(cut) == 0
    assert cut.is_connected()
    # the cut forces seam traffic the long way around the planes
    h_full, h_cut = full.hop_matrix(), cut.hop_matrix()
    seam_pair = (ISLTopology.node(full, 0, 0),
                 ISLTopology.node(full, cfg.num_planes - 1, 0))
    assert h_cut[seam_pair] > h_full[seam_pair]


def test_grid_connected_and_symmetric(starlink_cfg):
    topo = ISLTopology(starlink_cfg, TopologyConfig(kind="grid"))
    hops = topo.hop_matrix()
    assert np.all(hops >= 0)
    assert np.array_equal(hops, hops.T)
    assert np.all(np.diag(hops) == 0)
    # cross-plane shortcuts: farthest pair is far below ring-sum scale
    assert hops.max() <= (starlink_cfg.sats_per_plane // 2
                          + starlink_cfg.num_planes // 2)


def test_seam_cut_is_offset_sign_independent():
    """The same physical topology written with a westward offset must
    cut the same seam links as the eastward form."""
    cfg = ConstellationConfig(num_planes=5, sats_per_plane=8,
                              phasing_factor=2)
    east = ISLTopology(cfg, TopologyConfig(kind="grid", seam_cut=True))
    west = ISLTopology(
        cfg,
        TopologyConfig(kind="motif", inter_plane_offsets=(-1,),
                       seam_cut=True),
    )
    assert np.array_equal(east.adjacency, west.adjacency)


def test_seam_cut_degenerate_disjoint_rings_routing():
    """When a seam-cut grid degenerates to disjoint components, the
    hop/latency matrices must stay consistent: intra-plane blocks keep
    the exact ring metric, cross-component pairs are UNREACHABLE/inf,
    and floods never leak across components."""
    cfg = ConstellationConfig(num_planes=4, sats_per_plane=6)
    K = cfg.sats_per_plane
    isl = ISLConfig()
    t_hop = isl_hop_time(isl, PAYLOAD)
    # offset-2 cross-links + seam cut -> components {0,2} and {1,3}
    topo = ISLTopology(
        cfg,
        TopologyConfig(kind="motif", inter_plane_offsets=(2,),
                       seam_cut=True),
    )
    assert not topo.is_connected()
    rt = RoutingTable(topo, ISLPlan(intra=isl), PAYLOAD)
    hops = topo.hop_matrix()
    ring = ring_hops_matrix(K)
    for p in range(cfg.num_planes):
        blk = slice(p * K, (p + 1) * K)
        assert np.array_equal(hops[blk, blk], ring)
        assert np.array_equal(rt.latency[blk, blk], ring * t_hop)
    for p, q in ((0, 1), (0, 3), (2, 1), (2, 3)):
        bp, bq = slice(p * K, (p + 1) * K), slice(q * K, (q + 1) * K)
        assert np.all(hops[bp, bq] == -1)
        assert np.all(rt.hops[bp, bq] == -1)
        assert np.all(np.isinf(rt.latency[bp, bq]))
    for p, q in ((0, 2), (1, 3)):
        bp, bq = slice(p * K, (p + 1) * K), slice(q * K, (q + 1) * K)
        assert np.all(hops[bp, bq] >= 1)
        assert np.all(np.isfinite(rt.latency[bp, bq]))
    # a flood from component {0,2} must never reach component {1,3}
    t_recv, fhops, _ = rt.broadcast_times([topo.node(0, 0)], [100.0])
    reach = np.isfinite(t_recv).reshape(cfg.num_planes, K)
    assert np.all(reach[[0, 2]]) and not np.any(reach[[1, 3]])
    assert np.all(fhops.reshape(cfg.num_planes, K)[[1, 3]] == -1)


def test_seam_cut_clusters_respect_components():
    """Cluster formation must never group planes across a cut seam or
    across disconnected components (a cluster floods/relays
    internally)."""
    from repro.core.fedleo import form_clusters

    L = 6
    cfg = ConstellationConfig(num_planes=L, sats_per_plane=4)
    adj = ISLTopology(
        cfg, TopologyConfig(kind="grid", seam_cut=True)
    ).plane_adjacency()
    assert not adj[0, L - 1]            # the seam is cut
    for supply in (np.ones(L), np.arange(L, dtype=float)):
        for c in (2, 3, 4):
            groups = form_clusters(supply, c, seam_cut=True,
                                   adjacency=adj)
            assert sorted(p for g in groups for p in g) == list(range(L))
            for g in groups:
                # contiguous linear runs only: no {0, L-1} wrap group
                assert max(g) - min(g) == len(g) - 1


def test_sweep_fallback_matches_dijkstra(small_cfg):
    """The pure-numpy label-correcting solver (used when scipy is
    absent) must agree with the scipy fast path on every topology kind
    and weight ratio — including the extreme FSO asymmetry that makes
    min-latency paths circumnavigate planes."""
    for topo_cfg in (
        TopologyConfig(kind="ring"),
        TopologyConfig(kind="grid"),
        TopologyConfig(kind="grid", seam_cut=True),
        TopologyConfig(kind="motif", intra_slot_offsets=(1, 2)),
    ):
        topo = ISLTopology(small_cfg, topo_cfg)
        for w in ((1.0, 1.0), (256.0, 0.13), (1.0, 300.0)):
            ha_d, hb_d = topo._hop_split_dijkstra(*w)
            ha_s, hb_s = topo._hop_split_sweeps(*w)
            assert np.array_equal(ha_d == -1, ha_s == -1)
            # path costs must match exactly (the decomposition itself
            # may differ only between equal-cost paths)
            reach = ha_d >= 0
            c_d = ha_d * w[0] + hb_d * w[1]
            c_s = ha_s * w[0] + hb_s * w[1]
            assert np.allclose(c_d[reach], c_s[reach], rtol=0, atol=1e-9)


def test_motif_skip_ring_halves_diameter(small_cfg):
    ring = ISLTopology(small_cfg, TopologyConfig(kind="ring"))
    skip = ISLTopology(
        small_cfg,
        TopologyConfig(kind="motif", intra_slot_offsets=(1, 2),
                       inter_plane_offsets=()),
    )
    K = small_cfg.sats_per_plane
    blk_ring = ring.hop_matrix()[:K, :K]
    blk_skip = skip.hop_matrix()[:K, :K]
    assert blk_skip.max() < blk_ring.max()


def test_inter_isl_config_from_constellation(starlink_cfg):
    intra = ISLConfig.from_constellation(starlink_cfg, "intra")
    inter = ISLConfig.from_constellation(starlink_cfg, "inter")
    # real chord/c propagation delays, one-digit milliseconds at LEO
    assert 1e-3 < intra.hop_propagation_s < 20e-3
    assert 1e-3 < inter.hop_propagation_s < 20e-3
    # inter-plane links are FSO-provisioned, far above the RF intra rate
    assert inter.hop_rate_bps > 100 * intra.hop_rate_bps
    # overrides win
    assert ISLConfig.from_constellation(
        starlink_cfg, "intra", hop_propagation_s=0.0
    ).hop_propagation_s == 0.0


def test_routing_latency_mixes_edge_types(small_cfg):
    """A cross-plane path pays inter-plane hop times, not intra ones."""
    intra = ISLConfig()                       # slow RF
    inter = ISLConfig(hop_bandwidth_hz=250e6)  # fast FSO
    topo = ISLTopology(small_cfg, TopologyConfig(kind="grid"))
    rt = RoutingTable(topo, ISLPlan(intra=intra, inter=inter), PAYLOAD)
    t_a = isl_hop_time(intra, PAYLOAD)
    t_b = isl_hop_time(inter, PAYLOAD)
    assert np.allclose(
        rt.latency, rt.hops_intra * t_a + rt.hops_inter * t_b
    )
    # same-slot neighbors across planes: one cheap inter hop
    n0, n1 = topo.node(0, 0), topo.node(1, phased_slot_shift(small_cfg, 0, 1) % small_cfg.sats_per_plane)
    assert rt.hops_inter[n0, n1] == 1 and rt.hops_intra[n0, n1] == 0
    assert rt.latency[n0, n1] == t_b


# --- end-to-end FedLEOGrid ----------------------------------------------------
def _tiny_task(num_planes, sats_per_plane, samples_per_client=4):
    from repro.core import FederatedTask, TrainHyperparams
    from repro.data import make_classification_dataset, partition_iid
    from repro.models.cnn import apply_cnn, init_cnn
    from repro.optim import get_optimizer

    n = num_planes * sats_per_plane * samples_per_client
    ds = make_classification_dataset("mnist-like", num_samples=n, seed=0)
    test = make_classification_dataset("mnist-like", num_samples=64, seed=7)
    clients = partition_iid(ds, num_planes, sats_per_plane)
    hp = TrainHyperparams(local_epochs=100, learning_rate=0.05,
                          batch_size=16)
    return FederatedTask(
        init_fn=lambda r: init_cnn(r, (28, 28, 1), 10, widths=(4,),
                                   hidden=16),
        apply_fn=apply_cnn, clients=clients, test_set=test,
        optimizer=get_optimizer("sgd", 0.05), hp=hp, sim_epochs=1,
    )


def test_fedleo_grid_ring_mode_bit_identical_to_fedleo():
    from repro.core import FedLEO, FedLEOGrid, SimConfig

    cfg = ConstellationConfig(num_planes=3, sats_per_plane=6)
    sim = SimConfig(constellation=cfg, horizon_hours=48.0)
    sim_ring_graph = SimConfig(
        constellation=cfg, horizon_hours=48.0,
        topology=TopologyConfig(kind="grid", inter_plane_offsets=()),
    )
    ra = FedLEO(_tiny_task(3, 6), sim).run(max_rounds=2)
    rb = FedLEOGrid(_tiny_task(3, 6), sim_ring_graph,
                    cluster_planes=1).run(max_rounds=2)
    assert len(ra.history) == len(rb.history) == 2
    for ha, hb in zip(ra.history, rb.history):
        assert ha.t_hours == hb.t_hours
        for ea, eb in zip(ha.events["planes"], hb.events["clusters"]):
            assert eb["planes"] == [ea["plane"]]
            assert eb["source"] == (ea["plane"], ea["source_slot"])
            assert eb["sink"] == (ea["plane"], ea["sink_slot"])
            for k in ("t_broadcast_done", "t_models_at_sink",
                      "t_wait_sink", "t_upload_done"):
                assert ea[k] == eb[k]
        assert ha.metrics == hb.metrics


def test_fedleo_grid_cluster_round_small():
    from repro.core import FedLEOGrid, SimConfig

    cfg = ConstellationConfig(num_planes=4, sats_per_plane=6)
    sim = SimConfig(constellation=cfg, horizon_hours=48.0,
                    topology=TopologyConfig(kind="grid"))
    res = FedLEOGrid(_tiny_task(4, 6), sim, cluster_planes=2).run(
        max_rounds=2
    )
    assert len(res.history) == 2
    assert np.isfinite(res.final_accuracy)
    for h in res.history:
        assert len(h.events["clusters"]) == 2     # 4 planes / 2 per sink
        for ev in h.events["clusters"]:
            assert len(ev["planes"]) == 2
            assert ev["t_upload_done"] >= ev["t_models_at_sink"]
            assert ev["t_wait_sink"] >= 0.0


def test_fedleo_grid_round_starlink_40x22():
    """End-to-end FedLEOGrid round at mega-constellation scale: real
    (tiny-proxy) training for all 880 satellites, cluster sinks over
    the +Grid topology from the preset."""
    from repro.configs.constellations import make_sim_config
    from repro.core import FedLEOGrid

    sim = make_sim_config(
        "starlink-40x22", ground_stations=("rolla", "punta-arenas"),
        topology="auto", horizon_hours=6.0,
    )
    assert sim.topology.kind == "grid"
    assert sim.isl_inter is not None
    task = _tiny_task(40, 22, samples_per_client=2)
    strat = FedLEOGrid(task, sim, cluster_planes=4)
    res = strat.run(max_rounds=1)
    assert len(res.history) == 1
    assert np.isfinite(res.final_accuracy)
    clusters = res.history[0].events["clusters"]
    assert len(clusters) == 10                    # 40 planes / 4 per sink
    # every cluster's sink serves >= 2 planes via cross-plane relay:
    # 10 GS round-trips this round instead of 40
    for ev in clusters:
        assert len(ev["planes"]) == 4
        assert ev["t_upload_done"] >= ev["t_models_at_sink"] - 1e-6
