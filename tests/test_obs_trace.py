"""repro.obs: traced == untraced bit-identity, decomposition sanity,
trace export/report round-trips (ISSUE 7 acceptance).

The zero-interference contract: attaching a ``TraceRecorder``
(``SimConfig.trace=True``) must not perturb a single schedule, sink
decision, timestamp or metric — proven end-to-end here across the
ring, grid, station-handover and async-re-admission configurations on
real (tiny) JAX training runs.
"""
import dataclasses
import functools
import io
import json

import pytest

from repro.core import FedLEO, FedLEOGrid, SimConfig
from repro.core.baselines import ALL_BASELINES
from repro.obs import (
    NULL_RECORDER,
    TRACE_SCHEMA_VERSION,
    GroupDecomposition,
    RoundDecomposition,
    TraceRecorder,
    format_round_line,
    ledger_rb_utilization,
    mean_phase_seconds,
    round_log_record,
)
from repro.obs.export import read_trace, to_chrome_trace, write_trace
from repro.obs.report import main as report_main
from repro.obs.report import report, round_decompositions
from repro.obs.utilization import occupancy_timeline, trace_rb_utilization
from repro.orbits.constellation import ConstellationConfig, GroundStation
from repro.orbits.topology import TopologyConfig


def _small_task(num_planes=2, sats_per_plane=4):
    from repro.core import FederatedTask, TrainHyperparams
    from repro.data import make_classification_dataset, partition_iid
    from repro.models.cnn import apply_cnn, init_cnn
    from repro.optim import get_optimizer

    n = num_planes * sats_per_plane * 4
    ds = make_classification_dataset("mnist-like", num_samples=n, seed=0)
    test = make_classification_dataset("mnist-like", num_samples=64, seed=7)
    clients = partition_iid(ds, num_planes, sats_per_plane)
    hp = TrainHyperparams(local_epochs=100, learning_rate=0.05,
                          batch_size=16)
    return FederatedTask(
        init_fn=lambda r: init_cnn(r, (28, 28, 1), 10, widths=(4,),
                                   hidden=16),
        apply_fn=apply_cnn, clients=clients, test_set=test,
        optimizer=get_optimizer("sgd", 0.05), hp=hp, sim_epochs=1,
    )


def _two_stations():
    a = GroundStation()
    b = GroundStation(lat_deg=a.lat_deg + 4.0, lon_deg=a.lon_deg + 3.0,
                      name="GS-B")
    return a, b


_CFG = ConstellationConfig(num_planes=2, sats_per_plane=4)


def _sim_configs():
    """The four equivalence configurations of the acceptance criteria."""
    a, b = _two_stations()
    ring = SimConfig(constellation=_CFG, horizon_hours=48.0)
    grid = SimConfig(constellation=_CFG, horizon_hours=48.0,
                     topology=TopologyConfig(kind="grid"),
                     gs_rb_capacity=1)
    handover = SimConfig(constellation=_CFG, horizon_hours=48.0,
                         ground_stations=(a, b), gs_rb_capacity=1,
                         gs_handover=True)
    async_ = SimConfig(constellation=_CFG, horizon_hours=48.0,
                       gs_rb_capacity=1, async_readmit=True)
    return {
        "ring": (FedLEO, ring, {}),
        "grid": (FedLEOGrid, grid, {"cluster_planes": 2}),
        "handover": (FedLEO, handover, {}),
        "async": (ALL_BASELINES["AsyncFLEO"], async_, {}),
    }


def _run(cls, sim, kw, trace):
    strat = cls(_small_task(), dataclasses.replace(sim, trace=trace), **kw)
    res = strat.run(max_rounds=2)
    rec = strat.env.recorder
    if rec is not None:
        rec.detach()
    return res, rec


def _assert_identical(ra, rb):
    assert len(ra.history) == len(rb.history) and ra.history
    for ha, hb in zip(ra.history, rb.history):
        assert ha.t_hours == hb.t_hours
        assert ha.events == hb.events
        assert ha.metrics == hb.metrics
        assert ha.decomposition.as_dict() == hb.decomposition.as_dict()


# --- the acceptance criterion: traced == untraced, end to end ------------------
@pytest.mark.parametrize("config", ["ring", "grid", "handover", "async"])
def test_traced_run_bit_identical(config):
    cls, sim, kw = _sim_configs()[config]
    assert SimConfig().trace is False               # default off
    plain, rec_plain = _run(cls, sim, kw, trace=False)
    traced, rec = _run(cls, sim, kw, trace=True)
    assert rec_plain is None                        # untraced: no recorder
    _assert_identical(plain, traced)
    # and the trace actually recorded the session
    assert rec is not None and rec.events
    assert rec.counters.get("rounds") == len(traced.history)
    assert rec.counters.get("commit", 0) > 0


# --- decomposition sanity -------------------------------------------------------
def test_round_decomposition_structure():
    _, rec = _run(FedLEO, SimConfig(constellation=_CFG,
                                    horizon_hours=48.0), {}, trace=True)
    decomps = [
        RoundDecomposition.from_dict(ev.attrs["decomposition"])
        for ev in rec.events if ev.kind == "round"
    ]
    assert len(decomps) == 2
    for d in decomps:
        assert d.t_end > d.t_start and d.round_s > 0
        assert len(d.groups) == _CFG.num_planes    # one group per plane
        means = d.phase_means()
        assert means["groups"] == float(_CFG.num_planes)
        for g in d.groups:
            spans = g.phase_spans()
            # phases tile the group's round span exactly, in order
            assert spans[0][1] == g.t_round_start
            assert spans[-1][2] == g.t_upload_done
            for (_, a0, a1), (_, b0, b1) in zip(spans, spans[1:]):
                assert a1 == b0
            assert all(t1 >= t0 for _, t0, t1 in spans)
            assert g.queue_delay_s >= 0.0
            assert g.window_wait_s >= 0.0
            assert g.queue_delay_s <= g.sink_wait_s + 1e-9
            # round-trip through the dict form
            assert GroupDecomposition.from_dict(g.as_dict()) == g


def test_mean_phase_seconds_empty_and_engine_population():
    assert mean_phase_seconds([]) == {}
    # every HistoryPoint carries the decomposition even when tracing
    # is OFF (it replaces the events-dict scraping)
    res, rec = _run(FedLEO, SimConfig(constellation=_CFG,
                                      horizon_hours=48.0), {}, trace=False)
    assert rec is None
    for h in res.history:
        assert h.decomposition is not None
        assert h.decomposition.round_s == pytest.approx(
            (h.t_hours - (h.decomposition.t_start / 3600.0)) * 3600.0
        )
        assert h.decomposition.groups


# --- recorder primitives --------------------------------------------------------
def test_null_recorder_is_inert():
    before = len(NULL_RECORDER.events)
    NULL_RECORDER.span("x", "rounds", "s", 0.0, 1.0)
    NULL_RECORDER.instant("x", "rounds", "i", 0.0)
    NULL_RECORDER.count("c")
    NULL_RECORDER.on_round(
        RoundDecomposition(round_index=1, t_start=0.0, t_end=1.0)
    )
    NULL_RECORDER.detach()
    assert len(NULL_RECORDER.events) == before == 0
    assert NULL_RECORDER.counters == {}


def test_round_log_record_format_matches_legacy():
    metrics = {"accuracy": 0.51234, "loss": 1.9875}
    rec = round_log_record("fedleo", 3, 12.3456, metrics)
    line = format_round_line(rec)
    assert line == (
        "[fedleo] round   3 t=  12.35h acc=0.5123 loss=1.9875"
    )


# --- export round-trips ---------------------------------------------------------
@functools.lru_cache(maxsize=1)
def _traced_fixture():
    """One shared traced run: every consumer only READS the recorder."""
    _, rec = _run(
        FedLEO,
        SimConfig(constellation=_CFG, horizon_hours=48.0,
                  gs_rb_capacity=1),
        {}, trace=True,
    )
    return rec


def test_jsonl_write_read_round_trip(tmp_path):
    rec = _traced_fixture()
    path = str(tmp_path / "trace.jsonl")
    n = write_trace(rec, path)
    assert n == len(rec.events)
    meta, counters, events = read_trace(path)
    assert meta["schema"] == TRACE_SCHEMA_VERSION
    assert meta["stations"] == rec.meta["stations"]
    assert meta["rb_capacity"] == [1]
    assert counters == rec.counters
    assert [e.as_dict() for e in events] == [
        e.as_dict() for e in rec.events
    ]


def test_jsonl_corrupt_tail_and_append_merge(tmp_path):
    rec = _traced_fixture()
    path = str(tmp_path / "trace.jsonl")
    write_trace(rec, path)
    # corrupt tail: a truncated half-line must be skipped, not fatal
    with open(path, "a") as f:
        f.write('{"kind": "commit", "seq": 99, "tru')
    _, counters, events = read_trace(path)
    assert len(events) == len(rec.events)
    # append a second block: counters sum, events concatenate
    write_trace(rec, path, append=True)
    meta2, counters2, events2 = read_trace(path)
    assert len(events2) == 2 * len(rec.events)
    assert counters2 == {k: 2 * v for k, v in counters.items()}
    assert meta2["schema"] == TRACE_SCHEMA_VERSION


def test_chrome_trace_export_shape(tmp_path):
    rec = _traced_fixture()
    trace = to_chrome_trace(rec.meta, rec.events, rec.counters)
    evs = trace["traceEvents"]
    phases = {e["ph"] for e in evs}
    assert {"M", "X", "i", "C"} <= phases
    for e in evs:
        if e["ph"] == "X":
            assert e["dur"] > 0 and e["ts"] >= 0
        if e["ph"] == "C":
            assert "booked_rbs" in e["args"]
    # commit spans land on the station process with its name row
    names = [e for e in evs if e["ph"] == "M"
             and e["name"] == "thread_name"]
    assert any(e["args"]["name"].startswith("Rolla") for e in names)
    assert trace["otherData"]["schema"] == TRACE_SCHEMA_VERSION
    json.dumps(trace)                          # serializable end to end


# --- utilization ----------------------------------------------------------------
def test_trace_rb_utilization_from_synthetic_spans():
    rec = TraceRecorder()
    # station 0: one RB booked for [0, 50] and [50, 100] back to back
    rec.span("commit", "gs/0", "upload r1", 0.0, 50.0, rid=1)
    rec.span("commit", "gs/0", "upload r2", 50.0, 100.0, rid=2)
    # station 1: idle
    timeline = occupancy_timeline(rec.events)
    assert 0 in timeline and 1 not in timeline
    util = trace_rb_utilization(rec.events, 0.0, 200.0, capacities=[1, 1])
    assert util[0] == pytest.approx(0.5)
    # a release span cancels its commit in the occupancy integral
    rec.span("release", "gs/0", "release r2", 50.0, 100.0, rid=2)
    util = trace_rb_utilization(rec.events, 0.0, 200.0, capacities=[1, 1])
    assert util[0] == pytest.approx(0.25)


def test_ledger_utilization_matches_trace_utilization():
    rec = _traced_fixture()
    spans = [e for e in rec.events if e.kind == "commit"]
    t1 = max(e.t_end_s for e in spans)
    from_trace = trace_rb_utilization(
        rec.events, 0.0, t1, capacities=rec.meta["rb_capacity"]
    )
    assert from_trace and all(0.0 < u <= 1.0 for u in from_trace.values())


def test_ledger_rb_utilization_direct():
    from repro.comms.ledger import GSResourceLedger

    led = GSResourceLedger(2, 2)
    led.reserve(0, 0.0, 50.0)
    led.reserve(0, 0.0, 100.0)
    util = ledger_rb_utilization(led, 0.0, 100.0)
    assert util[0] == pytest.approx((50.0 + 100.0) / (100.0 * 2))
    assert util[1] == 0.0


# --- reporter CLI ---------------------------------------------------------------
def test_reporter_round_trip(tmp_path):
    rec = _traced_fixture()
    path = str(tmp_path / "trace.jsonl")
    perfetto = str(tmp_path / "trace.perfetto.json")
    write_trace(rec, path)
    out = io.StringIO()
    summary = report(path, perfetto_out=perfetto, out=out)
    assert summary["rounds"] == 2
    assert summary["events"] == len(rec.events)
    text = out.getvalue()
    assert "per-round phase decomposition" in text
    assert "RB utilization" in text
    assert "session counters" in text
    with open(perfetto) as f:
        loaded = json.load(f)
    assert loaded["traceEvents"]
    # the decompositions survive the file round-trip bit-exactly
    _, _, events = read_trace(path)
    decomps = round_decompositions(events)
    assert [d.as_dict() for d in decomps] == [
        ev.attrs["decomposition"] for ev in rec.events
        if ev.kind == "round"
    ]


def test_reporter_main_exit_codes(tmp_path, capsys):
    rec = _traced_fixture()
    path = str(tmp_path / "trace.jsonl")
    write_trace(rec, path)
    assert report_main([path]) == 0
    capsys.readouterr()
    assert report_main([str(tmp_path / "missing.jsonl")]) == 2
    assert "error" in capsys.readouterr().err


# --- recorder lifecycle ---------------------------------------------------------
def test_detach_unhooks_everything():
    from repro.comms.environment import CommsEnvironment

    sim = SimConfig(constellation=_CFG, horizon_hours=24.0, trace=True)
    env = CommsEnvironment.from_sim(sim)
    rec = env.recorder
    assert rec is not None and env.predictor.recorder is rec
    rec.detach()
    assert env.recorder is None and env.predictor.recorder is None
    rec.detach()                               # idempotent
    # a detached recorder keeps its collected data readable
    assert isinstance(rec.events, list) and isinstance(rec.counters, dict)
