"""Link-budget model tests (paper eqs. 5-8, 13-16, 20)."""
import math

import pytest
from hypothesis import given, strategies as st

from repro.comms import (
    ISLConfig,
    LinkConfig,
    downlink_time,
    free_space_path_loss,
    isl_hop_time,
    model_exchange_time,
    propagation_time,
    relay_time,
    shannon_rate,
    snr_db,
    snr_linear,
    transmission_time,
    uplink_time,
)


def test_path_loss_formula():
    # eq. (6) at d=1000 km, f=2.4 GHz
    L = free_space_path_loss(1.0e6, 2.4e9)
    expected_db = 20 * math.log10(4 * math.pi * 1.0e6 * 2.4e9 / 299792458.0)
    assert abs(10 * math.log10(L) - expected_db) < 1e-9


@given(st.floats(min_value=500e3, max_value=3000e3))
def test_snr_decreases_with_distance(d):
    cfg = LinkConfig()
    assert snr_linear(cfg, d) > snr_linear(cfg, d * 1.5)


def test_shannon_rate_capped_at_table1():
    cfg = LinkConfig()
    # paper Table I: R = 16 Mb/s max
    r = shannon_rate(cfg, 1500e3)
    assert r <= 16e6 + 1e-9
    assert r > 1e6  # the 1500 km LEO link is comfortably above 1 Mb/s


@given(
    st.floats(min_value=1e6, max_value=1e9),
    st.floats(min_value=500e3, max_value=3000e3),
)
def test_exchange_time_components(bits, d):
    cfg = LinkConfig()
    t = model_exchange_time(cfg, bits, d)
    rate = shannon_rate(cfg, d)
    assert t >= transmission_time(bits, rate)
    assert t >= propagation_time(d)
    assert abs(t - (bits / rate + d / 299792458.0)) < 1e-9


def test_uplink_faster_than_downlink():
    # uplink uses full B; downlink one RB of B/N (eqs. 15 vs 16)
    cfg = LinkConfig()
    bits = 32e6
    assert uplink_time(cfg, bits, 1500e3) < downlink_time(cfg, bits, 1500e3)


@given(st.integers(min_value=1, max_value=8),
       st.floats(min_value=1e6, max_value=1e8))
def test_relay_time_linear_in_hops(h, bits):
    isl = ISLConfig()
    assert abs(relay_time(isl, bits, h) - h * isl_hop_time(isl, bits)) < 1e-9
