"""GS resource-block contention, rolling horizon, dynamic clusters.

The load-bearing guarantees of the contention-aware scheduling stack:
  * ``GSResourceLedger`` interval bookkeeping is exact (capacity,
    half-open intervals, earliest feasible fit);
  * with unlimited (or unreached) capacity the ledger-aware planner is
    BIT-IDENTICAL to the contention-free one — today's behavior is the
    degenerate case;
  * under scarce capacity, concurrent uploads on one station serialize
    (never double-book) and completion is monotonically delayed;
  * a rolling-horizon predictor grows its window table chunk-by-chunk
    into exactly the prebuilt table, and scheduling queries
    extend-and-retry instead of silently returning None;
  * dynamic cluster formation covers every plane exactly once, respects
    seam cuts and inter-plane connectivity, and degenerates to static
    single-plane clusters on a ring.
"""
import json

import numpy as np
import pytest

from repro.comms import GSResourceLedger, ISLConfig, LinkConfig
from repro.core.fedleo import (
    form_clusters,
    make_clusters,
    plan_plane_round,
)
from repro.core.scheduling import (
    reserve_decision,
    select_sink,
    select_sink_cluster,
)
from repro.orbits import (
    ConstellationConfig,
    GroundStation,
    TopologyConfig,
    VisibilityPredictor,
    WalkerDelta,
)
from repro.orbits.constellation import Satellite

PAYLOAD = 3.2e7


@pytest.fixture(scope="module")
def world():
    cfg = ConstellationConfig(num_planes=3, sats_per_plane=6)
    walker = WalkerDelta(cfg)
    from repro.configs.constellations import GROUND_STATION_PRESETS

    gss = [GroundStation(), GROUND_STATION_PRESETS["punta-arenas"]]
    pred = VisibilityPredictor(walker, gss, horizon_s=24 * 3600)
    return cfg, walker, gss, pred


# --- ledger bookkeeping -------------------------------------------------------
def test_ledger_earliest_fit_capacity_one():
    led = GSResourceLedger(2, 1)
    led.reserve(0, 10.0, 20.0)
    assert led.earliest_fit(0, 0.0, 100.0, 5.0) == 0.0
    assert led.earliest_fit(0, 8.0, 100.0, 5.0) == 20.0      # pushed past
    assert led.earliest_fit(0, 12.0, 100.0, 3.0) == 20.0
    assert led.earliest_fit(0, 8.0, 15.0, 5.0) is None       # window too short
    assert led.earliest_fit(1, 8.0, 100.0, 5.0) == 8.0       # other station


def test_ledger_capacity_counts_concurrency():
    led = GSResourceLedger(1, 2)
    led.reserve(0, 10.0, 20.0)
    assert led.earliest_fit(0, 8.0, 100.0, 5.0) == 8.0       # one RB free
    led.reserve(0, 12.0, 30.0)
    # [12, 20) saturated: earliest feasible start is the first release
    assert led.earliest_fit(0, 8.0, 100.0, 5.0) == 20.0
    assert led.earliest_fit(0, 0.0, 100.0, 2.0) == 0.0       # fits before


def test_ledger_half_open_intervals_and_release():
    led = GSResourceLedger(1, 1)
    led.reserve(0, 0.0, 10.0)
    led.reserve(0, 10.0, 20.0)          # back-to-back is legal
    assert led.occupancy(0, 10.0) == 1
    assert led.earliest_fit(0, 0.0, 100.0, 1.0) == 20.0
    led.release_before(10.0)
    a, b = led.busy_intervals(0)
    assert list(a) == [10.0] and list(b) == [20.0]


def test_ledger_unlimited_is_identity():
    led = GSResourceLedger(1, None)
    for _ in range(64):
        led.reserve(0, 0.0, 1e9)
    assert led.earliest_fit(0, 3.0, 4.0, 1e6) == 3.0
    with pytest.raises(ValueError):
        GSResourceLedger(1, 0)


# --- degenerate-case equivalence ----------------------------------------------
def test_unreached_capacity_bit_identical_to_no_ledger(world):
    """Pre-booked capacity below the cap must not perturb a single
    decision — the ledger-aware planner IS the old planner until a
    station saturates."""
    cfg, walker, gss, pred = world
    link, isl = LinkConfig(), ISLConfig()
    K = cfg.sats_per_plane
    t_done = [3600.0 + 60.0 * s for s in range(K)]
    led = GSResourceLedger(len(gss), 4)
    led.reserve(0, 0.0, 1e6)            # 1 of 4 RBs busy all day
    led.reserve(1, 0.0, 1e6)
    for plane in range(cfg.num_planes):
        a = select_sink(walker=walker, gs=gss, predictor=pred, link=link,
                        isl=isl, plane=plane, t_train_done=t_done,
                        payload_bits=PAYLOAD)
        b = select_sink(walker=walker, gs=gss, predictor=pred, link=link,
                        isl=isl, plane=plane, t_train_done=t_done,
                        payload_bits=PAYLOAD, ledger=led)
        assert a is not None and a == b


def test_scarce_capacity_serializes_same_station(world):
    """Two identical plane rounds against a 1-RB ledger: the second
    upload must not overlap the first on the same station, and its
    completion can only move later."""
    cfg, walker, gss, pred = world
    link, isl = LinkConfig(), ISLConfig()
    K = cfg.sats_per_plane
    t_done = [3600.0] * K
    led = GSResourceLedger(len(gss), 1)

    free = select_sink(walker=walker, gs=gss, predictor=pred, link=link,
                       isl=isl, plane=0, t_train_done=t_done,
                       payload_bits=PAYLOAD)
    first = select_sink(walker=walker, gs=gss, predictor=pred, link=link,
                        isl=isl, plane=0, t_train_done=t_done,
                        payload_bits=PAYLOAD, ledger=led)
    assert first == free                # empty ledger: degenerate case
    reserve_decision(led, first)
    second = select_sink(walker=walker, gs=gss, predictor=pred, link=link,
                         isl=isl, plane=0, t_train_done=t_done,
                         payload_bits=PAYLOAD, ledger=led)
    assert second is not None
    assert second.t_upload_done >= first.t_upload_done
    if second.window.gs_index == first.window.gs_index:
        # same station: the occupied stretch may not overlap
        assert (second.t_upload_start >= first.t_upload_done
                or second.t_upload_done <= first.t_upload_start)
    reserve_decision(led, second)
    # the ledger never over-books: max concurrency <= capacity
    for gi in range(len(gss)):
        s, e = led.reservations(gi)
        for t in np.concatenate([s, e - 1e-9]):
            assert led.occupancy(gi, float(t)) <= 1


def test_fedleo_strategy_unlimited_capacity_bit_identical():
    """End-to-end engine guard: FedLEO with a huge-but-finite RB cap
    reproduces the contention-free run exactly (schedules, times,
    metrics)."""
    from repro.core import FedLEO, SimConfig
    from tests.test_topology_routing import _tiny_task

    cfg = ConstellationConfig(num_planes=3, sats_per_plane=6)
    sim_free = SimConfig(constellation=cfg, horizon_hours=48.0)
    sim_cap = SimConfig(constellation=cfg, horizon_hours=48.0,
                        gs_rb_capacity=10_000)
    ra = FedLEO(_tiny_task(3, 6), sim_free).run(max_rounds=2)
    rb = FedLEO(_tiny_task(3, 6), sim_cap).run(max_rounds=2)
    assert len(ra.history) == len(rb.history) == 2
    for ha, hb in zip(ra.history, rb.history):
        assert ha.t_hours == hb.t_hours
        assert ha.events == hb.events
        assert ha.metrics == hb.metrics


def test_fedleo_grid_contended_round_runs():
    """FedLEOGrid with a 1-RB ledger and rolling horizon completes
    rounds; uploads on any one station never overlap."""
    from repro.core import FedLEOGrid, SimConfig
    from tests.test_topology_routing import _tiny_task

    cfg = ConstellationConfig(num_planes=4, sats_per_plane=6)
    sim = SimConfig(constellation=cfg, horizon_hours=48.0,
                    topology=TopologyConfig(kind="grid"),
                    gs_rb_capacity=1, rolling_horizon_hours=12.0)
    strat = FedLEOGrid(_tiny_task(4, 6), sim, cluster_planes=2)
    res = strat.run(max_rounds=2)
    assert len(res.history) == 2
    assert np.isfinite(res.final_accuracy)
    s, e = strat.ledger.reservations(0)
    order = np.argsort(s)
    assert np.all(s[order][1:] >= e[order][:-1] - 1e-9)   # serialized


# --- rolling horizon ----------------------------------------------------------
def test_rolling_table_identical_to_prebuilt(world):
    cfg, walker, gss, _ = world
    H = 12 * 3600.0
    pre = VisibilityPredictor(walker, gss, horizon_s=H)
    roll = VisibilityPredictor(walker, gss, horizon_s=3 * 3600.0,
                               rolling=True, max_horizon_s=H)
    assert roll.built_end == 3 * 3600.0
    assert roll.ensure_horizon(H)
    assert roll.built_end == H
    for f in ("plane", "slot", "t_start", "t_end", "gs_index"):
        assert np.array_equal(getattr(pre.table, f)[: len(roll.table)],
                              getattr(roll.table, f))
    # prebuilt covers 24 h here? no — both capped at 12 h: same length
    assert len(pre.table) == len(roll.table)
    assert not roll.extend_once()       # cap reached
    assert not pre.extend_once()        # non-rolling never extends


def test_rolling_queries_match_prebuilt(world):
    cfg, walker, gss, pred24 = world
    H = 24 * 3600.0
    roll = VisibilityPredictor(walker, gss, horizon_s=1800.0,
                               rolling=True, max_horizon_s=H)
    for p in range(cfg.num_planes):
        for s in range(cfg.sats_per_plane):
            for t in (0.0, 4000.0, 11 * 3600.0):
                assert (roll.next_window(Satellite(p, s), t)
                        == pred24.next_window(Satellite(p, s), t))


def test_rolling_select_sink_matches_prebuilt(world):
    cfg, walker, gss, pred24 = world
    link, isl = LinkConfig(), ISLConfig()
    K = cfg.sats_per_plane
    roll = VisibilityPredictor(walker, gss, horizon_s=600.0,
                               rolling=True, max_horizon_s=24 * 3600.0)
    t_done = [3600.0 + 60.0 * s for s in range(K)]
    for plane in range(cfg.num_planes):
        a = select_sink(walker=walker, gs=gss, predictor=pred24, link=link,
                        isl=isl, plane=plane, t_train_done=t_done,
                        payload_bits=PAYLOAD)
        b = select_sink(walker=walker, gs=gss, predictor=roll, link=link,
                        isl=isl, plane=plane, t_train_done=t_done,
                        payload_bits=PAYLOAD)
        assert a is not None and b is not None
        assert (a.sink_slot, a.t_upload_start, a.t_upload_done,
                a.t_wait, a.window) == \
               (b.sink_slot, b.t_upload_start, b.t_upload_done,
                b.t_wait, b.window)


def test_naive_sink_slot_extends_instead_of_none(world):
    """Satellite task: near the horizon end a plane used to silently
    drop out (next_window -> None).  The rolling predictor must extend
    and answer what a longer prebuilt table would."""
    from repro.core.scheduling import naive_sink_slot

    cfg, walker, gss, pred24 = world
    t_late = 2 * 3600.0                 # past the short initial chunk
    short = VisibilityPredictor(walker, gss, horizon_s=600.0)
    roll = VisibilityPredictor(walker, gss, horizon_s=600.0,
                               rolling=True, max_horizon_s=24 * 3600.0)
    for plane in range(cfg.num_planes):
        assert naive_sink_slot(short, plane, t_late) is None    # old symptom
        assert (naive_sink_slot(roll, plane, t_late)
                == naive_sink_slot(pred24, plane, t_late))


def test_rolling_predictor_guards():
    cfg = ConstellationConfig(num_planes=2, sats_per_plane=4)
    walker = WalkerDelta(cfg)
    with pytest.raises(ValueError):
        VisibilityPredictor(walker, GroundStation(), horizon_s=600.0,
                            rolling=True)                # no max_horizon_s
    with pytest.raises(ValueError):
        VisibilityPredictor(walker, GroundStation(), horizon_s=600.0,
                            rolling=True, max_horizon_s=3600.0,
                            engine="reference")


# --- dynamic cluster formation ------------------------------------------------
def test_form_clusters_partition_and_sizes():
    supply = np.arange(12, dtype=float)
    for c in (1, 2, 3, 4, 5):
        groups = form_clusters(supply, c)
        flat = sorted(p for g in groups for p in g)
        assert flat == list(range(12))                  # exact cover
        assert all(len(g) <= c for g in groups)
        assert groups == sorted(groups, key=lambda g: g[0])


def test_form_clusters_uniform_supply_is_static():
    """Ties resolve to rotation 0 — the static make_clusters grouping."""
    for L, c in ((12, 4), (8, 2), (5, 8)):
        assert form_clusters(np.ones(L), c) == make_clusters(L, c)


def test_form_clusters_rotation_follows_supply():
    # adjacent anchors 0 and 1 are the only well-served planes; with
    # L=8, c=4 rotation 0 buries both in one cluster (score 5) while
    # rotation 1 gives each cluster its own anchor (score 10) — the
    # anchor-separating rotation must win
    supply = np.array([5.0, 5.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0])
    groups = form_clusters(supply, 4)
    per_cluster = [max(supply[list(g)]) for g in groups]
    assert sorted(per_cluster, reverse=True)[:2] == [5.0, 5.0]


def test_form_clusters_never_cross_cut_seam():
    """Clusters must never be formed across a cut polar seam."""
    L, c = 10, 4
    for supply in (np.ones(L), np.arange(L, dtype=float),
                   np.arange(L, 0, -1, dtype=float)):
        groups = form_clusters(supply, c, seam_cut=True)
        for g in groups:
            assert max(g) - min(g) == len(g) - 1        # linear contiguity
        flat = sorted(p for g in groups for p in g)
        assert flat == list(range(L))


def test_form_clusters_splits_disconnected_runs():
    """A topology without usable inter-plane links degenerates to
    single-plane clusters; partially connected runs split into their
    components."""
    from repro.orbits import ISLTopology

    L = 6
    cfg = ConstellationConfig(num_planes=L, sats_per_plane=4)
    ring_adj = ISLTopology(cfg, TopologyConfig(kind="ring")).plane_adjacency()
    groups = form_clusters(np.ones(L), 3, adjacency=ring_adj)
    assert groups == [(p,) for p in range(L)]
    # offset-2 seam-cut grid: components {0,2,4} and {1,3,5}
    adj = ISLTopology(
        cfg,
        TopologyConfig(kind="motif", inter_plane_offsets=(2,),
                       seam_cut=True),
    ).plane_adjacency()
    groups = form_clusters(np.ones(L), 2, seam_cut=True, adjacency=adj)
    flat = sorted(p for g in groups for p in g)
    assert flat == list(range(L))
    for g in groups:
        assert all(adj[a, b] for a in g for b in g if a != b) or len(g) == 1


def test_fedleo_grid_dynamic_clusters_respond_to_supply():
    """The strategy's per-round grouping is a valid partition sized by
    cluster_planes and differs across rounds only through supply."""
    from repro.core import FedLEOGrid, SimConfig
    from tests.test_topology_routing import _tiny_task

    cfg = ConstellationConfig(num_planes=6, sats_per_plane=4)
    sim = SimConfig(constellation=cfg, horizon_hours=48.0,
                    topology=TopologyConfig(kind="grid"))
    strat = FedLEOGrid(_tiny_task(6, 4), sim, cluster_planes=3)
    for t in (0.0, 3 * 3600.0, 9 * 3600.0):
        groups = strat.round_clusters(t)
        flat = sorted(p for g in groups for p in g)
        assert flat == list(range(6))
        assert all(len(g) <= 3 for g in groups)
    static = FedLEOGrid(_tiny_task(6, 4), sim, cluster_planes=3,
                        dynamic_clusters=False)
    assert static.round_clusters(0.0) == static.clusters


# --- benchmark substrate ------------------------------------------------------
def test_append_bench_tolerates_truncated_last_line(tmp_path):
    from benchmarks.common import BENCH_SCHEMA, append_bench

    path = tmp_path / "BENCH.json"
    path.write_text('{"bench": "old", "ok": true}\n{"bench": "trunc')
    rec = {"bench": "new", "x": 1}
    append_bench(rec, str(path))
    lines = path.read_text().splitlines()
    assert json.loads(lines[0]) == {"bench": "old", "ok": True}
    last = json.loads(lines[-1])                        # parseable append,
    assert last.pop("schema") == BENCH_SCHEMA           # stamped with the
    assert last.pop("run_id")                           # schema + run id
    assert last == rec                                  # (caller's dict kept)
    assert rec == {"bench": "new", "x": 1}
    assert len(lines) == 3                              # partial quarantined
    # healthy files are appended without extra separators
    append_bench(rec, str(path))
    assert len(path.read_text().splitlines()) == 4
