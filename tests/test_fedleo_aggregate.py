"""Pallas aggregation on real model pytrees: ``make_fedleo_aggregate``
kernel path vs reference, and the staleness weighting (ISSUE 10).

The kernel path flattens every replicated leaf into one (R, N) stream
through ``kernels.aggregate_flat`` (interpret mode on CPU).  Parity is
checked against BOTH the in-module reference path and the per-leaf
fp32 ``aggregate_flat_ref`` ground truth, over ragged leaf shapes
(conv kernels, biases, dense mats), bf16/f32/mixed dtypes, zero-weight
replicas, and staleness-discounted weights.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.aggregate_ref import aggregate_flat_ref
from repro.models.cnn import init_cnn
from repro.optim import get_optimizer
from repro.train.fedleo_step import make_fedleo_aggregate, staleness_weights
from repro.train.steps import TrainState

R = 4


def _stacked_cnn_state(dtype=jnp.float32, seed=0):
    """A real CNN TrainState with the leading orbit-replica axis R:
    ragged leaves (4-D conv kernels, 1-D biases, 2-D dense mats)."""
    params = init_cnn(jax.random.PRNGKey(seed), (28, 28, 1), 10,
                      widths=(8, 16), hidden=32)
    params = jax.tree_util.tree_map(lambda p: p.astype(dtype), params)
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), R)

    def stack(p):
        return jnp.stack([
            p + 0.01 * jax.random.normal(keys[i], p.shape, p.dtype)
            .astype(p.dtype) for i in range(R)
        ])

    stacked = jax.tree_util.tree_map(stack, params)
    opt = get_optimizer("sgd", 0.05)
    return TrainState(
        params=stacked, opt_state=opt.init(stacked),
        step=jnp.zeros((), jnp.int32),
    )


def _max_err(a: TrainState, b: TrainState) -> float:
    errs = jax.tree_util.tree_map(
        lambda x, y: float(jnp.max(jnp.abs(
            x.astype(jnp.float32) - y.astype(jnp.float32)
        ))) if x.ndim else abs(float(x) - float(y)),
        a, b,
    )
    return max(jax.tree_util.tree_leaves(errs), default=0.0)


@pytest.mark.parametrize("dtype,tol", [
    (jnp.float32, 1e-6),
    (jnp.bfloat16, 1e-6),   # both paths accumulate in f32
])
def test_kernel_matches_reference_on_real_pytree(dtype, tol):
    state = _stacked_cnn_state(dtype)
    w = jnp.array([1.0, 2.0, 3.0, 4.0])
    ref = make_fedleo_aggregate(use_kernel=False)(state, w)
    ker = make_fedleo_aggregate(use_kernel=True)(state, w)
    assert _max_err(ref, ker) <= tol
    # dtypes survive the kernel round-trip
    for a, b in zip(jax.tree_util.tree_leaves(ref.params),
                    jax.tree_util.tree_leaves(ker.params)):
        assert a.dtype == b.dtype


def test_kernel_matches_flat_ref_ground_truth():
    """Each replicated param leaf must equal the fp32 per-leaf
    ``aggregate_flat_ref`` ground truth broadcast back over R."""
    state = _stacked_cnn_state(jnp.float32)
    w = jnp.array([0.5, 1.5, 2.0, 1.0])
    wn = w / jnp.sum(w)
    out = make_fedleo_aggregate(use_kernel=True)(state, w)
    for leaf, agg in zip(jax.tree_util.tree_leaves(state.params),
                         jax.tree_util.tree_leaves(out.params)):
        gt = aggregate_flat_ref(leaf.reshape(R, -1), wn)
        np.testing.assert_allclose(
            np.asarray(agg[0].reshape(-1), np.float32),
            np.asarray(gt, np.float32), atol=1e-6,
        )
        # every replica row carries the same aggregated model
        np.testing.assert_array_equal(np.asarray(agg[0]),
                                      np.asarray(agg[-1]))


def test_zero_weight_replica_excluded():
    state = _stacked_cnn_state(jnp.float32)
    w = jnp.array([1.0, 0.0, 1.0, 1.0])
    for use_kernel in (False, True):
        out = make_fedleo_aggregate(use_kernel=use_kernel)(state, w)
        # perturb replica 1 only: a zero-weight client must not move
        # the aggregate
        poisoned = jax.tree_util.tree_map(
            lambda x: x.at[1].mul(100.0) if x.ndim else x, state.params
        )
        out2 = make_fedleo_aggregate(use_kernel=use_kernel)(
            TrainState(params=poisoned, opt_state=state.opt_state,
                       step=state.step), w,
        )
        assert _max_err(out, out2) == 0.0


def test_scalar_and_step_leaves_pass_through():
    state = _stacked_cnn_state(jnp.float32)
    w = jnp.ones(R)
    for use_kernel in (False, True):
        out = make_fedleo_aggregate(use_kernel=use_kernel)(state, w)
        assert int(out.step) == int(state.step)


def test_mixed_dtype_tree_parity():
    state = _stacked_cnn_state(jnp.float32)
    # make one param leaf bf16: exercises the common-dtype concat path
    leaves, treedef = jax.tree_util.tree_flatten(state.params)
    leaves[0] = leaves[0].astype(jnp.bfloat16)
    params = jax.tree_util.tree_unflatten(treedef, leaves)
    state = TrainState(params=params, opt_state=state.opt_state,
                       step=state.step)
    w = jnp.array([1.0, 2.0, 3.0, 4.0])
    ref = make_fedleo_aggregate(use_kernel=False)(state, w)
    ker = make_fedleo_aggregate(use_kernel=True)(state, w)
    assert _max_err(ref, ker) <= 1e-2   # bf16 output rounding
    assert jax.tree_util.tree_leaves(ker.params)[0].dtype == jnp.bfloat16


class TestStalenessWeights:
    def test_zero_staleness_is_identity(self):
        w = jnp.array([1.0, 2.0, 3.0])
        out = staleness_weights(w, jnp.zeros(3))
        np.testing.assert_allclose(np.asarray(out), np.asarray(w))

    def test_staler_is_discounted_monotonically(self):
        w = jnp.ones(3)
        out = staleness_weights(w, jnp.array([0.0, 3600.0, 7200.0]))
        assert out[0] > out[1] > out[2] > 0

    def test_one_hour_at_default_power(self):
        out = staleness_weights(jnp.ones(1), jnp.array([3600.0]))
        assert float(out[0]) == pytest.approx(2.0 ** -0.5)

    def test_aggregate_accepts_staleness(self):
        state = _stacked_cnn_state(jnp.float32)
        w = jnp.ones(R)
        stale = jnp.array([0.0, 0.0, 7200.0, 7200.0])
        plain = make_fedleo_aggregate()(state, w)
        disc = make_fedleo_aggregate()(state, w, stale)
        assert _max_err(plain, disc) > 0.0    # discount moved the mean
        ker = make_fedleo_aggregate(use_kernel=True)(state, w, stale)
        assert _max_err(disc, ker) <= 1e-6
