"""Launch-layer integration: lower+compile on a small fake-device mesh.

Runs in a SUBPROCESS because the forced host-device count must be set
before jax initializes (the main test process keeps 1 device, per the
dry-run isolation rule).  Uses smoke configs so the whole thing takes
seconds; the full 40-pair × 2-mesh sweep artifacts live in
dryrun_single_pod.jsonl / dryrun_multi_pod.jsonl.
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.launch.dryrun import collective_bytes, cost_analysis_dict, lower_pair
from repro.launch.mesh import make_mesh_compat

mesh = make_mesh_compat((2, 2, 2), ("pod", "data", "model"))
out = {}
for arch, shape in [("gemma-7b", "train_4k"),
                    ("kimi-k2-1t-a32b", "train_4k"),
                    ("mamba2-780m", "decode_32k")]:
    import dataclasses
    from repro.configs.base import INPUT_SHAPES
    cfg = get_smoke_config(arch)
    # shrink the input shape for speed
    sh = INPUT_SHAPES[shape]
    INPUT_SHAPES[shape] = dataclasses.replace(sh, seq_len=256,
                                              global_batch=8)
    try:
        lowered, meta = lower_pair(arch, shape, mesh, cfg=cfg)
        compiled = lowered.compile()
        cost = cost_analysis_dict(compiled)
        coll = collective_bytes(compiled.as_text())
        out[f"{arch}|{shape}"] = {
            "ok": True,
            "flops": float(cost.get("flops", -1)),
            "collectives": {k: float(v) for k, v in coll.items()},
        }
    finally:
        INPUT_SHAPES[shape] = sh
print("RESULT:" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def dryrun_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    # forced host-device count only multiplies the CPU platform; pin it
    # so jax never probes a (baked-in but absent) TPU backend for 60 s
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, timeout=540, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout[-2000:]
    return json.loads(line[0][len("RESULT:"):])


def test_lower_compile_on_multipod_mesh(dryrun_result):
    assert len(dryrun_result) == 3
    for key, rec in dryrun_result.items():
        assert rec["ok"], key
        assert rec["flops"] > 0, key


def test_train_step_has_gradient_collectives(dryrun_result):
    rec = dryrun_result["gemma-7b|train_4k"]
    # data-parallel gradient sync must appear as collective traffic
    assert sum(rec["collectives"].values()) > 0


def test_moe_dispatch_lowered(dryrun_result):
    assert dryrun_result["kimi-k2-1t-a32b|train_4k"]["ok"]


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes

    hlo = """
      %ag = bf16[8,128]{1,0} all-gather(%x), dimensions={0}
      %ar.1 = f32[64]{0} all-reduce(%y), to_apply=%add
      %a2a = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(%p, %q)
      %done = f32[64]{0} all-reduce-done(%ar.1)
    """
    out = collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 2
    assert out["all-reduce"] == 64 * 4
    assert out["all-to-all"] == 2 * 16 * 4
