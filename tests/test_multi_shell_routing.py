"""ISSUE 8 tentpole: multi-shell topology + memory-lean routing.

Covers the four equivalence contracts the refactor must hold:

  * single-shell ``MultiShellTopology`` is the exact degenerate case of
    ``ISLTopology`` (edge set, hop matrices, schedules — bit-identical),
  * ``RoutingTable(lazy=True)`` answers broadcast/submatrix/relay
    queries identically to the eager all-pairs build,
  * ``hop_split_rows`` (per-source Dijkstra) matches the full solver's
    rows (exact unreachable masks, optimal costs),
  * ``MultiShellWalker`` dispatches to the same geometry the per-shell
    ``WalkerDelta`` computes,

plus the benchmark-side helpers (``overhead_fraction`` clamping,
``measure_peak_mb``) and the compact hop dtypes.
"""
import numpy as np
import pytest

from repro.comms.isl import ISLConfig
from repro.comms.routing import ISLPlan, RoutingTable
from repro.orbits import (
    INTER,
    INTRA,
    ConstellationConfig,
    GroundStation,
    ISLTopology,
    MultiShellConfig,
    MultiShellTopology,
    MultiShellWalker,
    Satellite,
    TopologyConfig,
    WalkerDelta,
    get_isl_topology,
    make_walker,
)
from repro.orbits.topology import UNREACHABLE, _count_dtype

PAYLOAD = 1.28e8

SHELL_A = ConstellationConfig(
    num_planes=8, sats_per_plane=11, altitude_m=550e3,
    inclination_deg=53.0, phasing_factor=3,
)
SHELL_B = ConstellationConfig(
    num_planes=6, sats_per_plane=11, altitude_m=570e3,
    inclination_deg=70.0, phasing_factor=1,
)


@pytest.fixture(scope="module")
def two_shell():
    return MultiShellConfig(shells=(SHELL_A, SHELL_B))


@pytest.fixture(scope="module")
def grid_cfg():
    return TopologyConfig(kind="grid")


# --- config surface ---------------------------------------------------------------
def test_multi_shell_config_properties(two_shell):
    assert two_shell.num_planes == 14
    assert two_shell.sats_per_plane == 11
    assert two_shell.num_satellites == 154
    assert two_shell.plane_offsets == (0, 8)
    assert two_shell.shell_of_plane(0) == 0
    assert two_shell.shell_of_plane(7) == 0
    assert two_shell.shell_of_plane(8) == 1
    assert two_shell.shell_of_plane(13) == 1
    with pytest.raises(ValueError):
        two_shell.shell_of_plane(14)
    # the slowest (highest) shell sets the conservative period
    assert two_shell.period_s == SHELL_B.period_s
    assert two_shell.altitude_m == SHELL_A.altitude_m


def test_multi_shell_config_rejects_ragged_grid():
    with pytest.raises(ValueError):
        MultiShellConfig(shells=(
            SHELL_A,
            ConstellationConfig(num_planes=4, sats_per_plane=9),
        ))
    with pytest.raises(ValueError):
        MultiShellConfig(shells=())


# --- walker dispatch --------------------------------------------------------------
def test_multi_shell_walker_matches_per_shell_walkers(two_shell):
    msw = MultiShellWalker(two_shell)
    wa, wb = WalkerDelta(SHELL_A), WalkerDelta(SHELL_B)
    t = np.linspace(0.0, 5400.0, 7)

    # positions: global plane p >= 8 is shell B's plane p - 8
    for p_global, walker, p_local in ((2, wa, 2), (10, wb, 2)):
        got = msw.positions_batch(
            np.array([p_global]), np.array([5]), t[None, :]
        )
        want = walker.positions_batch(
            np.array([p_local]), np.array([5]), t[None, :]
        )
        assert np.array_equal(got, want)
        sat = Satellite(plane=p_global, slot=5)
        local = Satellite(plane=p_local, slot=5)
        assert np.array_equal(
            msw.position_of(sat, t), walker.position_of(local, t)
        )

    gs = GroundStation(lat_deg=38.0, lon_deg=-91.8)
    el = msw.elevations_from(gs, t)
    assert el.shape == (14, 11, 7)
    assert np.array_equal(el[:8], wa.elevations_from(gs, t))
    assert np.array_equal(el[8:], wb.elevations_from(gs, t))


def test_make_walker_dispatch(two_shell):
    assert isinstance(make_walker(two_shell), MultiShellWalker)
    assert isinstance(make_walker(SHELL_A), WalkerDelta)


# --- degenerate single shell: bit-identical to ISLTopology ------------------------
@pytest.mark.parametrize("kind", ["ring", "grid"])
def test_single_shell_multi_topology_is_degenerate(kind, grid_cfg):
    tcfg = TopologyConfig(kind=kind)
    single = ISLTopology(SHELL_A, tcfg)
    multi = MultiShellTopology(MultiShellConfig(shells=(SHELL_A,)), tcfg)
    assert np.array_equal(multi.adjacency, single.adjacency)
    for k in (None, INTRA, INTER):
        for a, b in zip(multi.edges(k), single.edges(k)):
            assert np.array_equal(a, b)
    h_a1, h_b1 = single.hop_split(256.0, 0.13)
    h_a2, h_b2 = multi.hop_split(256.0, 0.13)
    assert np.array_equal(h_a1, h_a2)
    assert np.array_equal(h_b1, h_b2)


def test_single_shell_schedules_bit_identical(grid_cfg):
    """Routing built through the multi-shell path must reproduce the
    single-shell planner's broadcast/relay times exactly."""
    plan = ISLPlan(intra=ISLConfig())
    rt_single = RoutingTable(
        ISLTopology(SHELL_A, grid_cfg), plan, PAYLOAD
    )
    rt_multi = RoutingTable(
        MultiShellTopology(MultiShellConfig(shells=(SHELL_A,)), grid_cfg),
        plan, PAYLOAD,
    )
    sources = [0, 23, 47]
    t_src = [10.0, 20.0, 30.0]
    for a, b in zip(
        rt_single.broadcast_times(sources, t_src),
        rt_multi.broadcast_times(sources, t_src),
    ):
        assert np.array_equal(a, b)
    t_ready = [float(i) for i in range(rt_single.num_nodes)]
    assert np.array_equal(
        rt_single.relay_times(5, t_ready), rt_multi.relay_times(5, t_ready)
    )


def test_get_isl_topology_dispatches_multi_shell(two_shell, grid_cfg):
    topo = get_isl_topology(two_shell, grid_cfg)
    assert isinstance(topo, MultiShellTopology)
    # cached: same object back for the same (config, topology) pair
    assert get_isl_topology(two_shell, grid_cfg) is topo


# --- two-shell stitching ----------------------------------------------------------
def test_two_shell_graph_connected_with_typed_cross_links(
    two_shell, grid_cfg
):
    topo = MultiShellTopology(two_shell, grid_cfg)
    assert topo.num_nodes == 154
    assert topo.is_connected()
    off = two_shell.plane_offsets[1] * two_shell.sats_per_plane
    i, j = topo.edges()
    cross = (i < off) != (j < off)
    # the shells are linked, and only via INTER-typed edges
    assert np.count_nonzero(cross) > 0
    kinds = topo.adjacency[i[cross], j[cross]]
    assert np.all(kinds == INTER)
    # cross-link cap: each sat gets at most cross_links_per_sat
    # proposals per side, union-merged
    deg = np.bincount(
        np.concatenate([i[cross], j[cross]]), minlength=topo.num_nodes
    )
    assert deg.max() <= 2 * two_shell.cross_links_per_sat


def test_two_shell_range_gate_can_sever_shells(grid_cfg):
    """An impossible cross-shell range budget leaves the shells as two
    disconnected components — the feasibility gate is real."""
    cfg = MultiShellConfig(
        shells=(SHELL_A, SHELL_B), cross_max_range_m=1.0
    )
    topo = MultiShellTopology(cfg, grid_cfg)
    assert not topo.is_connected()


def test_multi_shell_topology_rejects_single_shell_config(grid_cfg):
    with pytest.raises(TypeError):
        MultiShellTopology(SHELL_A, grid_cfg)


# --- compact hop dtypes -----------------------------------------------------------
def test_count_dtype_thresholds():
    assert _count_dtype(88) is np.int16
    assert _count_dtype(2**14) is np.int16
    assert _count_dtype(2**14 + 1) is np.int32


def test_hop_matrices_use_compact_dtype(grid_cfg):
    topo = ISLTopology(SHELL_A, grid_cfg)
    h_a, h_b = topo.hop_split(256.0, 0.13)
    assert h_a.dtype == np.int16 and h_b.dtype == np.int16
    rt = RoutingTable(topo, ISLPlan(intra=ISLConfig()), PAYLOAD)
    # summed hop counts stay integral; latency stays float64
    assert np.issubdtype(rt.hops.dtype, np.integer)
    assert rt.latency.dtype == np.float64


# --- per-source rows vs full solver -----------------------------------------------
@pytest.mark.parametrize("weights", [(256.0, 0.13), (1.0, 1.0)])
def test_hop_split_rows_matches_full_solver(weights, grid_cfg):
    topo = ISLTopology(SHELL_A, grid_cfg)
    w_a, w_b = weights
    h_a, h_b = topo.hop_split(w_a, w_b)
    src = np.asarray([0, 17, 43, 87])
    r_a, r_b = topo.hop_split_rows(src, w_a, w_b)
    # unreachable masks exactly equal; costs to optimum (equal-cost
    # ties may decompose hops differently between solvers)
    assert np.array_equal(r_a == UNREACHABLE, h_a[src] == UNREACHABLE)
    cost_full = np.where(
        h_a[src] == UNREACHABLE, np.inf,
        h_a[src] * w_a + h_b[src] * w_b,
    )
    cost_rows = np.where(
        r_a == UNREACHABLE, np.inf, r_a * w_a + r_b * w_b
    )
    assert np.allclose(cost_rows, cost_full, atol=1e-9)


def test_hop_split_rows_on_disconnected_ring():
    topo = ISLTopology(SHELL_A, TopologyConfig(kind="ring"))
    src = np.asarray([0])
    r_a, r_b = topo.hop_split_rows(src, 1.0, 1.0)
    K = SHELL_A.sats_per_plane
    assert np.all(r_a[0, :K] != UNREACHABLE)
    assert np.all(r_a[0, K:] == UNREACHABLE)
    assert np.all(r_b[0, K:] == UNREACHABLE)


# --- lazy routing table -----------------------------------------------------------
def test_lazy_routing_matches_eager(grid_cfg):
    topo = ISLTopology(SHELL_A, grid_cfg)
    plan = ISLPlan(intra=ISLConfig())
    eager = RoutingTable(topo, plan, PAYLOAD)
    lazy = RoutingTable(topo, plan, PAYLOAD, lazy=True)
    assert not lazy.materialized

    sources = [0, 12, 55]
    t_src = [0.0, 5.0, 9.0]
    for a, b in zip(
        eager.broadcast_times(sources, t_src),
        lazy.broadcast_times(sources, t_src),
    ):
        assert np.array_equal(a, b)
    nodes = np.asarray(sources)
    for a, b in zip(eager.submatrix(nodes), lazy.submatrix(nodes)):
        assert np.allclose(a, b, atol=1e-9)
    t_ready = [1.0] * eager.num_nodes
    assert np.allclose(
        eager.relay_times(12, t_ready), lazy.relay_times(12, t_ready),
        atol=1e-9,
    )
    # row queries alone never built the (N, N) matrices...
    assert not lazy.materialized
    assert set(lazy._row_cache) == {0, 12, 55}
    # ...but direct attribute access materializes them, exactly
    assert np.array_equal(lazy.latency, eager.latency)
    assert np.array_equal(lazy.hops, eager.hops)
    assert lazy.materialized


def test_lazy_routing_on_two_shell(two_shell, grid_cfg):
    topo = MultiShellTopology(two_shell, grid_cfg)
    plan = ISLPlan(intra=ISLConfig())
    eager = RoutingTable(topo, plan, PAYLOAD)
    lazy = RoutingTable(topo, plan, PAYLOAD, lazy=True)
    # one source per shell, receivers across both shells
    sources = [0, 8 * 11]
    for a, b in zip(
        eager.broadcast_times(sources, [0.0, 0.0]),
        lazy.broadcast_times(sources, [0.0, 0.0]),
    ):
        assert np.allclose(a, b, atol=1e-9)


# --- benchmark helpers ------------------------------------------------------------
def test_overhead_fraction_clamps_and_medians():
    from benchmarks.common import overhead_fraction

    def spin(iters):
        x = 0
        for i in range(iters):
            x += i
        return x

    # identical arms: noise must clamp to >= 0, never the seed's -7.7%
    frac, plain_us, traced_us = overhead_fraction(
        lambda: spin(20000), lambda: spin(20000), samples=5
    )
    assert frac >= 0.0
    assert plain_us > 0.0 and traced_us > 0.0

    # a genuinely slower traced arm shows up as positive overhead
    frac_slow, p_us, t_us = overhead_fraction(
        lambda: spin(20000), lambda: spin(400000), samples=3
    )
    assert frac_slow > 1.0
    assert t_us > p_us


def test_measure_peak_mb_sees_transient():
    from benchmarks.common import measure_peak_mb, peak_rss_mb

    out, wall_us, peak_mb = measure_peak_mb(
        lambda: np.zeros(2_000_000, dtype=np.float64).sum()
    )
    assert out == 0.0
    assert wall_us > 0.0
    assert peak_mb >= 16.0          # the 16 MB transient is visible
    assert peak_rss_mb() > 0.0
