"""Model propagation + distributed sink scheduling tests (paper §IV)."""
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.comms import ISLConfig, LinkConfig, downlink_time, isl_hop_time
from repro.core.propagation import (
    broadcast_schedule,
    relay_completion_time,
    relay_schedule,
    ring_hops,
)
from repro.core.scheduling import first_visible_download, select_sink
from repro.orbits import (
    ConstellationConfig,
    GroundStation,
    VisibilityPredictor,
    WalkerDelta,
)


@given(st.integers(2, 32), st.integers(0, 31), st.integers(0, 31))
def test_ring_hops_metric(k, a, b):
    a, b = a % k, b % k
    # symmetric, bounded by floor(k/2), zero iff same slot
    assert ring_hops(k, a, b) == ring_hops(k, b, a)
    assert ring_hops(k, a, b) <= k // 2
    assert (ring_hops(k, a, b) == 0) == (a == b)


@given(st.integers(2, 16), st.integers(0, 15))
def test_broadcast_reaches_all_exactly_once(k, src):
    src = src % k
    isl = ISLConfig()
    events = broadcast_schedule(k, [src], [100.0], 1e7, isl)
    assert len(events) == k
    t_hop = isl_hop_time(isl, 1e7)
    for e in events:
        # receipt time = source time + hop-distance * hop time
        assert abs(e.t_receive - (100.0 + ring_hops(k, src, e.slot) * t_hop)) < 1e-9
    # the source receives instantly; the farthest waits floor(k/2) hops
    assert events[src].t_receive == 100.0
    assert max(e.hops for e in events) == k // 2


def test_duplicate_drop_two_sources():
    """Two visible satellites: each slot keeps the EARLIEST copy (§IV-A:
    'simply drop the duplicate')."""
    isl = ISLConfig()
    k = 8
    ev_two = broadcast_schedule(k, [0, 4], [0.0, 0.0], 1e7, isl)
    ev_one = broadcast_schedule(k, [0], [0.0], 1e7, isl)
    for e2, e1 in zip(ev_two, ev_one):
        assert e2.t_receive <= e1.t_receive + 1e-12
    # slot 4's copy must now be instant
    assert ev_two[4].t_receive == 0.0


@given(st.integers(2, 12))
def test_relay_completion_is_max(k):
    isl = ISLConfig()
    t_ready = [float(i) for i in range(k)]
    events = relay_schedule(k, 0, t_ready, 1e7, isl)
    assert relay_completion_time(events) == max(e.t_receive for e in events)


@pytest.fixture(scope="module")
def sim_world():
    cfg = ConstellationConfig(num_planes=3, sats_per_plane=6)
    walker = WalkerDelta(cfg)
    gs = GroundStation()
    pred = VisibilityPredictor(walker, gs, horizon_s=36 * 3600)
    return cfg, walker, gs, pred


def test_sink_selection_deterministic(sim_world):
    """The scheduler is distributed: every satellite evaluates the same
    pure function -> repeated evaluation must agree exactly."""
    cfg, walker, gs, pred = sim_world
    link, isl = LinkConfig(), ISLConfig()
    t_done = [3600.0 + 60.0 * s for s in range(cfg.sats_per_plane)]
    a = select_sink(walker=walker, gs=gs, predictor=pred, link=link,
                    isl=isl, plane=0, t_train_done=t_done,
                    payload_bits=3.2e7)
    b = select_sink(walker=walker, gs=gs, predictor=pred, link=link,
                    isl=isl, plane=0, t_train_done=t_done,
                    payload_bits=3.2e7)
    assert a is not None
    assert (a.sink_slot, a.t_upload_done) == (b.sink_slot, b.t_upload_done)


def test_sink_window_fits_upload(sim_world):
    """AW(c_opt, GS) >= exchange time (eq. 22 feasibility)."""
    cfg, walker, gs, pred = sim_world
    link, isl = LinkConfig(), ISLConfig()
    payload = 3.2e7
    t_done = [7200.0] * cfg.sats_per_plane
    d = select_sink(walker=walker, gs=gs, predictor=pred, link=link,
                    isl=isl, plane=1, t_train_done=t_done,
                    payload_bits=payload)
    assert d is not None
    assert d.window.t_end >= d.t_upload_done - 1e-6
    assert d.t_upload_start >= d.t_models_at_sink - 1e-6
    assert d.t_wait >= 0.0


def test_sink_minimizes_completion(sim_world):
    """No other feasible candidate finishes earlier than the chosen sink."""
    cfg, walker, gs, pred = sim_world
    from repro.core.propagation import ring_hops as rh
    from repro.core.scheduling import _distance_at
    link, isl = LinkConfig(), ISLConfig()
    payload = 3.2e7
    K = cfg.sats_per_plane
    t_done = [1800.0 * (1 + s % 3) for s in range(K)]
    d = select_sink(walker=walker, gs=gs, predictor=pred, link=link,
                    isl=isl, plane=2, t_train_done=t_done,
                    payload_bits=payload)
    assert d is not None
    t_hop = isl_hop_time(isl, payload)
    from repro.orbits.constellation import Satellite
    for cand in range(K):
        t_ready = max(t_done[s] + rh(K, s, cand) * t_hop for s in range(K))
        for w in pred.windows_of(Satellite(2, cand)):
            if w.t_end <= t_ready:
                continue
            t0 = max(w.t_start, t_ready)
            dd = _distance_at(walker, gs, Satellite(2, cand), t0)
            tc = downlink_time(link, payload, dd)
            if w.t_end - t0 >= tc:
                assert t0 + tc >= d.t_upload_done - 1e-6
                break


def test_first_visible_download(sim_world):
    cfg, walker, gs, pred = sim_world
    link = LinkConfig()
    out = first_visible_download(
        walker=walker, gs=gs, predictor=pred, link=link, plane=0,
        t=0.0, payload_bits=3.2e7,
    )
    assert out is not None
    slot, t_done = out
    assert 0 <= slot < cfg.sats_per_plane
    assert t_done > 0.0
