"""repro.compute: device tiers, roofline estimation, fleet model, the
eq. (11) executed-work fix, and the strategy-side wiring (ISSUE 10).

The load-bearing invariant: ``SimConfig.compute=None`` and the
all-default uniform profile are bit-identical end-to-end — schedules,
sink decisions and metrics (the degenerate-case discipline every
SimConfig extension in this repo follows).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compute import (
    DEVICE_TIERS,
    DeviceProfile,
    FleetComputeModel,
    SatAssignment,
    SatelliteComputeProfile,
    arch_payload_bits,
    seconds_per_sample,
    step_time_s,
)
from repro.compute.roofline import analytic_step_cost
from repro.core import FedLEO, FederatedTask, SimConfig, TrainHyperparams
from repro.data import make_classification_dataset, partition_noniid_by_orbit
from repro.models.cnn import apply_cnn, init_cnn
from repro.optim import get_optimizer

SLOW, FAST = "gemma-7b", "mamba2-780m"


# --- profiles ---------------------------------------------------------------------
class TestProfiles:
    def test_device_tier_validation(self):
        with pytest.raises(ValueError):
            DeviceProfile("bad", peak_flops=0.0, hbm_bytes_per_s=1e9)
        with pytest.raises(ValueError):
            DeviceProfile("bad", peak_flops=1e12, hbm_bytes_per_s=1e9,
                          mfu_fraction=1.5)

    def test_assignment_validation(self):
        with pytest.raises(ValueError):
            SatAssignment(arch="no-such-arch")
        with pytest.raises(ValueError):
            SatAssignment(arch=FAST, device="no-such-device")
        SatAssignment()                         # degenerate: always valid

    def test_profile_validation(self):
        with pytest.raises(ValueError):
            SatelliteComputeProfile(shape="no-such-shape")
        with pytest.raises(ValueError):
            SatelliteComputeProfile(mode="no-such-mode")
        # compiled/measured require the smoke configs (full-size does
        # not compile on this host)
        with pytest.raises(ValueError):
            SatelliteComputeProfile(mode="compiled", smoke=False)

    def test_assignment_resolution_order(self):
        override = SatAssignment(arch=SLOW, device="cubesat-cpu")
        prof = SatelliteComputeProfile(
            planes=(SatAssignment(arch=FAST),),
            sat_overrides=((0, 3, override),),
        )
        assert prof.assignment(0, 3) == override       # sat override
        assert prof.assignment(0, 0).arch == FAST      # plane entry
        assert prof.assignment(7, 0).arch is None      # default

    def test_per_plane_constructor(self):
        prof = SatelliteComputeProfile.per_plane([SLOW, None, FAST])
        assert prof.assignment(0).arch == SLOW
        assert prof.assignment(1).arch is None
        assert prof.assignment(2).arch == FAST


# --- roofline ---------------------------------------------------------------------
class TestRoofline:
    def test_analytic_cost_positive_and_cached(self):
        c = analytic_step_cost(FAST, "train_4k", True)
        assert c.flops > 0 and c.hbm_bytes > 0 and c.tokens > 0
        # lru cache: identical key returns the identical object
        assert analytic_step_cost(FAST, "train_4k", True) is c

    def test_bigger_arch_costs_more(self):
        dev = DEVICE_TIERS["orbital-gpu"]
        slow = seconds_per_sample(SLOW, "train_4k", dev, smoke=False)
        fast = seconds_per_sample(FAST, "train_4k", dev, smoke=False)
        assert slow > fast > 0

    def test_faster_device_is_faster(self):
        t_cube = step_time_s(FAST, "train_4k", DEVICE_TIERS["cubesat-cpu"],
                             smoke=False)
        t_tpu = step_time_s(FAST, "train_4k",
                            DEVICE_TIERS["orbital-tpu-v5e"], smoke=False)
        assert t_cube > t_tpu > 0

    def test_roofline_is_max_of_both_axes(self):
        c = analytic_step_cost(FAST, "train_4k", True)
        dev = DEVICE_TIERS["orbital-gpu"]
        t = step_time_s(FAST, "train_4k", dev)
        assert t == pytest.approx(max(
            c.flops / (dev.peak_flops * dev.mfu_fraction),
            c.hbm_bytes / dev.hbm_bytes_per_s,
        ))

    def test_payload_bits_from_param_count(self):
        from repro.configs import get_smoke_config

        bits = arch_payload_bits(FAST, bits_per_param=32)
        assert bits == float(
            get_smoke_config(FAST).param_count_estimate()
        ) * 32
        assert arch_payload_bits(FAST, bits_per_param=8) * 4 == bits


# --- fleet model ------------------------------------------------------------------
class TestFleetModel:
    def test_degenerate_tier_answers_none(self):
        fleet = FleetComputeModel(SatelliteComputeProfile.uniform(), 5)
        for plane in range(5):
            assert fleet.seconds_per_sample(plane) is None
            assert fleet.payload_bits(plane) is None
            assert fleet.train_time_s(
                plane, local_epochs=1, n_batches=1, batch_size=1
            ) is None

    def test_train_time_composes_eq11(self):
        fleet = FleetComputeModel(
            SatelliteComputeProfile.per_plane([FAST]), 1
        )
        sps = fleet.seconds_per_sample(0)
        assert fleet.train_time_s(
            0, local_epochs=3, n_batches=2, batch_size=16
        ) == pytest.approx(3 * 2 * 16 * sps)

    def test_payload_gated_on_opt_in(self):
        archs = [FAST]
        off = FleetComputeModel(
            SatelliteComputeProfile.per_plane(archs), 1
        )
        on = FleetComputeModel(
            SatelliteComputeProfile.per_plane(
                archs, payload_from_arch=True
            ), 1,
        )
        assert off.payload_bits(0) is None
        assert on.payload_bits(0) == arch_payload_bits(FAST)

    def test_plane_summary(self):
        fleet = FleetComputeModel(
            SatelliteComputeProfile.per_plane([SLOW, None]), 2
        )
        rows = fleet.plane_summary()
        assert [r["arch"] for r in rows] == [SLOW, None]
        assert rows[0]["seconds_per_sample"] > 0
        assert rows[1]["seconds_per_sample"] is None


# --- task + strategy wiring -------------------------------------------------------
def _small_task(num_samples=400, sim_epochs=2, compute=None):
    ds = make_classification_dataset("mnist-like", num_samples=num_samples,
                                     seed=0)
    test = make_classification_dataset("mnist-like", num_samples=100,
                                       seed=99)
    clients = partition_noniid_by_orbit(ds, 5, 8)
    hp = TrainHyperparams(local_epochs=100, learning_rate=0.05,
                          batch_size=16)
    return FederatedTask(
        init_fn=lambda r: init_cnn(r, (28, 28, 1), 10, widths=(8,),
                                   hidden=32),
        apply_fn=apply_cnn,
        clients=clients,
        test_set=test,
        optimizer=get_optimizer("sgd", 0.05),
        hp=hp,
        sim_epochs=sim_epochs,
        compute=compute,
    )


class TestExecutedWorkFix:
    """Satellite (a): eq. (11) must charge the samples actually
    processed — ``_local_train_one`` runs full-batch steps for tiny
    clients (m < b_k), so the clock charges m, not b_k."""

    def test_tiny_client_charges_executed_samples(self):
        task = _small_task(num_samples=400)       # ~10 samples/client
        hp = task.hp
        cid = 0
        m = task.num_samples(cid)
        assert m < hp.batch_size                  # the tiny-client case
        n_batches, bsz = task.executed_batches(cid)
        assert (n_batches, bsz) == (1, m)
        expected = (hp.local_epochs * 1 * m * hp.cycles_per_sample
                    ) / hp.cpu_freq_hz
        assert task.train_time_s(cid) == pytest.approx(expected)

    def test_large_client_unchanged(self):
        task = _small_task(num_samples=3200)      # ~80 samples/client
        hp = task.hp
        cid = 0
        m = task.num_samples(cid)
        assert m >= hp.batch_size
        n_batches, bsz = task.executed_batches(cid)
        assert bsz == hp.batch_size
        assert n_batches == m // hp.batch_size    # the pre-fix formula
        expected = (hp.local_epochs * n_batches * hp.batch_size
                    * hp.cycles_per_sample) / hp.cpu_freq_hz
        assert task.train_time_s(cid) == pytest.approx(expected)


class TestStrategyWiring:
    def test_strategy_resolves_compute_without_mutating_task(self):
        task = _small_task()
        sim = SimConfig(compute=SatelliteComputeProfile.per_plane(
            [SLOW, FAST, None, FAST, SLOW]
        ))
        strat = FedLEO(task, sim)
        assert strat.compute is not None
        assert task.compute is None               # task untouched

    def test_hetero_train_times_ordered(self):
        task = _small_task()
        sim = SimConfig(compute=SatelliteComputeProfile.per_plane(
            [SLOW, FAST, None, FAST, SLOW], smoke=False,
        ))
        strat = FedLEO(task, sim)
        slow_c = task.clients_on_plane(0)[0]
        fast_c = task.clients_on_plane(1)[0]
        deg_c = task.clients_on_plane(2)[0]
        assert strat.train_time_s(slow_c) > strat.train_time_s(fast_c)
        # degenerate plane: exactly the paper's uniform formula
        assert strat.train_time_s(deg_c) == task.train_time_s(deg_c)

    def test_sat_and_group_payload_bits(self):
        task = _small_task()
        sim = SimConfig(compute=SatelliteComputeProfile.per_plane(
            [SLOW, FAST, None], payload_from_arch=True,
        ))
        strat = FedLEO(task, sim)
        assert strat.sat_payload_bits(0) == arch_payload_bits(SLOW)
        assert strat.sat_payload_bits(2) == float(task.payload_bits)
        # group payload: max over member planes
        assert strat.group_payload_bits((0, 1)) == arch_payload_bits(SLOW)
        assert strat.group_payload_bits((2,)) == float(task.payload_bits)
        # payload-unaware profile: always the task's uniform payload
        plain = FedLEO(_small_task(), SimConfig(
            compute=SatelliteComputeProfile.per_plane([SLOW])
        ))
        assert plain.group_payload_bits((0,)) == plain.payload_bits

    def test_uniform_profile_bit_identical_end_to_end(self):
        """THE degenerate-case gate: compute=None vs the all-default
        profile — identical round times, metrics and decompositions."""
        r0 = FedLEO(_small_task(), SimConfig()).run(max_rounds=1)
        ru = FedLEO(_small_task(), SimConfig(
            compute=SatelliteComputeProfile.uniform()
        )).run(max_rounds=1)
        assert len(r0.history) == len(ru.history) == 1
        a, b = r0.history[0], ru.history[0]
        assert a.t_hours == b.t_hours
        assert a.metrics == b.metrics
        assert a.events == b.events

    def test_hetero_profile_changes_round_time(self):
        r0 = FedLEO(_small_task(), SimConfig()).run(max_rounds=1)
        rh = FedLEO(_small_task(), SimConfig(
            compute=SatelliteComputeProfile.per_plane(
                [SLOW, FAST, None, FAST, SLOW], smoke=False,
            )
        )).run(max_rounds=1)
        assert rh.history[0].t_hours > r0.history[0].t_hours
