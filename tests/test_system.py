"""End-to-end behaviour tests for the FedLEO system.

The headline reproduction properties (paper Table II / §IV):
  1. FedLEO converges under the paper's non-IID split;
  2. its round latency beats the star topology (eq. 12 < eq. 10);
  3. the whole stack (orbits -> comms -> scheduling -> training ->
     aggregation) is driven end-to-end, including the U-Net/DeepGlobe
     path and the paper's CNN path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedLEO, FederatedTask, SimConfig, TrainHyperparams
from repro.core.fltask import cross_entropy_loss
from repro.data import (
    make_classification_dataset,
    make_segmentation_dataset,
    partition_iid,
    partition_noniid_by_orbit,
)
from repro.models.cnn import apply_cnn, apply_unet, init_cnn, init_unet
from repro.optim import get_optimizer


@pytest.mark.slow
def test_fedleo_end_to_end_noniid():
    ds = make_classification_dataset("mnist-like", num_samples=1200, seed=0)
    test = make_classification_dataset("mnist-like", num_samples=300,
                                       seed=77)
    clients = partition_noniid_by_orbit(ds, 5, 8)
    hp = TrainHyperparams(local_epochs=100, learning_rate=0.05,
                          batch_size=16)
    task = FederatedTask(
        init_fn=lambda r: init_cnn(r, (28, 28, 1), 10, widths=(8, 16),
                                   hidden=32),
        apply_fn=apply_cnn,
        clients=clients,
        test_set=test,
        optimizer=get_optimizer("sgd", 0.05),
        hp=hp,
        sim_epochs=8,
    )
    res = FedLEO(task, SimConfig(horizon_hours=72.0)).run(max_rounds=4)
    assert res.final_accuracy > 0.6
    # simulated clock plausibility: rounds take hours, not seconds/days
    assert 0.5 < res.final_time_hours < 72.0


def test_unet_deepglobe_path():
    """The paper's DeepGlobe road-extraction experiment (U-Net)."""
    ds = make_segmentation_dataset(num_samples=32, size=32, seed=0)
    test = make_segmentation_dataset(num_samples=8, size=32, seed=9)
    clients = partition_iid(ds, 2, 2)   # small constellation for CPU
    from repro.orbits import ConstellationConfig

    hp = TrainHyperparams(local_epochs=20, learning_rate=0.01,
                          batch_size=4)
    task = FederatedTask(
        init_fn=lambda r: init_unet(r, in_ch=3, base=4, depth=2),
        apply_fn=apply_unet,
        clients=clients,
        test_set=test,
        optimizer=get_optimizer("adam", 1e-3),
        hp=hp,
        sim_epochs=3,
    )
    sim = SimConfig(
        constellation=ConstellationConfig(num_planes=2, sats_per_plane=2),
        horizon_hours=72.0,
    )
    res = FedLEO(task, sim).run(max_rounds=2)
    assert len(res.history) == 2
    # pixel accuracy should beat the trivial floor quickly
    assert res.final_accuracy > 0.5


def test_round_time_decomposition_eq12():
    """T*_sum structure: round end == max over planes of sink upload."""
    ds = make_classification_dataset("mnist-like", num_samples=400, seed=4)
    clients = partition_noniid_by_orbit(ds, 5, 8)
    hp = TrainHyperparams()
    task = FederatedTask(
        init_fn=lambda r: init_cnn(r, (28, 28, 1), 10, widths=(8,),
                                   hidden=16),
        apply_fn=apply_cnn,
        clients=clients,
        test_set=ds,
        optimizer=get_optimizer("sgd", 0.05),
        hp=hp,
        sim_epochs=1,
    )
    strat = FedLEO(task, SimConfig(horizon_hours=72.0))
    res = strat.run(max_rounds=1)
    ev = res.history[0].events["planes"]
    t_end = res.history[0].t_hours * 3600.0
    assert abs(t_end - max(p["t_upload_done"] for p in ev)) < 1e-6
