import os

# Smoke tests and benches must see ONE device — the 512-device flag is
# set only inside repro.launch.dryrun (see MULTI-POD DRY-RUN rules).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    from hypothesis import settings
except ModuleNotFoundError:
    # hypothesis is an optional dependency: without it the suite must
    # still collect (property tests auto-skip, everything else runs).
    # Install an import shim so ``from hypothesis import given,
    # strategies as st`` keeps working in every test module.
    import sys
    import types

    import pytest

    class _AnyStrategy:
        """Stands in for any strategy object/combinator chain."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    def _given(*_args, **_kwargs):
        def deco(fn):
            # Zero-arg replacement: pytest must not treat the wrapped
            # test's strategy parameters as fixture requests.
            def skipper():
                pytest.skip("hypothesis not installed")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    class _Settings:
        def __init__(self, *args, **kwargs):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*args, **kwargs):
            pass

        @staticmethod
        def load_profile(*args, **kwargs):
            pass

    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _AnyStrategy()

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _Settings
    _hyp.strategies = _st
    _hyp.assume = lambda *args, **kwargs: True
    _hyp.example = lambda *args, **kwargs: (lambda fn: fn)
    _hyp.note = lambda *args, **kwargs: None
    _hyp.HealthCheck = _AnyStrategy()

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
else:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
