import os

# Smoke tests and benches must see ONE device — the 512-device flag is
# set only inside repro.launch.dryrun (see MULTI-POD DRY-RUN rules).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import settings

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")
