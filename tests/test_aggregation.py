"""Aggregation math (eqs. 4/9) + non-IID weighting properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.aggregation import (
    global_aggregate,
    index_pytree,
    noniid_weights,
    partial_aggregate,
    stack_pytrees,
    weighted_average,
)


def _rand_tree(rng, k):
    return {
        "w": jnp.asarray(rng.standard_normal((k, 4, 3)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal((k, 5)), jnp.float32),
    }


def test_weighted_average_matches_manual():
    rng = np.random.default_rng(0)
    k = 5
    tree = _rand_tree(rng, k)
    w = jnp.asarray(rng.random(k), jnp.float32)
    out = weighted_average(tree, w)
    wn = np.asarray(w) / np.asarray(w).sum()
    np.testing.assert_allclose(
        out["w"], np.einsum("k,kij->ij", wn, np.asarray(tree["w"])),
        rtol=1e-5,
    )


@given(st.lists(st.integers(1, 1000), min_size=2, max_size=8))
def test_partial_aggregate_is_convex(counts):
    """Eq. (9): the partial model is a convex combination — it lies inside
    the componentwise min/max envelope of the client models."""
    rng = np.random.default_rng(1)
    k = len(counts)
    tree = _rand_tree(rng, k)
    out = partial_aggregate(tree, counts)
    for key in tree:
        x = np.asarray(tree[key])
        o = np.asarray(out[key])
        assert (o <= x.max(axis=0) + 1e-5).all()
        assert (o >= x.min(axis=0) - 1e-5).all()


def test_identical_models_fixed_point():
    """Aggregating identical models returns the same model (any weights)."""
    rng = np.random.default_rng(2)
    one = {"w": jnp.asarray(rng.standard_normal((3, 3)), jnp.float32)}
    stacked = stack_pytrees([one, one, one])
    out = global_aggregate(stacked, [10, 20, 30])
    np.testing.assert_allclose(out["w"], one["w"], rtol=1e-6)


def test_noniid_weights_class_coverage():
    """Orbits holding exclusive classes keep influence despite small m_k
    (the piggybacked-histogram weighting of §IV-A)."""
    # orbit 0: tiny dataset but sole holder of classes 4-9
    hists = np.array([
        [0, 0, 0, 0, 10, 10, 10, 10, 10, 10],
        [500, 500, 500, 500, 0, 0, 0, 0, 0, 0],
        [500, 500, 500, 500, 0, 0, 0, 0, 0, 0],
    ], dtype=float)
    w = noniid_weights(hists)
    assert abs(w.sum() - 1.0) < 1e-9
    # orbit 0 holds 6 of 10 class "shares" -> weight 0.6
    assert abs(w[0] - 0.6) < 1e-9
    # m_k-proportional weighting would have given orbit 0 only 60/2060
    m_weight = hists.sum(1) / hists.sum()
    assert w[0] > 10 * m_weight[0]


@given(st.integers(2, 6), st.integers(2, 10))
def test_noniid_weights_uniform_when_balanced(k, c):
    hists = np.full((k, c), 7.0)
    w = noniid_weights(hists)
    np.testing.assert_allclose(w, np.full(k, 1.0 / k), rtol=1e-9)


def test_global_aggregate_blend():
    rng = np.random.default_rng(3)
    tree = _rand_tree(rng, 2)
    hists = np.array([[100, 0], [0, 100]], dtype=float)
    pure = global_aggregate(tree, [300, 100])
    balanced = global_aggregate(tree, [300, 100], histograms=hists,
                                noniid_alpha=1.0)
    # fully balanced weighting = equal weights here (each holds one class)
    manual = weighted_average(tree, jnp.asarray([0.5, 0.5]))
    np.testing.assert_allclose(balanced["w"], manual["w"], rtol=1e-5)
    assert not np.allclose(pure["w"], balanced["w"])


def test_stack_index_roundtrip():
    rng = np.random.default_rng(4)
    trees = [
        {"a": jnp.asarray(rng.standard_normal(3), jnp.float32)}
        for _ in range(4)
    ]
    stacked = stack_pytrees(trees)
    for i in range(4):
        np.testing.assert_array_equal(
            index_pytree(stacked, i)["a"], trees[i]["a"]
        )


def test_kernel_path_matches_jnp_path():
    rng = np.random.default_rng(5)
    tree = _rand_tree(rng, 4)
    w = jnp.asarray([0.4, 0.3, 0.2, 0.1], jnp.float32)
    a = weighted_average(tree, w, use_kernel=False)
    b = weighted_average(tree, w, use_kernel=True)   # interpret on CPU
    for key in tree:
        np.testing.assert_allclose(a[key], b[key], rtol=1e-5, atol=1e-6)
