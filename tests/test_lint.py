"""repro.analysis.lint: each AST rule fires on a minimal synthetic
violation, stays quiet on the compliant twin, and the real tree is
clean (the CI gate, asserted here so a violation fails the tier-1
suite locally too — mypy may not be installed, the lint always is).
"""
import textwrap
from pathlib import Path

import pytest

from repro.analysis import lint

SRC_ROOT = Path(__file__).resolve().parent.parent / "src"


def run_on(tmp_path, rel, code):
    """Lint one synthetic file planted at repo-relative ``rel``."""
    f = tmp_path / rel
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(textwrap.dedent(code))
    findings, n_files = lint.run_lint([tmp_path / "repro"])
    assert n_files == 1
    return findings


def rules(findings):
    return [f.rule for f in findings]


# --- rule 1: ledger encapsulation ---------------------------------------------
def test_ledger_mutation_flagged_outside_session(tmp_path):
    findings = run_on(tmp_path, "repro/core/foo.py", """
        def book(ledger: object) -> None:
            ledger.reserve(0, 1.0, 2.0)
    """)
    assert rules(findings) == ["ledger-encapsulation"]
    assert "CommsEnvironment.commit" in findings[0].message


def test_ledger_mutation_allowed_in_owner_files(tmp_path):
    findings = run_on(tmp_path, "repro/comms/environment.py", """
        def commit(self, legs: object) -> None:
            for gi, t0, t1 in legs:
                self.ledger.reserve(gi, t0, t1)
    """)
    assert rules(findings) == []


def test_ledger_read_is_fine(tmp_path):
    findings = run_on(tmp_path, "repro/core/foo.py", """
        def fit(ledger: object) -> float:
            return ledger.earliest_fit(0, 1.0, 2.0, 0.5)
    """)
    assert rules(findings) == []


def test_reserve_transfer_shim_grandfathered():
    """The one legacy booking function keeps its direct mutation."""
    findings, _ = lint.run_lint([SRC_ROOT / "repro" / "core"
                                 / "scheduling.py"])
    assert "ledger-encapsulation" not in rules(findings)


# --- rule 2: deprecated scheduling shims --------------------------------------
def test_deprecated_shim_call_flagged(tmp_path):
    findings = run_on(tmp_path, "repro/core/foo.py", """
        from repro.core.scheduling import earliest_transfer

        def plan(**kw: object) -> None:
            earliest_transfer(**kw)
    """)
    assert rules(findings) == ["deprecated-shim"]


def test_deprecated_shim_alias_and_module_call_flagged(tmp_path):
    findings = run_on(tmp_path, "repro/orbits/foo.py", """
        import repro.core.scheduling as sched
        from repro.core.scheduling import select_sink as pick

        def plan(**kw):
            pick(**kw)
            sched.naive_sink_slot(None, 0, 0.0)
    """)
    assert rules(findings).count("deprecated-shim") == 2


def test_shim_import_alone_is_fine(tmp_path):
    """Re-exports (core/__init__.py keeps the public names) don't call."""
    findings = run_on(tmp_path, "repro/core/foo.py", """
        from repro.core.scheduling import earliest_transfer, select_sink
    """)
    assert rules(findings) == []


# --- rule 3: unit-suffix discipline -------------------------------------------
def test_unitless_numeric_field_flagged(tmp_path):
    findings = run_on(tmp_path, "repro/comms/link.py", """
        import dataclasses

        @dataclasses.dataclass(frozen=True)
        class Budget:
            duration: float
            bandwidth_hz: float = 1.0e6
            t_start: float = 0.0
            gs_index: int = 0
    """)
    assert rules(findings) == ["unit-suffix"]
    assert "Budget.duration" in findings[0].message


def test_unit_rule_only_applies_to_scheduling_files(tmp_path):
    findings = run_on(tmp_path, "repro/models/foo.py", """
        import dataclasses

        @dataclasses.dataclass
        class Widths:
            hidden: int = 32
    """)
    assert rules(findings) == []


def test_exempt_fields_pass(tmp_path):
    findings = run_on(tmp_path, "repro/comms/ledger.py", """
        import dataclasses

        @dataclasses.dataclass
        class Rec:
            rid: int
            seed: int = 0
            plane: int = 0
    """)
    assert rules(findings) == []


# --- rule 4: wall-clock ban ---------------------------------------------------
def test_wall_clock_flagged_in_sim_packages(tmp_path):
    findings = run_on(tmp_path, "repro/orbits/foo.py", """
        import time

        def now() -> float:
            return time.time()
    """)
    assert rules(findings) == ["wall-clock"]


def test_wall_clock_fine_outside_sim_packages(tmp_path):
    findings = run_on(tmp_path, "repro/launch/foo.py", """
        import time

        def now() -> float:
            return time.perf_counter()
    """)
    assert rules(findings) == []


# --- rule 5: annotation completeness ------------------------------------------
def test_unannotated_def_flagged(tmp_path):
    findings = run_on(tmp_path, "repro/core/foo.py", """
        def f(x, y: int):
            return x
    """)
    got = rules(findings)
    assert got == ["annotation", "annotation"]   # params + return
    assert "unannotated parameter(s): x" in findings[0].message


def test_annotated_def_and_init_pass(tmp_path):
    findings = run_on(tmp_path, "repro/core/foo.py", """
        class C:
            def __init__(self, x: int):
                self.x = x

            def get(self) -> int:
                return self.x
    """)
    assert rules(findings) == []


def test_annotation_rule_skips_learning_substrate(tmp_path):
    # the learning substrate (models/, kernels/, ...) stays outside
    # the annotation gate
    findings = run_on(tmp_path, "repro/models/foo.py", """
        def f(x):
            return x
    """)
    assert rules(findings) == []


def test_annotation_rule_covers_orbits(tmp_path):
    # orbits/ and configs/ joined the gate in PR 8
    findings = run_on(tmp_path, "repro/orbits/foo.py", """
        def f(x):
            return x
    """)
    assert rules(findings) == ["annotation", "annotation"]


# --- infra --------------------------------------------------------------------
def test_syntax_error_is_a_finding_not_a_crash(tmp_path):
    findings = run_on(tmp_path, "repro/core/foo.py", "def broken(:\n")
    assert rules(findings) == ["syntax"]


def test_finding_str_format(tmp_path):
    findings = run_on(tmp_path, "repro/core/foo.py", """
        def f(x):
            return x
    """)
    s = str(findings[0])
    assert s.startswith("repro/core/foo.py:2: [annotation]")


def test_cli_exit_codes(tmp_path, capsys):
    ok = tmp_path / "repro" / "core"
    ok.mkdir(parents=True)
    (ok / "good.py").write_text("def f(x: int) -> int:\n    return x\n")
    assert lint.main([str(tmp_path / "repro")]) == 0
    (ok / "bad.py").write_text("def f(x):\n    return x\n")
    assert lint.main([str(tmp_path / "repro")]) == 1


def test_repo_tree_is_clean():
    """The enforced gate: the real src/repro tree has zero findings."""
    findings, n_files = lint.run_lint([SRC_ROOT / "repro"])
    assert n_files > 50
    assert findings == [], "\n".join(str(f) for f in findings)
