"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
executed in interpret mode on CPU (the kernels target TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.aggregate import aggregate_flat
from repro.kernels.aggregate_ref import aggregate_flat_ref
from repro.kernels.flash import flash_attention
from repro.kernels.flash_ref import flash_attention_ref
from repro.kernels.ssd import ssd_scan
from repro.kernels.ssd_ref import ssd_naive, ssd_ref


# --- aggregate -------------------------------------------------------------------
@pytest.mark.parametrize("k,n", [(2, 64), (5, 1000), (8, 40000), (3, 17),
                                 (40, 4096)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_aggregate_sweep(k, n, dtype):
    rng = np.random.default_rng(k * n)
    x = jnp.asarray(rng.standard_normal((k, n)), dtype)
    w = jnp.asarray(rng.random(k), jnp.float32)
    w = w / w.sum()
    out = aggregate_flat(x, w, block_n=4096, interpret=True)
    ref = aggregate_flat_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol,
    )


def test_aggregate_pytree_wrapper():
    from repro.kernels.aggregate_ops import aggregate_pytree

    rng = np.random.default_rng(0)
    tree = {
        "a": jnp.asarray(rng.standard_normal((3, 8, 4)), jnp.float32),
        "b": [jnp.asarray(rng.standard_normal((3, 5)), jnp.float32)],
    }
    w = jnp.asarray([0.5, 0.25, 0.25], jnp.float32)
    out = aggregate_pytree(tree, w)
    np.testing.assert_allclose(
        out["a"], np.einsum("k,kij->ij", np.asarray(w), tree["a"]),
        rtol=1e-5,
    )
    assert out["b"][0].shape == (5,)


# --- flash attention --------------------------------------------------------------
@pytest.mark.parametrize(
    "b,s,h,g,d,causal,window",
    [
        (1, 128, 4, 2, 32, True, None),
        (2, 256, 8, 2, 64, True, None),
        (1, 128, 4, 4, 32, True, 64),      # sliding window
        (1, 256, 4, 1, 32, False, None),   # MQA, bidirectional
        (2, 128, 2, 2, 128, True, None),   # MHA, wide head
    ],
)
def test_flash_sweep(b, s, h, g, d, causal, window):
    rng = np.random.default_rng(s + h)
    q = jnp.asarray(rng.standard_normal((b, s, h, d)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, g, d)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, g, d)) * 0.5, jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-3),
                                       (jnp.bfloat16, 3e-2)])
def test_flash_dtypes(dtype, tol):
    rng = np.random.default_rng(7)
    b, s, h, g, d = 1, 128, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((b, s, h, d)) * 0.5, dtype)
    k = jnp.asarray(rng.standard_normal((b, s, g, d)) * 0.5, dtype)
    v = jnp.asarray(rng.standard_normal((b, s, g, d)) * 0.5, dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_soft_cap():
    rng = np.random.default_rng(8)
    b, s, h, g, d = 1, 128, 2, 2, 32
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, g, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, g, d)), jnp.float32)
    out = flash_attention(q, k, v, logit_soft_cap=20.0, block_q=64,
                          block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v, logit_soft_cap=20.0)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


# --- SSD scan -----------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,s,h,p,g,n,chunk",
    [
        (1, 64, 2, 8, 1, 8, 16),
        (2, 128, 4, 16, 2, 8, 32),
        (1, 256, 4, 32, 4, 16, 64),
        (1, 128, 8, 16, 1, 32, 128),   # single chunk == full seq
    ],
)
def test_ssd_sweep(b, s, h, p, g, n, chunk):
    rng = np.random.default_rng(s + n)
    x = jnp.asarray(rng.standard_normal((b, s, h, p)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.random((b, s, h)) * 0.5 + 0.1, jnp.float32)
    A = -jnp.asarray(rng.random(h) * 0.5 + 0.1, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((b, s, g, n)) * 0.5, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((b, s, g, n)) * 0.5, jnp.float32)
    truth = ssd_naive(x, dt, A, Bm, Cm)
    ref = ssd_ref(x, dt, A, Bm, Cm, chunk=chunk)
    kern = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    np.testing.assert_allclose(ref, truth, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(kern, truth, rtol=2e-3, atol=2e-3)


def test_ssd_decode_matches_chunked():
    """Sequential decode steps == chunked scan on the same sequence."""
    from repro.models.mamba2 import ssd_chunked, ssd_decode_step

    rng = np.random.default_rng(9)
    b, s, h, p, g, n = 1, 32, 2, 8, 1, 8
    x = jnp.asarray(rng.standard_normal((b, s, h, p)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.random((b, s, h)) * 0.5 + 0.1, jnp.float32)
    A = -jnp.asarray(rng.random(h) * 0.5 + 0.1, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((b, s, g, n)) * 0.5, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((b, s, g, n)) * 0.5, jnp.float32)
    y_chunked, final = ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        y, state = ssd_decode_step(
            x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], state
        )
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_seq, y_chunked, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(state, final, rtol=2e-3, atol=2e-3)


def test_chunked_attention_vs_dense():
    """The XLA flash-style path used by the dry-run matches dense attn."""
    from repro.models.layers import (
        _attn_mask, attention_scores, chunked_attention,
    )

    rng = np.random.default_rng(11)
    b, s, h, g, d = 2, 256, 8, 2, 32
    q = jnp.asarray(rng.standard_normal((b, s, h, d)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, g, d)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, g, d)) * 0.5, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    for causal, win in [(True, None), (True, 64), (False, None)]:
        ref = attention_scores(q, k, v, _attn_mask(pos, pos, causal, win),
                               h // g)
        out = chunked_attention(q, k, v, h // g, causal=causal, window=win,
                                q_chunk=64, k_chunk=64)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)
