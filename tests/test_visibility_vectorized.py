"""Vectorized visibility/scheduling engine: equivalence against the
scalar reference, horizon clamping, multi-GS union semantics, and the
constellation presets (ISSUE 1 tentpole)."""
import numpy as np
import pytest

from repro.comms import ISLConfig, LinkConfig
from repro.configs.constellations import (
    CONSTELLATION_PRESETS,
    get_constellation,
    get_ground_stations,
    make_sim_config,
)
from repro.core.scheduling import first_visible_download, select_sink
from repro.orbits import (
    ConstellationConfig,
    GroundStation,
    VisibilityPredictor,
    WalkerDelta,
    visibility_table,
    visibility_windows,
    visibility_windows_reference,
)


def _sorted_key(wins):
    return sorted(wins, key=lambda w: (w.plane, w.slot, w.t_start))


# --- vectorized vs scalar-reference equivalence ------------------------------------
RANDOM_CASES = []
_rng = np.random.default_rng(1234)
for _ in range(6):
    RANDOM_CASES.append(
        dict(
            num_planes=int(_rng.integers(2, 7)),
            sats_per_plane=int(_rng.integers(3, 9)),
            altitude_m=float(_rng.uniform(400e3, 1800e3)),
            inclination_deg=float(_rng.uniform(40.0, 95.0)),
            phasing_factor=int(_rng.integers(0, 3)),
            gs_lat=float(_rng.uniform(-60.0, 75.0)),
            gs_lon=float(_rng.uniform(-180.0, 180.0)),
        )
    )


@pytest.mark.parametrize("case", RANDOM_CASES)
def test_vectorized_matches_reference_randomized(case):
    cfg = ConstellationConfig(
        num_planes=case["num_planes"],
        sats_per_plane=case["sats_per_plane"],
        altitude_m=case["altitude_m"],
        inclination_deg=case["inclination_deg"],
        phasing_factor=case["phasing_factor"],
    )
    walker = WalkerDelta(cfg)
    gs = GroundStation(lat_deg=case["gs_lat"], lon_deg=case["gs_lon"])
    vec = visibility_windows(walker, gs, 0.0, 8 * 3600.0)
    ref = visibility_windows_reference(walker, gs, 0.0, 8 * 3600.0)
    assert len(vec) == len(ref)
    for a, b in zip(_sorted_key(vec), _sorted_key(ref)):
        assert (a.plane, a.slot) == (b.plane, b.slot)
        assert abs(a.t_start - b.t_start) <= 1e-3
        assert abs(a.t_end - b.t_end) <= 1e-3


def test_vectorized_matches_reference_unrefined():
    cfg = ConstellationConfig(num_planes=3, sats_per_plane=5)
    walker = WalkerDelta(cfg)
    gs = GroundStation()
    vec = visibility_windows(walker, gs, 0.0, 6 * 3600.0, refine=False)
    ref = visibility_windows_reference(
        walker, gs, 0.0, 6 * 3600.0, refine=False
    )
    assert [(w.plane, w.slot, w.t_start, w.t_end) for w in _sorted_key(vec)] \
        == [(w.plane, w.slot, w.t_start, w.t_end) for w in _sorted_key(ref)]


def test_window_table_structure():
    cfg = ConstellationConfig(num_planes=2, sats_per_plane=4)
    table = visibility_table(WalkerDelta(cfg), GroundStation(), 0.0,
                             12 * 3600.0)
    assert len(table) > 0
    # start-sorted structured arrays, valid [start, end] intervals
    assert np.all(np.diff(table.t_start) >= 0)
    assert np.all(table.t_end > table.t_start)
    assert table.plane.dtype == np.int32
    views = table.to_windows()
    assert views[0].t_start == table.t_start[0]
    assert views[0].duration > 0


# --- horizon clamping (grid-overshoot regression) ----------------------------------
def test_windows_clamped_to_horizon():
    """The seed's arange grid sampled past t_end, so clipped windows
    could overshoot the requested horizon; both engines must clamp."""
    cfg = ConstellationConfig(num_planes=4, sats_per_plane=6)
    walker = WalkerDelta(cfg)
    gs = GroundStation()
    # horizon deliberately NOT a multiple of the coarse step
    t_end = 4 * 3600.0 + 7.0
    for fn in (visibility_windows, visibility_windows_reference):
        wins = fn(walker, gs, 0.0, t_end, coarse_step_s=10.0)
        assert wins, "expected at least one window"
        for w in wins:
            assert w.t_end <= t_end
            assert w.t_start >= 0.0


# --- predictor queries on the bisect index -----------------------------------------
def test_predictor_engines_agree():
    cfg = ConstellationConfig(num_planes=3, sats_per_plane=6)
    walker = WalkerDelta(cfg)
    gs = GroundStation()
    vec = VisibilityPredictor(walker, gs, horizon_s=24 * 3600.0)
    ref = VisibilityPredictor(walker, gs, horizon_s=24 * 3600.0,
                              engine="reference")
    assert len(vec.windows) == len(ref.windows)
    for sat in walker.satellites:
        for t in (0.0, 3600.0, 7200.0, 20 * 3600.0):
            wv, wr = vec.next_window(sat, t), ref.next_window(sat, t)
            assert (wv is None) == (wr is None)
            if wv is not None:
                assert abs(wv.t_start - wr.t_start) <= 1e-3
                assert abs(wv.t_end - wr.t_end) <= 1e-3
            dv = vec.next_window_with_duration(sat, t, 120.0)
            dr = ref.next_window_with_duration(sat, t, 120.0)
            assert (dv is None) == (dr is None)
            if dv is not None:
                assert abs(dv.t_start - dr.t_start) <= 1e-3


def test_predictor_next_window_is_first_ending_after():
    """Bisect-indexed next_window must equal the linear-scan answer."""
    cfg = ConstellationConfig(num_planes=2, sats_per_plane=5)
    walker = WalkerDelta(cfg)
    pred = VisibilityPredictor(walker, GroundStation(),
                               horizon_s=24 * 3600.0)
    for sat in walker.satellites:
        wins = pred.windows_of(sat)
        for t in np.linspace(0.0, 24 * 3600.0, 37):
            expect = next((w for w in wins if w.t_end > t), None)
            got = pred.next_window(sat, float(t))
            assert got == expect


# --- scheduling decisions unchanged on the batched path ----------------------------
@pytest.fixture(scope="module")
def sched_world():
    cfg = ConstellationConfig(num_planes=3, sats_per_plane=6)
    walker = WalkerDelta(cfg)
    gs = GroundStation()
    vec = VisibilityPredictor(walker, gs, horizon_s=36 * 3600.0)
    ref = VisibilityPredictor(walker, gs, horizon_s=36 * 3600.0,
                              engine="reference")
    return cfg, walker, gs, vec, ref


@pytest.mark.parametrize("require_next_download", [False, True])
def test_select_sink_decisions_unchanged(sched_world, require_next_download):
    cfg, walker, gs, vec, ref = sched_world
    link, isl = LinkConfig(), ISLConfig()
    K = cfg.sats_per_plane
    for plane in range(cfg.num_planes):
        for base in (1800.0, 7200.0, 20 * 3600.0):
            t_done = [base + 120.0 * (s % 4) for s in range(K)]
            a = select_sink(walker=walker, gs=gs, predictor=vec, link=link,
                            isl=isl, plane=plane, t_train_done=t_done,
                            payload_bits=3.2e7,
                            require_next_download=require_next_download)
            b = select_sink(walker=walker, gs=gs, predictor=ref, link=link,
                            isl=isl, plane=plane, t_train_done=t_done,
                            payload_bits=3.2e7,
                            require_next_download=require_next_download)
            assert (a is None) == (b is None)
            if a is not None:
                assert a.sink_slot == b.sink_slot
                assert a.t_upload_done == pytest.approx(b.t_upload_done,
                                                        abs=1e-3)
                assert a.t_wait == pytest.approx(b.t_wait, abs=1e-3)
                # completion is the downlink only; the next-round
                # download widens feasibility but never the completion
                assert a.window.t_end >= a.t_upload_done - 1e-6


def test_require_next_download_only_widens_feasibility(sched_world):
    """t_upload_done is t0 + t_c^D regardless of the flag: requiring
    room for the next download must not inflate the completion time of
    an unchanged (sink, window) decision."""
    cfg, walker, gs, vec, _ = sched_world
    link, isl = LinkConfig(), ISLConfig()
    K = cfg.sats_per_plane
    t_done = [7200.0] * K
    plain = select_sink(walker=walker, gs=gs, predictor=vec, link=link,
                        isl=isl, plane=0, t_train_done=t_done,
                        payload_bits=3.2e7)
    strict = select_sink(walker=walker, gs=gs, predictor=vec, link=link,
                         isl=isl, plane=0, t_train_done=t_done,
                         payload_bits=3.2e7, require_next_download=True)
    assert plain is not None and strict is not None
    if (strict.sink_slot, strict.window.t_start) == (
            plain.sink_slot, plain.window.t_start):
        assert strict.t_upload_done == pytest.approx(plain.t_upload_done,
                                                     abs=1e-9)


def test_first_visible_download_unchanged(sched_world):
    cfg, walker, gs, vec, ref = sched_world
    link = LinkConfig()
    for plane in range(cfg.num_planes):
        for t in (0.0, 3600.0, 12 * 3600.0):
            a = first_visible_download(walker=walker, gs=gs, predictor=vec,
                                       link=link, plane=plane, t=t,
                                       payload_bits=3.2e7)
            b = first_visible_download(walker=walker, gs=gs, predictor=ref,
                                       link=link, plane=plane, t=t,
                                       payload_bits=3.2e7)
            assert (a is None) == (b is None)
            if a is not None:
                assert a[0] == b[0]
                assert a[1] == pytest.approx(b[1], abs=1e-3)


# --- multi-GS union semantics ------------------------------------------------------
def test_multi_gs_union_of_windows():
    cfg = ConstellationConfig(num_planes=3, sats_per_plane=6)
    walker = WalkerDelta(cfg)
    gs_a, gs_b = get_ground_stations(["rolla", "svalbard"])
    horizon = 12 * 3600.0
    both = VisibilityPredictor(walker, [gs_a, gs_b], horizon_s=horizon)
    only_a = VisibilityPredictor(walker, gs_a, horizon_s=horizon)
    only_b = VisibilityPredictor(walker, gs_b, horizon_s=horizon)
    assert len(both.windows) == len(only_a.windows) + len(only_b.windows)
    # every union window is tagged with its own station and matches it
    singles = {0: only_a, 1: only_b}
    for w in both.windows:
        src = singles[w.gs_index].windows
        assert any(
            v.plane == w.plane and v.slot == w.slot
            and abs(v.t_start - w.t_start) < 1e-9 for v in src
        )
    # union can only shorten (or keep) the wait to the next contact
    sat = walker.satellites[0]
    for t in (0.0, 3 * 3600.0, 9 * 3600.0):
        wu = both.wait_time(sat, t)
        for single in (only_a, only_b):
            ws = single.wait_time(sat, t)
            if ws is not None:
                assert wu is not None and wu <= ws + 1e-9


def test_multi_gs_first_visible_download_is_true_minimum():
    """Under a union predictor, overlapping windows from different
    stations must not mask an earlier-completing transfer: compare
    against a brute-force scan over ALL windows of every slot."""
    from repro.comms.link import uplink_time
    from repro.core.scheduling import _distance_at

    cfg = ConstellationConfig(num_planes=3, sats_per_plane=6)
    walker = WalkerDelta(cfg)
    gss = list(get_ground_stations(["rolla", "awarua"]))
    pred = VisibilityPredictor(walker, gss, horizon_s=24 * 3600.0)
    link = LinkConfig()
    payload = 3.2e7

    for plane in range(cfg.num_planes):
        for t in (0.0, 3600.0, 6 * 3600.0, 15 * 3600.0):
            got = first_visible_download(
                walker=walker, gs=gss, predictor=pred, link=link,
                plane=plane, t=t, payload_bits=payload,
            )
            # brute force: true earliest completion over every window
            best = None
            for slot in range(cfg.sats_per_plane):
                from repro.orbits.constellation import Satellite
                sat = Satellite(plane, slot)
                for w in pred.windows_of(sat):
                    if w.t_end <= t:
                        continue
                    t0 = max(w.t_start, t)
                    d = _distance_at(walker, gss[w.gs_index], sat, t0)
                    t_ul = uplink_time(link, payload, d)
                    if w.t_end - t0 < t_ul:
                        continue
                    if best is None or t0 + t_ul < best:
                        best = t0 + t_ul
            assert (got is None) == (best is None)
            if got is not None:
                assert got[1] == pytest.approx(best, abs=1e-6)


def test_earliest_transfer_is_true_minimum_multi_gs():
    """The shared baseline retry helper must return the earliest
    completion over ALL (possibly overlapping) union windows."""
    from repro.comms.link import downlink_time
    from repro.core.scheduling import _distance_at, earliest_transfer

    cfg = ConstellationConfig(num_planes=2, sats_per_plane=5)
    walker = WalkerDelta(cfg)
    gss = list(get_ground_stations(["rolla", "awarua"]))
    pred = VisibilityPredictor(walker, gss, horizon_s=24 * 3600.0)
    link = LinkConfig()
    payload = 3.2e7

    def tt(_gi, d):
        tc = downlink_time(link, payload, d)
        return tc, tc

    for sat in walker.satellites:
        for t in (0.0, 2 * 3600.0, 11 * 3600.0):
            hit = earliest_transfer(walker=walker, predictor=pred,
                                    sat=sat, t=t, transfer_time=tt)
            best = None
            for w in pred.windows_of(sat):
                if w.t_end <= t:
                    continue
                t0 = max(w.t_start, t)
                tc = downlink_time(
                    link, payload,
                    _distance_at(walker, gss[w.gs_index], sat, t0),
                )
                if w.t_end - t0 >= tc and (best is None or t0 + tc < best):
                    best = t0 + tc
            assert (hit is None) == (best is None)
            if hit is not None:
                assert hit[1] == pytest.approx(best, abs=1e-6)


# --- memory-bounded chunking (ISSUE 8 tentpole) ------------------------------------
def test_scan_chunk_len_scales_with_budget():
    from repro.orbits.visibility import (
        _MIN_CHUNK_T,
        DEFAULT_MEM_BUDGET_MB,
        scan_chunk_len,
    )

    # tighter budget -> shorter chunks, monotonically
    assert scan_chunk_len(1584, 1.0) < scan_chunk_len(1584, 16.0)
    assert scan_chunk_len(1584, 16.0) < scan_chunk_len(1584, 256.0)
    # more satellites under the same budget -> shorter chunks
    assert scan_chunk_len(2376, 64.0) <= scan_chunk_len(880, 64.0)
    # the floor keeps pathological budgets from degenerating to 1-sample
    # chunks (bisection needs a neighborhood)
    assert scan_chunk_len(10**6, 0.001) == _MIN_CHUNK_T
    assert scan_chunk_len(1584, DEFAULT_MEM_BUDGET_MB) >= _MIN_CHUNK_T


def test_chunking_equivalence_72x22_across_budgets():
    """mem_budget_mb partitions EVALUATION, never results: the 72x22
    window table must be bit-identical under a budget that forces many
    tiny chunks (windows straddling chunk boundaries merged) and under
    the default budget that fits the whole scan in one chunk."""
    from repro.orbits.visibility import scan_chunk_len

    cfg = get_constellation("starlink-gen1")
    walker = WalkerDelta(cfg)
    gs = get_ground_stations(("rolla",))[0]
    horizon_s = 3 * 3600.0
    n_samples = int(horizon_s / 60.0) + 1

    tables = {}
    for budget in (0.2, 2.0, 256.0):
        tables[budget] = visibility_table(
            walker, gs, 0.0, horizon_s, coarse_step_s=60.0,
            mem_budget_mb=budget,
        )
    # the scenario exercises real chunking: tightest budget splits the
    # scan, loosest covers it whole
    assert scan_chunk_len(cfg.num_satellites, 0.2) < n_samples
    assert scan_chunk_len(cfg.num_satellites, 256.0) >= n_samples

    ref = tables[256.0]
    assert len(ref) > 0
    for budget, table in tables.items():
        for field in ("plane", "slot", "t_start", "t_end"):
            assert np.array_equal(
                getattr(table, field), getattr(ref, field)
            ), f"budget {budget} MB diverged on {field}"


def test_chunk_boundary_windows_not_split():
    """A window open across a chunk boundary must come back as ONE
    window, not two abutting at the boundary sample."""
    cfg = ConstellationConfig(num_planes=4, sats_per_plane=6)
    walker = WalkerDelta(cfg)
    gs = GroundStation()
    ref = visibility_table(walker, gs, 0.0, 6 * 3600.0,
                           coarse_step_s=30.0, mem_budget_mb=256.0)
    tiny = visibility_table(walker, gs, 0.0, 6 * 3600.0,
                            coarse_step_s=30.0, mem_budget_mb=0.001)
    assert len(tiny) == len(ref)
    assert np.array_equal(tiny.t_start, ref.t_start)
    assert np.array_equal(tiny.t_end, ref.t_end)


def test_predictor_budget_passthrough_identical():
    cfg = ConstellationConfig(num_planes=3, sats_per_plane=6)
    walker = WalkerDelta(cfg)
    gs = GroundStation()
    tight = VisibilityPredictor(walker, gs, horizon_s=12 * 3600.0,
                                mem_budget_mb=0.01)
    loose = VisibilityPredictor(walker, gs, horizon_s=12 * 3600.0)
    assert len(tight.table) == len(loose.table)
    assert np.array_equal(tight.table.t_start, loose.table.t_start)
    assert np.array_equal(tight.table.t_end, loose.table.t_end)


def test_presets_registry():
    assert "starlink-40x22" in CONSTELLATION_PRESETS
    cfg = get_constellation("starlink-40x22")
    assert cfg.num_planes == 40 and cfg.sats_per_plane == 22
    with pytest.raises(ValueError):
        get_constellation("nope")
    sim = make_sim_config("paper-5x8", ("rolla", "svalbard"),
                          horizon_hours=6.0)
    assert len(sim.all_ground_stations) == 2
    assert sim.horizon_hours == 6.0
    # single-station presets keep the plain ground_station field
    sim1 = make_sim_config("paper-5x8", ("rolla",))
    assert sim1.ground_stations == ()


def test_ideal_baselines_override_multi_gs_list():
    """FedSat/FedISL ideal setups replace the whole ground segment: a
    multi-GS SimConfig must not leak past the North-Pole replacement."""
    from repro.core import FederatedTask, TrainHyperparams
    from repro.core.baselines import FedISLIdeal, FedSat
    from repro.data import (
        make_classification_dataset,
        partition_noniid_by_orbit,
    )
    from repro.models.cnn import apply_cnn, init_cnn
    from repro.optim import get_optimizer

    ds = make_classification_dataset("mnist-like", num_samples=80, seed=0)
    test = make_classification_dataset("mnist-like", num_samples=40, seed=1)
    task = FederatedTask(
        init_fn=lambda r: init_cnn(r, (28, 28, 1), 10, widths=(4,),
                                   hidden=8),
        apply_fn=apply_cnn,
        clients=partition_noniid_by_orbit(ds, 5, 8),
        test_set=test,
        optimizer=get_optimizer("sgd", 0.05),
        hp=TrainHyperparams(local_epochs=10, batch_size=4),
        sim_epochs=1,
    )
    sim = make_sim_config("paper-5x8", ("rolla", "svalbard"),
                          horizon_hours=6.0)
    for cls in (FedSat, FedISLIdeal):
        strat = cls(task, sim)
        assert [g.name for g in strat.gs_list] == ["North-Pole"]


@pytest.mark.slow
def test_fedleo_round_on_starlink_preset_two_gs():
    """Acceptance: a FedLEO round completes end-to-end on the
    Starlink-scale preset with 2 ground stations."""
    from repro.core import FedLEO, FederatedTask, TrainHyperparams
    from repro.data import (
        make_classification_dataset,
        partition_noniid_by_orbit,
    )
    from repro.models.cnn import apply_cnn, init_cnn
    from repro.optim import get_optimizer

    # 53-degree shell: pair the paper's mid-latitude GS with a southern
    # one (a polar site would never see this inclination); 24 h so every
    # plane's ground track crosses a station
    sim = make_sim_config(
        "starlink-40x22", ("rolla", "punta-arenas"), horizon_hours=24.0
    )
    L = sim.constellation.num_planes
    K = sim.constellation.sats_per_plane
    ds = make_classification_dataset(
        "mnist-like", num_samples=4 * L * K, seed=0
    )
    test = make_classification_dataset("mnist-like", num_samples=64, seed=1)
    clients = partition_noniid_by_orbit(ds, L, K, seed=0)
    task = FederatedTask(
        init_fn=lambda r: init_cnn(r, (28, 28, 1), 10, widths=(4,),
                                   hidden=8),
        apply_fn=apply_cnn,
        clients=clients,
        test_set=test,
        optimizer=get_optimizer("sgd", 0.05),
        hp=TrainHyperparams(local_epochs=10, batch_size=4),
        sim_epochs=1,
    )
    res = FedLEO(task, sim).run(max_rounds=1)
    assert len(res.history) == 1
    planes = res.history[0].events["planes"]
    assert len(planes) == L
    for ev in planes:
        assert ev["t_upload_done"] >= ev["t_models_at_sink"]
    assert np.isfinite(res.final_accuracy)
